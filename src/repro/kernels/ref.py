"""Pure-jnp oracles for every Pallas kernel (correctness references).

These are deliberately naive (O(S^2) attention, per-step SSM recurrence,
per-byte DFA stepping) — they define semantics; kernels and the blocked
production paths in ops.py are tested against them.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Multi-pattern DFA (Aho-Corasick) — the paper's regex accelerator.
# ---------------------------------------------------------------------------

def build_aho_corasick(patterns) -> tuple[np.ndarray, np.ndarray]:
    """Compile literal byte patterns into a dense DFA.

    Returns (table, out_count): table[s, b] = next state, out_count[s] = number
    of pattern occurrences ending when entering state s. Offline rule
    compilation — mirrors loading Snort rules into the regex accelerator.
    """
    patterns = [p.encode() if isinstance(p, str) else bytes(p) for p in patterns]
    # Trie build.
    goto = [{}]
    out = [0]
    for pat in patterns:
        s = 0
        for ch in pat:
            if ch not in goto[s]:
                goto.append({})
                out.append(0)
                goto[s][ch] = len(goto) - 1
            s = goto[s][ch]
        out[s] += 1
    # BFS failure links -> dense DFA.
    n = len(goto)
    fail = [0] * n
    table = np.zeros((n, 256), dtype=np.int32)
    from collections import deque
    q = deque()
    for ch in range(256):
        nxt = goto[0].get(ch, 0)
        table[0, ch] = nxt
        if nxt:
            fail[nxt] = 0
            q.append(nxt)
    while q:
        s = q.popleft()
        out[s] += out[fail[s]]
        for ch in range(256):
            if ch in goto[s]:
                nxt = goto[s][ch]
                fail[nxt] = table[fail[s], ch]
                table[s, ch] = nxt
                q.append(nxt)
            else:
                table[s, ch] = table[fail[s], ch]
    return table, np.asarray(out, dtype=np.int32)


def dfa_scan(payload: jnp.ndarray, length: jnp.ndarray, table: jnp.ndarray,
             out_count: jnp.ndarray) -> jnp.ndarray:
    """Per-packet match counts by serial per-byte DFA stepping.

    payload: (B, L) uint8; length: (B,) valid bytes; table: (S, 256) int32.
    Returns (B,) int32 total pattern occurrences within the valid prefix.
    """
    B, L = payload.shape

    def step(carry, j):
        state, matches = carry
        byte = payload[:, j].astype(jnp.int32)
        nxt = table[state, byte]
        valid = j < length
        state = jnp.where(valid, nxt, state)
        matches = matches + jnp.where(valid, out_count[state], 0)
        return (state, matches), None

    init = (jnp.zeros((B,), jnp.int32), jnp.zeros((B,), jnp.int32))
    (state, matches), _ = jax.lax.scan(step, init, jnp.arange(L))
    return matches


# ---------------------------------------------------------------------------
# ARX cipher + keyed hash — AES / SHA accelerator analogs (structural).
# ---------------------------------------------------------------------------

_ROUNDS = 8
_GOLDEN = np.uint32(0x9E3779B9)


def _rotl(x: jnp.ndarray, k: int) -> jnp.ndarray:
    return ((x << k) | (x >> (32 - k))).astype(jnp.uint32)


def arx_cipher(words: jnp.ndarray, key: jnp.ndarray) -> jnp.ndarray:
    """8-round ARX permutation over uint32 words. words: (..., W) uint32,
    key: (4,) uint32. Same data-movement shape as an AES-CTR pass."""
    x = words.astype(jnp.uint32)
    W = x.shape[-1]
    lanes = jnp.arange(W, dtype=jnp.uint32)
    for r in range(_ROUNDS):
        rk = (key[r % 4] + jnp.uint32(r) * _GOLDEN).astype(jnp.uint32)
        x = (x + rk).astype(jnp.uint32)
        x = _rotl(x, 5) ^ (x + lanes).astype(jnp.uint32)
        x = (x ^ _rotl(x, 13)) + _rotl(x, 7)
        x = x.astype(jnp.uint32)
    return x


def keyed_hash(words: jnp.ndarray, key: jnp.ndarray) -> jnp.ndarray:
    """Keyed fold digest (SHA stand-in). words: (B, W) uint32 -> (B, 4)."""
    x = words.astype(jnp.uint32)
    h = jnp.tile(key[None, :4], (x.shape[0], 1)).astype(jnp.uint32)

    def step(h, w):
        # w: (B,) one word column
        h0 = (h[:, 0] + w).astype(jnp.uint32)
        h1 = h[:, 1] ^ _rotl(h0, 11)
        h2 = (h[:, 2] + _rotl(h1, 7)).astype(jnp.uint32)
        h3 = h[:, 3] ^ (h2 + _GOLDEN).astype(jnp.uint32)
        return jnp.stack([h1, h2, h3, h0], axis=1), None

    h, _ = jax.lax.scan(step, h, x.T)
    return h


# ---------------------------------------------------------------------------
# Attention oracles.
# ---------------------------------------------------------------------------

def mha_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *, causal: bool = True,
            window: int | None = None, scale: float | None = None) -> jnp.ndarray:
    """Naive softmax attention with GQA. q: (B, Sq, Hq, D), k/v: (B, Sk, Hkv, D).

    window: sliding-window size (attend to keys within `window` positions
    back, inclusive of self) — Gemma-3 local layers.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32) * scale
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, Sq, Hkv, G, D)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf, kf)
    # Positions: queries occupy the last Sq slots of the Sk timeline.
    qpos = jnp.arange(Sq) + (Sk - Sq)
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
               kv_len: jnp.ndarray, *, scale: float | None = None) -> jnp.ndarray:
    """Single-token decode attention. q: (B, Hq, D), k/v: (B, S, Hkv, D),
    kv_len: (B,) valid cache length. Returns (B, Hq, D)."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = scale if scale is not None else D ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D) * scale
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, k.astype(jnp.float32))
    valid = jnp.arange(S)[None] < kv_len[:, None]
    logits = jnp.where(valid[:, None, None], logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)


# ---------------------------------------------------------------------------
# Mamba-2 SSD oracle (scalar-decay SSM, per-step recurrence).
# ---------------------------------------------------------------------------

def ssd_ref(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray,
            h0: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """State-space duality reference.

    x: (B, S, H, P)  inputs (P = head channel dim)
    a: (B, S, H)     per-step decay in (0, 1]
    b: (B, S, H, N)  input projections (N = state dim)
    c: (B, S, H, N)  output projections
    h0: (B, H, N, P) initial state.
    Returns (y: (B, S, H, P), h_final: (B, H, N, P)).

    Recurrence: h_t = a_t * h_{t-1} + b_t ⊗ x_t ; y_t = c_t · h_t.
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, N, P), jnp.float32)

    def step(h, t):
        at = a[:, t].astype(jnp.float32)                      # (B, H)
        bt = b[:, t].astype(jnp.float32)                      # (B, H, N)
        ct = c[:, t].astype(jnp.float32)                      # (B, H, N)
        xt = x[:, t].astype(jnp.float32)                      # (B, H, P)
        h = at[..., None, None] * h + bt[..., :, None] * xt[..., None, :]
        yt = jnp.einsum("bhn,bhnp->bhp", ct, h)
        return h, yt

    h, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1)                                # (B, S, H, P)
    return y.astype(x.dtype), h
