"""Multi-pattern DFA scan (Aho-Corasick) — the paper's regex accelerator, TPU-native.

BlueField-2's RXP regex engine is a fixed-function block; the TPU analogue is
a vectorized DFA scan. GPU ports step one packet per thread; the TPU-native
rethink (DESIGN.md §2) instead keeps a *vector of packet states* and turns the
per-byte transition into lane-parallel VPU work:

  next_state[p] = table[state[p], byte[p]]
               = rowsum( onehot(state[p]) ⊙ tableT[byte[p], :] )

i.e. one single-axis row gather (tableT indexed by the byte vector) plus a
broadcast-compare one-hot and a lane reduction — no 2-D scatter/gather, which
TPUs lack. Packets are blocked into VMEM tiles of (block_b, L) bytes with the
dense transition table resident in VMEM (S·256·4 B; 256-state Snort-style rule
sets = 256 KB ≪ 16 MB VMEM).

Match semantics: out_count[s] occurrences are credited when entering state s
(Aho-Corasick with counted outputs). Validated against ref.dfa_scan.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import compat


def _dfa_kernel(payload_ref, length_ref, tableT_ref, out_count_ref, match_ref, *,
                num_states: int, max_len: int):
    payload = payload_ref[...]                      # (BB, L) int32 (pre-widened)
    length = length_ref[...]                        # (BB, 1) int32
    BB = payload.shape[0]
    state_ids = jax.lax.broadcasted_iota(jnp.int32, (BB, num_states), 1)

    def step(j, carry):
        state, matches = carry                      # (BB, 1), (BB, 1)
        byte = jax.lax.dynamic_slice_in_dim(payload, j, 1, axis=1)  # (BB, 1)
        cols = tableT_ref[...][byte[:, 0]]          # (BB, S): tableT[byte[p], :]
        onehot = (state == state_ids).astype(jnp.int32)             # (BB, S)
        nxt = jnp.sum(onehot * cols, axis=1, keepdims=True)         # (BB, 1)
        valid = j < length
        state = jnp.where(valid, nxt, state)
        hits_all = out_count_ref[...][state[:, 0]][:, None]         # (BB, 1)
        matches = matches + jnp.where(valid, hits_all, 0)
        return state, matches

    init = (jnp.zeros((BB, 1), jnp.int32), jnp.zeros((BB, 1), jnp.int32))
    _, matches = jax.lax.fori_loop(0, max_len, step, init)
    match_ref[...] = matches


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def dfa_regex(payload: jnp.ndarray, length: jnp.ndarray, table: jnp.ndarray,
              out_count: jnp.ndarray, *, block_b: int = 128,
              interpret: bool = False) -> jnp.ndarray:
    """payload: (B, L) uint8, length: (B,), table: (S, 256) int32,
    out_count: (S,) int32. Returns per-packet match counts (B,) int32."""
    B, L = payload.shape
    S = table.shape[0]
    block_b = min(block_b, B)
    assert B % block_b == 0, (B, block_b)
    tableT = table.T.astype(jnp.int32)              # (256, S) row-gather layout
    payload_i = payload.astype(jnp.int32)
    length2 = length.astype(jnp.int32)[:, None]

    kernel = functools.partial(_dfa_kernel, num_states=S, max_len=L)
    out = pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, L), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
            pl.BlockSpec((256, S), lambda i: (0, 0)),
            pl.BlockSpec((S,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.int32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(payload_i, length2, tableT, out_count.astype(jnp.int32))
    return out[:, 0]
