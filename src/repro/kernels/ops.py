"""Public kernel API with backend dispatch.

Three implementations per op:
  * ``pallas``    — the TPU kernel (pl.pallas_call, BlockSpec VMEM tiling);
  * ``interpret`` — same kernel body executed in Pallas interpret mode
                    (CPU correctness path, used by tests);
  * ``blocked``   — pure-jnp *flash-style* blocked algorithm: identical math,
                    O(block) memory, differentiable (custom VJP with a blocked
                    backward). XLA-compilable on any backend — this is what
                    the multi-pod dry-run lowers, so the compiled HLO reflects
                    flash memory behaviour rather than naive O(S²) attention;
  * ``ref``       — the naive oracle (kernels/ref.py), tests only.

``default_impl()`` picks ``pallas`` on TPU and ``blocked`` elsewhere.
"""
from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref as _ref
from repro.kernels import flash_attention as _fa
from repro.kernels import decode_attention as _da
from repro.kernels import ssd_scan as _ssd
from repro.kernels import dfa_regex as _dfa
from repro.kernels import crypto as _crypto

build_aho_corasick = _ref.build_aho_corasick


def default_impl() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "blocked"


# When True, blocked-algorithm scans are fully unrolled so XLA cost analysis
# counts every iteration (it counts while bodies ONCE). Used by the roofline
# decomposition (launch/decompose.py); never in production steps.
_UNROLL_SCANS = bool(int(os.environ.get("REPRO_UNROLL_SCANS", "0")))


def set_unroll_scans(v: bool) -> None:
    global _UNROLL_SCANS
    _UNROLL_SCANS = v


def _unroll(n: int) -> int:
    return n if _UNROLL_SCANS else 1


# ---------------------------------------------------------------------------
# Attention (train/prefill).
# ---------------------------------------------------------------------------

def _mask_block(qpos, kpos, causal: bool, window: Optional[int]):
    m = jnp.ones((qpos.shape[0], kpos.shape[0]), jnp.bool_)
    if causal:
        m &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        m &= kpos[None, :] > qpos[:, None] - window
    return m


def _bias_block(qpos, kpos, causal: bool, window: Optional[int]):
    """Additive f32 mask bias (Sq, bk): 0 attendable / NEG_INF masked.

    Masking by arithmetic instead of rank-5 boolean `where` operands: XLA
    was materializing the broadcast pred tensors stacked across the KV-scan
    iterations (nk x B x Sq x Hkv x G x bk bools — tens of GB at 4k/32k
    sequence); an f32 bias folds into the logits add and the per-row
    emptiness guard comes from the running max itself (see fwd)."""
    return jnp.where(_mask_block(qpos, kpos, causal, window), 0.0,
                     _fa.NEG_INF).astype(jnp.float32)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attention_blocked(q, k, v, causal, window, scale, block_k):
    out, _ = _attention_blocked_fwd(q, k, v, causal, window, scale, block_k)
    return out


def _attention_blocked_fwd(q, k, v, causal, window, scale, block_k):
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    bk = min(block_k, Sk)
    assert Sk % bk == 0
    nk = Sk // bk
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D) * scale
    qpos = jnp.arange(Sq) + (Sk - Sq)

    def step(carry, ik):
        acc, m, l = carry
        kb = jax.lax.dynamic_slice_in_dim(k, ik * bk, bk, 1).astype(jnp.float32)
        vb = jax.lax.dynamic_slice_in_dim(v, ik * bk, bk, 1).astype(jnp.float32)
        logits = jnp.einsum("bqhgd,bkhd->bqhgk", qf, kb)
        kpos = ik * bk + jnp.arange(bk)
        bias = _bias_block(qpos, kpos, causal, window)          # (Sq, bk) f32
        logits = logits + bias[None, :, None, None, :]
        m_new = jnp.maximum(m, logits.max(axis=-1))
        # rows with no valid key so far have m_new == NEG_INF: zero their p
        # (otherwise exp(NEG_INF - NEG_INF) == 1 corrupts l); once a real
        # key appears, masked entries decay to exp(~NEG_INF) == 0 naturally.
        live = (m_new > 0.5 * _fa.NEG_INF).astype(jnp.float32)
        p = jnp.exp(logits - m_new[..., None]) * live[..., None]
        alpha = jnp.exp(m - m_new)
        l = alpha * l + p.sum(axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum("bqhgk,bkhd->bqhgd", p, vb)
        return (acc, m_new, l), None

    acc0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    m0 = jnp.full((B, Sq, Hkv, G), _fa.NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), jnp.arange(nk),
                                  unroll=_unroll(nk))
    safe_l = jnp.where(l == 0.0, 1.0, l)
    out = (acc / safe_l[..., None]).reshape(B, Sq, Hq, D).astype(q.dtype)
    lse = jnp.where(l > 0.0, m + jnp.log(safe_l), jnp.float32(1e30))
    return out, (q, k, v, out, lse)


def _attention_blocked_bwd(causal, window, scale, block_k, res, dout):
    q, k, v, out, lse = res
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    bk = min(block_k, Sk)
    nk = Sk // bk
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    do = dout.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    of = out.astype(jnp.float32).reshape(B, Sq, Hkv, G, D)
    delta = (do * of).sum(-1)                                   # (B,Sq,Hkv,G)
    qpos = jnp.arange(Sq) + (Sk - Sq)

    def step(dq, ik):
        kb = jax.lax.dynamic_slice_in_dim(k, ik * bk, bk, 1).astype(jnp.float32)
        vb = jax.lax.dynamic_slice_in_dim(v, ik * bk, bk, 1).astype(jnp.float32)
        logits = jnp.einsum("bqhgd,bkhd->bqhgk", qf * scale, kb)
        kpos = ik * bk + jnp.arange(bk)
        bias = _bias_block(qpos, kpos, causal, window)
        # lse from fwd is +1e30 for rows with no valid keys -> p == 0 there;
        # masked entries carry bias NEG_INF -> p == 0 (no boolean operands).
        p = jnp.exp(logits + bias[None, :, None, None, :] - lse[..., None])
        dv = jnp.einsum("bqhgk,bqhgd->bkhd", p, do)
        dp = jnp.einsum("bqhgd,bkhd->bqhgk", do, vb)
        ds = p * (dp - delta[..., None]) * scale
        dq = dq + jnp.einsum("bqhgk,bkhd->bqhgd", ds, kb)
        dk = jnp.einsum("bqhgk,bqhgd->bkhd", ds, qf)
        return dq, (dk, dv)

    dq0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(step, dq0, jnp.arange(nk),
                                  unroll=_unroll(nk))
    dk = jnp.moveaxis(dks, 0, 1).reshape(B, Sk, Hkv, D)
    dv = jnp.moveaxis(dvs, 0, 1).reshape(B, Sk, Hkv, D)
    return (dq.reshape(B, Sq, Hq, D).astype(q.dtype), dk.astype(k.dtype),
            dv.astype(v.dtype))


_attention_blocked.defvjp(lambda q, k, v, causal, window, scale, block_k:
                          _attention_blocked_fwd(q, k, v, causal, window, scale,
                                                 block_k),
                          _attention_blocked_bwd)


def attention(q, k, v, *, causal: bool = True, window: Optional[int] = None,
              scale: Optional[float] = None, impl: Optional[str] = None,
              block_k: int = 256):
    """Flash attention. q: (B,Sq,Hq,D); k,v: (B,Sk,Hkv,D)."""
    impl = impl or default_impl()
    scale_v = float(scale) if scale is not None else q.shape[-1] ** -0.5
    if impl == "ref":
        return _ref.mha_ref(q, k, v, causal=causal, window=window, scale=scale_v)
    if impl in ("pallas", "interpret"):
        return _fa.flash_attention(q, k, v, causal=causal, window=window,
                                   scale=scale_v, interpret=(impl == "interpret"))
    if impl == "blocked":
        return _attention_blocked(q, k, v, causal, window, scale_v, block_k)
    raise ValueError(f"unknown impl {impl}")


# ---------------------------------------------------------------------------
# Decode attention (one token vs deep KV cache).
# ---------------------------------------------------------------------------

def decode_attention(q, k, v, kv_len, *, scale: Optional[float] = None,
                     impl: Optional[str] = None, block_k: int = 512):
    """q: (B,Hq,D); k,v: (B,S,Hkv,D); kv_len: (B,)."""
    impl = impl or default_impl()
    scale_v = float(scale) if scale is not None else q.shape[-1] ** -0.5
    if impl == "ref":
        return _ref.decode_ref(q, k, v, kv_len, scale=scale_v)
    if impl in ("pallas", "interpret"):
        return _da.decode_attention(q, k, v, kv_len, scale=scale_v,
                                    block_k=block_k,
                                    interpret=(impl == "interpret"))
    if impl == "blocked":
        # One query token: O(S) logits is already flash-equivalent memory.
        return _ref.decode_ref(q, k, v, kv_len, scale=scale_v)
    raise ValueError(f"unknown impl {impl}")


# ---------------------------------------------------------------------------
# Mamba-2 SSD.
# ---------------------------------------------------------------------------

def _ssd_blocked(x, a, b, c, chunk: int):
    """Chunked SSD in pure jnp: same math as the kernel, scan over chunks."""
    B, S, H, P = x.shape
    N = b.shape[-1]
    ck = min(chunk, S)
    assert S % ck == 0
    nc = S // ck
    la_full = jnp.log(a.astype(jnp.float32))
    t_idx = jnp.arange(ck)

    def step(h, ic):
        sl = lambda arr: jax.lax.dynamic_slice_in_dim(arr, ic * ck, ck, 1)
        xc = sl(x).astype(jnp.float32)               # (B,T,H,P)
        lac = sl(la_full)                            # (B,T,H)
        bc = sl(b).astype(jnp.float32)               # (B,T,H,N)
        cc = sl(c).astype(jnp.float32)               # (B,T,H,N)
        cl = jnp.cumsum(lac, axis=1)                 # (B,T,H)
        decay = jnp.exp(cl[:, :, None] - cl[:, None, :])          # (B,T,S,H)... axes: (B,t,s,H)
        lmask = (t_idx[:, None] >= t_idx[None, :]).astype(jnp.float32)
        cb = jnp.einsum("bthn,bshn->btsh", cc, bc)
        y_intra = jnp.einsum("btsh,bshp->bthp", cb * decay * lmask[None, :, :, None], xc)
        ch = jnp.einsum("bthn,bhnp->bthp", cc, h)
        y = y_intra + jnp.exp(cl)[..., None] * ch
        w = jnp.exp(cl[:, -1:, :] - cl)              # (B,T,H)
        h_next = jnp.exp(cl[:, -1])[..., None, None] * h + jnp.einsum(
            "bthn,bthp->bhnp", bc * w[..., None], xc)
        return h_next, y

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    h_fin, ys = jax.lax.scan(step, h0, jnp.arange(nc), unroll=_unroll(nc))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    return y.astype(x.dtype), h_fin


def ssd(x, a, b, c, *, chunk: int = 128, impl: Optional[str] = None):
    """Mamba-2 SSD. x: (B,S,H,P), a: (B,S,H) in (0,1], b/c: (B,S,H,N)."""
    impl = impl or default_impl()
    if impl == "ref":
        return _ref.ssd_ref(x, a, b, c)
    if impl in ("pallas", "interpret"):
        return _ssd.ssd_scan(x, a, b, c, chunk=chunk,
                             interpret=(impl == "interpret"))
    if impl == "blocked":
        return _ssd_blocked(x, a, b, c, chunk)
    raise ValueError(f"unknown impl {impl}")


# ---------------------------------------------------------------------------
# NIC accelerator ops (regex / crypto / hash).
# ---------------------------------------------------------------------------

def regex_scan(payload, length, table, out_count, *, impl: Optional[str] = None,
               block_b: int = 128):
    impl = impl or default_impl()
    if impl in ("ref", "blocked"):
        return _ref.dfa_scan(payload, length, jnp.asarray(table),
                             jnp.asarray(out_count))
    return _dfa.dfa_regex(payload, length, jnp.asarray(table),
                          jnp.asarray(out_count), block_b=block_b,
                          interpret=(impl == "interpret"))


def cipher(words, key, *, impl: Optional[str] = None, block_b: int = 256):
    impl = impl or default_impl()
    if impl in ("ref", "blocked"):
        return _ref.arx_cipher(words, key)
    return _crypto.arx_cipher(words, key, block_b=block_b,
                              interpret=(impl == "interpret"))


def digest(words, key, *, impl: Optional[str] = None, block_b: int = 256):
    impl = impl or default_impl()
    if impl in ("ref", "blocked"):
        return _ref.keyed_hash(words, key)
    return _crypto.keyed_hash(words, key, block_b=block_b,
                              interpret=(impl == "interpret"))
