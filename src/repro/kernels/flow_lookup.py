"""Exact-match flow-classification lookup — the megaflow fast-path kernel.

The flow cache (``core.flowcache``) keeps fid -> (pipeline, epoch) in an
open-addressed table with a BOUNDED probe window: a key may only live in the
``window`` consecutive slots starting at its hash bucket. That makes lookup
branch-free vector code — gather the window, compare keys, take the first
live match — and makes deletion trivial (no tombstones: absence means "not
in the window", never "probe until an empty slot").

Three implementations of the same probe, pinned bit-identical against each
other and a dict oracle in ``tests/test_flow_lookup.py``:

  * ``lookup_numpy``  — host-side oracle; also what the cache's mutation
                        path (insert/evict/expire) uses to find slots;
  * ``lookup_jnp``    — one jitted XLA gather program, the fallback the
                        fast path uses off-TPU (and what interpret-mode
                        tests compare the Pallas kernel against);
  * ``lookup_pallas`` — TPU kernel blocked over queries with the table
                        planes VMEM-resident (DFA-style row gather, see
                        ``kernels/dfa_regex.py``). Tables beyond ~2^19
                        slots would need HBM residency + DMA streaming;
                        the sim sizes below that.

Keys are int64 flow ids split into two uint32 planes (lo, hi) so no path
needs x64 mode; the bucket hash is the same wraparound uint32 mix in all
three. A slot is live iff its pid plane is >= 0. Outputs per query:

  slot  — table slot holding the key (any epoch), or -1 if absent;
  pid   — cached pipeline id if the entry is live AND epoch-fresh, else -1;
  fresh — bool, live key match with entry epoch == current epoch.

``slot`` without ``fresh`` is the revalidation handle: after an epoch bump
the entry is refreshed in place instead of re-inserted. Compilations are
counted at trace time (``trace_counts``) so benchmarks can assert zero
steady-state recompiles.
"""
from __future__ import annotations

import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import compat

# Trace-time compile counters (idiom shared with core.sched_kernel): the
# Python body of a jitted function runs once per specialization, so steady
# state leaves these untouched.
_TRACE_COUNTS: Dict[str, int] = {}


def _count_trace(name: str) -> None:
    _TRACE_COUNTS[name] = _TRACE_COUNTS.get(name, 0) + 1


def trace_counts() -> Dict[str, int]:
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


# -- key splitting + bucket hash ---------------------------------------------

_M1 = np.uint32(0x9E3779B1)      # golden-ratio odd constants; wraparound
_M2 = np.uint32(0x85EBCA77)      # uint32 multiplies are identical in
_M3 = np.uint32(0xC2B2AE3D)      # numpy, XLA and Mosaic.


def split_fids(fids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """int64 flow ids -> (lo, hi) uint32 planes (bit-exact round trip)."""
    u = np.asarray(fids, dtype=np.int64).view(np.uint64)
    lo = (u & np.uint64(0xFFFFFFFF)).astype(np.uint32)
    hi = (u >> np.uint64(32)).astype(np.uint32)
    return lo, hi


def bucket_hash(lo, hi):
    """uint32 mix of the two key words — same code path for numpy and jnp
    arrays (both wrap uint32 arithmetic)."""
    h = (lo * _M1) ^ (hi * _M2)
    h = (h ^ (h >> 15)) * _M3
    return h ^ (h >> 13)


# -- numpy oracle -------------------------------------------------------------

def lookup_numpy(key_lo: np.ndarray, key_hi: np.ndarray, pid: np.ndarray,
                 epoch: np.ndarray, q_lo: np.ndarray, q_hi: np.ndarray,
                 cur_epoch: int, window: int
                 ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    cap = key_lo.shape[0]
    base = bucket_hash(q_lo, q_hi) & np.uint32(cap - 1)
    idx = ((base[:, None] + np.arange(window, dtype=np.uint32))
           & np.uint32(cap - 1)).astype(np.int64)              # (F, W)
    match = ((key_lo[idx] == q_lo[:, None])
             & (key_hi[idx] == q_hi[:, None]) & (pid[idx] >= 0))
    found = match.any(axis=1)
    first = match.argmax(axis=1)
    rows = np.arange(idx.shape[0])
    slot = np.where(found, idx[rows, first], -1).astype(np.int64)
    safe = np.where(slot >= 0, slot, 0)
    fresh = found & (epoch[safe] == np.int32(cur_epoch))
    out_pid = np.where(fresh, pid[safe], -1).astype(np.int32)
    return slot, out_pid, fresh


# -- jitted jnp fallback -------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("window",))
def _lookup_jnp(key_lo, key_hi, pid, epoch, q_lo, q_hi, cur_epoch, *, window):
    _count_trace("flow_lookup_jnp")
    cap = key_lo.shape[0]
    base = bucket_hash(q_lo, q_hi) & np.uint32(cap - 1)
    offs = jnp.arange(window, dtype=jnp.uint32)
    idx = ((base[:, None] + offs[None, :])
           & np.uint32(cap - 1)).astype(jnp.int32)             # (F, W)
    match = ((key_lo[idx] == q_lo[:, None])
             & (key_hi[idx] == q_hi[:, None]) & (pid[idx] >= 0))
    found = match.any(axis=1)
    first = jnp.argmax(match, axis=1)
    slot_w = jnp.take_along_axis(idx, first[:, None], axis=1)[:, 0]
    slot = jnp.where(found, slot_w, -1)
    safe = jnp.where(slot >= 0, slot, 0)
    fresh = found & (epoch[safe] == cur_epoch)
    out_pid = jnp.where(fresh, pid[safe], -1).astype(jnp.int32)
    return slot, out_pid, fresh


def lookup_jnp(key_lo, key_hi, pid, epoch, q_lo, q_hi, cur_epoch: int,
               window: int):
    return _lookup_jnp(key_lo, key_hi, pid, epoch, q_lo, q_hi,
                       jnp.int32(cur_epoch), window=window)


# -- Pallas kernel -------------------------------------------------------------

def _lookup_kernel(qlo_ref, qhi_ref, epoch_now_ref, keylo_ref, keyhi_ref,
                   pid_ref, ep_ref, slot_ref, pid_out_ref, fresh_ref, *,
                   cap: int, window: int):
    qlo = qlo_ref[...][:, 0]                                    # (BF,)
    qhi = qhi_ref[...][:, 0]
    bf = qlo.shape[0]
    base = bucket_hash(qlo, qhi) & np.uint32(cap - 1)
    offs = jax.lax.broadcasted_iota(jnp.uint32, (bf, window), 1)
    idx = (base[:, None] + offs) & np.uint32(cap - 1)           # (BF, W)
    flat = idx.reshape(-1).astype(jnp.int32)
    # DFA-style row gather: table planes are (C, 1) so a 1-D index vector
    # gathers rows (the only gather shape the TPU lowering supports well).
    klo = keylo_ref[...][flat].reshape(bf, window)
    khi = keyhi_ref[...][flat].reshape(bf, window)
    pids = pid_ref[...][flat].reshape(bf, window)
    eps = ep_ref[...][flat].reshape(bf, window)
    match = (klo == qlo[:, None]) & (khi == qhi[:, None]) & (pids >= 0)
    found = match.sum(axis=1) > 0
    first = jnp.argmax(match, axis=1)
    idx_i = idx.astype(jnp.int32)
    slot = jnp.where(found, jnp.take_along_axis(idx_i, first[:, None], 1)[:, 0],
                     -1)
    mpid = jnp.take_along_axis(pids, first[:, None], 1)[:, 0]
    mep = jnp.take_along_axis(eps, first[:, None], 1)[:, 0]
    fresh = found & (mep == epoch_now_ref[0, 0])
    slot_ref[...] = slot[:, None]
    pid_out_ref[...] = jnp.where(fresh, mpid, -1)[:, None]
    fresh_ref[...] = fresh[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("window", "block_f", "interpret"))
def _lookup_pallas(key_lo, key_hi, pid, epoch, q_lo, q_hi, cur_epoch, *,
                   window, block_f, interpret):
    _count_trace("flow_lookup_pallas")
    cap = key_lo.shape[0]
    F = q_lo.shape[0]
    bf = min(block_f, F)
    assert F % bf == 0, (F, bf)
    kernel = functools.partial(_lookup_kernel, cap=cap, window=window)
    slot, mpid, fresh = pl.pallas_call(
        kernel,
        grid=(F // bf,),
        in_specs=[
            pl.BlockSpec((bf, 1), lambda i: (i, 0)),
            pl.BlockSpec((bf, 1), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
            pl.BlockSpec((cap, 1), lambda i: (0, 0)),
            pl.BlockSpec((cap, 1), lambda i: (0, 0)),
            pl.BlockSpec((cap, 1), lambda i: (0, 0)),
            pl.BlockSpec((cap, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bf, 1), lambda i: (i, 0)),
            pl.BlockSpec((bf, 1), lambda i: (i, 0)),
            pl.BlockSpec((bf, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((F, 1), jnp.int32),
            jax.ShapeDtypeStruct((F, 1), jnp.int32),
            jax.ShapeDtypeStruct((F, 1), jnp.int32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(q_lo[:, None], q_hi[:, None], cur_epoch,
      key_lo[:, None], key_hi[:, None], pid[:, None], epoch[:, None])
    return slot[:, 0], mpid[:, 0], fresh[:, 0] != 0


def lookup_pallas(key_lo, key_hi, pid, epoch, q_lo, q_hi, cur_epoch: int,
                  window: int, block_f: int = 512, interpret: bool = False):
    return _lookup_pallas(key_lo, key_hi, pid, epoch, q_lo, q_hi,
                          jnp.full((1, 1), cur_epoch, jnp.int32),
                          window=window, block_f=block_f, interpret=interpret)


# -- incremental device-table maintenance -------------------------------------

@jax.jit
def _apply_updates(key_lo, key_hi, pid, epoch, slots, u_lo, u_hi, u_pid,
                   u_epoch):
    _count_trace("flow_table_update")
    # slots padded with out-of-range sentinels; mode="drop" ignores them, so
    # one compiled program serves every (pow-2 bucketed) update size.
    return (key_lo.at[slots].set(u_lo, mode="drop"),
            key_hi.at[slots].set(u_hi, mode="drop"),
            pid.at[slots].set(u_pid, mode="drop"),
            epoch.at[slots].set(u_epoch, mode="drop"))


def apply_updates(planes, slots, u_lo, u_hi, u_pid, u_epoch):
    """Scatter host-side table mutations into the device-resident planes.

    ``planes`` is the (key_lo, key_hi, pid, epoch) tuple of device arrays;
    returns the updated tuple. Pad ``slots`` with values >= capacity to hit
    a cached specialization (dropped by the scatter).
    """
    return _apply_updates(*planes, jnp.asarray(slots), jnp.asarray(u_lo),
                          jnp.asarray(u_hi), jnp.asarray(u_pid),
                          jnp.asarray(u_epoch))
