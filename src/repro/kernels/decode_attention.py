"""Flash-decode — single-token attention over a blocked KV cache (Pallas TPU).

One new query token attends to a seq_len-deep KV cache. The cache is streamed
through VMEM in block_k tiles with a running (max, sum, acc) carried in
scratch, so VMEM holds O(block_k * D) regardless of cache depth — this is
what makes `decode_32k` / `long_500k` KV depths feasible per-chip.

Validity is passed as a precomputed (B, S) bool mask (avoids SMEM scalar
plumbing and composes with paged/ragged caches). GQA: q is reshaped to
(B, Hkv, G, D) and each grid step processes one kv-head's G query heads, so
the QK^T tile is (G, block_k) — MXU-friendly when G*ceil align, and the same
kernel serves MHA (G = Hq) and MQA (Hkv = 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30
LANES = 128


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, o_ref, acc_ref, m_ref, l_ref, *,
                   num_kv_blocks: int, scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale           # (G, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                   # (bk, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                   # (bk, d)
    valid = valid_ref[0, :]                                     # (bk,) bool

    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (G, bk)
    logits = jnp.where(valid[None, :], logits, NEG_INF)

    m_prev = m_ref[:, 0]
    m_next = jnp.maximum(m_prev, jnp.max(logits, axis=1))
    p = jnp.exp(logits - m_next[:, None]) * valid[None, :].astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_next)
    l_ref[...] = jnp.broadcast_to(
        (alpha * l_ref[:, 0] + jnp.sum(p, axis=1))[:, None], l_ref.shape)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_next[:, None], m_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0, :, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_k", "interpret"))
def decode_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     kv_len: jnp.ndarray, *, scale: float | None = None,
                     block_k: int = 512, interpret: bool = False) -> jnp.ndarray:
    """q: (B, Hq, D); k, v: (B, S, Hkv, D); kv_len: (B,) -> out (B, Hq, D)."""
    B, Hq, D = q.shape
    _, S, Hkv, _ = k.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv
    block_k = min(block_k, S)
    assert S % block_k == 0, (S, block_k)
    nk = S // block_k
    scale_v = scale if scale is not None else D ** -0.5
    qg = q.reshape(B, Hkv, G, D)
    valid = jnp.arange(S)[None, :] < kv_len[:, None]            # (B, S)

    kernel = functools.partial(_decode_kernel, num_kv_blocks=nk, scale=scale_v)
    out = pl.pallas_call(
        kernel,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, ik: (b, ik, h, 0)),
            pl.BlockSpec((1, block_k), lambda b, h, ik: (b, ik)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, D), lambda b, h, ik: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((G, D), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
            pltpu.VMEM((G, LANES), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(qg, k, v, valid)
    return out.reshape(B, Hq, D)
