"""Mamba-2 SSD (state-space duality) chunked scan — Pallas TPU kernel.

The SSD recurrence h_t = a_t h_{t-1} + b_t ⊗ x_t, y_t = c_t · h_t is computed
chunk-by-chunk: within a T-sized chunk the quadratic form
Y = (mask ⊙ exp(cl_t - cl_s) ⊙ (C Bᵀ)) X runs on the MXU ((T,N)x(N,T),
(T,T)x(T,P) matmuls — T = N = 128 aligns with the systolic array), while the
cross-chunk state (N, P) is carried in VMEM scratch through the sequential
chunk grid axis. This is the TPU-native re-blocking of Mamba-2's algorithm:
instead of the paper's warp-level GPU tiling we choose chunk = 128 so every
matmul is MXU-shaped and the carried state never leaves VMEM.

Requires a_t > 0 (true for Mamba-2's exp(-softplus)·dt parameterization).
Validated against `ref.ssd_ref` with interpret=True.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat


def _ssd_kernel(x_ref, la_ref, b_ref, c_ref, y_ref, hout_ref, h_ref, *,
                num_chunks: int):
    ic = pl.program_id(1)

    @pl.when(ic == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, :, 0, :].astype(jnp.float32)      # (T, P)
    la = la_ref[0, :, 0].astype(jnp.float32)       # (T,)  log a_t
    b = b_ref[0, :, 0, :].astype(jnp.float32)      # (T, N)
    c = c_ref[0, :, 0, :].astype(jnp.float32)      # (T, N)
    h = h_ref[...]                                 # (N, P) carried state

    cl = jnp.cumsum(la)                            # (T,) cl[t] = sum_{i<=t} log a_i
    T = x.shape[0]
    t_idx = jax.lax.broadcasted_iota(jnp.int32, (T, T), 0)
    s_idx = jax.lax.broadcasted_iota(jnp.int32, (T, T), 1)
    # decay[t, s] = prod_{i=s+1..t} a_i  for s <= t
    decay = jnp.exp(cl[:, None] - cl[None, :])
    lmask = (s_idx <= t_idx).astype(jnp.float32)
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (T, T)
    y_intra = jax.lax.dot_general(cb * decay * lmask, x,
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)  # (T, P)
    # Contribution of the carried state: y_state[t] = exp(cl[t]) * (c_t · h).
    ch = jax.lax.dot_general(c, h, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (T, P)
    y = y_intra + jnp.exp(cl)[:, None] * ch
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # State update: h' = exp(cl[T-1]) h + sum_s exp(cl[T-1] - cl[s]) b_s ⊗ x_s.
    w = jnp.exp(cl[T - 1] - cl)                     # (T,)
    bw = b * w[:, None]                             # (T, N)
    h_next = jnp.exp(cl[T - 1]) * h + jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_ref[...] = h_next

    @pl.when(ic == num_chunks - 1)
    def _finish():
        hout_ref[0, 0, :, :] = h_next.astype(hout_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x: jnp.ndarray, a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, *,
             chunk: int = 128, interpret: bool = False):
    """x: (B,S,H,P), a: (B,S,H) decays in (0,1], b/c: (B,S,H,N).

    Returns (y: (B,S,H,P), h_final: (B,H,N,P)). Zero initial state (prefill
    semantics; decode carries state through `serving.ssm_state`).
    """
    B, S, H, P = x.shape
    N = b.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    la = jnp.log(a.astype(jnp.float32))

    kernel = functools.partial(_ssd_kernel, num_chunks=nc)
    y, h_fin = pl.pallas_call(
        kernel,
        grid=(B * H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bh, ic: (bh // H, ic, bh % H, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ic: (bh // H, ic, bh % H)),
            pl.BlockSpec((1, chunk, 1, N), lambda bh, ic: (bh // H, ic, bh % H, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda bh, ic: (bh // H, ic, bh % H, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bh, ic: (bh // H, ic, bh % H, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bh, ic: (bh // H, bh % H, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, S, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(x, la, b, c)
    return y, h_fin
