"""ARX cipher / keyed-hash rounds — AES & SHA accelerator analogs (Pallas TPU).

BlueField/Pensando crypto engines are opaque fixed-function blocks; what
matters for Meili is their *throughput shape*: a fixed number of rounds of
cheap word ops over every payload byte. We reproduce that shape with an
8-round ARX permutation (add-rotate-xor, VPU-native — TPUs have no AES-NI
analogue so ARX is the idiomatic substitute) and a keyed fold digest.

Payloads are pre-packed to uint32 words outside the kernel; blocks of
(block_b, W) words stream through VMEM. Not cryptographically secure — see
DESIGN.md §2 (structural analog only).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import compat

from repro.kernels import ref as _ref


def _cipher_kernel(words_ref, key_ref, out_ref):
    out_ref[...] = _ref.arx_cipher(words_ref[...], key_ref[0])


def _hash_kernel(words_ref, key_ref, out_ref):
    out_ref[...] = _ref.keyed_hash(words_ref[...], key_ref[0])


def _call(kernel, words: jnp.ndarray, key: jnp.ndarray, out_w: int,
          block_b: int, interpret: bool) -> jnp.ndarray:
    B, W = words.shape
    block_b = min(block_b, B)
    assert B % block_b == 0, (B, block_b)
    return pl.pallas_call(
        kernel,
        grid=(B // block_b,),
        in_specs=[
            pl.BlockSpec((block_b, W), lambda i: (i, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_b, out_w), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, out_w), jnp.uint32),
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(words.astype(jnp.uint32), key.astype(jnp.uint32)[None, :])


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def arx_cipher(words: jnp.ndarray, key: jnp.ndarray, *, block_b: int = 256,
               interpret: bool = False) -> jnp.ndarray:
    """words: (B, W) uint32, key: (4,) uint32 -> (B, W) uint32."""
    return _call(_cipher_kernel, words, key, words.shape[1], block_b, interpret)


@functools.partial(jax.jit, static_argnames=("block_b", "interpret"))
def keyed_hash(words: jnp.ndarray, key: jnp.ndarray, *, block_b: int = 256,
               interpret: bool = False) -> jnp.ndarray:
    """words: (B, W) uint32, key: (>=4,) uint32 -> (B, 4) uint32 digest."""
    return _call(_hash_kernel, words, key[:4], 4, block_b, interpret)
