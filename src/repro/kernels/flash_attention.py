"""Blocked causal/sliding-window GQA attention — Pallas TPU kernel.

TPU-native flash attention: the KV sequence is streamed through VMEM in
(block_k)-sized tiles while a running (max, sum, acc) triple lives in VMEM
scratch; QK^T and PV tiles hit the MXU. Grid = (batch*q_heads, q_blocks,
kv_blocks) with the KV axis innermost ("arbitrary" dimension semantics:
sequential, so scratch carries across kv steps).

Masking: causal and optional sliding window (Gemma-3 local layers). Fully
masked tiles are handled by multiplying probabilities with the mask (never
relying on exp(-inf)).

This kernel is the TPU *target*; it is validated on CPU via interpret=True
against `ref.mha_ref` (tests/test_kernels.py) and selected at runtime by
`ops.attention(..., impl="pallas")`.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import compat

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  block_q: int, block_k: int, seq_q: int, seq_k: int,
                  num_kv_blocks: int, causal: bool, window: int | None,
                  scale: float):
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, :, 0, :].astype(jnp.float32) * scale          # (bq, d)
    k = k_ref[0, :, 0, :].astype(jnp.float32)                  # (bk, d)
    v = v_ref[0, :, 0, :].astype(jnp.float32)                  # (bk, d)

    logits = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (bq, bk)

    iq = pl.program_id(1)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) \
        + (seq_k - seq_q)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), jnp.bool_)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[:, 0]                                        # (bq,)
    m_cur = jnp.max(logits, axis=1)
    m_next = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(logits - m_next[:, None]) * mask.astype(jnp.float32)
    alpha = jnp.exp(m_prev - m_next)
    l_next = alpha * l_ref[:, 0] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_next[:, None], m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_next[:, None], l_ref.shape)

    @pl.when(ik == num_kv_blocks - 1)
    def _finish():
        l = l_ref[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, :, 0, :] = (acc_ref[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "scale", "block_q", "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False) -> jnp.ndarray:
    """q: (B, Sq, Hq, D); k, v: (B, Sk, Hkv, D) with Hq % Hkv == 0."""
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, (Hq, Hkv)
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, Sk, block_q, block_k)
    scale_v = scale if scale is not None else D ** -0.5
    nq, nk = Sq // block_q, Sk // block_k
    grid = (B * Hq, nq, nk)

    kernel = functools.partial(
        _flash_kernel, block_q=block_q, block_k=block_k, seq_q=Sq, seq_k=Sk,
        num_kv_blocks=nk, causal=causal, window=window, scale=scale_v)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D),
                         lambda bh, iq, ik: (bh // Hq, iq, bh % Hq, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda bh, iq, ik: (bh // Hq, ik, (bh % Hq) // G, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda bh, iq, ik: (bh // Hq, ik, (bh % Hq) // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D),
                               lambda bh, iq, ik: (bh // Hq, iq, bh % Hq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
            pltpu.VMEM((block_q, LANES), jnp.float32),
        ],
        compiler_params=compat.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
