"""Version compatibility for Pallas TPU APIs.

JAX has renamed the TPU lowering-parameter dataclass across releases:
older releases expose ``pltpu.TPUCompilerParams``, newer ones
``pltpu.CompilerParams``. All kernels import the name from here so a
single site absorbs the drift.
"""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) \
    or getattr(pltpu, "TPUCompilerParams")
