"""Assigned architecture configs (+ registry). --arch <id> resolves here."""
from repro.configs.base import ArchConfig, ShapeConfig, SHAPES, cells, skipped_cells

from repro.configs import (seamless_m4t_medium, minicpm_2b, gemma3_1b, olmo_1b,
                           qwen2_5_32b, moonshot_v1_16b_a3b,
                           phi3_5_moe_42b_a6_6b, mamba2_370m, llava_next_34b,
                           jamba_1_5_large_398b)

ARCHS = {m.CONFIG.name: m.CONFIG for m in (
    seamless_m4t_medium, minicpm_2b, gemma3_1b, olmo_1b, qwen2_5_32b,
    moonshot_v1_16b_a3b, phi3_5_moe_42b_a6_6b, mamba2_370m, llava_next_34b,
    jamba_1_5_large_398b)}


def get_arch(name: str) -> ArchConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; have {sorted(ARCHS)}")
    return ARCHS[name]
