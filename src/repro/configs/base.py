"""Architecture + shape configuration system.

One `ArchConfig` per assigned architecture (exact public-literature configs);
`reduced()` derives the CPU smoke-test variant (same family, tiny dims).
`SHAPES` defines the four assigned input-shape cells; applicability masks
(long_500k needs sub-quadratic attention) live here so the dry-run driver,
tests and EXPERIMENTS.md agree.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 0                  # 0 => d_model // n_heads

    # attention flavor
    qkv_bias: bool = False
    rope_theta: float = 1e4
    window: Optional[int] = None     # sliding window for local layers
    local_global_period: int = 0     # gemma3: one global layer per period
    nonparam_ln: bool = False        # olmo: non-parametric LayerNorm

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    first_dense: int = 0             # leading dense layers before MoE stack

    # SSM (Mamba-2)
    ssm_state: int = 0               # N
    ssm_head_dim: int = 0            # P
    ssm_expand: int = 2
    conv_kernel: int = 4

    # hybrid (jamba): one attention layer per `attn_period` layers,
    # MoE every `moe_period` layers.
    attn_period: int = 0
    moe_period: int = 0

    # enc-dec
    enc_layers: int = 0
    dec_layers: int = 0
    cross_attention: bool = False

    # modality frontend stub
    frontend: Optional[str] = None   # "audio" | "vision"
    frontend_tokens: int = 0         # stub positions prepended to the text seq

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # training
    schedule: str = "cosine"         # "cosine" | "wsd" (minicpm)
    microbatch: int = 16             # grad-accumulation steps for train_4k
    remat: bool = True
    bf16_optimizer_state: bool = False   # jamba-398B: fits 16 GB/chip this way

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def d_inner(self) -> int:        # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_head_dim else 0

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Same-family tiny config for CPU smoke tests."""
        kw: Dict = dict(
            n_layers=min(self.n_layers, 4), d_model=64, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads else 0,
            d_ff=128, vocab=512, d_head=16, microbatch=1)
        if self.n_experts:
            kw.update(n_experts=4, top_k=min(self.top_k, 2))
        if self.ssm_state:
            kw.update(ssm_state=16, ssm_head_dim=8)
        if self.window:
            kw.update(window=16)
        if self.local_global_period:
            kw.update(local_global_period=2, n_layers=4)
        if self.attn_period:
            kw.update(attn_period=4, moe_period=2, n_layers=8)
        if self.enc_layers:
            kw.update(enc_layers=2, dec_layers=2)
        if self.first_dense:
            kw.update(first_dense=1)
        if self.frontend_tokens:
            kw.update(frontend_tokens=8)
        return self.replace(**kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                        # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# Pure full-attention archs skip long_500k (sub-quadratic attention required).
SUBQUADRATIC = {"gemma3-1b", "mamba2-370m", "jamba-1.5-large-398b"}


def cells(arch_name: str) -> List[Tuple[str, str]]:
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and arch_name not in SUBQUADRATIC:
            continue
        out.append((arch_name, s.name))
    return out


def skipped_cells(arch_name: str) -> List[Tuple[str, str, str]]:
    if arch_name in SUBQUADRATIC:
        return []
    return [(arch_name, "long_500k",
             "pure full attention — long_500k needs sub-quadratic attention")]
