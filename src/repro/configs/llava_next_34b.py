"""llava-next-34b [hf:llava-hf/llava-v1.6-*; unverified] — VLM, anyres tiling.

60L, d_model=7168, 56H (GQA kv=8), d_ff=20480, vocab=64000, head_dim=128.
Vision frontend is a stub: input_specs provides 576 precomputed patch
embeddings per image, prepended to the text sequence (anyres tiles are
flows of patch-packets in the Meili example). long_500k SKIPPED.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, d_ff=20480,
    vocab=64000, d_head=128, frontend="vision", frontend_tokens=576,
    tie_embeddings=False, microbatch=16)
