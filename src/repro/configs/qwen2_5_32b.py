"""qwen2.5-32b [hf:Qwen/Qwen2.5-*; hf] — dense GQA with QKV bias.

64L, d_model=5120, 40H (GQA kv=8), d_ff=27648, vocab=152064, head_dim=128.
long_500k SKIPPED (pure full attention).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-32b", family="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_ff=27648,
    vocab=152064, d_head=128, qkv_bias=True, rope_theta=1e6,
    tie_embeddings=False, microbatch=16)
