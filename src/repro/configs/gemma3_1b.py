"""gemma3-1b [hf:google/gemma-3-1b-pt; unverified] — 5:1 local:global, 128k.

26L, d_model=1152, 4H (GQA kv=1 = MQA), d_ff=6912, vocab=262144,
head_dim=256, sliding window 512 on local layers, one global layer per 6.
long_500k RUNS: 5/6 of layers are O(W·S); decode is O(S)/token.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-1b", family="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, d_ff=6912,
    vocab=262144, d_head=256, window=512, local_global_period=6,
    rope_theta=1e6, tie_embeddings=True, microbatch=4)
