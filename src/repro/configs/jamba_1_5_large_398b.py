"""jamba-1.5-large-398b [arXiv:2403.19887; hf] — Mamba+attention 1:7, MoE 16e top-2.

72L, d_model=8192, 64H (GQA kv=8), d_ff=24576, vocab=65536. One attention
layer per 8 (the rest Mamba-2), MoE every 2nd layer. ssm: N=128, P=64
(d_inner=16384, 256 ssm heads). bf16 optimizer state to fit 16 GB/chip on a
single pod (DESIGN.md §4). long_500k RUNS (hybrid).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=24576,
    vocab=65536, d_head=128, n_experts=16, top_k=2,
    attn_period=8, moe_period=2, ssm_state=128, ssm_head_dim=64,
    ssm_expand=2, bf16_optimizer_state=True, tie_embeddings=False,
    microbatch=32)
