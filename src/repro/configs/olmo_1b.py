"""olmo-1b [arXiv:2402.00838; hf] — dense, non-parametric LayerNorm.

16L, d_model=2048, 16H (kv=16 = MHA), d_ff=8192, vocab=50304.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="olmo-1b", family="dense",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=8192,
    vocab=50304, nonparam_ln=True, tie_embeddings=True, microbatch=4)
