"""mamba2-370m [arXiv:2405.21060; unverified] — SSD, attention-free.

48L, d_model=1024, d_inner=2048, ssm_state N=128, head dim P=64 (H=32),
vocab=50280. long_500k RUNS (O(1)/token decode state).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-370m", family="ssm",
    n_layers=48, d_model=1024, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50280, ssm_state=128, ssm_head_dim=64, ssm_expand=2,
    tie_embeddings=True, microbatch=4)
