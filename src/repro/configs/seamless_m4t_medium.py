"""seamless-m4t-medium [arXiv:2308.11596; hf] — enc-dec, multimodal (audio).

12L encoder + 12L decoder, d_model=1024, 16H (GQA kv=16 = MHA), d_ff=4096,
vocab=256206. The speech frontend is a stub: input_specs feeds precomputed
frame embeddings to the encoder (DESIGN.md §4).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium", family="encdec",
    n_layers=24, enc_layers=12, dec_layers=12, cross_attention=True,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096, vocab=256206,
    frontend="audio", tie_embeddings=True, microbatch=8)
