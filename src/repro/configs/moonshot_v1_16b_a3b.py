"""moonshot-v1-16b-a3b [hf:moonshotai/Moonlight-16B-A3B; hf] — MoE 64e top-6.

48L, d_model=2048, 16H (kv=16), per-expert d_ff=1408, vocab=163840,
64 experts top-6, leading dense layer (DeepSeek-style stack).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1408,
    vocab=163840, d_head=128, n_experts=64, top_k=6, first_dense=1,
    tie_embeddings=True, microbatch=16)
