"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct; hf] — 16e top-2.

32L, d_model=4096, 32H (GQA kv=8), per-expert d_ff=6400, vocab=32064.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=6400,
    vocab=32064, d_head=128, n_experts=16, top_k=2,
    tie_embeddings=False, microbatch=16)
