"""minicpm-2b [arXiv:2404.06395; hf] — dense llama-like, WSD schedule.

40L, d_model=2304, 36H (GQA kv=36 = MHA), d_ff=5760, vocab=122753.
36 heads do not divide a 16-way model axis: the sharding resolver falls back
to head_dim (64) tensor parallelism (parallel/sharding.py).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_heads=36, n_kv_heads=36, d_ff=5760,
    vocab=122753, d_head=64, schedule="wsd", tie_embeddings=True,
    microbatch=8)
