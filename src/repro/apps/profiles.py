"""Calibrated paper-cluster cost model for the six evaluation apps.

Single home for the per-stage, per-1500B-packet latencies (µs) on one
resource unit (ARM A72 core or accelerator engine) and the stage->resource
map. Derived from the paper's observable aggregates: Fig 9 single-pipeline
rates, Fig 2 bottleneck structure (L7 Filter regex-bound, Malware Detection
CPU-bound), §8.5 TO overhead. ``benchmarks/common.py`` re-exports these
tables; the service runtime (``repro.service``) builds tenant profiles from
them, so src/ never imports from benchmarks/.
"""
from __future__ import annotations

from typing import Dict

from repro.core.graph import PKT_BYTES
from repro.core.profiler import AppProfile, synthetic_profile

PKT_BITS = PKT_BYTES * 8.0

# Calibrated per-stage latencies (µs per 1500 B packet, one resource unit).
APP_STAGE_LATENCY_US: Dict[str, Dict[str, float]] = {
    # Intrusion Detection [3 fn: CPU, regex]  (CPU-bound like Malware Det.;
    # regex engine ~13 Gbps, matching Fig 2's L7-Filter regex bound)
    "ID": {"flow_ext": 2.20, "dpi_regex": 0.92, "verdict": 1.80},
    # IPComp Gateway [2 fn: CPU, compression]
    "ICG": {"ipcomp_encap": 1.80, "compress": 2.10},
    # IPsec Gateway [4 fn: CPU, regex, AES] — Listing 1
    "ISG": {"ddos_check": 2.00, "url_check": 0.92, "ipsec_encap": 1.00,
            "sha": 1.30, "aes": 1.90},
    # Firewall [2 fn: CPU]  (Fig 9: ~25 Gbps @ 7 pipelines => ~3.7 Gbps each)
    "FW": {"rule_match": 2.90, "conn_track": 3.20},
    # Flow Monitor [2 fn: CPU]
    "FM": {"flow_ext": 2.90, "flow_metrics": 3.20},
    # L7 Load Balancer [socket]  (Fig 9: ~60 Gbps @ 7 => ~8.8 Gbps each)
    "LLB": {"reg_sock": 0.20, "epoll_in": 1.36},
}

# Resource kind per stage (matches apps/nf.py definitions).
APP_STAGE_RESOURCE: Dict[str, Dict[str, str]] = {
    "ID": {"flow_ext": "cpu", "dpi_regex": "regex", "verdict": "cpu"},
    "ICG": {"ipcomp_encap": "cpu", "compress": "compression"},
    "ISG": {"ddos_check": "cpu", "url_check": "regex", "ipsec_encap": "cpu",
            "sha": "crypto", "aes": "crypto"},
    "FW": {"rule_match": "cpu", "conn_track": "cpu"},
    "FM": {"flow_ext": "cpu", "flow_metrics": "cpu"},
    "LLB": {"reg_sock": "cpu", "epoll_in": "cpu"},
}

# Remote hop penalty between stages on different NICs (paper §8.5: ~4.5 µs
# round trip; Table 1 shows +3.75 µs avg for the distributed IPComp GW).
HOP_US = 4.5


def unit_gbps(lat_us: float) -> float:
    """Throughput of one resource unit running a stage (1500 B packets)."""
    return PKT_BITS / (lat_us * 1e-6) / 1e9


def stage_unit_gbps(app_key: str) -> Dict[str, float]:
    return {s: unit_gbps(l) for s, l in APP_STAGE_LATENCY_US[app_key].items()}


def paper_profile(app_key: str, batch_pkts: int = 256) -> AppProfile:
    """An AppProfile for one evaluation app from the calibrated tables.

    Latencies are per *sequence batch* of ``batch_pkts`` packets (the
    profiler's sequence unit), so ``t_s``/``t_p`` come out in the paper's
    per-unit Gbps ranges regardless of batch size.
    """
    lat_us = APP_STAGE_LATENCY_US[app_key]
    l_s = {s: l * 1e-6 * batch_pkts for s, l in lat_us.items()}
    return synthetic_profile(list(lat_us), l_s, PKT_BITS * batch_pkts)
