"""The paper's six SmartNIC applications (Appendix F) on the Meili model."""

from repro.apps.nf import (intrusion_detection, ipcomp_gateway, ipsec_gateway,
                           firewall, flow_monitor, l7_load_balancer, ALL_APPS,
                           app_resources)
from repro.apps.packets import synth_packets
