"""Synthetic traffic generation — the DPDK-Pktgen / MACCDC-replay stand-in.

Deterministic (seeded) flows of 1500 B packets; a configurable fraction of
payloads embed rule-matching byte patterns so regex stages do real work.
``synth_packets`` draws flows uniformly; ``synth_packets_weighted`` assigns
packets to flows by an explicit probability vector, which the service
workload generator uses for heavy-tailed (Pareto) flow-size mixes.
"""
from __future__ import annotations

from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.graph import PKT_BYTES, PacketBatch, make_packets

DEFAULT_PATTERNS = ("attack", "GET /admin")


def _payloads(rng: np.random.Generator, batch: int, pkt_bytes: int,
              embed_patterns: Sequence[str], embed_frac: float) -> np.ndarray:
    payload = rng.integers(0, 256, size=(batch, pkt_bytes), dtype=np.uint8)
    # Embed known patterns into a fraction of packets (MACCDC has hits too).
    n_embed = int(batch * embed_frac)
    for i in range(n_embed):
        pat = embed_patterns[i % len(embed_patterns)].encode()
        pos = rng.integers(0, pkt_bytes - len(pat))
        payload[i, pos:pos + len(pat)] = np.frombuffer(pat, dtype=np.uint8)
    return payload


def _five_tuple(flows: np.ndarray, flow_base: int = 0) -> np.ndarray:
    """5-tuples for a per-packet flow-index vector; `flow_base` offsets the
    address space so different tenants never share flow ids."""
    batch = flows.shape[0]
    f = flows + flow_base
    five = np.zeros((batch, 5), dtype=np.int32)
    five[:, 0] = 0x0A000000 + f              # src ip per flow
    five[:, 1] = 0x0A800000 + (f // 4)       # dst ip
    five[:, 2] = 1024 + (f % 60000)          # sport
    five[:, 3] = 443                         # dport
    five[:, 4] = 6                           # TCP
    return five


def _build(payload: np.ndarray, pkt_bytes: int, flows: np.ndarray,
           flow_base: int) -> PacketBatch:
    length = np.full((payload.shape[0],), pkt_bytes, dtype=np.int32)
    return make_packets(jnp.asarray(payload), jnp.asarray(length),
                        jnp.asarray(_five_tuple(flows, flow_base)))


def synth_packets(batch: int = 256, num_flows: int = 32, seed: int = 0,
                  pkt_bytes: int = PKT_BYTES,
                  embed_patterns: Sequence[str] = DEFAULT_PATTERNS,
                  embed_frac: float = 0.1) -> PacketBatch:
    rng = np.random.default_rng(seed)
    payload = _payloads(rng, batch, pkt_bytes, embed_patterns, embed_frac)
    flows = rng.integers(0, num_flows, size=(batch,))
    return _build(payload, pkt_bytes, flows, flow_base=0)


def pareto_flow_weights(num_flows: int, alpha: float, seed: int) -> np.ndarray:
    """Normalized heavy-tailed flow popularity (Pareto shape `alpha`; smaller
    alpha => heavier tail / more elephant flows). Deterministic per seed."""
    rng = np.random.default_rng(seed)
    w = rng.pareto(alpha, size=num_flows) + 1.0
    return w / w.sum()


def synth_packets_weighted(batch: int, num_flows: int,
                           weights: Optional[np.ndarray] = None,
                           seed: int = 0, pkt_bytes: int = PKT_BYTES,
                           flow_base: int = 0,
                           embed_patterns: Sequence[str] = DEFAULT_PATTERNS,
                           embed_frac: float = 0.1) -> PacketBatch:
    """Like synth_packets but packets pick flows per `weights` (heavy-tailed
    traffic: a few elephant flows carry most packets, exercising the TO's
    spill path), with a per-tenant `flow_base` address-space offset."""
    rng = np.random.default_rng(seed)
    payload = _payloads(rng, batch, pkt_bytes, embed_patterns, embed_frac)
    if weights is None:
        flows = rng.integers(0, num_flows, size=(batch,))
    else:
        flows = rng.choice(num_flows, size=batch, p=weights)
    return _build(payload, pkt_bytes, flows, flow_base)
