"""Synthetic traffic generation — the DPDK-Pktgen / MACCDC-replay stand-in.

Deterministic (seeded) flows of 1500 B packets; a configurable fraction of
payloads embed rule-matching byte patterns so regex stages do real work.
"""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.graph import PKT_BYTES, PacketBatch, make_packets


def synth_packets(batch: int = 256, num_flows: int = 32, seed: int = 0,
                  pkt_bytes: int = PKT_BYTES,
                  embed_patterns: Sequence[str] = ("attack", "GET /admin"),
                  embed_frac: float = 0.1) -> PacketBatch:
    rng = np.random.default_rng(seed)
    payload = rng.integers(0, 256, size=(batch, pkt_bytes), dtype=np.uint8)
    # Embed known patterns into a fraction of packets (MACCDC has hits too).
    n_embed = int(batch * embed_frac)
    for i in range(n_embed):
        pat = embed_patterns[i % len(embed_patterns)].encode()
        pos = rng.integers(0, pkt_bytes - len(pat))
        payload[i, pos:pos + len(pat)] = np.frombuffer(pat, dtype=np.uint8)
    length = np.full((batch,), pkt_bytes, dtype=np.int32)
    flows = rng.integers(0, num_flows, size=(batch,))
    five = np.zeros((batch, 5), dtype=np.int32)
    five[:, 0] = 0x0A000000 + flows          # src ip per flow
    five[:, 1] = 0x0A800000 + (flows // 4)   # dst ip
    five[:, 2] = 1024 + flows                # sport
    five[:, 3] = 443                         # dport
    five[:, 4] = 6                           # TCP
    return make_packets(jnp.asarray(payload), jnp.asarray(length),
                        jnp.asarray(five))
