"""The six evaluation applications (paper Appendix F, Table 3) as Meili apps.

| App                 | Abs.   | Stateful | #fn | Resources          |
| Intrusion Detection | packet |   yes    |  3  | CPU, regex         |
| IPComp Gateway      | packet |   no     |  2  | CPU, compression   |
| IPsec Gateway       | packet |   no     |  4  | CPU, regex, AES    |
| Firewall            | packet |   yes    |  2  | CPU                |
| Flow Monitor        | packet |   yes    |  2  | CPU                |
| L7 Load Balancer    | socket |   yes    |  1  | CPU                |

UCFs are JAX functions over PacketBatch (DESIGN.md §2). IPsec Gateway follows
Listing 1: ddos_check -> url_check (regex) -> ipsec (encap+sha) -> AES.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import accel
from repro.core.graph import FlowBatch, MeiliApp, PacketBatch

SNORT_RULES = ["attack", "GET /admin", "cmd.exe", "/etc/passwd", "SELECT *"]
DDOS_THRESHOLD = 1.2


# --------------------------------------------------------------------------
# Shared UCFs
# --------------------------------------------------------------------------

def _byte_hist(payload: jnp.ndarray, nbins: int = 16) -> jnp.ndarray:
    """(B, L) bytes -> (B, nbins) normalized histogram over high nibbles."""
    hi = (payload >> 4).astype(jnp.int32)                      # (B, L)
    onehot = jax.nn.one_hot(hi, nbins, dtype=jnp.float32)
    h = onehot.sum(axis=1)
    return h / jnp.maximum(h.sum(axis=1, keepdims=True), 1.0)


def _entropy(p: jnp.ndarray) -> jnp.ndarray:
    return -(p * jnp.log2(jnp.maximum(p, 1e-12))).sum(axis=-1)


def ddos_check(pkt: PacketBatch) -> jnp.ndarray:
    """Listing 1 structure (sum_ent vs joint_ent): flood traffic is
    repetitive/low-entropy, so packets whose entropy margin collapses below
    THRESHOLD are flagged and dropped."""
    h1 = _byte_hist(pkt.payload[:, :750])
    h2 = _byte_hist(pkt.payload[:, 750:])
    sum_ent = _entropy(h1) + _entropy(h2)
    joint = _entropy((h1 + h2) / 2.0)
    ddos_flag = (sum_ent - joint) < DDOS_THRESHOLD
    return ~ddos_flag                                          # keep-mask


def url_filter(pkt: PacketBatch) -> jnp.ndarray:
    """Post-regex verdict: drop packets with any rule hit."""
    return pkt.meta["match_num"] == 0


def encap(pkt: PacketBatch) -> PacketBatch:
    """ESP-style encap: bump proto, record SPI + original length in meta."""
    ft = pkt.five_tuple.at[:, 4].set(50)                        # proto = ESP
    return dataclasses.replace(pkt, five_tuple=ft).with_meta(
        spi=pkt.length * 0 + 0x1001, orig_len=pkt.length)


# --------------------------------------------------------------------------
# The applications
# --------------------------------------------------------------------------

def intrusion_detection(rules=SNORT_RULES, impl=None) -> MeiliApp:
    """3 functions: flow extraction, DPI regex, verdict. CPU + regex."""
    app = MeiliApp("intrusion-detection")
    app.flow_ext(lambda p: p.five_tuple[:, 0] ^ p.five_tuple[:, 2],
                 window=128, slide=64, name="flow_ext")
    app.accel(accel.regex(rules, impl=impl, name="dpi_regex"))
    app.pkt_flt(url_filter, name="verdict")
    app.declare_state("id_alerts", "full-access")
    return app


def ipcomp_gateway(impl=None) -> MeiliApp:
    """2 functions: encap + compression (RFC 3173). CPU + compression."""
    app = MeiliApp("ipcomp-gateway")
    app.pkt_trans(encap, name="ipcomp_encap")
    app.accel(accel.compression(rt=0.5, name="compress"))
    return app


def ipsec_gateway(rules=SNORT_RULES, impl=None) -> MeiliApp:
    """Listing 1 verbatim: ddos_check, url_check (regex), ipsec(encap+sha), AES.

    4 functions over CPU + regex + AES — deployable only by pooling BF-2
    (regex) with Pensando (AES): the paper's headline heterogeneity case.
    """
    app = MeiliApp("ipsec-gateway")
    app.pkt_flt(ddos_check, name="ddos_check")
    app.accel(accel.regex(rules, impl=impl, name="url_check"))

    def ipsec(pkt: PacketBatch) -> PacketBatch:
        return encap(pkt)

    app.pkt_trans(ipsec, name="ipsec_encap")
    app.accel(accel.sha(key=(7, 11, 13, 17), impl=impl, name="sha"))
    app.accel(accel.AES(key=(1, 2, 3, 4), impl=impl, name="aes"))
    return app


def firewall() -> MeiliApp:
    """2 functions: 5-tuple rule match + connection tracking. CPU only."""
    app = MeiliApp("firewall")

    def rule_match(pkt: PacketBatch) -> jnp.ndarray:
        blocked_port = pkt.five_tuple[:, 3] == 23               # telnet
        blocked_src = ((pkt.five_tuple[:, 0] >> 24) & 0xFF) == 0xC0  # 192.0.0.0/8
        return ~(blocked_port | blocked_src)

    app.pkt_flt(rule_match, name="rule_match")

    def conn_track(pkt: PacketBatch, flows: FlowBatch) -> FlowBatch:
        seen = pkt.mask.astype(jnp.int32)
        return dataclasses.replace(flows, meta={**flows.meta, "conn_pkts": seen})

    app.flow_trans(conn_track, name="conn_track")
    app.declare_state("conn_table", "full-access")
    return app


def flow_monitor() -> MeiliApp:
    """2 functions: flow extraction + COMPUTE aggregation. CPU only.
    Uses the COMPUTE operator with a non-external-write pattern (paper §7)."""
    app = MeiliApp("flow-monitor")
    app.flow_ext(lambda p: p.five_tuple[:, 0], window=256, slide=256,
                 name="flow_ext")

    def metrics(pkt: PacketBatch, flows: FlowBatch) -> FlowBatch:
        return dataclasses.replace(flows, meta={
            **flows.meta,
            "pkt_count": pkt.mask.astype(jnp.int32),
            "byte_count": pkt.length * pkt.mask.astype(jnp.int32)})

    app.flow_trans(metrics, name="flow_metrics")
    app.declare_state("flow_counters", "non-external-write")
    return app


def l7_load_balancer(num_backends: int = 8) -> MeiliApp:
    """1 socket function: epoll_in — authenticate (hmac), rate-limit,
    redirect to a backend (Appendix B's API gateway shape)."""
    app = MeiliApp("l7-load-balancer")
    app.reg_sock()

    def epoll_in(pkt: PacketBatch) -> PacketBatch:
        words = pkt.payload[:, :64].astype(jnp.uint32)
        hmac = words.sum(axis=1) * jnp.uint32(2654435761)
        backend = (hmac % jnp.uint32(num_backends)).astype(jnp.int32)
        return pkt.with_meta(hmac=hmac, backend=backend)

    app.epoll(epoll_in, name="epoll_in")
    app.declare_state("lb_sessions", "full-access")
    return app


def ALL_APPS(impl=None) -> Dict[str, MeiliApp]:
    return {
        "ID": intrusion_detection(impl=impl),
        "ICG": ipcomp_gateway(impl=impl),
        "ISG": ipsec_gateway(impl=impl),
        "FW": firewall(),
        "FM": flow_monitor(),
        "LLB": l7_load_balancer(),
    }


def app_resources(app: MeiliApp) -> List[str]:
    return sorted({f.resource for f in app.stages})
