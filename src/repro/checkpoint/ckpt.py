"""Sharded checkpointing with manifest, atomic commit, elastic restore.

Layout:  <dir>/step_<n>/
            manifest.json       — tree structure, leaf shapes/dtypes, step
            shard_<h>.npz       — this host's param/optimizer leaves
            COMMIT              — written last; restore ignores dirs without it

Failover integration (paper Appendix D): the training driver checkpoints
periodically; on NIC/chip failure the job restarts from the latest COMMIT'd
step on the surviving mesh — elastic restore re-shards automatically because
leaves are saved unsharded-per-host here (single-host container) and restored
through `jax.device_put` against the new sharding. The deterministic data
pipeline resumes from the stored step, so the sample stream is exactly
replayed.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import tempfile
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

Tree = Any


def _flatten_with_names(tree: Tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def save_checkpoint(directory: str, step: int, tree: Tree,
                    host_index: int = 0) -> str:
    """Atomic per-step save (write to tmp, rename, then COMMIT)."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_names(tree)
    arrays = {name: np.asarray(leaf) for name, leaf in leaves}
    np.savez(os.path.join(tmp, f"shard_{host_index}.npz"), **arrays)
    treedef = jax.tree_util.tree_structure(tree)
    manifest = {
        "step": step,
        "leaves": {name: {"shape": list(np.shape(v)),
                          "dtype": str(np.asarray(v).dtype)}
                   for name, v in arrays.items()},
        "treedef": str(treedef),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    with open(os.path.join(final, "COMMIT"), "w") as f:
        f.write("ok")
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and \
                os.path.exists(os.path.join(directory, d, "COMMIT")):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: Tree, step: Optional[int] = None,
                       host_index: int = 0, shardings: Optional[Tree] = None
                       ) -> Tuple[Tree, int]:
    """Restore into the structure of `like`; re-shard via `shardings` if the
    mesh changed (elastic restart)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    data = np.load(os.path.join(path, f"shard_{host_index}.npz"))
    names = [n for n, _ in _flatten_with_names(like)]
    leaves_like = jax.tree_util.tree_leaves(like)
    treedef = jax.tree_util.tree_structure(like)
    restored = []
    sh_leaves = (jax.tree_util.tree_leaves(shardings) if shardings is not None
                 else [None] * len(names))
    for name, proto, sh in zip(names, leaves_like, sh_leaves):
        arr = data[name]
        arr = arr.astype(np.asarray(proto).dtype) if hasattr(proto, "dtype") \
            else arr
        restored.append(jax.device_put(arr, sh) if sh is not None
                        else jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored), step


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    every: int = 50
    keep: int = 3

    def maybe_save(self, step: int, tree: Tree) -> Optional[str]:
        if step % self.every != 0:
            return None
        path = save_checkpoint(self.directory, step, tree)
        self._gc()
        return path

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)
