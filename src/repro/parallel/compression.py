"""Gradient compression for cross-pod (DCN) all-reduce: int8 quantization
with error feedback.

At 512+ chips the pod-axis gradient all-reduce crosses DCN, which is an order
of magnitude slower than ICI. Quantizing gradients to int8 with per-tensor
scale cuts those bytes 4x (vs f32 accumulation) / 2x (vs bf16); the residual
(quantization error) is fed back into the next step's gradient so the scheme
is unbiased in the long run (error-feedback SGD compresses safely).

Used by launch/train.py when `compress_grads=True`; the dry-run shows the
collective-byte reduction in §Perf.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Tree = Any


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)).astype(jnp.float32) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127
                 ).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def compress_tree(grads: Tree, residual: Tree) -> Tuple[Tree, Tree, Tree]:
    """Returns (quantized tree, scales tree, new residual tree)."""
    def one(g, r):
        gf = g.astype(jnp.float32) + r
        q, s = quantize_int8(gf)
        deq = dequantize_int8(q, s)
        return q, s, gf - deq

    out = jax.tree.map(one, grads, residual)
    q = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    s = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return q, s, res


def zero_residual(params: Tree) -> Tree:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def psum_compressed(grads: Tree, residual: Tree, axis_name: str
                    ) -> Tuple[Tree, Tree]:
    """int8 psum over `axis_name` with error feedback (shard_map contexts)."""
    q, s, res = compress_tree(grads, residual)
    # Sum int8 payloads in int32 (the collective moves int8 bytes), then
    # rescale by the max participating scale (conservative, unbiased w/ EF).
    def allreduce(qi, si):
        tot = jax.lax.psum(qi.astype(jnp.int32), axis_name)
        smax = jax.lax.pmax(si, axis_name)
        return (tot.astype(jnp.float32) * smax)

    summed = jax.tree.map(allreduce, q, s)
    return summed, res
