"""Distribution substrate: logical-axis sharding rules, mesh utilities,
collective helpers, gradient compression."""

from repro.parallel.sharding import (LogicalRules, default_rules, spec_for,
                                     tree_specs, shardings_for, constrain)
