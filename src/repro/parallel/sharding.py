"""Logical-axis sharding resolver (MaxText-style logical axis rules).

Every parameter/activation carries a tuple of *logical* dim names (its "axes
tree", built in parallel with the params tree at init). A rule table maps
each logical name to an ordered list of mesh-axis candidates; the resolver
assigns the first candidate whose size divides the dimension and whose mesh
axes are not already used by another dim of the same tensor. This gives:

  * automatic fallbacks (e.g. heads -> head_dim tensor parallelism when the
    head count does not divide the model axis — minicpm's 36 heads on a
    16-way axis),
  * per-experiment overrides (the §Perf hillclimb swaps rule tables, not
    model code),
  * safe behaviour on any mesh (axes absent from the mesh are skipped).
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

# A rule: logical name -> ordered candidates; each candidate is a tuple of
# mesh axes used together on that dim (e.g. ("pod", "data") for global batch).
LogicalRules = Dict[str, List[Tuple[str, ...]]]


def dp_heavy_rules() -> LogicalRules:
    """Fully-sharded data parallelism (ZeRO-3 style) for archs whose head
    counts do not divide the model axis (minicpm 36H, qwen 40H, llava 56H,
    gemma3 4H): the batch spreads over data x model (with graceful fallback
    when the per-step batch is smaller), weights shard over ('data','model')
    on their embed dim and are all-gathered at use. Attention runs fully
    batch-parallel — no replicated compute, no contraction-dim psums."""
    return {
        "batch": [("pod", "data", "model"), ("data", "model"),
                  ("pod", "data"), ("data",)],
        # sequence parallelism: when the batch cannot cover data x model
        # (prefill B=32), activations shard their seq dim on the idle model
        # axis instead of replicating 16x (K/V gathered per layer).
        "seq": [("model",)],
        "kv_seq": [("model",)],
        "embed": [("data", "model"), ("data",)],
        "vocab": [("model",)],
        "heads": [],
        "head_dim": [],
        "kv_heads": [],
        "ff": [],
        "experts": [("model",)],
        "expert_ff": [],
        "state": [], "conv": [], "layers": [], "frames": [],
        "capacity": [("data",)], "moe_tokens": [("data",)],
        "vocab_embed": [],          # embed-table model dim: replicated
        "loss_batch": [("data", "model"), ("data",)],
        "cache_state": [("model",)],  # SSM decode state N dim
        "none": [],
    }


def rules_for(cfg, mesh, fsdp: bool = True) -> LogicalRules:
    """Pick the baseline rule table for an arch on this mesh.

    * heads AND kv_heads divide the model axis -> full TP (default rules).
    * only kv_heads indivisible (jamba/phi: Hq=64/32, Hkv=8 on a 16-way
      axis) -> the GQA (Hkv, G) reshape cannot stay sharded (measured:
      superquadratic GSPMD reshard blow-up), so attention runs
      batch-parallel while MLP/MoE keep model-axis TP.
    * heads indivisible (minicpm/qwen/llava/gemma3) -> fully-sharded DP.
    """
    model_size = dict(mesh.shape).get("model", 1)
    if cfg.n_heads and cfg.n_heads % model_size != 0:
        return dp_heavy_rules()
    if cfg.n_kv_heads and cfg.n_kv_heads % model_size != 0:
        rules = default_rules(fsdp)
        rules["heads"] = []
        rules["kv_heads"] = []
        rules["seq"] = [("model",)]   # sequence-parallel attention activations
        return rules
    return default_rules(fsdp)


def batch_dp_degree(rules: LogicalRules, mesh, global_batch: int) -> int:
    """Data-parallel degree the 'batch' rule will actually achieve for this
    global batch (first candidate whose size divides it)."""
    for cand in rules.get("batch", []):
        cand = tuple(a for a in cand if a in mesh.axis_names)
        if not cand:
            continue
        size = int(np.prod([dict(mesh.shape)[a] for a in cand]))
        if size and global_batch % size == 0:
            return size
    return 1


def default_rules(fsdp: bool = True) -> LogicalRules:
    """Baseline rule table: DP(+pod) on batch, TP on model, FSDP on embed."""
    return {
        "batch": [("pod", "data"), ("data",)],
        "seq": [],
        "kv_seq": [("model",)],          # decode caches: depth-shard fallback
        "embed": [("data",)] if fsdp else [],
        "vocab": [("model",)],
        "heads": [("model",)],
        # NOTE: no head_dim fallback by default — contraction-dim TP makes
        # every blocked-attention logits tile a cross-model psum (measured
        # ~128 s collective term on minicpm train_4k). Heads-indivisible
        # archs run attention batch-parallel with FSDP'd weights instead;
        # §Perf revisits with sequence-parallel attention.
        "head_dim": [],
        "kv_heads": [("model",)],
        "ff": [("model",)],
        "experts": [("model",)],
        "expert_ff": [],
        "state": [],
        "conv": [],
        "layers": [],
        "frames": [],
        "capacity": [("data",)],   # MoE (E,C,D) buffers: C over data
        "moe_tokens": [("data",)],
        "vocab_embed": [],         # embed-table model dim: replicated (small)
        "loss_batch": [("data",)], # CE logits: batch on data so vocab->model
        "cache_state": [("model",)],  # SSM decode state N dim
        "none": [],
    }


# Dims are assigned mesh axes in priority order, so e.g. `kv_heads` gets the
# model axis before the `kv_seq` fallback competes for it.
_PRIORITY = {
    "batch": 0, "loss_batch": 0, "experts": 1, "vocab": 1, "ff": 1,
    "heads": 1, "kv_heads": 1, "embed": 2, "head_dim": 3, "kv_seq": 4,
    "moe_tokens": 4,
}


def spec_for(axes: Sequence[Optional[str]], shape: Sequence[int],
             rules: LogicalRules, mesh: Mesh) -> PartitionSpec:
    """Resolve one tensor's PartitionSpec from its logical axes."""
    assert len(axes) == len(shape), (axes, shape)
    used: set = set()
    out: List = [None] * len(axes)
    order = sorted(range(len(axes)),
                   key=lambda i: _PRIORITY.get(axes[i] or "none", 9))
    for i in order:
        name, dim = axes[i], shape[i]
        for cand in rules.get(name or "none", []):
            cand = tuple(a for a in cand if a in mesh.axis_names)
            if not cand or any(a in used for a in cand):
                continue
            size = int(np.prod([mesh.shape[a] for a in cand]))
            if size > 0 and dim % size == 0:
                out[i] = cand if len(cand) > 1 else cand[0]
                used.update(cand)
                break
    return PartitionSpec(*out)


def tree_specs(axes_tree, params_tree, rules: LogicalRules, mesh: Mesh):
    """Map parallel (params, axes) trees -> tree of PartitionSpec."""
    return jax.tree.map(
        lambda a, p: spec_for(a, p.shape, rules, mesh),
        axes_tree, params_tree,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))


def shardings_for(axes_tree, params_tree, rules: LogicalRules, mesh: Mesh):
    specs = tree_specs(axes_tree, params_tree, rules, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def constrain(x, axes: Sequence[Optional[str]], rules: LogicalRules,
              mesh: Optional[Mesh]) -> jax.Array:
    """with_sharding_constraint by logical names (no-op without a mesh)."""
    if mesh is None or mesh.empty:
        return x
    spec = spec_for(axes, x.shape, rules, mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Activation-sharding context: model code calls constrain_act(x, axes) with
# logical names; the launcher installs (rules, mesh) before tracing. Without
# explicit constraints GSPMD may resolve the FSDP-weight/batch-activation
# conflict by REPLICATING activations across the data axis (measured: 16x
# activation blow-up on heads-indivisible archs). No-op when not installed
# (host tests / single device).
# ---------------------------------------------------------------------------

_ACT = {"rules": None, "mesh": None}


def set_activation_sharding(rules: Optional[LogicalRules],
                            mesh: Optional[Mesh]) -> None:
    _ACT["rules"], _ACT["mesh"] = rules, mesh


def constrain_act(x, axes: Sequence[Optional[str]]):
    rules, mesh = _ACT["rules"], _ACT["mesh"]
    if rules is None or mesh is None:
        return x
    if len(axes) != x.ndim:
        # shared layer code runs at several ranks (decode drops the seq dim);
        # constraints are best-effort hints — skip on rank mismatch.
        return x
    return constrain(x, axes, rules, mesh)
