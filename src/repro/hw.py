"""Target hardware constants (TPU v5e) + paper-cluster calibration numbers.

All roofline math reads from here so EXPERIMENTS.md, the dry-run driver and
the controller's profiler agree on one set of constants.
"""

# --- TPU v5e (the roofline target; container runs CPU) -----------------------
PEAK_FLOPS_BF16 = 197e12       # FLOP/s per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW_PER_LINK = 50e9         # bytes/s per link (~)
ICI_LINKS = 4                  # per chip on a 2D torus (v5e: 4 neighbours)
HBM_PER_CHIP = 16 * 1024**3    # bytes

# --- Meili paper cluster calibration (§8 methodology, Figs 2/9/15) -----------
# Per-core throughputs (Gbps) used by the testbed cost model; calibrated so
# single-pipeline app throughputs land in the ranges the paper reports
# (Fig 9: ~4-9 Gbps per pipeline; TO redirection 100 Gbps at 1500B per core).
NIC_LINK_GBPS = 100.0
TO_CORE_GBPS_1500B = 100.0
PKT_BYTES = 1500
