"""Meili core — the paper's primary contribution in JAX.

Programming model (graph, accel), scalable data plane (replication,
ringbuffer, orchestrator, executor, state_engine), unified control plane
(pool, allocation, profiler, controller), and a discrete-event timing
simulator (sim) used to validate the pipeline math without NIC hardware.
"""

from repro.core.replication import (num_replication, num_pipelines,
                                    pipeline_throughput, efficiency,
                                    full_replication)
from repro.core.allocation import resource_alloc, Allocation, commit, release
from repro.core.graph import (MeiliApp, PacketBatch, FlowBatch, Function,
                              make_packets, run_pipeline, PKT_BYTES)
from repro.core.pool import Pool, NicSpec, paper_cluster, tpu_pod_pool, CPU
from repro.core.controller import MeiliController, Deployment
from repro.core.orchestrator import TrafficOrchestrator
from repro.core.executor import ParallelDataPlane, PipelineRunner
from repro.core.state_engine import (StateService, bounded_sync,
                                     bounded_sync_deltas)
from repro.core.profiler import measure_app, synthetic_profile, AppProfile
from repro.core.qos import (ResourceGovernor, TenantQuota, ScaleVerdict,
                            quota_from_sla)
