"""Device-resident exact-match flow cache — the megaflow fast path (ISSUE 9).

The OVS hardware-offload split, reproduced for the Traffic Orchestrator: the
FIRST packet batch of a flow takes the slow path (the full §5.1.2 placement
decision in ``TrafficOrchestrator.partition_assign``) and every later batch
hits this exact-match table, so steady-state per-batch control cost is
O(cache misses), not O(unique flows).

Structure: an open-addressed fid -> (pipeline, epoch) table with bounded
probe windows (``kernels.flow_lookup`` holds the probe math and the three
lookup backends). The table is mirrored as device arrays: batch lookups run
as one jitted gather program (Pallas kernel on TPU), and host-side mutations
— inserts, refreshes, deletions — are streamed to the device as bucketed
scatter updates, so a pure-hit steady state moves nothing host->device.

Consistency is by *epoch*, not by scanning: any control-plane action that
can re-home flows (migration begin/finish, pipeline halt/add, failover)
bumps ``epoch``; a lookup whose entry carries an older epoch is reported as
a key match but NOT fresh, so the orchestrator revalidates that flow once
through the slow path and refreshes the entry in place. Eviction is
seeded-clock second chance: a hit sets the slot's reference bit; an insert
into a full window first spends reference bits, then evicts the oldest
stamp, with a seeded per-slot jitter breaking stamp ties — seeded so that
benchmark and test runs are bit-reproducible (see DESIGN.md).

Recency (``stamp``) doubles as the idle-expiry signal that bounds BOTH the
cache and the orchestrator's ``flow_table``/``spill_table`` dicts: entries
untouched for ``idle_ttl`` assignment rounds expire, and the orchestrator
prunes table entries whose cache stamp has gone cold (a month of flow churn
cannot OOM the control plane). The cache stores only each flow's HOME
pipeline; capacity validation against the live pipeline set happens per
batch in the orchestrator, which is why entries stay correct across
capacity changes without invalidation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flow_lookup as fl


def _pow2(n: int) -> int:
    return 1 << max(4, int(n - 1).bit_length())


def default_backend() -> str:
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


@dataclasses.dataclass(frozen=True)
class FlowCacheConfig:
    capacity: int = 1 << 17        # slots (rounded up to a power of two)
    window: int = 8                # bounded probe window (slots per key)
    idle_ttl: int = 4096           # rounds before an untouched entry expires
    expire_every: int = 256        # rounds between idle-expiry sweeps
    backend: Optional[str] = None  # numpy | jnp | pallas | interpret
    block_f: int = 512             # pallas query block
    seed: int = 0                  # clock-eviction tie-break seed
    enabled: bool = True           # False: recency ledger only, no fast path


class FlowCache:
    """fid -> (home pipeline, epoch) with recency stamps and clock bits."""

    def __init__(self, config: Optional[FlowCacheConfig] = None, **kw):
        self.cfg = config or FlowCacheConfig(**kw)
        cap = _pow2(self.cfg.capacity)
        self.capacity = cap
        self.window = int(self.cfg.window)
        assert self.window <= cap
        self.backend = self.cfg.backend or default_backend()
        self.epoch = 0
        # Host-authoritative planes. pid < 0 == empty slot.
        self.key_lo = np.zeros(cap, np.uint32)
        self.key_hi = np.zeros(cap, np.uint32)
        self.pid = np.full(cap, -1, np.int32)
        self.ep = np.zeros(cap, np.int32)
        self.stamp = np.zeros(cap, np.int64)     # last-touch round
        self.ref = np.zeros(cap, np.uint8)       # second-chance bit
        # Seeded tie-break for clock eviction among equal stamps.
        self._tie = np.random.default_rng(self.cfg.seed).random(cap)
        # Device mirror of the lookup planes (key_lo/key_hi/pid/ep). Host
        # mutations accumulate in _pending (slot indices) and are flushed as
        # one bucketed scatter before the next device lookup; stamps/refs
        # never leave the host (the kernel does not read them).
        self._planes: Optional[Tuple] = None
        self._pending: list = []
        self._full_upload = True
        self.stats: Dict[str, int] = {
            "hits": 0, "misses": 0, "evictions": 0, "invalidations": 0,
            "expirations": 0, "inserts": 0, "refreshes": 0, "fallbacks": 0,
            "lookups": 0, "uploads": 0, "scatter_updates": 0,
        }

    # -- epoch ----------------------------------------------------------------
    def invalidate(self, reason: str = "") -> None:
        """Bump the epoch: every cached entry becomes stale at once (O(1));
        each flow revalidates through the slow path on its next appearance."""
        self.epoch += 1
        self.stats["invalidations"] += 1

    # -- lookup ----------------------------------------------------------------
    def lookup(self, fids: np.ndarray
               ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Batch probe. Returns (slot, pid, fresh): ``slot`` is the table
        slot holding the key at ANY epoch (-1 absent) — the in-place refresh
        handle; ``pid``/``fresh`` report an epoch-current hit."""
        fids = np.asarray(fids, np.int64)
        self.stats["lookups"] += int(fids.size)
        lo, hi = fl.split_fids(fids)
        if self.backend == "numpy" or fids.size == 0:
            return fl.lookup_numpy(self.key_lo, self.key_hi, self.pid,
                                   self.ep, lo, hi, self.epoch, self.window)
        planes = self._device_planes()
        F = fids.size
        Fp = _pow2(F)
        if Fp != F:
            lo = np.concatenate([lo, np.zeros(Fp - F, np.uint32)])
            hi = np.concatenate([hi, np.zeros(Fp - F, np.uint32)])
        qlo, qhi = jnp.asarray(lo), jnp.asarray(hi)
        if self.backend in ("pallas", "interpret"):
            bf = min(self.cfg.block_f, Fp)
            slot, pid, fresh = fl.lookup_pallas(
                *planes, qlo, qhi, self.epoch, window=self.window,
                block_f=bf, interpret=(self.backend == "interpret"))
        else:
            slot, pid, fresh = fl.lookup_jnp(*planes, qlo, qhi, self.epoch,
                                             window=self.window)
        return (np.asarray(slot)[:F].astype(np.int64),
                np.asarray(pid)[:F], np.asarray(fresh)[:F])

    def _device_planes(self) -> Tuple:
        if self._planes is None or self._full_upload:
            self._planes = (jnp.asarray(self.key_lo), jnp.asarray(self.key_hi),
                            jnp.asarray(self.pid), jnp.asarray(self.ep))
            self._full_upload = False
            self._pending.clear()
            self.stats["uploads"] += 1
        elif self._pending:
            slots = np.unique(np.concatenate(self._pending))
            n = slots.size
            npad = _pow2(n)
            pad = np.full(npad - n, self.capacity, np.int64)  # dropped
            s = np.concatenate([slots, pad])
            safe = np.concatenate([slots, np.zeros(npad - n, np.int64)])
            self._planes = fl.apply_updates(
                self._planes, s, self.key_lo[safe], self.key_hi[safe],
                self.pid[safe], self.ep[safe])
            self._pending.clear()
            self.stats["scatter_updates"] += 1
        return self._planes

    def _mark(self, slots: np.ndarray) -> None:
        if slots.size:
            if len(self._pending) > 64:          # coalesce long mutation runs
                self._pending = [np.unique(np.concatenate(self._pending))]
            self._pending.append(np.asarray(slots, np.int64))

    # -- mutation --------------------------------------------------------------
    def touch(self, slots: np.ndarray, round_: int) -> None:
        """LRU touch on assignment: hits refresh recency + reference bit.
        Host-only state — no device traffic in a pure-hit steady state."""
        slots = np.asarray(slots, np.int64)
        slots = slots[slots >= 0]
        if slots.size:
            self.stamp[slots] = round_
            self.ref[slots] = 1

    def refresh(self, slots: np.ndarray, pids: np.ndarray,
                round_: int) -> None:
        """Revalidate matched-but-stale entries in place (post epoch bump)."""
        slots = np.asarray(slots, np.int64)
        keep = slots >= 0
        slots, pids = slots[keep], np.asarray(pids, np.int32)[keep]
        if not slots.size:
            return
        self.pid[slots] = pids
        self.ep[slots] = self.epoch
        self.stamp[slots] = round_
        self.ref[slots] = 1
        self.stats["refreshes"] += int(slots.size)
        self._mark(slots)

    def insert(self, fids: np.ndarray, pids: np.ndarray, round_: int) -> None:
        """Insert new keys (callers pass keys ``lookup`` reported absent).

        Vectorized first-empty-slot placement; keys whose chosen slot
        conflicts (two new keys, one empty slot) or whose window is full
        fall back to the per-key clock-eviction path."""
        fids = np.asarray(fids, np.int64)
        pids = np.asarray(pids, np.int32)
        if not fids.size:
            return
        lo, hi = fl.split_fids(fids)
        mask = np.uint32(self.capacity - 1)
        base = fl.bucket_hash(lo, hi) & mask
        win = ((base[:, None] + np.arange(self.window, dtype=np.uint32))
               & mask).astype(np.int64)                       # (n, W)
        empty = self.pid[win] < 0
        has_empty = empty.any(axis=1)
        choice = win[np.arange(win.shape[0]), empty.argmax(axis=1)]
        # First claimant per slot wins the vector path; the rest loop.
        _, first_idx = np.unique(choice, return_index=True)
        ok = np.zeros(fids.size, bool)
        ok[first_idx] = True
        ok &= has_empty
        tgt = choice[ok]
        self.key_lo[tgt] = lo[ok]
        self.key_hi[tgt] = hi[ok]
        self.pid[tgt] = pids[ok]
        self.ep[tgt] = self.epoch
        self.stamp[tgt] = round_
        self.ref[tgt] = 1
        self.stats["inserts"] += int(tgt.size)
        self._mark(tgt)
        for i in np.nonzero(~ok)[0]:
            self._insert_one(int(win[i][0]), win[i], lo[i], hi[i],
                             int(pids[i]), round_)

    def _insert_one(self, _base: int, win: np.ndarray, lo: np.uint32,
                    hi: np.uint32, pid: int, round_: int) -> None:
        empty = np.nonzero(self.pid[win] < 0)[0]
        if empty.size:
            slot = int(win[empty[0]])
        else:
            # Seeded-clock second chance: referenced entries spend their bit
            # and survive this round; the victim is the oldest unreferenced
            # stamp (seeded jitter breaks ties deterministically).
            cand = np.nonzero(self.ref[win] == 0)[0]
            if cand.size == 0:
                self.ref[win] = 0                 # clock hand sweeps the window
                cand = np.arange(win.size)
            w = win[cand]
            victim = cand[np.lexsort((self._tie[w], self.stamp[w]))[0]]
            slot = int(win[victim])
            self.stats["evictions"] += 1
        self.key_lo[slot] = lo
        self.key_hi[slot] = hi
        self.pid[slot] = pid
        self.ep[slot] = self.epoch
        self.stamp[slot] = round_
        self.ref[slot] = 1
        self.stats["inserts"] += 1
        self._mark(np.array([slot], np.int64))

    def record(self, fids: np.ndarray, pids: np.ndarray, round_: int) -> None:
        """Post-slow-path bookkeeping: touch/refresh present keys, insert
        absent ones — one numpy probe, O(misses) insert work."""
        fids = np.asarray(fids, np.int64)
        if not fids.size:
            return
        pids = np.asarray(pids, np.int32)
        lo, hi = fl.split_fids(fids)
        slot, _, fresh = fl.lookup_numpy(self.key_lo, self.key_hi, self.pid,
                                         self.ep, lo, hi, self.epoch,
                                         self.window)
        present = slot >= 0
        stale = present & ~fresh
        self.touch(slot[present], round_)
        # Present entries are refreshed when stale OR re-homed (pid drift
        # without an epoch bump cannot happen for cached assignments, but
        # the slow path is authoritative — mirror whatever it decided).
        moved = present & (self.pid[np.where(present, slot, 0)] != pids)
        upd = stale | moved
        if upd.any():
            self.refresh(slot[upd], pids[upd], round_)
        absent = ~present
        if absent.any():
            self.insert(fids[absent], pids[absent], round_)

    def delete(self, fids: np.ndarray) -> int:
        """Drop entries for ``fids`` (used by table pruning so the cache
        never resurrects a flow the orchestrator forgot)."""
        fids = np.asarray(fids, np.int64)
        if not fids.size:
            return 0
        lo, hi = fl.split_fids(fids)
        slot, _, _ = fl.lookup_numpy(self.key_lo, self.key_hi, self.pid,
                                     self.ep, lo, hi, self.epoch, self.window)
        slots = slot[slot >= 0]
        if slots.size:
            self.pid[slots] = -1
            self._mark(slots)
        return int(slots.size)

    def expire_idle(self, round_: int) -> int:
        """Clear entries untouched for ``idle_ttl`` rounds (one vectorized
        sweep, amortized by ``expire_every``)."""
        ttl = self.cfg.idle_ttl
        old = np.nonzero((self.pid >= 0) & (self.stamp < round_ - ttl))[0]
        if old.size:
            self.pid[old] = -1
            self.stats["expirations"] += int(old.size)
            self._mark(old)
        return int(old.size)

    def prewarm(self, max_queries: int = 1 << 14,
                max_updates: int = 1 << 12) -> None:
        """Compile every pow-2 specialization the steady state can touch
        (query buckets up to ``max_queries``, scatter buckets up to
        ``max_updates``) so benchmark windows observe zero recompiles."""
        if self.backend == "numpy":
            return
        planes = self._device_planes()
        n = 16
        while n <= max_queries:
            self.lookup(np.zeros(n, np.int64))
            n <<= 1
        n = 16
        while n <= min(max_updates, self.capacity):
            # All-sentinel slots: dropped by the scatter, planes unchanged.
            s = np.full(n, self.capacity, np.int64)
            z = np.zeros(n, np.uint32)
            zi = np.zeros(n, np.int32)
            self._planes = fl.apply_updates(planes, s, z, z, zi, zi)
            planes = self._planes
            n <<= 1

    # -- introspection ---------------------------------------------------------
    def last_seen(self, fids: np.ndarray) -> np.ndarray:
        """Recency stamp per fid, -1 when the flow has no live entry."""
        fids = np.asarray(fids, np.int64)
        if not fids.size:
            return np.zeros(0, np.int64)
        lo, hi = fl.split_fids(fids)
        slot, _, _ = fl.lookup_numpy(self.key_lo, self.key_hi, self.pid,
                                     self.ep, lo, hi, self.epoch, self.window)
        return np.where(slot >= 0, self.stamp[np.where(slot >= 0, slot, 0)],
                        -1).astype(np.int64)

    def occupancy(self) -> int:
        return int((self.pid >= 0).sum())

    def stats_snapshot(self) -> Dict[str, int]:
        return dict(self.stats, occupancy=self.occupancy(), epoch=self.epoch)

    def check_device_mirror(self) -> bool:
        """Test hook: the device planes must equal the host planes after a
        flush (incremental scatters may not drift)."""
        if self.backend == "numpy" or self._planes is None:
            return True
        planes = self._device_planes()
        host = (self.key_lo, self.key_hi, self.pid, self.ep)
        return all(np.array_equal(np.asarray(d), h)
                   for d, h in zip(planes, host))
