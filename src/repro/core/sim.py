"""Discrete-event pipeline timing simulator (validates §5.1.1 / Fig 7-8).

Models each stage replica as a deterministic server with the stage's profiled
per-sequence latency; sequences flow through stages in order, each picking the
earliest-free replica. Used to (a) unit-test that Algorithm 1 eliminates
bubbles at the short stages, (b) reproduce the paper's Fig 7 end-to-end
latency ordering (t1 < t2 < t3 with full replication faster but far less
efficient), and (c) drive the resource-efficiency benchmarks without
SmartNIC hardware (DESIGN.md §7).

Inter-stage hand-offs may add a network hop penalty when the placement puts
consecutive stages on different NICs (paper Table 1: ~3-4 µs observed for the
distributed IPComp gateway; §8.5 measures ~4.5 µs round trips).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence, Tuple


@dataclasses.dataclass
class SimResult:
    makespan: float                 # time last sequence leaves the pipeline
    latencies: List[float]          # per-sequence end-to-end latency
    busy_time: Dict[str, float]     # stage -> total busy server-seconds
    replicas: Dict[str, int]

    @property
    def throughput(self) -> float:
        return len(self.latencies) / self.makespan if self.makespan else 0.0

    def utilization(self, latency: Dict[str, float]) -> float:
        """Resource-weighted mean replica utilization over the makespan."""
        total = sum(self.replicas.values()) * self.makespan
        used = sum(self.busy_time.values())
        return used / total if total else 0.0

    @property
    def avg_latency(self) -> float:
        return sum(self.latencies) / len(self.latencies)


def simulate(stages: Sequence[str], latency: Dict[str, float],
             R: Dict[str, int], num_seqs: int,
             arrival_interval: float = 0.0,
             hop_penalty: Dict[Tuple[str, str], float] | None = None) -> SimResult:
    """Run `num_seqs` sequences through the replicated pipeline.

    arrival_interval=0 models a saturating ingress (back-to-back arrivals);
    hop_penalty maps (stage_i, stage_{i+1}) -> added latency when the
    placement crosses NICs.
    """
    hop_penalty = hop_penalty or {}
    # Earliest-free time per replica, per stage.
    free: Dict[str, List[float]] = {s: [0.0] * R[s] for s in stages}
    busy: Dict[str, float] = {s: 0.0 for s in stages}
    starts: List[float] = [i * arrival_interval for i in range(num_seqs)]
    done: List[float] = []

    for i in range(num_seqs):
        t = starts[i]
        t0 = t
        prev: Optional[str] = None
        for s in stages:
            if prev is not None:
                t += hop_penalty.get((prev, s), 0.0)
            # earliest-free replica (replica list kept as a heap)
            heapq.heapify(free[s])
            ready = heapq.heappop(free[s])
            begin = max(t, ready)
            end = begin + latency[s]
            heapq.heappush(free[s], end)
            busy[s] += latency[s]
            t = end
            prev = s
        done.append(t - t0)
    makespan = max(starts[i] + done[i] for i in range(num_seqs))
    return SimResult(makespan=makespan, latencies=done, busy_time=busy,
                     replicas=dict(R))
