"""Per-application Traffic Orchestrator (paper §5.1.2, §5.2).

The TO runs on a host core (paper: one reserved ARM core per NIC) and manages
the application's replicated pipelines:

  * a **flow table** mapping flow-id -> pipeline-id plus per-pipeline load;
  * **flow-granular partitioning**: packets of an existing flow stick to its
    pipeline; a heavy flow spills to additional pipelines only once its
    current pipeline hits its per-round capacity; new flows go to the
    pipeline with the highest available capacity;
  * **sequence-numbered aggregation**: each sub-batch carries a unique
    sequence number; egress batches are reordered so the application observes
    the original packet order;
  * **lazy flow state migration** between pipelines during adaptive scaling.

Control decisions (dict lookups over ~128 flows) are host-side numpy —
exactly where they run in the paper; the data movement (gather/scatter of
packet tensors) is JAX.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import PacketBatch


def flow_ids(batch: PacketBatch) -> np.ndarray:
    """Stable per-packet flow id from the 5-tuple (host-side)."""
    ft = np.asarray(batch.five_tuple, dtype=np.int64)
    h = ft[:, 0] * 1000003 + ft[:, 1] * 10007 + ft[:, 2] * 101 + ft[:, 3] * 13 + ft[:, 4]
    return h


def take_batch(batch: PacketBatch, idx: jnp.ndarray) -> PacketBatch:
    """Gather a sub-batch (device-side data movement)."""
    return jax.tree.map(lambda a: a[idx], batch)


@dataclasses.dataclass
class SubBatch:
    """One partitioned unit: pipeline id, sequence number, original indices."""

    pid: int
    seq: int
    indices: np.ndarray          # positions in the source batch
    data: PacketBatch


@dataclasses.dataclass
class PipelineStatus:
    pid: int
    capacity: float              # packets per partition round
    load: float = 0.0            # packets assigned this round
    active: bool = True

    @property
    def available(self) -> float:
        return max(0.0, self.capacity - self.load) if self.active else 0.0


class TrafficOrchestrator:
    def __init__(self, num_pipelines: int, capacity_per_pipeline: float):
        self.pipelines: List[PipelineStatus] = [
            PipelineStatus(pid=i, capacity=capacity_per_pipeline)
            for i in range(num_pipelines)
        ]
        self.flow_table: Dict[int, int] = {}
        self.spill_table: Dict[int, List[int]] = {}         # heavy-flow extras
        self.halted_flows: Dict[int, List[SubBatch]] = {}   # migration buffers
        self._seq = 0

    # -- §5.1.2 traffic partitioning ------------------------------------------
    def partition(self, batch: PacketBatch) -> List[SubBatch]:
        """Split an ingress batch across pipelines, flow-granular."""
        fids = flow_ids(batch)
        B = len(fids)
        for p in self.pipelines:
            p.load = 0.0
        assign = np.full(B, -1, dtype=np.int64)

        order = np.arange(B)
        for i in order:
            f = int(fids[i])
            if f in self.halted_flows:
                assign[i] = -2  # buffered during migration
                continue
            pid = self.flow_table.get(f)
            if pid is not None and self.pipelines[pid].active and \
                    self.pipelines[pid].available >= 1.0:
                assign[i] = pid
                self.pipelines[pid].load += 1.0
                continue
            # Heavy flow already spilled: keep using its spill pipelines so
            # the flow touches as FEW pipelines as possible (§5.1.2).
            cand = None
            for spid in self.spill_table.get(f, ()):
                p = self.pipelines[spid]
                if p.active and p.available >= 1.0:
                    cand = p
                    break
            if cand is None:
                # New flow, saturated, or halted: the pipeline with the
                # highest available capacity (§5.2).
                cand = max((p for p in self.pipelines if p.active),
                           key=lambda p: p.available, default=None)
                if cand is None or cand.available < 1.0:
                    cand = max((p for p in self.pipelines if p.active),
                               key=lambda p: p.capacity)
                if pid is not None and cand.pid != pid:
                    self.spill_table.setdefault(f, []).append(cand.pid)
            assign[i] = cand.pid
            cand.load += 1.0
            if pid is None:
                self.flow_table[f] = cand.pid  # first pipeline stays "home"

        subs: List[SubBatch] = []
        for pid in range(len(self.pipelines)):
            idx = np.nonzero(assign == pid)[0]
            if idx.size == 0:
                continue
            subs.append(SubBatch(pid=pid, seq=self._seq,
                                 indices=idx,
                                 data=take_batch(batch, jnp.asarray(idx))))
            self._seq += 1
        # Buffer packets of halted (migrating) flows.
        hidx = np.nonzero(assign == -2)[0]
        if hidx.size:
            for f in set(int(x) for x in fids[hidx]):
                sel = hidx[fids[hidx] == f]
                self.halted_flows[f].append(
                    SubBatch(pid=-1, seq=self._seq, indices=sel,
                             data=take_batch(batch, jnp.asarray(sel))))
                self._seq += 1
        return subs

    # -- §5.1.2 aggregation -----------------------------------------------------
    @staticmethod
    def aggregate(subs: Sequence[SubBatch], total: int) -> PacketBatch:
        """Reorder processed sub-batches back to original packet order."""
        subs = sorted(subs, key=lambda s: s.seq)
        all_idx = np.concatenate([s.indices for s in subs])
        inv = np.empty(total, dtype=np.int64)
        if all_idx.size != total:
            raise ValueError(f"aggregate: {all_idx.size} packets != batch {total}")
        inv[all_idx] = np.arange(total)
        cat = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                           *[s.data for s in subs])
        return jax.tree.map(lambda a: a[jnp.asarray(inv)], cat)

    # -- §5.2 flow state migration ----------------------------------------------
    def begin_migration(self, flow: int) -> None:
        """Halt a flow: subsequent packets buffer in the TO's side ring."""
        self.halted_flows.setdefault(flow, [])

    def finish_migration(self, flow: int, dst_pid: int) -> List[SubBatch]:
        """Re-home the flow and release its buffered packets to dst."""
        self.flow_table[flow] = dst_pid
        buffered = self.halted_flows.pop(flow, [])
        for s in buffered:
            s.pid = dst_pid
        return buffered

    # -- adaptive scaling hooks (§6.1) -------------------------------------------
    def add_pipeline(self, capacity: float) -> int:
        pid = len(self.pipelines)
        self.pipelines.append(PipelineStatus(pid=pid, capacity=capacity))
        return pid

    def halt_pipeline(self, pid: int) -> List[int]:
        """Deactivate a pipeline; returns the flows that must migrate."""
        self.pipelines[pid].active = False
        return [f for f, p in self.flow_table.items() if p == pid]

    def utilization(self) -> Dict[int, float]:
        return {p.pid: (p.load / p.capacity if p.capacity else 0.0)
                for p in self.pipelines}
