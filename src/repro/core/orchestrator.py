"""Per-application Traffic Orchestrator (paper §5.1.2, §5.2).

The TO runs on a host core (paper: one reserved ARM core per NIC) and manages
the application's replicated pipelines:

  * a **flow table** mapping flow-id -> pipeline-id plus per-pipeline load;
  * **flow-granular partitioning**: packets of an existing flow stick to its
    pipeline; a heavy flow spills to additional pipelines only once its
    current pipeline hits its per-round capacity; new flows go to the
    pipeline with the highest available capacity;
  * **sequence-numbered aggregation**: each sub-batch carries a unique
    sequence number; egress batches are reordered so the application observes
    the original packet order;
  * **lazy flow state migration** between pipelines during adaptive scaling.

Control decisions are host-side numpy — exactly where they run in the paper
(the TO owns one reserved ARM core, so its work must stay cheap and must not
touch the device). The partitioner is **flow-granular and vectorized**:
decisions are made once per unique flow (~128 flows/round in the paper's
traffic, via ``np.unique``), never per packet, and the per-packet ``assign``
array is produced with numpy slice/scatter ops. Packets of the same flow are
allocated contiguously in arrival order: home pipeline first, then existing
spill pipelines, then highest-available — identical to walking the flow's
packets one at a time (the reference loop in ``tests/test_partition_vectorized``
checks this equivalence). Flows themselves are served in first-appearance
order (flow-major). That is a deliberate departure from a packet-interleaved
walk: under saturation the two can pick different spill victims, but
flow-major matches §5.1.2's granularity — the flow is the decision unit —
and gives each flow the fewest pipelines available at its turn. All data
movement (gather/scatter of packet tensors) stays JAX/device-side; see
``core.executor`` and ``DESIGN.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import PacketBatch

# Sentinel values in the per-packet assign array.
ASSIGN_NONE = -1      # not yet assigned (internal)
ASSIGN_HALTED = -2    # buffered behind a migrating flow

# Max per-flow ``slow_path_place`` trace events per batch (sampled; an
# aggregate ``flow_cache_batch`` event always carries the totals).
PLACE_TRACE_CAP = 32


def flow_ids(batch: PacketBatch) -> np.ndarray:
    """Stable per-packet flow id from the 5-tuple (host-side)."""
    ft = np.asarray(batch.five_tuple, dtype=np.int64)
    h = ft[:, 0] * 1000003 + ft[:, 1] * 10007 + ft[:, 2] * 101 + ft[:, 3] * 13 + ft[:, 4]
    return h


def take_batch(batch: PacketBatch, idx: jnp.ndarray) -> PacketBatch:
    """Gather a sub-batch (device-side data movement)."""
    return jax.tree.map(lambda a: a[idx], batch)


@dataclasses.dataclass
class SubBatch:
    """One partitioned unit: pipeline id, sequence number, original indices."""

    pid: int
    seq: int
    indices: np.ndarray          # positions in the source batch
    data: PacketBatch


@dataclasses.dataclass
class PipelineStatus:
    pid: int
    capacity: float              # packets per partition round
    load: float = 0.0            # packets assigned this round
    active: bool = True

    @property
    def available(self) -> float:
        return max(0.0, self.capacity - self.load) if self.active else 0.0


class TrafficOrchestrator:
    def __init__(self, num_pipelines: int, capacity_per_pipeline: float,
                 flow_cache=None, table_cap: int | None = None, trace=None):
        self.pipelines: List[PipelineStatus] = [
            PipelineStatus(pid=i, capacity=capacity_per_pipeline)
            for i in range(num_pipelines)
        ]
        self.flow_table: Dict[int, int] = {}
        self.spill_table: Dict[int, List[int]] = {}         # heavy-flow extras
        self.halted_flows: Dict[int, List[SubBatch]] = {}   # migration buffers
        self._seq = 0
        # Megaflow fast path (core.flowcache.FlowCache, or None = slow-only).
        # The cache is an accelerator, never an authority: a batch is served
        # from it only when the hits provably reproduce the slow path
        # (see _fast_assign), otherwise the whole batch falls back.
        self.flow_cache = flow_cache
        self.table_cap = table_cap            # bound on len(flow_table)
        self.trace = trace                    # obs.DecisionTrace or None
        self._round = 0                       # assignment rounds (LRU clock)
        self.fast_stats: Dict[str, int] = {
            "fast_batches": 0, "slow_batches": 0, "fallbacks": 0,
            "hit_flows": 0, "miss_flows": 0, "hit_pkts": 0, "miss_pkts": 0,
            "pruned": 0, "expired": 0,
        }

    # -- §5.1.2 traffic partitioning ------------------------------------------
    def partition_assign(self, batch: PacketBatch,
                         tenant: str | None = None) -> np.ndarray:
        """Vectorized flow-granular assignment for one ingress batch.

        Returns the per-packet ``assign`` array: pipeline id per packet, or
        ``ASSIGN_HALTED`` for packets of a migrating flow (those are gathered
        into the TO's side buffer before returning). Decisions are computed
        once per *flow*; per-packet work is numpy scatter only.

        With a ``flow_cache`` attached, flows with a fresh cache entry skip
        the decision loop entirely (megaflow fast path): one device lookup
        classifies the batch and only cache *misses* run the slow loop below.
        The fast path is byte-identical to the slow path — it validates that
        every cache hit would have been served fully by its home pipeline at
        its turn, and falls back to a pristine slow run otherwise.

        Per-flow allocation order (equals one-packet-at-a-time §5.1.2):
          1. the flow's home pipeline, while it has available capacity;
          2. the flow's existing spill pipelines, in spill order;
          3. repeatedly, the active pipeline with the highest available
             capacity (recorded as a new spill for a homed flow, or as the
             home for a new flow);
          4. if every active pipeline is saturated, the remainder overloads
             the highest-capacity active pipeline (load tracks the overload
             so ``utilization`` sees it).
        """
        fids = flow_ids(batch)
        B = len(fids)
        self._round += 1
        for p in self.pipelines:
            p.load = 0.0
        assign = np.full(B, ASSIGN_NONE, dtype=np.int64)
        if B == 0:
            return assign

        uniq, first_pos, inverse, counts = np.unique(
            fids, return_index=True, return_inverse=True, return_counts=True)

        cache = self.flow_cache
        done = False
        if cache is not None and cache.cfg.enabled:
            # The fast path groups only miss-flow packets itself, so the
            # full-batch argsort below is skipped on the hot path entirely.
            done = self._fast_assign(assign, uniq, first_pos, inverse,
                                     counts, tenant)
        if not done:
            by_flow = np.argsort(inverse, kind="stable")  # grouped, in order
            group_start = np.concatenate([[0], np.cumsum(counts)])
            if cache is not None:
                self.fast_stats["slow_batches"] += 1
            self._slow_assign(assign, uniq, first_pos, by_flow, group_start,
                              tenant)
            if cache is not None:
                self._record_slow(assign, uniq, by_flow, group_start)
        self._maintain()

        # Buffer packets of halted (migrating) flows (scan only the halted
        # subset, not the batch, once per flow).
        hidx = np.nonzero(assign == ASSIGN_HALTED)[0]
        if hidx.size:
            hfids = fids[hidx]
            for f in np.unique(hfids):
                sel = hidx[hfids == f]
                self.halted_flows[int(f)].append(
                    SubBatch(pid=-1, seq=self._seq, indices=sel,
                             data=take_batch(batch, jnp.asarray(sel))))
                self._seq += 1
        return assign

    def _slow_assign(self, assign: np.ndarray, uniq: np.ndarray,
                     first_pos: np.ndarray, by_flow: np.ndarray,
                     group_start: np.ndarray,
                     tenant: str | None = None) -> None:
        """The full §5.1.2 decision loop over every unique flow (in-place on
        ``assign``). This is the authority the fast path defers to."""
        npipe = len(self.pipelines)
        cap = np.array([p.capacity for p in self.pipelines], np.float64)
        active = np.array([p.active for p in self.pipelines], bool)
        avail = np.where(active, cap, 0.0)
        load = np.zeros(npipe, np.float64)
        traced = 0

        def grab(pid: int, seg: np.ndarray, off: int) -> int:
            """Assign as many of seg[off:] to pid as its capacity allows."""
            if avail[pid] < 1.0:
                return off
            take = min(seg.size - off, int(avail[pid]))
            assign[seg[off:off + take]] = pid
            avail[pid] -= take
            load[pid] += take
            return off + take

        # Flows in first-appearance order — the order the per-packet walk
        # would discover them.
        for u in np.argsort(first_pos, kind="stable"):
            f = int(uniq[u])
            seg = by_flow[group_start[u]:group_start[u + 1]]
            if f in self.halted_flows:
                assign[seg] = ASSIGN_HALTED
                continue
            # Raised lazily: a batch made entirely of halted-flow packets
            # must buffer cleanly even with every pipeline scaled down.
            if not active.any():
                raise ValueError("partition: no active pipelines")
            home = self.flow_table.get(f)
            was_new = home is None
            off = 0
            if home is not None and active[home]:
                off = grab(home, seg, off)
            if off < seg.size:
                for spid in self.spill_table.get(f, ()):
                    if active[spid]:
                        off = grab(spid, seg, off)
                    if off == seg.size:
                        break
            while off < seg.size:
                pid = int(np.argmax(np.where(active, avail, -1.0)))
                if avail[pid] >= 1.0:
                    off = grab(pid, seg, off)
                else:
                    # Every active pipeline saturated: overload the largest.
                    pid = int(np.argmax(np.where(active, cap, -1.0)))
                    assign[seg[off:]] = pid
                    load[pid] += seg.size - off
                    off = seg.size
                if home is None:
                    self.flow_table[f] = pid   # first pipeline stays "home"
                    home = pid
                elif pid != home:
                    sp = self.spill_table.setdefault(f, [])
                    if pid not in sp:
                        sp.append(pid)
            if was_new and self.trace is not None and traced < PLACE_TRACE_CAP:
                traced += 1
                self.trace.event("slow_path_place", tenant=tenant,
                                 flow=f, pipeline=int(home),
                                 reason="new_flow")

        for p, l in zip(self.pipelines, load):
            p.load = float(l)

    # -- megaflow fast path ------------------------------------------------------
    def _fast_assign(self, assign: np.ndarray, uniq: np.ndarray,
                     first_pos: np.ndarray, inverse: np.ndarray,
                     counts: np.ndarray,
                     tenant: str | None) -> bool:
        """Serve one batch from the flow cache; returns False to demand a
        pristine slow-path run instead (nothing committed in that case).

        A cache *hit* (fresh entry, live + active home pipeline, flow not
        halted) charges the flow's full packet count to its home. Misses run
        a position-exact replica of the slow loop: the availability each miss
        sees is ``cap − (hit charges from flows appearing earlier) − (grabs
        from earlier misses)``, which is what the interleaved slow walk would
        see *provided every hit was fully served by its home at its own turn*.
        That proviso is checked after the loop — for each pipeline, total
        non-overload grabs (hit + miss) must fit its capacity; if any hit
        could have spilled, the batch is re-run through `_slow_assign`
        untouched. Flow-table/spill mutations stage in pending dicts and
        commit only on success, so fallback is side-effect free.
        """
        cache = self.flow_cache
        npipe = len(self.pipelines)
        cap = np.array([p.capacity for p in self.pipelines], np.float64)
        active = np.array([p.active for p in self.pipelines], bool)
        F = uniq.size

        if self.halted_flows:
            hkeys = np.fromiter(self.halted_flows.keys(), np.int64,
                                len(self.halted_flows))
            halted = np.isin(uniq, hkeys)
        else:
            halted = np.zeros(F, bool)
        if not active.any():
            if (~halted).any():
                return False          # slow path raises the canonical error
            assign[:] = ASSIGN_HALTED
            self.fast_stats["fast_batches"] += 1
            return True

        slot, cpid, fresh = cache.lookup(uniq)
        in_range = (cpid >= 0) & (cpid < npipe)
        safe = np.where(in_range, cpid, 0)
        hit = fresh & in_range & active[safe] & ~halted
        miss = ~hit & ~halted
        hsel = np.nonzero(hit)[0]

        # Scatter hits + halted to packets in one gather; misses stay
        # ASSIGN_NONE until the loop below fills them.
        upid = np.full(F, np.int64(ASSIGN_NONE))
        upid[halted] = ASSIGN_HALTED
        upid[hit] = cpid[hit]
        assign[:] = upid[inverse]

        # Misses in first-appearance order — sort only the miss subset, not
        # every flow in the batch (first_pos values are distinct, so sorting
        # the subset equals filtering the full argsort).
        mu = np.flatnonzero(miss)              # miss flows, ascending uniq idx
        morder = mu[np.argsort(first_pos[mu], kind="stable")]
        M = morder.size
        mpos = first_pos[morder]

        # Per-flow packet segments for MISS flows only (the hot path never
        # argsorts the whole batch): gather miss packets, group by flow.
        psel = np.flatnonzero(miss[inverse])   # their packets, arrival order
        mseq = psel[np.argsort(inverse[psel], kind="stable")]
        mstart = np.concatenate([[0], np.cumsum(counts[mu])])
        mrank = np.searchsorted(mu, morder)    # uniq idx -> row in mstart

        # Hit charges bucketed by which miss they precede: a hit at position
        # h lands in bucket searchsorted(mpos, h) = number of misses before
        # it, so cumsum row k = every hit charge visible to miss k. Counts
        # are integral so the bincount sum is exact (no FP order effects).
        if hsel.size:
            interval = np.searchsorted(mpos, first_pos[hsel])
            seg_charge = np.bincount(
                interval * npipe + cpid[hsel],
                weights=counts[hsel].astype(np.float64),
                minlength=(M + 1) * npipe).reshape(M + 1, npipe)
        else:
            seg_charge = np.zeros((M + 1, npipe), np.float64)
        hit_prefix = np.cumsum(seg_charge, axis=0)
        hit_charge = hit_prefix[M]

        # The replica loop runs on native Python scalars (identical float64
        # arithmetic, ~3x less per-miss overhead than 8-wide numpy temps).
        # Python max() and np.argmax agree on ties: both keep the first max.
        cap_l = cap.tolist()
        active_l = active.tolist()
        hp_l = hit_prefix.tolist()
        taken_l = [0.0] * npipe
        over_l = [0.0] * npipe
        pend_home: Dict[int, int] = {}
        pend_spill: Dict[int, List[int]] = {}
        miss_homes = np.empty(M, np.int64)
        miss_clean = np.zeros(M, bool)         # cacheable: single-pipeline
        places: List = []                      # sampled trace tuples
        mfids = uniq[morder].tolist()
        mrank_l = mrank.tolist()
        ft_get = self.flow_table.get
        sp_get = self.spill_table.get
        pipe_rng = range(npipe)
        want_trace = self.trace is not None

        for k in range(M):
            f = mfids[k]
            r = mrank_l[k]
            seg = mseq[mstart[r]:mstart[r + 1]]
            nseg = seg.size
            hpk = hp_l[k]
            avail = [cap_l[i] - hpk[i] - taken_l[i] if active_l[i] else
                     -hpk[i] - taken_l[i] for i in pipe_rng]
            home = ft_get(f)
            was_new = home is None
            off = 0
            clean = True

            def grab(pid: int, off: int) -> int:
                a = avail[pid]
                if a < 1.0:
                    return off
                take = min(nseg - off, int(a))
                assign[seg[off:off + take]] = pid
                taken_l[pid] += take
                avail[pid] = a - take
                return off + take

            if home is not None and active_l[home]:
                off = grab(home, off)
            if off < nseg:
                for spid in sp_get(f, ()):
                    if active_l[spid]:
                        noff = grab(spid, off)
                        if noff != off:
                            clean = False
                            off = noff
                    if off == nseg:
                        break
            while off < nseg:
                pid = max(pipe_rng,
                          key=lambda i: avail[i] if active_l[i] else -1.0)
                if avail[pid] >= 1.0:
                    off = grab(pid, off)
                else:
                    pid = max(pipe_rng,
                              key=lambda i: cap_l[i] if active_l[i] else -1.0)
                    assign[seg[off:]] = pid
                    over_l[pid] += nseg - off
                    off = nseg
                if home is None:
                    pend_home[f] = pid
                    home = pid
                elif pid != home:
                    clean = False
                    sp = pend_spill.get(f)
                    if sp is None:
                        sp = pend_spill[f] = list(sp_get(f, ()))
                    if pid not in sp:
                        sp.append(pid)
            miss_homes[k] = home
            # Cache only flows served entirely by one pipeline (their home):
            # a heavy spiller must NOT become a hit — charging it all to home
            # would force a fallback every batch. Left uncached it stays a
            # miss and the replica loop spills it exactly like the slow path.
            # ``clean`` tracked inline == (assign[seg] == home).all(): every
            # packet lands via grab(home)/first-grab-of-a-new-flow unless a
            # spill/argmax/overload branch assigned some other pipeline.
            miss_clean[k] = clean
            if want_trace and len(places) < PLACE_TRACE_CAP:
                u = morder[k]
                if slot[u] < 0:
                    reason = "new_flow" if was_new else "cache_evicted"
                elif not fresh[u]:
                    reason = "stale_epoch"
                else:
                    reason = "inactive_home"
                places.append((f, int(home), reason))

        taken = np.array(taken_l, np.float64)
        over = np.array(over_l, np.float64)
        ok = bool(np.all(hit_charge + taken <= cap))
        if not ok:
            # Some hit would have spilled at its turn: the cached answer is
            # not the slow-path answer. Discard everything.
            assign[:] = ASSIGN_NONE
            self.fast_stats["fallbacks"] += 1
            cache.stats["fallbacks"] += 1
            if self.trace is not None:
                self.trace.event("fast_path_fallback", tenant=tenant,
                                 flows=int(F), hits=int(hsel.size),
                                 reason="hit_overcommit")
            return False

        self.flow_table.update(pend_home)
        for f, sp in pend_spill.items():
            self.spill_table[f] = sp
        load = hit_charge + taken + over
        for p, l in zip(self.pipelines, load):
            p.load = float(l)

        cache.touch(slot[hsel], self._round)
        if miss_clean.any():
            cache.record(uniq[morder[miss_clean]], miss_homes[miss_clean],
                         self._round)
        cache.stats["hits"] += int(hsel.size)
        cache.stats["misses"] += int(M)
        fs = self.fast_stats
        fs["fast_batches"] += 1
        fs["hit_flows"] += int(hsel.size)
        fs["miss_flows"] += int(M)
        fs["hit_pkts"] += int(counts[hsel].sum())
        fs["miss_pkts"] += int(counts[morder].sum())
        if self.trace is not None:
            for f, pid, reason in places:
                self.trace.event("slow_path_place", tenant=tenant, flow=f,
                                 pipeline=pid, reason=reason)
            self.trace.event("flow_cache_batch", tenant=tenant,
                             flows=int(F), hits=int(hsel.size),
                             misses=int(M), halted=int(halted.sum()))
        return True

    def _record_slow(self, assign: np.ndarray, uniq: np.ndarray,
                     by_flow: np.ndarray, group_start: np.ndarray) -> None:
        """Mirror slow-path decisions into the cache (cold/fallback batches).

        Only flows whose whole segment landed on a single pipeline — their
        home — are cached (same single-pipeline rule as the fast path:
        spillers must stay misses or they would poison every later batch
        with hit-overcommit fallbacks). One vectorized reduceat, no loop."""
        grouped = assign[by_flow]
        starts = group_start[:-1].astype(np.int64)
        mn = np.minimum.reduceat(grouped, starts)
        mx = np.maximum.reduceat(grouped, starts)
        uniform = (mn == mx) & (mn >= 0)
        if not uniform.any():
            return
        keys = uniq[uniform]
        homes = mn[uniform]
        tab = np.array([self.flow_table.get(int(f), -1) for f in keys],
                       np.int64)
        sel = tab == homes
        if sel.any():
            self.flow_cache.record(keys[sel], homes[sel], self._round)

    def _maintain(self) -> None:
        """Amortized state bounding: cache idle expiry every
        ``expire_every`` rounds; flow/spill-table pruning past ``table_cap``
        (coldest cache stamp first, halted flows always kept)."""
        cache = self.flow_cache
        if cache is None:
            return
        every = cache.cfg.expire_every
        if every > 0 and self._round % every == 0:
            self.fast_stats["expired"] += cache.expire_idle(self._round)
        if self.table_cap is not None and len(self.flow_table) > self.table_cap:
            self._prune_tables()

    def _prune_tables(self) -> None:
        cache = self.flow_cache
        keys = np.fromiter(self.flow_table.keys(), np.int64,
                           len(self.flow_table))
        seen = cache.last_seen(keys)    # -1 when evicted/expired from cache
        if self.halted_flows:
            hk = np.fromiter(self.halted_flows.keys(), np.int64,
                             len(self.halted_flows))
            seen[np.isin(keys, hk)] = np.iinfo(np.int64).max  # never pruned
        ndrop = len(self.flow_table) - self.table_cap
        order = np.argsort(seen, kind="stable")
        order = order[seen[order] < np.iinfo(np.int64).max][:ndrop]
        drop = keys[order]
        for f in drop.tolist():
            self.flow_table.pop(f, None)
            self.spill_table.pop(f, None)
        cache.delete(drop)
        self.fast_stats["pruned"] += int(drop.size)
        if self.trace is not None:
            self.trace.event("flow_table_prune", dropped=int(drop.size),
                             kept=len(self.flow_table))

    def partition(self, batch: PacketBatch) -> List[SubBatch]:
        """Split an ingress batch across pipelines, flow-granular.

        Compatibility view over :meth:`partition_assign`: materializes one
        SubBatch per non-empty pipeline (device gather per sub-batch). The
        fused data plane (``core.executor.ParallelDataPlane``) skips this and
        consumes the assign array directly.
        """
        assign = self.partition_assign(batch)
        subs: List[SubBatch] = []
        for pid in range(len(self.pipelines)):
            idx = np.nonzero(assign == pid)[0]
            if idx.size == 0:
                continue
            subs.append(SubBatch(pid=pid, seq=self._seq,
                                 indices=idx,
                                 data=take_batch(batch, jnp.asarray(idx))))
            self._seq += 1
        return subs

    # -- §5.1.2 aggregation -----------------------------------------------------
    @staticmethod
    def aggregate(subs: Sequence[SubBatch], total: int) -> PacketBatch:
        """Reorder processed sub-batches back to original packet order."""
        subs = sorted(subs, key=lambda s: s.seq)
        all_idx = np.concatenate([s.indices for s in subs])
        inv = np.empty(total, dtype=np.int64)
        if all_idx.size != total:
            raise ValueError(f"aggregate: {all_idx.size} packets != batch {total}")
        inv[all_idx] = np.arange(total)
        cat = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                           *[s.data for s in subs])
        return jax.tree.map(lambda a: a[jnp.asarray(inv)], cat)

    # -- §5.2 flow state migration ----------------------------------------------
    def _invalidate_cache(self, reason: str) -> None:
        if self.flow_cache is not None:
            self.flow_cache.invalidate(reason)

    def begin_migration(self, flow: int) -> None:
        """Halt a flow: subsequent packets buffer in the TO's side ring."""
        self.halted_flows.setdefault(flow, [])
        # The halted check masks cached entries already; the bump is the
        # §tentpole epoch discipline — O(1), no table scan.
        self._invalidate_cache("begin_migration")

    def finish_migration(self, flow: int, dst_pid: int) -> List[SubBatch]:
        """Re-home the flow and release its buffered packets to dst."""
        self.flow_table[flow] = dst_pid
        buffered = self.halted_flows.pop(flow, [])
        for s in buffered:
            s.pid = dst_pid
        # REQUIRED bump: the flow's cached home is now wrong; revalidation-
        # on-hit refreshes it (and everyone else) on next appearance.
        self._invalidate_cache("finish_migration")
        return buffered

    # -- adaptive scaling hooks (§6.1) -------------------------------------------
    def add_pipeline(self, capacity: float) -> int:
        # No epoch bump: existing homes stay valid, and hits never consult
        # the new pipeline (home-first semantics; see DESIGN.md).
        pid = len(self.pipelines)
        self.pipelines.append(PipelineStatus(pid=pid, capacity=capacity))
        return pid

    def halt_pipeline(self, pid: int) -> List[int]:
        """Deactivate a pipeline; returns the flows that must migrate."""
        self.pipelines[pid].active = False
        # Scale-down/failover bump. (The fast path's active[home] check
        # already rejects hits on a halted pipeline; the bump additionally
        # forces re-validation of everything placed under the old topology.)
        self._invalidate_cache("halt_pipeline")
        return [f for f, p in self.flow_table.items() if p == pid]

    def utilization(self) -> Dict[int, float]:
        return {p.pid: (p.load / p.capacity if p.capacity else 0.0)
                for p in self.pipelines}
