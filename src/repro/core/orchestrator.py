"""Per-application Traffic Orchestrator (paper §5.1.2, §5.2).

The TO runs on a host core (paper: one reserved ARM core per NIC) and manages
the application's replicated pipelines:

  * a **flow table** mapping flow-id -> pipeline-id plus per-pipeline load;
  * **flow-granular partitioning**: packets of an existing flow stick to its
    pipeline; a heavy flow spills to additional pipelines only once its
    current pipeline hits its per-round capacity; new flows go to the
    pipeline with the highest available capacity;
  * **sequence-numbered aggregation**: each sub-batch carries a unique
    sequence number; egress batches are reordered so the application observes
    the original packet order;
  * **lazy flow state migration** between pipelines during adaptive scaling.

Control decisions are host-side numpy — exactly where they run in the paper
(the TO owns one reserved ARM core, so its work must stay cheap and must not
touch the device). The partitioner is **flow-granular and vectorized**:
decisions are made once per unique flow (~128 flows/round in the paper's
traffic, via ``np.unique``), never per packet, and the per-packet ``assign``
array is produced with numpy slice/scatter ops. Packets of the same flow are
allocated contiguously in arrival order: home pipeline first, then existing
spill pipelines, then highest-available — identical to walking the flow's
packets one at a time (the reference loop in ``tests/test_partition_vectorized``
checks this equivalence). Flows themselves are served in first-appearance
order (flow-major). That is a deliberate departure from a packet-interleaved
walk: under saturation the two can pick different spill victims, but
flow-major matches §5.1.2's granularity — the flow is the decision unit —
and gives each flow the fewest pipelines available at its turn. All data
movement (gather/scatter of packet tensors) stays JAX/device-side; see
``core.executor`` and ``DESIGN.md``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import PacketBatch

# Sentinel values in the per-packet assign array.
ASSIGN_NONE = -1      # not yet assigned (internal)
ASSIGN_HALTED = -2    # buffered behind a migrating flow


def flow_ids(batch: PacketBatch) -> np.ndarray:
    """Stable per-packet flow id from the 5-tuple (host-side)."""
    ft = np.asarray(batch.five_tuple, dtype=np.int64)
    h = ft[:, 0] * 1000003 + ft[:, 1] * 10007 + ft[:, 2] * 101 + ft[:, 3] * 13 + ft[:, 4]
    return h


def take_batch(batch: PacketBatch, idx: jnp.ndarray) -> PacketBatch:
    """Gather a sub-batch (device-side data movement)."""
    return jax.tree.map(lambda a: a[idx], batch)


@dataclasses.dataclass
class SubBatch:
    """One partitioned unit: pipeline id, sequence number, original indices."""

    pid: int
    seq: int
    indices: np.ndarray          # positions in the source batch
    data: PacketBatch


@dataclasses.dataclass
class PipelineStatus:
    pid: int
    capacity: float              # packets per partition round
    load: float = 0.0            # packets assigned this round
    active: bool = True

    @property
    def available(self) -> float:
        return max(0.0, self.capacity - self.load) if self.active else 0.0


class TrafficOrchestrator:
    def __init__(self, num_pipelines: int, capacity_per_pipeline: float):
        self.pipelines: List[PipelineStatus] = [
            PipelineStatus(pid=i, capacity=capacity_per_pipeline)
            for i in range(num_pipelines)
        ]
        self.flow_table: Dict[int, int] = {}
        self.spill_table: Dict[int, List[int]] = {}         # heavy-flow extras
        self.halted_flows: Dict[int, List[SubBatch]] = {}   # migration buffers
        self._seq = 0

    # -- §5.1.2 traffic partitioning ------------------------------------------
    def partition_assign(self, batch: PacketBatch) -> np.ndarray:
        """Vectorized flow-granular assignment for one ingress batch.

        Returns the per-packet ``assign`` array: pipeline id per packet, or
        ``ASSIGN_HALTED`` for packets of a migrating flow (those are gathered
        into the TO's side buffer before returning). Decisions are computed
        once per *flow*; per-packet work is numpy scatter only.

        Per-flow allocation order (equals one-packet-at-a-time §5.1.2):
          1. the flow's home pipeline, while it has available capacity;
          2. the flow's existing spill pipelines, in spill order;
          3. repeatedly, the active pipeline with the highest available
             capacity (recorded as a new spill for a homed flow, or as the
             home for a new flow);
          4. if every active pipeline is saturated, the remainder overloads
             the highest-capacity active pipeline (load tracks the overload
             so ``utilization`` sees it).
        """
        fids = flow_ids(batch)
        B = len(fids)
        for p in self.pipelines:
            p.load = 0.0
        assign = np.full(B, ASSIGN_NONE, dtype=np.int64)
        if B == 0:
            return assign

        npipe = len(self.pipelines)
        cap = np.array([p.capacity for p in self.pipelines], np.float64)
        active = np.array([p.active for p in self.pipelines], bool)
        avail = np.where(active, cap, 0.0)
        load = np.zeros(npipe, np.float64)

        uniq, first_pos, inverse, counts = np.unique(
            fids, return_index=True, return_inverse=True, return_counts=True)
        by_flow = np.argsort(inverse, kind="stable")  # grouped, arrival order
        group_start = np.concatenate([[0], np.cumsum(counts)])

        def grab(pid: int, seg: np.ndarray, off: int) -> int:
            """Assign as many of seg[off:] to pid as its capacity allows."""
            if avail[pid] < 1.0:
                return off
            take = min(seg.size - off, int(avail[pid]))
            assign[seg[off:off + take]] = pid
            avail[pid] -= take
            load[pid] += take
            return off + take

        # Flows in first-appearance order — the order the per-packet walk
        # would discover them.
        for u in np.argsort(first_pos, kind="stable"):
            f = int(uniq[u])
            seg = by_flow[group_start[u]:group_start[u + 1]]
            if f in self.halted_flows:
                assign[seg] = ASSIGN_HALTED
                continue
            # Raised lazily: a batch made entirely of halted-flow packets
            # must buffer cleanly even with every pipeline scaled down.
            if not active.any():
                raise ValueError("partition: no active pipelines")
            home = self.flow_table.get(f)
            off = 0
            if home is not None and active[home]:
                off = grab(home, seg, off)
            if off < seg.size:
                for spid in self.spill_table.get(f, ()):
                    if active[spid]:
                        off = grab(spid, seg, off)
                    if off == seg.size:
                        break
            while off < seg.size:
                pid = int(np.argmax(np.where(active, avail, -1.0)))
                if avail[pid] >= 1.0:
                    off = grab(pid, seg, off)
                else:
                    # Every active pipeline saturated: overload the largest.
                    pid = int(np.argmax(np.where(active, cap, -1.0)))
                    assign[seg[off:]] = pid
                    load[pid] += seg.size - off
                    off = seg.size
                if home is None:
                    self.flow_table[f] = pid   # first pipeline stays "home"
                    home = pid
                elif pid != home:
                    sp = self.spill_table.setdefault(f, [])
                    if pid not in sp:
                        sp.append(pid)

        for p, l in zip(self.pipelines, load):
            p.load = float(l)

        # Buffer packets of halted (migrating) flows (scan only the halted
        # subset, not the batch, once per flow).
        hidx = np.nonzero(assign == ASSIGN_HALTED)[0]
        if hidx.size:
            hfids = fids[hidx]
            for f in np.unique(hfids):
                sel = hidx[hfids == f]
                self.halted_flows[int(f)].append(
                    SubBatch(pid=-1, seq=self._seq, indices=sel,
                             data=take_batch(batch, jnp.asarray(sel))))
                self._seq += 1
        return assign

    def partition(self, batch: PacketBatch) -> List[SubBatch]:
        """Split an ingress batch across pipelines, flow-granular.

        Compatibility view over :meth:`partition_assign`: materializes one
        SubBatch per non-empty pipeline (device gather per sub-batch). The
        fused data plane (``core.executor.ParallelDataPlane``) skips this and
        consumes the assign array directly.
        """
        assign = self.partition_assign(batch)
        subs: List[SubBatch] = []
        for pid in range(len(self.pipelines)):
            idx = np.nonzero(assign == pid)[0]
            if idx.size == 0:
                continue
            subs.append(SubBatch(pid=pid, seq=self._seq,
                                 indices=idx,
                                 data=take_batch(batch, jnp.asarray(idx))))
            self._seq += 1
        return subs

    # -- §5.1.2 aggregation -----------------------------------------------------
    @staticmethod
    def aggregate(subs: Sequence[SubBatch], total: int) -> PacketBatch:
        """Reorder processed sub-batches back to original packet order."""
        subs = sorted(subs, key=lambda s: s.seq)
        all_idx = np.concatenate([s.indices for s in subs])
        inv = np.empty(total, dtype=np.int64)
        if all_idx.size != total:
            raise ValueError(f"aggregate: {all_idx.size} packets != batch {total}")
        inv[all_idx] = np.arange(total)
        cat = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0),
                           *[s.data for s in subs])
        return jax.tree.map(lambda a: a[jnp.asarray(inv)], cat)

    # -- §5.2 flow state migration ----------------------------------------------
    def begin_migration(self, flow: int) -> None:
        """Halt a flow: subsequent packets buffer in the TO's side ring."""
        self.halted_flows.setdefault(flow, [])

    def finish_migration(self, flow: int, dst_pid: int) -> List[SubBatch]:
        """Re-home the flow and release its buffered packets to dst."""
        self.flow_table[flow] = dst_pid
        buffered = self.halted_flows.pop(flow, [])
        for s in buffered:
            s.pid = dst_pid
        return buffered

    # -- adaptive scaling hooks (§6.1) -------------------------------------------
    def add_pipeline(self, capacity: float) -> int:
        pid = len(self.pipelines)
        self.pipelines.append(PipelineStatus(pid=pid, capacity=capacity))
        return pid

    def halt_pipeline(self, pid: int) -> List[int]:
        """Deactivate a pipeline; returns the flows that must migrate."""
        self.pipelines[pid].active = False
        return [f for f, p in self.flow_table.items() if p == pid]

    def utilization(self) -> Dict[int, float]:
        return {p.pid: (p.load / p.capacity if p.capacity else 0.0)
                for p in self.pipelines}
