"""Application profiler (paper §6.1).

Meili decides single-pipeline performance by *offline profiling*: run each
CPU stage with one resource unit (1 core + 4 GB) and accelerator stages on
their engines, and record per-stage latency `l_s` / throughput `t_s` and
whole-pipeline `l_p` / `t_p`.

Two profiling backends:
  * ``measure``   — wall-clock the jitted stage on this host (used by the
                    runnable examples/benchmarks; the CPU here plays the role
                    of the NIC's ARM core);
  * ``cost_model``— roofline estimate from the stage's compiled
                    ``cost_analysis()`` against the target chip constants
                    (used for TPU-target planning in the dry-run, where
                    wall-clock on CPU would be meaningless).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax

from repro import hw
from repro.core.graph import MeiliApp, PacketBatch, apply_stage, stage_runner


@dataclasses.dataclass
class AppProfile:
    stages: list
    l_s: Dict[str, float]        # per-sequence(-batch) stage latency, seconds
    t_s: Dict[str, float]        # per-unit stage throughput, Gbps
    l_p: float                   # single-pipeline latency, seconds
    t_p: float                   # single-pipeline throughput, Gbps

    def batch_bits(self) -> float:
        return self._bits

    def __post_init__(self):
        self._bits = 0.0


def _time_call(fn: Callable, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def measure_app(app: MeiliApp, batch: PacketBatch, iters: int = 5) -> AppProfile:
    """Wall-clock profile of every stage with one resource unit.

    l_p is the end-to-end pipeline latency (sum of stage latencies — the
    minimum app latency reported to users, §6.1); t_p is the *streaming*
    single-pipeline throughput, set by the slowest stage.
    """
    bits = float(batch.length.sum()) * 8.0
    l_s: Dict[str, float] = {}
    cur = batch
    for fn in app.stages:
        runner = stage_runner(fn)
        l_s[fn.name] = _time_call(runner, cur, iters=iters)
        cur = runner(cur)
    l_p = sum(l_s.values())
    t_s = {n: bits / l / 1e9 for n, l in l_s.items()}
    t_p = bits / max(l_s.values()) / 1e9
    prof = AppProfile(stages=app.stage_names(), l_s=l_s, t_s=t_s, l_p=l_p, t_p=t_p)
    prof._bits = bits
    return prof


def cost_model_latency(fn: Callable, *args,
                       flops_rate: float = hw.PEAK_FLOPS_BF16,
                       mem_bw: float = hw.HBM_BW) -> float:
    """Roofline latency estimate of one jitted callable on the target chip."""
    lowered = jax.jit(fn).lower(*args)
    cost = lowered.compile().cost_analysis()
    flops = float(cost.get("flops", 0.0) or 0.0)
    nbytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    return max(flops / flops_rate, nbytes / mem_bw)


def synthetic_profile(stages, l_s: Dict[str, float], batch_bits: float) -> AppProfile:
    """Build a profile from known stage latencies (cost-model / paper tables)."""
    l_p = sum(l_s[s] for s in stages)
    t_s = {s: batch_bits / l_s[s] / 1e9 for s in stages}
    t_p = batch_bits / max(l_s[s] for s in stages) / 1e9
    prof = AppProfile(stages=list(stages), l_s=dict(l_s), t_s=t_s, l_p=l_p, t_p=t_p)
    prof._bits = batch_bits
    return prof
