"""Executors + the fused parallel data plane (paper §3, §5; ISSUE 1).

An Executor is the isolated runtime for one stage (paper: a container; here:
one jit-compiled program, shared process-wide by every replica of the stage).
A PipelineRunner chains executors; the ParallelDataPlane couples a
TrafficOrchestrator with N pipeline replicas and per-pipeline ring buffers,
implementing partition -> process -> aggregate.

Steady-state per-batch cost is ONE vectorized host pass (the TO's per-flow
partition, numpy) plus ONE cached fused device program that does everything
else:

  gather+pad packets into (N, M) lanes -> push/pop the persistent stacked
  ingress rings -> run the full stage chain once over all lanes -> gather
  the egress back to original packet order.

``M`` is the per-pipeline sub-batch slot count, padded up to a power-of-two
bucket so the set of compiled shapes stays small and bounded (recompiles are
counted in ``dispatch_stats`` — zero in steady state). Rings are allocated
once per data plane (one stacked device buffer for all N pipelines) instead
of per call. Aggregation is a single device-side gather with a
host-precomputed index, replacing the host concat + inverse-permutation of
the unfused design. See DESIGN.md ("Fused data plane").

Semantics contract (tested): ParallelDataPlane(app, R).process(batch) ==
graph.run_pipeline(app, batch) up to packet order — i.e. replication and
traffic partitioning never change application semantics. With migration
active, packets of halted flows are buffered by the TO and the processed
remainder is returned in original relative order.

That contract presumes UCFs are **per-packet (elementwise)**: splitting a
batch across pipeline replicas — fused or not — already changes which rows
a cross-row reduction would see, so a UCF that aggregates across its batch
has no well-defined parallel semantics. The fused dispatch additionally
runs the chain over all lanes at once, including pad slots whose content is
stale ring data; pad outputs are never referenced by the egress gather, but
a non-elementwise UCF would observe them. All paper apps (apps/nf.py) are
elementwise per the Table 2 paradigm ops.
"""
from __future__ import annotations

import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import (MeiliApp, PacketBatch, _cache_stats,
                              apply_stage, cache_put, chain_key,
                              chain_runner, stage_runner)
from repro.core.orchestrator import SubBatch, TrafficOrchestrator
from repro.core.ringbuffer import Ring, make_rings, pop_many, push_many
from repro.core import replication as repl

MIN_BUCKET = 16


def _bucket(n: int) -> int:
    """Round a sub-batch size up to the next power-of-two slot count."""
    return max(MIN_BUCKET, 1 << (max(1, n) - 1).bit_length())


class Executor:
    """One stage's runtime (compiled once, shared by all its replicas —
    replicas differ in placement/timing, not in program)."""

    def __init__(self, fn):
        self.fn = fn
        self.run = stage_runner(fn)          # process-wide cached program


class PipelineRunner:
    def __init__(self, app: MeiliApp):
        self.executors = [Executor(f) for f in app.stages]
        self._chain = chain_runner(app)      # one fused program per chain

    def process(self, batch: PacketBatch) -> PacketBatch:
        return self._chain(batch)


# One fused dispatch program per stage chain, shared by every data plane in
# the process (jax.jit caches per-shape specializations underneath).
_DISPATCH_PROGRAMS: Dict[Any, Callable] = {}


def _dispatch_program(app: MeiliApp) -> Callable:
    # NOTE: the "dispatch" hit/miss counters are NOT bumped here — this
    # lookup happens once per plane at construction. They are counted per
    # *call* in ParallelDataPlane.process(), where a miss means jax.jit
    # actually traced+compiled a fresh shape specialization (the event the
    # zero-steady-state-recompile invariant is about).
    key = chain_key(app)
    stats = _cache_stats("dispatch")
    prog = _DISPATCH_PROGRAMS.get(key)
    if prog is not None:
        return prog
    if prog is None:
        stages = tuple(app.stages)

        def dispatch(rings: Ring, batch: PacketBatch, perm: jnp.ndarray,
                     counts: jnp.ndarray, out_idx: jnp.ndarray
                     ) -> Tuple[Ring, PacketBatch]:
            # perm: (N, M) source index per lane slot; counts: (N,) valid
            # slots per lane; out_idx: (B,) flat lane*M+slot per egress row.
            stacked = jax.tree.map(lambda a: a[perm], batch)       # (N, M, ...)
            rings = push_many(rings, stacked, counts)              # ingress
            rings, rows, _valid = pop_many(rings, perm.shape[1])
            flat = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[2:]), rows)    # (N*M, ...)
            for fn in stages:
                flat = apply_stage(fn, flat)
            out = jax.tree.map(lambda a: a[out_idx], flat)         # egress
            return rings, out

        # Donate the ring: the caller replaces self._rings with the returned
        # one, so XLA may update the (lanes x cap x pkt) allocation in place
        # instead of copying it every batch.
        prog = cache_put(_DISPATCH_PROGRAMS, key,
                         jax.jit(dispatch, donate_argnums=(0,)),
                         stats=stats)
    return prog


class ParallelDataPlane:
    """N replicated pipelines + TO + persistent per-pipeline ring buffers."""

    def __init__(self, app: MeiliApp, num_pipelines: Optional[int] = None,
                 R: Optional[Dict[str, int]] = None,
                 latencies: Optional[Dict[str, float]] = None,
                 capacity_per_pipeline: float = 256.0,
                 ring_capacity: int = 4096,
                 metrics=None, profile: bool = False,
                 flow_cache: bool = True, flow_cache_config=None,
                 table_cap: Optional[int] = None, trace=None):
        if num_pipelines is None:
            if R is None:
                assert latencies is not None, "need num_pipelines, R or latencies"
                R = repl.num_replication(app.stage_names(), latencies)
            num_pipelines = repl.num_pipelines(R)
        self.app = app
        self.R = R
        # Megaflow fast path (ISSUE 9): classification served from the
        # device-resident exact-match cache; the TO's slow loop runs only on
        # misses. `flow_cache=False` restores the pure slow path (the bench
        # baseline arm); semantics are byte-identical either way.
        fc = None
        if flow_cache:
            from repro.core.flowcache import FlowCache, FlowCacheConfig
            fc = FlowCache(flow_cache_config or FlowCacheConfig())
        self.to = TrafficOrchestrator(num_pipelines, capacity_per_pipeline,
                                      flow_cache=fc, table_cap=table_cap,
                                      trace=trace)
        self._cache_metric_base: Dict[str, int] = {}
        self.pipelines = [PipelineRunner(app) for _ in range(num_pipelines)]
        self.ring_capacity = ring_capacity
        self._dispatch = _dispatch_program(app)
        self._rings: Optional[Ring] = None
        self._ring_cap = 0
        self._ring_lanes = 0
        self._ring_proto_key = None
        # compiles = real XLA specializations of the shared dispatch program,
        # read off jax.jit's own cache (shape-key proxy as fallback on jax
        # versions without _cache_size). Steady state must show zero growth.
        # by_tenant: per-tenant call/packet attribution when the caller (the
        # service runtime) tags batches with the submitting tenant.
        self._shape_keys: set = set()
        self.dispatch_stats: Dict[str, Any] = {
            "calls": 0, "compiles": 0, "by_tenant": {}}
        # Observability hooks (ISSUE 7): an optional MetricsRegistry sink for
        # call/compile counters, and a profile flag that times every fused
        # dispatch to completion (block_until_ready) into a histogram —
        # OFF by default because blocking serializes the device queue.
        self.metrics = metrics
        self.profile = profile

    def _tag_tenant(self, tenant: Optional[str], packets: int) -> None:
        if tenant is None:
            return
        per = self.dispatch_stats["by_tenant"].setdefault(
            tenant, {"calls": 0, "packets": 0})
        per["calls"] += 1
        per["packets"] += int(packets)

    def _jit_cache_size(self) -> Optional[int]:
        try:
            return self._dispatch._cache_size()
        except AttributeError:
            return None

    def _empty_result(self, batch: PacketBatch) -> PacketBatch:
        """A zero-packet batch with the same pytree structure a processed
        round returns (UCF-added meta keys included): the chain runs on a
        MIN_BUCKET dummy — not on zero rows, which some kernel impls reject —
        and the result is sliced empty."""
        dummy = jax.tree.map(
            lambda a: jnp.zeros((MIN_BUCKET,) + a.shape[1:], a.dtype), batch)
        return jax.tree.map(lambda a: a[:0], chain_runner(self.app)(dummy))

    # -- persistent stacked rings ---------------------------------------------
    def _ensure_rings(self, batch: PacketBatch, M: int) -> None:
        proto = jax.tree.map(lambda a: a[0], batch)
        proto_key = tuple((tuple(a.shape), str(a.dtype))
                          for a in jax.tree.leaves(proto))
        lanes = len(self.to.pipelines)
        if (self._rings is None or M > self._ring_cap
                or lanes != self._ring_lanes
                or proto_key != self._ring_proto_key):
            # Power-of-two cap: cursors are monotonic int32 indexed mod cap,
            # and slot indices survive the two's-complement wrap only when
            # cap divides 2^32.
            self._ring_cap = _bucket(max(self.ring_capacity, M))
            self._ring_lanes = lanes
            self._rings = make_rings(proto, self._ring_cap, lanes)
            self._ring_proto_key = proto_key

    def _sync_cache_metrics(self) -> None:
        """Publish flow-cache counter deltas into the metrics registry
        (counters only go up, so we ship increments from a local base)."""
        fc = self.to.flow_cache
        if fc is None or self.metrics is None:
            return
        snap = {"hits": fc.stats["hits"], "misses": fc.stats["misses"],
                "evictions": fc.stats["evictions"],
                "invalidations": fc.stats["invalidations"]}
        for k, v in snap.items():
            d = v - self._cache_metric_base.get(k, 0)
            if d > 0:
                self.metrics.counter(f"flow_cache_{k}_total",
                                     app=self.app.name).inc(d)
        self._cache_metric_base = snap

    def flow_cache_stats(self) -> Dict[str, Any]:
        """Fast-path counters for bench records: TO batch classification
        plus the cache's own stats (empty dict when the cache is off)."""
        fc = self.to.flow_cache
        if fc is None:
            return {}
        return dict(self.to.fast_stats, **fc.stats_snapshot())

    # -- partition -> fused dispatch -> aggregate ------------------------------
    def process(self, batch: PacketBatch,
                tenant: Optional[str] = None) -> PacketBatch:
        assign = self.to.partition_assign(batch, tenant=tenant)
        proc = np.nonzero(assign >= 0)[0]      # halted-flow packets buffered
        self._tag_tenant(tenant, proc.size)
        if proc.size == 0:
            return self._empty_result(batch)
        lanes_of = assign[proc]
        N = len(self.to.pipelines)
        counts = np.bincount(lanes_of, minlength=N).astype(np.int32)
        M = _bucket(int(counts.max()))

        # Host-side index algebra (numpy, O(B)): lane slot per packet and the
        # egress gather index that undoes the lane layout. Lane ids take only
        # N values, so a counting sort (one flatnonzero pass per lane) beats
        # a comparison argsort and is equally stable.
        order = np.concatenate(
            [np.flatnonzero(lanes_of == i) for i in range(N)])
        starts = np.concatenate([[0], np.cumsum(counts)[:-1]])
        lanes_sorted = lanes_of[order]
        ranks = np.arange(proc.size) - starts[lanes_sorted]
        perm = np.zeros((N, M), np.int32)      # pad slots gather row 0 (masked)
        perm[lanes_sorted, ranks] = proc[order]
        out_idx = np.empty(proc.size, np.int64)
        out_idx[order] = lanes_sorted * M + ranks

        # Every jit-facing shape is bucketed — M above, and here the ingress
        # batch and egress index — so variable-size traffic (B drifting round
        # to round) recompiles at most once per pow-2 bucket, not per size.
        B = batch.batch
        B_pad = _bucket(B)
        if B_pad != B:
            batch = jax.tree.map(
                lambda a: jnp.concatenate(
                    [a, jnp.zeros((B_pad - B,) + a.shape[1:], a.dtype)], 0),
                batch)
        P = proc.size
        P_pad = _bucket(P)
        if P_pad != P:
            out_idx = np.concatenate([out_idx, np.zeros(P_pad - P, np.int64)])

        self._ensure_rings(batch, M)
        self.dispatch_stats["calls"] += 1
        before = self._jit_cache_size()
        t0 = time.perf_counter() if self.profile else 0.0

        try:
            self._rings, out = self._dispatch(
                self._rings, batch, jnp.asarray(perm), jnp.asarray(counts),
                jnp.asarray(out_idx))
        except BaseException:
            # The ring was donated to the failed call and may already be
            # invalidated; drop it so the next round reallocates instead of
            # dying on deleted buffers forever.
            self._rings = None
            raise

        after = self._jit_cache_size()
        if after is not None:
            grew = after - before
            self.dispatch_stats["compiles"] += grew
            compiled = grew > 0
        else:                                 # proxy: predicted shape keys
            skey = (B_pad, P_pad, M, N, self._ring_cap, self._ring_proto_key)
            compiled = skey not in self._shape_keys
            if compiled:
                self._shape_keys.add(skey)
                self.dispatch_stats["compiles"] += 1
        # Process-wide compile-cache counters (ISSUE 7): one fused dispatch
        # call == one cache event. miss == jax.jit compiled a fresh shape
        # specialization; hit == warm reuse. Tests assert miss stays 0 after
        # warmup (zero steady-state recompiles, now an observable).
        dstats = _cache_stats("dispatch")
        dstats["miss" if compiled else "hit"] += 1
        if self.profile:
            jax.block_until_ready(out)
            us = (time.perf_counter() - t0) * 1e6
            if self.metrics is not None:
                self.metrics.histogram("dataplane_dispatch_us",
                                       app=self.app.name).observe(us)
        if self.metrics is not None:
            self._sync_cache_metrics()
            self.metrics.counter("dataplane_dispatch_calls_total",
                                 app=self.app.name).inc()
            if self.dispatch_stats["compiles"] > 0:
                self.metrics.gauge("dataplane_dispatch_compiles",
                                   app=self.app.name).set(
                                       self.dispatch_stats["compiles"])
        if P_pad != P:
            out = jax.tree.map(lambda a: a[:P], out)
        return out

    # -- per-stage device profiling (ISSUE 7) ----------------------------------
    def profile_stages(self, batch: PacketBatch,
                       iters: int = 1) -> Dict[str, float]:
        """Time each stage's jitted program to completion on ``batch`` and
        return mean µs per stage. Runs OUTSIDE the fused dispatch (stage
        programs are the same process-wide cached jits the unfused path
        uses), so a profile never perturbs steady-state compile counters of
        the fused program. Timings land in the attached registry as
        ``dataplane_stage_us{app=...,stage=...}`` histograms."""
        out: Dict[str, float] = {}
        cur = batch
        for fn in self.app.stages:
            run = stage_runner(fn)
            jax.block_until_ready(run(cur))          # warm: exclude compile
            t0 = time.perf_counter()
            for _ in range(max(1, iters)):
                nxt = run(cur)
                jax.block_until_ready(nxt)
            us = (time.perf_counter() - t0) * 1e6 / max(1, iters)
            out[fn.name] = us
            if self.metrics is not None:
                self.metrics.histogram("dataplane_stage_us",
                                       app=self.app.name,
                                       stage=fn.name).observe(us)
            cur = nxt
        return out

    # -- unfused reference path (kept as the dispatch-layer oracle) ------------
    def process_unfused(self, batch: PacketBatch,
                        tenant: Optional[str] = None) -> PacketBatch:
        """Per-sub-batch dispatch through PipelineRunner, then sequence-number
        aggregation — the pre-fusion data path, retained for A/B tests and
        benchmarks."""
        subs = self.to.partition(batch)
        self._tag_tenant(tenant, sum(s.indices.size for s in subs))
        if not subs:                       # empty batch or every flow halted
            return self._empty_result(batch)
        done: List[SubBatch] = []
        for sub in subs:
            out = self.pipelines[sub.pid].process(sub.data)
            done.append(SubBatch(pid=sub.pid, seq=sub.seq,
                                 indices=sub.indices, data=out))
        # With migration active the survivors are a subset of the batch:
        # remap original positions to ranks among survivors so aggregate
        # reorders within the processed subset.
        survivors = np.sort(np.concatenate([s.indices for s in done]))
        if survivors.size < batch.batch:
            done = [SubBatch(pid=s.pid, seq=s.seq,
                             indices=np.searchsorted(survivors, s.indices),
                             data=s.data) for s in done]
        return self.to.aggregate(done, total=survivors.size)
