"""Executors + the parallel data plane (paper §3, §5).

An Executor is the isolated runtime for one stage (paper: a container; here:
one jit-compiled program). A PipelineRunner chains executors; the
ParallelDataPlane couples a TrafficOrchestrator with N pipeline replicas and
per-pipeline ring buffers, implementing partition -> process -> aggregate.

Semantics contract (tested): ParallelDataPlane(app, R).process(batch) ==
graph.run_pipeline(app, batch) up to packet order — i.e. replication and
traffic partitioning never change application semantics.
"""
from __future__ import annotations

from typing import Dict, List, Optional

import jax
import numpy as np

from repro.core.graph import MeiliApp, PacketBatch, stage_runner
from repro.core.orchestrator import SubBatch, TrafficOrchestrator
from repro.core.ringbuffer import Ring, make_ring, pop, push
from repro.core import replication as repl


class Executor:
    """One stage's runtime (compiled once, shared by all its replicas —
    replicas differ in placement/timing, not in program)."""

    def __init__(self, fn):
        self.fn = fn
        self.run = stage_runner(fn)


class PipelineRunner:
    def __init__(self, app: MeiliApp):
        self.executors = [Executor(f) for f in app.stages]

    def process(self, batch: PacketBatch) -> PacketBatch:
        for ex in self.executors:
            batch = ex.run(batch)
        return batch


class ParallelDataPlane:
    """N replicated pipelines + TO + per-pipeline ring buffers."""

    def __init__(self, app: MeiliApp, num_pipelines: Optional[int] = None,
                 R: Optional[Dict[str, int]] = None,
                 latencies: Optional[Dict[str, float]] = None,
                 capacity_per_pipeline: float = 256.0,
                 ring_capacity: int = 4096):
        if num_pipelines is None:
            if R is None:
                assert latencies is not None, "need num_pipelines, R or latencies"
                R = repl.num_replication(app.stage_names(), latencies)
            num_pipelines = repl.num_pipelines(R)
        self.app = app
        self.R = R
        self.to = TrafficOrchestrator(num_pipelines, capacity_per_pipeline)
        self.pipelines = [PipelineRunner(app) for _ in range(num_pipelines)]
        self.ring_capacity = ring_capacity
        self._ingress: List[Optional[Ring]] = [None] * num_pipelines
        self._egress: List[Optional[Ring]] = [None] * num_pipelines

    def _rings_for(self, pid: int, proto: PacketBatch):
        if self._ingress[pid] is None:
            self._ingress[pid] = make_ring(jax.tree.map(lambda a: a[0], proto),
                                           self.ring_capacity)
        return self._ingress[pid]

    def process(self, batch: PacketBatch) -> PacketBatch:
        subs = self.to.partition(batch)
        done: List[SubBatch] = []
        for sub in subs:
            # ingress ring -> stage chain -> egress (rings are the hand-off
            # structure; on one host the pop is immediate).
            ring = make_ring(jax.tree.map(lambda a: a[0], sub.data),
                             max(self.ring_capacity, sub.data.batch))
            ring = push(ring, sub.data)
            ring, rows, valid = pop(ring, sub.data.batch)
            out = self.pipelines[sub.pid].process(rows)
            done.append(SubBatch(pid=sub.pid, seq=sub.seq, indices=sub.indices,
                                 data=out))
        return self.to.aggregate(done, total=batch.batch)
