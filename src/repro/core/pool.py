"""Resource-pool model: SmartNICs / TPU device groups as poolable resources.

The paper (§3, §6) manages a rack of heterogeneous SmartNICs as one pool.
Each NIC exposes: SoC cores ("resource units"), domain-specific accelerators
(regex / crypto / compression), and link bandwidth. On TPU, a "NIC" maps to a
*device group* (a mesh neighborhood) whose "accelerators" are Pallas-kernel
capabilities; see DESIGN.md §2. The pool abstraction is shared by both.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterable, List, Optional

# Tolerance for floating-point bandwidth bookkeeping. The ledger invariant
# (free + held == capacity, per NIC) is enforced to this epsilon; anything
# larger is an accounting bug, not rounding.
BW_EPS = 1e-6

# Resource type for CPU-like general cores (paper: ARM A72 "resource units").
CPU = "cpu"

# Accelerator kinds that appear in the paper's cluster.
REGEX = "regex"
CRYPTO = "crypto"          # paper: AES accelerator (Pensando)
COMPRESSION = "compression"
# TPU-side capabilities (beyond-paper tenants).
ATTENTION = "attention"
SSD = "ssd"


@dataclasses.dataclass
class NicSpec:
    """Static description of one pool member (SmartNIC or device group)."""

    name: str
    kind: str                       # e.g. "bf2", "bf1", "pensando", "tpu-v5e-group"
    cores: int                      # resource units
    accelerators: Dict[str, int]    # accel kind -> count
    bandwidth_gbps: float           # NIC link bandwidth (TPU: ICI egress of the group)
    core_mem_gb: float = 4.0        # paper: 1 core + 4 GB = one resource unit
    rack: str = "rack0"             # failure domain: one rack outage takes
                                    # every member down together (chaos layer)

    def has(self, resource: str) -> bool:
        if resource == CPU:
            return self.cores > 0
        return self.accelerators.get(resource, 0) > 0

    def capacity(self, resource: str) -> int:
        if resource == CPU:
            return self.cores
        return self.accelerators.get(resource, 0)


@dataclasses.dataclass
class NicState:
    """Mutable, controller-tracked view of one pool member (CA-synced, §3)."""

    spec: NicSpec
    free: Dict[str, int] = dataclasses.field(default_factory=dict)
    free_bw_gbps: float = 0.0
    alive: bool = True
    # Gray failure: the NIC silently delivers only this fraction of its
    # compute/bandwidth. Deliberately invisible to the allocator — `free`,
    # `take`, `give` are unchanged — so placement math stays oblivious while
    # achieved throughput (service/telemetry) degrades. Detection must come
    # from observed behavior, never from reading this field (the runtime's
    # suspicion scorer treats it as ground truth it cannot see).
    gray_frac: float = 1.0

    def __post_init__(self) -> None:
        if not self.free:
            self.free = {CPU: self.spec.cores, **dict(self.spec.accelerators)}
        if not self.free_bw_gbps:
            self.free_bw_gbps = self.spec.bandwidth_gbps

    def available(self, resource: str) -> int:
        return self.free.get(resource, 0) if self.alive else 0

    def take(self, resource: str, n: int) -> None:
        have = self.free.get(resource, 0)
        if n > have:
            raise ValueError(f"{self.spec.name}: cannot take {n} {resource}, only {have} free")
        self.free[resource] = have - n

    def give(self, resource: str, n: int) -> None:
        have = self.free.get(resource, 0)
        cap = self.spec.capacity(resource)
        if have + n > cap:
            raise ValueError(
                f"{self.spec.name}: over-credit of {resource}: "
                f"{have}+{n} exceeds capacity {cap}")
        self.free[resource] = have + n

    # -- strict bandwidth ledger (no clamp masking; raise on violation) --------
    def take_bw(self, gbps: float) -> None:
        """Charge link bandwidth. Raises if the charge exceeds what is free —
        a caller committing an allocation computed against stale pool state."""
        if gbps <= 0.0:
            return
        if gbps > self.free_bw_gbps + BW_EPS:
            raise ValueError(
                f"{self.spec.name}: cannot take {gbps:.6f} Gbps, only "
                f"{self.free_bw_gbps:.6f} free (ledger drift?)")
        self.free_bw_gbps = max(0.0, self.free_bw_gbps - gbps)

    def give_bw(self, gbps: float) -> None:
        """Credit link bandwidth back. Raises if the credit would push free
        bandwidth above the link capacity — an over-credit that the old
        ``min(.., cap)`` clamp used to silently mask."""
        if gbps <= 0.0:
            return
        cap = self.spec.bandwidth_gbps
        if self.free_bw_gbps + gbps > cap + BW_EPS:
            raise ValueError(
                f"{self.spec.name}: bandwidth over-credit: "
                f"{self.free_bw_gbps:.6f}+{gbps:.6f} exceeds link {cap} Gbps")
        self.free_bw_gbps = min(cap, self.free_bw_gbps + gbps)


class Pool:
    """The cluster-wide SmartNIC/device-group pool (one per rack, paper §3)."""

    def __init__(self, nics: List[NicSpec]):
        self.nics: Dict[str, NicState] = {s.name: NicState(spec=s) for s in nics}
        # Per-tenant usage ledger (resource kind -> units currently held),
        # maintained by the controller after every allocation mutation
        # (deploy / scale / failover / terminate). It is attribution only:
        # `free` above stays the single source of truth for capacity.
        self.usage: Dict[str, Dict[str, int]] = {}
        # Per-tenant quota rows beside the usage ledger (ISSUE 4): what each
        # tenant is *entitled* to, written by the ResourceGovernor when a
        # quota is declared. Attribution/reporting only — enforcement lives
        # in the governor's verdicts, never down here in the pool.
        self.quota: Dict[str, Dict[str, float]] = {}

    def names(self) -> List[str]:
        return [n for n, st in self.nics.items() if st.alive]

    def __getitem__(self, name: str) -> NicState:
        return self.nics[name]

    def mark_failed(self, name: str) -> None:
        self.nics[name].alive = False

    def revive(self, name: str) -> None:
        """Bring a NIC back. A revive models a repair/replacement, so any
        gray degradation is healed too — a revived NIC is a healthy NIC."""
        st = self.nics[name]
        st.alive = True
        st.gray_frac = 1.0

    # -- failure domains + gray degradation (chaos layer) ---------------------
    def rack_members(self, rack: str) -> List[str]:
        """Every pool member in one failure domain, alive or not."""
        return [n for n, st in self.nics.items() if st.spec.rack == rack]

    def mark_gray(self, name: str, fraction: float) -> None:
        """Silently degrade a NIC to ``fraction`` of its performance. The
        allocator keeps seeing full capacity — that is the point of a gray
        failure — only the achieved-throughput model reads the factor."""
        if not 0.0 < fraction <= 1.0:
            raise ValueError(f"gray fraction must be in (0, 1], got {fraction}")
        self.nics[name].gray_frac = fraction

    def clear_gray(self, name: str) -> None:
        self.nics[name].gray_frac = 1.0

    def capacity_frac(self, nics: Iterable[str]) -> float:
        """Effective capacity factor of a placement spanning ``nics``: the
        worst gray factor among them (stages chain through every member, so
        one sick NIC bottlenecks the whole pipeline)."""
        fr = [self.nics[n].gray_frac for n in nics if self.nics[n].alive]
        return min(fr) if fr else 1.0

    def total(self, resource: str) -> int:
        return sum(st.spec.capacity(resource) for st in self.nics.values() if st.alive)

    def free_total(self, resource: str) -> int:
        return sum(st.available(resource) for st in self.nics.values() if st.alive)

    def utilization(self, resource: str) -> float:
        tot = self.total(resource)
        if tot == 0:
            return 0.0
        return 1.0 - self.free_total(resource) / tot

    # -- per-tenant usage attribution (service runtime, ISSUE 2) --------------
    def set_usage(self, tenant: str, usage: Dict[str, int]) -> None:
        """Overwrite one tenant's attributed usage (controller resync)."""
        usage = {r: int(n) for r, n in usage.items() if n > 0}
        if usage:
            self.usage[tenant] = usage
        else:
            self.usage.pop(tenant, None)

    def clear_usage(self, tenant: str) -> None:
        self.usage.pop(tenant, None)

    # -- per-tenant quota rows (QoS governor, ISSUE 4) ------------------------
    def set_quota(self, tenant: str, max_units: Optional[int] = None,
                  max_gbps: Optional[float] = None,
                  weight: float = 1.0) -> None:
        """Record one tenant's entitlement beside its usage row."""
        row: Dict[str, float] = {"weight": float(weight)}
        if max_units is not None:
            row["max_units"] = float(max_units)
        if max_gbps is not None:
            row["max_gbps"] = float(max_gbps)
        self.quota[tenant] = row

    def clear_quota(self, tenant: str) -> None:
        self.quota.pop(tenant, None)

    def quota_row(self, tenant: str) -> Dict[str, float]:
        return dict(self.quota.get(tenant, {}))

    def reserved_units(self, tenant: Optional[str] = None) -> int:
        """Attributed units held by one tenant (or all tenants combined),
        counting every resource kind — a core and an accelerator engine are
        each one 'resource unit' in the paper's efficiency accounting."""
        if tenant is not None:
            return sum(self.usage.get(tenant, {}).values())
        return sum(sum(u.values()) for u in self.usage.values())

    def usage_snapshot(self) -> Dict[str, Dict[str, int]]:
        return {t: dict(u) for t, u in self.usage.items()}

    # -- ledger invariants -----------------------------------------------------
    def check_ledger(self,
                     unit_holdings: Iterable[Dict[str, Dict[str, int]]] = (),
                     bw_charges: Iterable[Dict[str, float]] = (),
                     strict: bool = True) -> List[str]:
        """Verify pool truth against the holders' view of what they own.

        ``unit_holdings``: per-holder nic -> kind -> units currently held.
        ``bw_charges``:   per-holder nic -> net Gbps currently charged.

        Invariant, per NIC and resource kind:  free + Σ held == capacity, and
        free bandwidth + Σ charges == link bandwidth (within BW_EPS). Dead
        NICs are checked too — failover must return the lost ledger entries
        so a revived NIC comes back clean. Returns the list of violations
        (raises instead when ``strict``).
        """
        held_units: Dict[str, Dict[str, int]] = {}
        for holding in unit_holdings:
            for nic, kinds in holding.items():
                row = held_units.setdefault(nic, {})
                for k, u in kinds.items():
                    row[k] = row.get(k, 0) + u
        held_bw: Dict[str, float] = {}
        for charge in bw_charges:
            for nic, g in charge.items():
                held_bw[nic] = held_bw.get(nic, 0.0) + g

        problems: List[str] = []
        for name, st in self.nics.items():
            kinds = set(st.free) | set(held_units.get(name, {}))
            for k in kinds:
                free = st.free.get(k, 0)
                held = held_units.get(name, {}).get(k, 0)
                cap = st.spec.capacity(k)
                if free < 0 or free + held != cap:
                    problems.append(
                        f"{name}/{k}: free {free} + held {held} != cap {cap}")
            bw_free = st.free_bw_gbps
            bw_held = held_bw.get(name, 0.0)
            bw_cap = st.spec.bandwidth_gbps
            if bw_free < -BW_EPS or abs(bw_free + bw_held - bw_cap) > 1e-3:
                problems.append(
                    f"{name}/bw: free {bw_free:.6f} + held {bw_held:.6f}"
                    f" != link {bw_cap}")
        if strict and problems:
            raise AssertionError("pool ledger drift: " + "; ".join(problems))
        return problems

    def snapshot(self) -> Dict[str, Dict[str, float]]:
        """Controller-agent status sync (paper §3: CA <-> Meili Controller)."""
        out = {}
        for name, st in self.nics.items():
            out[name] = {"alive": st.alive, "free_bw_gbps": st.free_bw_gbps, **st.free}
        return out


def paper_cluster(n_bf2: int = 8, n_bf1: int = 4, n_pensando: int = 4,
                  bw_gbps: float = 100.0, racks: int = 4) -> Pool:
    """The paper's evaluation cluster (§8 Methodology).

    8x BlueField-2 (8 ARM cores, regex + compression), 4x BlueField-1
    (16 cores, no accelerators), 4x Pensando (16 cores, AES + compression),
    all with 100 GbE links. One core per NIC is reserved for the TO
    (paper §8.1), so the usable core counts are 7/15/15.

    NICs are spread over ``racks`` failure domains, each kind in contiguous
    blocks, so every rack holds a slice of every NIC class — a rack outage
    removes a proportional cut of each resource kind, never a whole kind.
    """
    racks = max(1, racks)

    def rack_of(i: int, n: int) -> str:
        return f"rack{i * racks // max(1, n)}"

    nics: List[NicSpec] = []
    for i in range(n_bf2):
        nics.append(NicSpec(f"bf2-{i}", "bf2", cores=7,
                            accelerators={REGEX: 1, COMPRESSION: 1},
                            bandwidth_gbps=bw_gbps, rack=rack_of(i, n_bf2)))
    for i in range(n_bf1):
        nics.append(NicSpec(f"bf1-{i}", "bf1", cores=15, accelerators={},
                            bandwidth_gbps=bw_gbps, rack=rack_of(i, n_bf1)))
    for i in range(n_pensando):
        nics.append(NicSpec(f"pensando-{i}", "pensando", cores=15,
                            accelerators={CRYPTO: 1, COMPRESSION: 1},
                            bandwidth_gbps=bw_gbps,
                            rack=rack_of(i, n_pensando)))
    return Pool(nics)


def tpu_pod_pool(groups: int = 16, chips_per_group: int = 16,
                 ici_gbps_per_group: float = 4 * 50 * 8) -> Pool:
    """A TPU v5e pod viewed as a Meili pool: each mesh row = one device group.

    Chips stand in for "cores"; every group exposes the kernel capabilities
    (attention / ssd / regex / crypto / compression run as Pallas programs).
    Group egress bandwidth = 4 ICI links x 50 GB/s, expressed in Gbps.
    """
    nics = [
        NicSpec(
            f"group-{i}", "tpu-v5e-group", cores=chips_per_group,
            accelerators={ATTENTION: chips_per_group, SSD: chips_per_group,
                          REGEX: chips_per_group, CRYPTO: chips_per_group,
                          COMPRESSION: chips_per_group},
            bandwidth_gbps=ici_gbps_per_group,
            rack=f"rack{i * 4 // max(1, groups)}",
        )
        for i in range(groups)
    ]
    return Pool(nics)
