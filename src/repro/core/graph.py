"""Meili programming model (paper §4): functions, packet/socket paradigms.

Applications are chains/DAGs of fine-grained *functions*; each function is a
user-customized callback (UCF) over one of two base abstractions:

  * ``PacketBatch``  — the ``Meili_packet`` analog, batched for TPU: headers
    (5-tuple), payload bytes, lengths, a liveness mask (pkt_flt drops), and a
    per-packet metadata dict that UCFs may read/compute/extend.
  * ``FlowBatch``    — the ``Meili_flow`` analog: connection descriptor plus
    per-connection metadata.

Paradigm operations (Table 2): pkt_trans / pkt_flt / flow_ext / flow_trans
for packet processing; reg_sock / epoll for socket processing (modeled as
event batches); Accelerator Function APIs (regex / AES / compression / ...)
are provided by ``core.accel`` and appear as ordinary stages with a non-CPU
resource kind, which is exactly what Algorithm 2 needs for placement.

UCFs must be JAX-traceable; each stage compiles to one jitted program (the
Executor). Stage granularity is the unit of replication (Algorithm 1) and
placement (Algorithm 2).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core.pool import CPU

PKT_BYTES = 1500  # paper: 1500B packet buffers (§5.1.2, §8 methodology)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class PacketBatch:
    """Batched Meili_packet: (B,) packets processed as one sequence batch."""

    payload: jnp.ndarray                 # (B, PKT_BYTES) uint8
    length: jnp.ndarray                  # (B,) int32 valid payload bytes
    five_tuple: jnp.ndarray              # (B, 5) int32: sip dip sport dport proto
    mask: jnp.ndarray                    # (B,) bool — False once dropped
    meta: Dict[str, jnp.ndarray]         # per-packet metadata (UCF-computed)

    @property
    def batch(self) -> int:
        return self.payload.shape[0]

    def with_meta(self, **kv: jnp.ndarray) -> "PacketBatch":
        return dataclasses.replace(self, meta={**self.meta, **kv})


def make_packets(payload: jnp.ndarray, length: jnp.ndarray,
                 five_tuple: jnp.ndarray) -> PacketBatch:
    b = payload.shape[0]
    return PacketBatch(payload=payload.astype(jnp.uint8),
                       length=length.astype(jnp.int32),
                       five_tuple=five_tuple.astype(jnp.int32),
                       mask=jnp.ones((b,), jnp.bool_), meta={})


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FlowBatch:
    """Batched Meili_flow: per-connection descriptor + metadata."""

    five_tuple: jnp.ndarray              # (F, 5) int32
    meta: Dict[str, jnp.ndarray]

    @property
    def flows(self) -> int:
        return self.five_tuple.shape[0]


@dataclasses.dataclass(frozen=True)
class Function:
    """One pipeline stage: a named UCF plus its resource kind."""

    name: str
    kind: str                            # pkt_trans|pkt_flt|flow_ext|flow_trans|accel|socket
    ucf: Callable[..., Any]
    resource: str = CPU                  # CPU or accelerator kind (pool.REGEX, ...)
    params: Dict[str, Any] = dataclasses.field(default_factory=dict)


class MeiliApp:
    """Application = ordered chain of Functions (Listing 1 style).

    The paper describes a DAG; its algorithms (1, 2) and all six evaluation
    apps use linear chains, so the chain is the first-class form here.
    """

    def __init__(self, name: str):
        self.name = name
        self.stages: List[Function] = []
        self.state_decls: Dict[str, dict] = {}

    # -- packet paradigm ------------------------------------------------------
    def pkt_trans(self, ucf: Callable[[PacketBatch], PacketBatch],
                  name: Optional[str] = None) -> "MeiliApp":
        self.stages.append(Function(name or ucf.__name__, "pkt_trans", ucf))
        return self

    def pkt_flt(self, ucf: Callable[[PacketBatch], jnp.ndarray],
                name: Optional[str] = None) -> "MeiliApp":
        """UCF returns a keep-mask (B,) bool; dropped packets stay masked out."""
        self.stages.append(Function(name or ucf.__name__, "pkt_flt", ucf))
        return self

    def flow_ext(self, ucf: Callable[[PacketBatch], jnp.ndarray], window: int,
                 slide: int, name: Optional[str] = None) -> "MeiliApp":
        """UCF maps packets -> flow keys; packets pass through unmodified."""
        self.stages.append(Function(name or ucf.__name__, "flow_ext", ucf,
                                    params={"window": window, "slide": slide}))
        return self

    def flow_trans(self, ucf: Callable[[PacketBatch, FlowBatch], FlowBatch],
                   name: Optional[str] = None) -> "MeiliApp":
        self.stages.append(Function(name or ucf.__name__, "flow_trans", ucf))
        return self

    # -- accelerator stages (core.accel supplies the UCF) ----------------------
    def accel(self, fn: Function) -> "MeiliApp":
        self.stages.append(fn)
        return self

    # -- socket paradigm (event-batch model; see DESIGN.md §2) -----------------
    def reg_sock(self, name: str = "reg_sock") -> "MeiliApp":
        self.stages.append(Function(name, "socket", lambda b: b))
        return self

    def epoll(self, ucf: Callable[[PacketBatch], PacketBatch], event: str = "EPOLLIN",
              name: Optional[str] = None) -> "MeiliApp":
        self.stages.append(Function(name or ucf.__name__, "socket", ucf,
                                    params={"event": event}))
        return self

    # -- state declarations (wired to core.state_engine at deploy) -------------
    def declare_state(self, name: str, pattern: str, shape=(), dtype=jnp.int32):
        assert pattern in ("non-external-write", "full-access")
        self.state_decls[name] = dict(pattern=pattern, shape=shape, dtype=dtype)
        return self

    # -- introspection ----------------------------------------------------------
    def stage_names(self) -> List[str]:
        return [f.name for f in self.stages]

    def resource_needs(self) -> Dict[str, str]:
        return {f.name: f.resource for f in self.stages}


def apply_stage(fn: Function, batch: PacketBatch) -> PacketBatch:
    """Execute one stage on a batch (the Executor's inner body)."""
    if fn.kind == "pkt_trans" or fn.kind == "socket" or fn.kind == "accel":
        out = fn.ucf(batch)
        return out if isinstance(out, PacketBatch) else batch
    if fn.kind == "pkt_flt":
        keep = fn.ucf(batch)
        return dataclasses.replace(batch, mask=batch.mask & keep)
    if fn.kind == "flow_ext":
        keys = fn.ucf(batch)
        return batch.with_meta(flow_key=keys)
    if fn.kind == "flow_trans":
        # Flow view derived on the fly; UCF updates flow metadata which is
        # scattered back to per-packet meta by key.
        flows = FlowBatch(five_tuple=batch.five_tuple, meta=dict(batch.meta))
        out = fn.ucf(batch, flows)
        return batch.with_meta(**out.meta)
    raise ValueError(f"unknown stage kind {fn.kind}")


def run_pipeline(app: MeiliApp, batch: PacketBatch) -> PacketBatch:
    """Reference single-pipeline execution (no replication) — the semantic
    oracle against which the parallel data plane is tested."""
    for fn in app.stages:
        batch = apply_stage(fn, batch)
    return batch


# -- process-wide compiled-program caches -------------------------------------
#
# Replicas differ in placement/timing, never in program: N pipeline replicas
# of one app must share ONE compiled program per stage (and one per chain),
# or deployment cost scales O(N x stages) in compiles. Programs are cached
# process-wide, keyed on stage *identity* — the (kind, ucf, params) triple
# that fully determines the traced computation. MeiliApp instances built
# from the same Function objects (e.g. every PipelineRunner replica of one
# app) hit the same entry. App factories create fresh UCF closures per call,
# so distinct constructions of "the same" app key separately — the caches
# are therefore bounded (FIFO eviction; holders keep their own reference, an
# evicted entry only costs a re-jit for future lookups) so long-running
# services that construct apps repeatedly don't grow memory without bound.

_CACHE_CAP = 256

# Process-wide compile-cache accounting (ISSUE 7): every lookup against the
# program caches below (and the executor's fused dispatch cache, which
# registers itself under "dispatch") is counted as a hit or a miss, and
# every FIFO eviction as an evict. A miss == one jax.jit trace+compile, so
# "zero steady-state recompiles" is now an observable counter the tier-1
# suite asserts on, not a docstring claim.
COMPILE_CACHE_STATS: Dict[str, Dict[str, int]] = {}


def _cache_stats(cache_name: str) -> Dict[str, int]:
    return COMPILE_CACHE_STATS.setdefault(
        cache_name, {"hit": 0, "miss": 0, "evict": 0})


def compile_cache_stats() -> Dict[str, Dict[str, int]]:
    """A snapshot copy of the per-cache hit/miss/evict counters."""
    return {k: dict(v) for k, v in COMPILE_CACHE_STATS.items()}


def reset_compile_cache_stats() -> None:
    for stats in COMPILE_CACHE_STATS.values():
        for k in stats:
            stats[k] = 0


def cache_put(cache: Dict, key, value, cap: int = _CACHE_CAP,
              stats: Optional[Dict[str, int]] = None):
    """Insert into a bounded process-wide program cache (FIFO eviction)."""
    if len(cache) >= cap:
        cache.pop(next(iter(cache)))
        if stats is not None:
            stats["evict"] += 1
    cache[key] = value
    return value


_STAGE_RUNNERS: Dict[Any, Callable] = {}
_CHAIN_RUNNERS: Dict[Any, Callable] = {}


def _stage_key(fn: Function):
    try:
        params = tuple(sorted(fn.params.items()))
        hash(params)
    except TypeError:
        params = id(fn.params)            # unhashable params: identity key
    return (fn.kind, fn.ucf, params)


def chain_key(app: "MeiliApp"):
    """Identity of an app's full stage chain (the fused-program cache key)."""
    return tuple(_stage_key(f) for f in app.stages)


def stage_runner(fn: Function) -> Callable[[PacketBatch], PacketBatch]:
    """A jit-compiled single-stage program (one Executor), cached
    process-wide by stage identity."""
    key = _stage_key(fn)
    stats = _cache_stats("stage")
    runner = _STAGE_RUNNERS.get(key)
    if runner is None:
        stats["miss"] += 1
        runner = cache_put(_STAGE_RUNNERS, key,
                           jax.jit(lambda b: apply_stage(fn, b)),
                           stats=stats)
    else:
        stats["hit"] += 1
    return runner


def chain_runner(app: "MeiliApp") -> Callable[[PacketBatch], PacketBatch]:
    """The app's full stage chain fused into ONE jitted program (one XLA
    dispatch per batch instead of one per stage), cached process-wide."""
    key = chain_key(app)
    stats = _cache_stats("chain")
    runner = _CHAIN_RUNNERS.get(key)
    if runner is None:
        stats["miss"] += 1
        stages = tuple(app.stages)

        def run(batch: PacketBatch) -> PacketBatch:
            for fn in stages:
                batch = apply_stage(fn, batch)
            return batch

        runner = cache_put(_CHAIN_RUNNERS, key, jax.jit(run), stats=stats)
    else:
        stats["hit"] += 1
    return runner
