"""Meili Controller + per-NIC Controller Agents (paper §3, §6, Appendix D).

The controller receives (program, throughput target) submissions
(``app_sub_thr``), derives the replication plan with Algorithm 1, computes
resource demand from the profiled throughputs, places units with
Algorithm 2/3, and deploys: per-pipeline ring buffers, TO flow tables,
executors. It keeps per-NIC state synchronized via CAs, performs adaptive
scaling when targets change, and fails over to backup NICs.

Demand formula (§6.1): with profile (t_p, l_p, t_s, l_s), Algorithm 1 gives
R; the R-allocation's throughput t_R is estimated from the replication-aware
pipeline rate; then

    r_s = R · ⌊t_t / t_R⌋            (whole R-granular pipeline groups)
        + I · ⌈(t_t − ⌊t_t/t_R⌋·t_R) / t_p⌉   (minimal-granularity remainder)

FCFS across applications; unsatisfiable targets are placed best-effort.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable, Dict, List, Optional

from repro.core import allocation as alloc_mod
from repro.core import defrag as defrag_mod
from repro.core import replication
from repro.core.allocation import (Allocation, commit, nic_charge, release,
                                   resource_alloc)
from repro.core.graph import MeiliApp
from repro.core.orchestrator import TrafficOrchestrator
from repro.core.pool import Pool
from repro.core.profiler import AppProfile
from repro.core.qos import ResourceGovernor
from repro.core.state_engine import StateService
from repro.obs import Obs


@dataclasses.dataclass
class Deployment:
    app: MeiliApp
    target_gbps: float
    profile: AppProfile
    R: Dict[str, int]
    r_s: Dict[str, int]
    allocation: Allocation
    num_pipelines: int
    to: TrafficOrchestrator
    achievable_gbps: float
    backup_nic: Optional[str] = None
    state_snapshot: Optional[dict] = None
    # StateService.version at the last snapshot (None = never replicated):
    # the dirty flag that lets unchanged state skip the full re-traverse.
    replica_version: Optional[int] = None
    tenant: Optional[str] = None      # service-runtime owner (defaults to app name)

    def nics_used(self) -> List[str]:
        return [n for n, row in self.allocation.A.items()
                if any(v > 0 for v in row.values())]

    def usage(self) -> Dict[str, int]:
        """Resource kind -> units currently held (for pool attribution)."""
        need = self.app.resource_needs()
        out: Dict[str, int] = {}
        for s in self.profile.stages:
            kind = need[s]
            out[kind] = out.get(kind, 0) + self.allocation.units(s)
        return out


class ControllerAgent:
    """Per-NIC agent: Resource Manager + Runtime Manager (paper §3)."""

    def __init__(self, nic: str, pool: Pool):
        self.nic = nic
        self.pool = pool

    def status(self) -> dict:
        st = self.pool[self.nic]
        return {"nic": self.nic, "alive": st.alive, "free": dict(st.free),
                "free_bw_gbps": st.free_bw_gbps}


class MeiliController:
    def __init__(self, pool: Pool, clock: Callable[[], float] = time.monotonic,
                 governor: Optional[ResourceGovernor] = None,
                 obs: Optional[Obs] = None):
        self.pool = pool
        # Shared observability context (ISSUE 7): one metrics registry +
        # decision-audit trace for the whole pool. Controller operations
        # land as timed spans, governor verdicts as decision events, and a
        # service runtime layered on top reuses this same context so every
        # layer writes one causally-ordered log.
        self.obs = obs or Obs()
        # Every capacity/priority decision — admission clamp, scale grant,
        # migration do-no-harm, failover ordering — routes through one
        # governor (permissive defaults when no quotas are registered).
        self.governor = governor or ResourceGovernor()
        self.governor.bind(pool)
        self.governor.attach_obs(self.obs)
        self.agents = {n: ControllerAgent(n, pool) for n in pool.nics}
        self.deployments: Dict[str, Deployment] = {}
        self.state = StateService(list(pool.nics))
        self.clock = clock
        self.events: List[dict] = []    # controller action log (scaling/failover)
        # Service-runtime hooks: callables fired with every event dict the
        # controller logs (deploy/scale/failover/terminate), so a runtime
        # layered on top can react (rebuild data planes, retry placement)
        # without polling the event log.
        self.hooks: List[Callable[[dict], None]] = []
        # One-shot chaos hook: fired (then cleared) inside migrate() after the
        # allocation swap but before flows are re-homed — the exposed
        # make-before-break window a mid-migration fault lands in.
        self.mid_migration_hook: Optional[Callable[[str], None]] = None

    def add_hook(self, fn: Callable[[dict], None]) -> None:
        self.hooks.append(fn)

    def _emit(self, event: dict) -> None:
        self.events.append(event)
        labels = {"op": event.get("event", "")}
        shard = self.shard_of(event.get("tenant") or event.get("app"))
        if shard is not None:
            labels["shard"] = shard
        self.obs.metrics.counter("controller_ops_total", **labels).inc()
        for fn in self.hooks:
            fn(event)

    # -- shard facade hooks (ISSUE 8) ------------------------------------------
    # The legacy controller IS the 0-shard layout: placement sees the whole
    # pool, reconciliation is a no-op, and nothing carries a shard label.
    # ``core.shard.ShardedController`` overrides these to route placement
    # through per-rack ControlShards.
    def shard_of(self, tenant: Optional[str]) -> Optional[str]:
        """Owning shard of a tenant (None in the unsharded layout)."""
        return None

    def shard_of_nic(self, nic: Optional[str]) -> Optional[str]:
        """Owning shard of a NIC (None in the unsharded layout)."""
        return None

    def reconcile(self, tick: Optional[int] = None) -> None:
        """Cross-shard reconciliation step (headroom digests, bounded
        staleness). The unsharded controller reads pool truth directly —
        nothing to reconcile."""
        return None

    def _alloc_for(self, tenant: str, stages, demand: Dict[str, int],
                   t_s, need: Dict[str, str], op: str = "place"):
        """Placement hook every allocation (submit / scale growth /
        failover re-place) routes through. The sharded controller
        restricts this to the owning shard's NICs, spilling cross-rack
        when the shard cannot fit the demand."""
        return resource_alloc(stages, demand, t_s, self.pool, need)

    def drain_nic_candidates(self, nic: str,
                             exclude: Optional[set] = None) -> List[List[str]]:
        """Candidate NIC sets for draining deployments off ``nic``
        (gray-failure probation), in preference order. The sharded
        controller prepends the sick NIC's shard-local healthy set so
        drains stay within the failure domain when possible."""
        exclude = exclude or set()
        return [[n for n in self.pool.names()
                 if n != nic and n not in exclude]]

    def _account(self, dep: Deployment) -> None:
        """Resync the pool's per-tenant usage ledger from the deployment's
        current allocation (idempotent; called after every mutation)."""
        self.pool.set_usage(dep.tenant or dep.app.name, dep.usage())

    def flight_state(self) -> Dict[str, dict]:
        """Per-NIC pool state for the flight recorder's per-tick snapshot
        (ISSUE 10). The unsharded layout carries no shard labels and no
        shard digests; ``ShardedController`` overrides to add both."""
        pool = self.pool
        nics: Dict[str, dict] = {}
        for n in sorted(pool.names()):
            st = pool[n]
            nics[n] = {"alive": st.alive, "free_bw_gbps": st.free_bw_gbps,
                       "gray_frac": st.gray_frac}
        return {"nics": nics, "shards": {}}

    # -- §6.1 demand calculation -------------------------------------------------
    def demand(self, profile: AppProfile, target_gbps: float
               ) -> tuple[Dict[str, int], Dict[str, int], float]:
        stages = profile.stages
        R = replication.num_replication(stages, profile.l_s)
        # throughput of one R-allocated pipeline group (Gbps)
        rate = replication.pipeline_throughput(stages, profile.l_s, R)  # seq/s
        t_R = rate * profile.batch_bits() / 1e9
        n_groups = int(math.floor(target_gbps / t_R))
        r_s = {s: R[s] * n_groups for s in stages}
        rem = target_gbps - n_groups * t_R
        if rem > 1e-9:
            n_min = int(math.ceil(rem / profile.t_p))
            for s in stages:
                r_s[s] += n_min  # I = one minimal unit per stage
        return R, r_s, t_R

    # -- submission (Meili.app_sub_thr) -------------------------------------------
    def submit(self, app: MeiliApp, target_gbps: float, profile: AppProfile,
               backup_nic: Optional[str] = None,
               tenant: Optional[str] = None) -> Deployment:
        with self.obs.trace.span("submit", tenant=tenant or app.name,
                                 app=app.name,
                                 asked_gbps=target_gbps) as sp:
            # Admission routes through the governor: a target above the
            # tenant's declared quota is clamped before any demand/placement
            # math runs.
            target_gbps = self.governor.admission_target(tenant or app.name,
                                                         target_gbps)
            R, r_s, t_R = self.demand(profile, target_gbps)
            need = app.resource_needs()
            alloc = self._alloc_for(tenant or app.name, profile.stages, r_s,
                                    profile.t_s, need, op="submit")
            commit(self.pool, alloc, need)
            achievable = self._achievable(profile, alloc, r_s)
            num_pipes = max(1, max((alloc.units(s) for s in profile.stages),
                                   default=1))
            cap = self._pipeline_capacity(profile, num_pipes)
            to = TrafficOrchestrator(num_pipelines=num_pipes,
                                     capacity_per_pipeline=cap)
            for name, decl in app.state_decls.items():
                self.state.declare(name, decl["pattern"])
            placed = {s: alloc.units(s) for s in profile.stages}
            dep = Deployment(app=app, target_gbps=target_gbps, profile=profile,
                             R=R, r_s=placed, allocation=alloc,
                             num_pipelines=num_pipes, to=to,
                             achievable_gbps=achievable, backup_nic=backup_nic,
                             tenant=tenant or app.name)
            self.deployments[app.name] = dep
            self._account(dep)
            sp.note(granted_gbps=target_gbps, achievable_gbps=achievable,
                    nics=sorted(dep.nics_used()))
            self._emit({"t": self.clock(), "event": "deploy", "app": app.name,
                        "tenant": dep.tenant, "target": target_gbps,
                        "achievable": achievable})
            return dep

    def terminate(self, app_name: str) -> None:
        dep = self.deployments.pop(app_name)
        release(self.pool, dep.allocation, dep.app.resource_needs(),
                dep.profile.t_s)
        self.pool.clear_usage(dep.tenant or dep.app.name)
        self._emit({"t": self.clock(), "event": "terminate",
                    "app": app_name, "tenant": dep.tenant})

    # -- §6.1 adaptive scaling ------------------------------------------------------
    def adaptive_scale(self, app_name: str, new_target_gbps: float) -> Deployment:
        """Recompute demand and adjust allocation incrementally: current
        runtime is kept; extra pipelines are added (or halted + flows
        migrated) to meet the new target."""
        t0 = self.clock()
        dep = self.deployments[app_name]
        with self.obs.trace.span("scale", tenant=dep.tenant, app=app_name,
                                 target_gbps=new_target_gbps) as sp:
            dep = self._adaptive_scale(dep, app_name, new_target_gbps, t0)
            sp.note(achievable_gbps=dep.achievable_gbps,
                    num_pipelines=dep.num_pipelines)
            return dep

    def _adaptive_scale(self, dep: Deployment, app_name: str,
                        new_target_gbps: float, t0: float) -> Deployment:
        need = dep.app.resource_needs()
        R, r_s_new, _ = self.demand(dep.profile, new_target_gbps)
        delta = {s: r_s_new[s] - dep.r_s.get(s, 0) for s in dep.profile.stages}

        if any(d > 0 for d in delta.values()):
            grow = {s: max(0, d) for s, d in delta.items()}
            extra = self._alloc_for(dep.tenant or app_name,
                                    dep.profile.stages, grow,
                                    dep.profile.t_s, need, op="scale")
            commit(self.pool, extra, need)
            dep.allocation.merge(extra)
        if any(d < 0 for d in delta.values()):
            self._shrink(dep, {s: -d for s, d in delta.items() if d < 0}, need)

        dep.r_s = {s: dep.allocation.units(s) for s in dep.profile.stages}
        new_pipes = max(1, max(dep.r_s.values(), default=1))
        cap = self._pipeline_capacity(dep.profile, new_pipes)
        while len(dep.to.pipelines) < new_pipes:
            dep.to.add_pipeline(cap)
        for p in dep.to.pipelines:
            p.capacity = cap
        if len([p for p in dep.to.pipelines if p.active]) > new_pipes:
            # Halt the surplus pipelines and spread their flows across the
            # least-loaded survivors (funnelling everything to pipeline 0
            # hot-spots it on every scale-down).
            for p in dep.to.pipelines[new_pipes:]:
                if p.active:
                    dep.to.halt_pipeline(p.pid)
            survivors = [p.pid for p in dep.to.pipelines if p.active]
            flow_count = {pid: 0 for pid in survivors}
            for f, pid in dep.to.flow_table.items():
                if pid in flow_count:
                    flow_count[pid] += 1
            for f, pid in list(dep.to.flow_table.items()):
                if pid in flow_count:
                    continue   # still on a surviving pipeline
                dst = min(survivors, key=lambda q: (flow_count[q], q))
                dep.to.begin_migration(f)
                dep.to.finish_migration(f, dst_pid=dst)
                flow_count[dst] += 1
        dep.num_pipelines = new_pipes
        dep.target_gbps = new_target_gbps
        dep.achievable_gbps = self._achievable(dep.profile, dep.allocation,
                                               dep.r_s)
        self._account(dep)
        self._emit({"t": self.clock(), "event": "scale", "app": app_name,
                    "tenant": dep.tenant, "target": new_target_gbps,
                    "response_s": self.clock() - t0})
        return dep

    def _shrink(self, dep: Deployment, give_back: Dict[str, int],
                need: Dict[str, str]) -> None:
        """Return units to the pool, mirroring the Algorithm-3 colocation
        credit on the way out: the bandwidth credited back is the canonical
        charge *delta* of the shrunk row (capped by what this deployment
        actually holds on the NIC), never the naive per-unit sum. Removing a
        stage that a colocated successor was crediting can make the row's
        charge go UP (the hand-off now crosses the link again) — that case
        takes the extra bandwidth from the pool instead of crediting."""
        alloc = dep.allocation
        t_s = dep.profile.t_s
        S = dep.profile.stages
        for s, cnt in give_back.items():
            left = cnt
            for nic, row in alloc.A.items():
                if left <= 0:
                    break
                have = row.get(s, 0)
                take = min(have, left)
                if take <= 0:
                    continue
                charge_before = nic_charge(row, S, t_s)
                row[s] = have - take
                charge_after = nic_charge(row, S, t_s)
                self.pool[nic].give(need[s], take)
                held = alloc.bw_charge.get(nic, 0.0)
                delta = charge_before - charge_after
                if delta > 0.0:
                    credit = min(delta, held)
                    self.pool[nic].give_bw(credit)
                    alloc.bw_charge[nic] = held - credit
                elif delta < 0.0:
                    extra = min(-delta, self.pool[nic].free_bw_gbps)
                    self.pool[nic].take_bw(extra)
                    alloc.bw_charge[nic] = held + extra
                left -= take
        # Resync the allocator's view with pool truth: no zero-unit rows, no
        # stale bw_after — a later resource_alloc + commit must see reality.
        for nic in list(alloc.A):
            row = alloc.A[nic]
            for s in [k for k, u in row.items() if u <= 0]:
                del row[s]
            if alloc.bw_charge.get(nic, 0.0) <= 1e-12:
                alloc.bw_charge.pop(nic, None)
            alloc.bw_after[nic] = self.pool[nic].free_bw_gbps

    # -- Appendix D: failover -----------------------------------------------------
    def replicate_for_failover(self, app_name: str) -> None:
        """Periodic state + packet-cache replication to the backup NIC.

        Dirty-flag gated: if no state API write landed since the last
        snapshot (``StateService.version`` unchanged), the snapshot is
        already current and the full cross-NIC traverse is skipped."""
        dep = self.deployments[app_name]
        if dep.backup_nic is None:
            return
        if dep.replica_version == self.state.version:
            return
        entries = self.state.traverse(local=dep.backup_nic)
        dep.state_snapshot = {e.s_name: e.value for e in entries}
        dep.replica_version = self.state.version

    def handle_failure(self, nic: str) -> List[str]:
        """NIC (or its link) failed: re-place affected stage units, restore
        state from the last synchronized snapshot, re-home flows.

        The lost units and bandwidth charge are returned to the *failed*
        NIC's ledger (it is dead, so they are unobservable until a revive —
        but a revived NIC must come back clean, and the pool-wide ledger
        invariant must keep holding). Each impacted tenant's failover
        response time is measured from the start of ITS OWN re-placement,
        not a shared epoch that inflates later tenants' numbers.

        Re-placement order and demand route through the governor: impacted
        tenants re-place heaviest-weight first (scarce surviving capacity
        goes to the contracts the pool values most), and the re-placed
        demand is clamped to the tenant's unit quota."""
        self.pool.mark_failed(nic)
        impacted: List[str] = []
        victims = [name for name, dep in self.deployments.items()
                   if any(u > 0
                          for u in dep.allocation.A.get(nic, {}).values())]
        order = self.governor.failover_order(victims)
        with self.obs.trace.span("failover", nic=nic,
                                 victims=list(order)) as fsp:
            for name in order:
                dep = self.deployments[name]
                lost = {s: u for s, u in dep.allocation.A.get(nic, {}).items()
                        if u > 0}
                t0 = self.clock()
                impacted.append(name)
                with self.obs.trace.span("replace", tenant=dep.tenant,
                                         nic=nic, app=name,
                                         lost=dict(lost)) as rsp:
                    need = dep.app.resource_needs()
                    # Return the lost ledger entries to the dead NIC...
                    st = self.pool[nic]
                    for s, u in lost.items():
                        st.give(need[s], u)
                    st.give_bw(dep.allocation.bw_charge.pop(nic, 0.0))
                    dep.allocation.A[nic] = {}
                    dep.allocation.bw_after[nic] = st.free_bw_gbps
                    # ...and re-place the units lost on it, quota-clamped.
                    held = sum(dep.allocation.units(s)
                               for s in dep.profile.stages)
                    capped = self.governor.replacement_demand(
                        dep.tenant or name, lost, held_units=held)
                    lost_demand = {s: capped.get(s, 0)
                                   for s in dep.profile.stages}
                    replacement = self._alloc_for(dep.tenant or name,
                                                  dep.profile.stages,
                                                  lost_demand,
                                                  dep.profile.t_s, need,
                                                  op="failover")
                    commit(self.pool, replacement, need)
                    dep.allocation.merge(replacement)
                    unmet = {s: u for s, u in replacement.unmet.items()
                             if u > 0}
                    dep.r_s = {s: dep.allocation.units(s)
                               for s in dep.profile.stages}
                    dep.achievable_gbps = self._achievable(
                        dep.profile, dep.allocation, dep.r_s)
                    if dep.state_snapshot:
                        for k, v in dep.state_snapshot.items():
                            self.state.fstate_set(k, v)
                    self._account(dep)
                    rsp.note(unmet=dict(unmet),
                             achievable_gbps=dep.achievable_gbps)
                    self._emit({"t": self.clock(), "event": "failover",
                                "app": name, "tenant": dep.tenant, "nic": nic,
                                "unmet": unmet,
                                "response_s": self.clock() - t0})
            fsp.note(impacted=list(impacted))
        return impacted

    # -- online re-placement / defragmentation (make-before-break) ----------------
    def migrate(self, app_name: str,
                only_nics: Optional[List[str]] = None,
                require_improvement: bool = True,
                forced: bool = False) -> Optional[dict]:
        """Re-place a live deployment onto a better-packed NIC set.

        Make-before-break: the destination units are allocated and committed
        *while the old placement still serves traffic*, flows are handed
        over through the TO's migration protocol (halt -> buffer -> re-home),
        and only then is the source placement released. A do-no-harm guard
        rejects any plan that would raise the deployment's hop count or
        lower its achievable throughput — rejected plans leave the pool
        untouched. ``forced`` skips that guard: a probation drain off a
        gray-failing NIC is worth extra hops, so only placement feasibility
        gates it. Returns the emitted migrate event, or None if no
        admissible plan exists.
        """
        t0 = self.clock()
        dep = self.deployments[app_name]
        with self.obs.trace.span("migrate", tenant=dep.tenant, app=app_name,
                                 forced=forced) as sp:
            ev = self._migrate(dep, app_name, only_nics, require_improvement,
                               forced, t0)
            if ev is None:
                sp.note(outcome="rejected")
            else:
                sp.note(outcome="committed",
                        nics_before=ev["nics_before"],
                        nics_after=ev["nics_after"],
                        hop_pairs_before=ev["hop_pairs_before"],
                        hop_pairs_after=ev["hop_pairs_after"])
            return ev

    def _migrate(self, dep: Deployment, app_name: str,
                 only_nics: Optional[List[str]], require_improvement: bool,
                 forced: bool, t0: float) -> Optional[dict]:
        need = dep.app.resource_needs()
        demand = {s: dep.allocation.units(s) for s in dep.profile.stages}
        if only_nics is None:
            shadow = defrag_mod.plan_migration(dep, self.pool)
        else:
            shadow = resource_alloc(dep.profile.stages, demand,
                                    dep.profile.t_s, self.pool, need,
                                    only_nics=only_nics)
        if shadow is None or not shadow.satisfied():
            return None
        # Do-no-harm guard, evaluated on the shadow plan before any commit —
        # the policy itself lives in the governor (migration_verdict).
        impact = defrag_mod.migration_impact(
            dep, shadow, self._achievable(dep.profile, shadow, demand))
        old_hops, new_hops = impact.hops_before, impact.hops_after
        new_achievable = impact.achievable_after
        if not forced and not self.governor.migration_verdict(
                hops_before=impact.hops_before, hops_after=impact.hops_after,
                achievable_before=impact.achievable_before,
                achievable_after=impact.achievable_after,
                nics_before=impact.nics_before, nics_after=impact.nics_after,
                require_improvement=require_improvement):
            return None

        # MAKE: commit the destination units (the pool now holds both).
        commit(self.pool, shadow, need)
        old_alloc = dep.allocation

        # Migrate flows via the TO: halt every flow (in-flight packets buffer
        # in the side ring), swap the allocation, release the source units —
        # then re-home the flows. The window between begin and finish is the
        # exposed make-before-break state the chaos layer's mid-migration
        # fault lands in: the one-shot hook below fires with every flow
        # buffered and the ledger already swapped to the destination, so an
        # injected failure must drain cleanly through handle_failure while
        # the hand-off is in flight.
        for f in list(dep.to.flow_table):
            dep.to.begin_migration(f)

        dep.allocation = shadow
        dep.r_s = {s: shadow.units(s) for s in dep.profile.stages}
        dep.achievable_gbps = new_achievable
        release(self.pool, old_alloc, need, dep.profile.t_s)
        self._account(dep)

        if self.mid_migration_hook is not None:
            hook, self.mid_migration_hook = self.mid_migration_hook, None
            hook(app_name)

        for f, pid in list(dep.to.flow_table.items()):
            dep.to.finish_migration(f, dst_pid=pid)
        event = {"t": self.clock(), "event": "migrate", "app": app_name,
                 "tenant": dep.tenant,
                 "nics_before": sorted(n for n, row in old_alloc.A.items()
                                       if any(v > 0 for v in row.values())),
                 "nics_after": sorted(dep.nics_used()),
                 "hop_pairs_before": old_hops, "hop_pairs_after": new_hops,
                 "response_s": self.clock() - t0}
        self._emit(event)
        return event

    def defragment(self, max_migrations: int = 1,
                   min_score: float = 1.0) -> List[dict]:
        """One background re-placement pass: score every deployment's
        fragmentation, try to migrate the worst offenders (score-descending)
        onto compact NIC sets, stop after ``max_migrations`` moves. Returns
        the migrate events of the moves that went through."""
        scores = self.governor.defrag_order(
            defrag_mod.fragmentation_score(dep, self.pool)
            for dep in self.deployments.values())
        moved: List[dict] = []
        for sc in scores:
            if sc.score < min_score or len(moved) >= max_migrations:
                break
            ev = self.migrate(sc.app)
            if ev is not None:
                moved.append(ev)
        return moved

    def check_ledger(self, strict: bool = True) -> List[str]:
        """Pool-truth invariant: per NIC and kind, free + Σ deployments'
        held units == capacity, and free bw + Σ recorded charges == link."""
        holdings = []
        charges = []
        for dep in self.deployments.values():
            need = dep.app.resource_needs()
            h: Dict[str, Dict[str, int]] = {}
            for n, row in dep.allocation.A.items():
                for s, u in row.items():
                    if u > 0:
                        kinds = h.setdefault(n, {})
                        kinds[need[s]] = kinds.get(need[s], 0) + u
            holdings.append(h)
            charges.append(dict(dep.allocation.bw_charge))
        return self.pool.check_ledger(holdings, charges, strict=strict)

    # -- CA synchronization (paper §3: periodic status sync) ------------------------
    def tick(self) -> dict:
        return {n: a.status() for n, a in self.agents.items()}

    # -- helpers ---------------------------------------------------------------------
    def _achievable(self, profile: AppProfile, alloc: Allocation,
                    r_s: Dict[str, int]) -> float:
        """Throughput the placed units sustain: per-stage placed capacity min."""
        caps = []
        for s in profile.stages:
            units = alloc.units(s)
            caps.append(units * profile.t_s[s])
        return min(caps) if caps else 0.0

    def _pipeline_capacity(self, profile: AppProfile, num_pipes: int) -> float:
        """Packets per partition round per pipeline (for the TO's flow table)."""
        return max(1.0, 1024.0 / max(1, num_pipes))
