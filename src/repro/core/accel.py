"""Accelerator Function APIs (paper §4.2).

``Meili.regex / Meili.AES / Meili.sha / Meili.compression`` — uniform
invocation over heterogeneous accelerator implementations. Users pass only
the shared parameters (data pointer + rules / key / ratio); Meili binds the
hardware-specific settings (here: kernel impl selection, block shapes,
device placement by the allocator). Each API returns a `Function` stage whose
`resource` field is the accelerator kind Algorithm 2 allocates.

Payload word-packing (uint8 -> uint32) happens once per stage boundary, the
TPU analog of the DMA alignment the NIC SDKs perform.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core import pool
from repro.core.graph import Function, PacketBatch
from repro.kernels import ops


def _payload_words(batch: PacketBatch) -> jnp.ndarray:
    pay = batch.payload
    B, L = pay.shape
    Lw = (L // 4) * 4
    w = pay[:, :Lw].reshape(B, Lw // 4, 4).astype(jnp.uint32)
    return (w[..., 0] | (w[..., 1] << 8) | (w[..., 2] << 16) | (w[..., 3] << 24))


def _words_to_payload(words: jnp.ndarray, orig: jnp.ndarray) -> jnp.ndarray:
    B, W = words.shape
    out = jnp.stack([(words >> s) & 0xFF for s in (0, 8, 16, 24)], axis=-1)
    out = out.reshape(B, W * 4).astype(jnp.uint8)
    L = orig.shape[1]
    return jnp.concatenate([out, orig[:, W * 4:]], axis=1) if W * 4 < L else out[:, :L]


def regex(rules: Sequence[str], *, impl: Optional[str] = None,
          name: str = "regex") -> Function:
    """Multi-pattern matching; match count lands in meta['match_num']."""
    table, out_count = ops.build_aho_corasick(rules)
    table_j, out_j = jnp.asarray(table), jnp.asarray(out_count)

    def ucf(batch: PacketBatch) -> PacketBatch:
        matches = ops.regex_scan(batch.payload, batch.length, table_j, out_j,
                                 impl=impl)
        return batch.with_meta(match_num=matches)

    return Function(name, "accel", ucf, resource=pool.REGEX,
                    params={"rules": list(rules)})


def AES(key: np.ndarray | Sequence[int], *, impl: Optional[str] = None,
        name: str = "aes") -> Function:
    """Payload encryption in place (ARX analog; see DESIGN.md §2)."""
    key_j = jnp.asarray(np.asarray(key, dtype=np.uint32)[:4])

    def ucf(batch: PacketBatch) -> PacketBatch:
        words = _payload_words(batch)
        enc = ops.cipher(words, key_j, impl=impl)
        return dataclasses.replace(batch,
                                   payload=_words_to_payload(enc, batch.payload))

    return Function(name, "accel", ucf, resource=pool.CRYPTO)


def sha(key: np.ndarray | Sequence[int] = (1, 2, 3, 4), *,
        impl: Optional[str] = None, name: str = "sha") -> Function:
    """Keyed digest into meta['digest'] (B, 4) uint32 (HMAC stand-in)."""
    key_j = jnp.asarray(np.asarray(key, dtype=np.uint32)[:4])

    def ucf(batch: PacketBatch) -> PacketBatch:
        words = _payload_words(batch)
        return batch.with_meta(digest=ops.digest(words, key_j, impl=impl))

    return Function(name, "accel", ucf, resource=pool.CRYPTO)


def compression(rt: float = 0.5, *, name: str = "compression") -> Function:
    """Compression accelerator analog: RLE cost model — computes the
    compressed length into meta['comp_len'] (the NIC engine is an opaque
    throughput box; Meili only needs its latency/throughput shape)."""

    def ucf(batch: PacketBatch) -> PacketBatch:
        pay = batch.payload
        runs = (pay[:, 1:] != pay[:, :-1]).astype(jnp.int32).sum(axis=1) + 1
        est = jnp.minimum(runs * 2, (batch.length * rt).astype(jnp.int32))
        return batch.with_meta(comp_len=est)

    return Function(name, "accel", ucf, resource=pool.COMPRESSION)
