"""Unified QoS governor: per-tenant quotas, weighted fair sharing, and
backlog-aware capacity verdicts (ISSUE 4).

The paper's pooling win (§8, 3.07x vs standalone) presumes tenants
multiplex *headroom* — one tenant's burst may borrow slack, but must never
starve another tenant's contracted SLO. Before this module the decisions
that enforce that were smeared across three layers: admission strictness in
the tenant registry, an ad-hoc capacity-pressure clamp in the service
runtime's autoscaler, and a do-no-harm guard inline in the controller's
migration path. The ``ResourceGovernor`` is the single policy object all
four choke points consult:

  admission   ``MeiliController.submit`` clamps the requested target to the
              tenant's quota; ``TenantRegistry.admit`` turns the placement
              outcome into an admit/reject verdict (the old inline
              ``allocation.satisfied()`` check).
  scaling     ``ServiceRuntime`` hands the governor its demand estimate and
              gets back a ``ScaleVerdict`` — quota-capped, burst-credited
              (token bucket), and *partially granted* when the pool's
              per-tick headroom ledger cannot support the full ask.
  defrag      ``MeiliController.migrate`` asks ``migration_verdict`` whether
              a shadow plan is harmless (and improving) before committing.
  failover    ``MeiliController.handle_failure`` re-places impacted tenants
              in governor priority order (weight-descending), so scarce
              post-failure capacity goes to the heaviest contracts first.

On the data-plane side the governor schedules the per-tick dispatch as a
deficit-weighted round-robin (DWRR, Shreedhar & Varghese) over the tenants'
ingress queues: the telemetry backlog *is* the queue depth scheduled
against, so an over-quota tenant queues behind its own deficit instead of
triggering pool-wide rescales. Weights come from the quota declaration
(default: the SLA priority), and long-run served bytes under saturation
converge to the weight ratios.

Quotas default to the tenant's contract (``quota_from_sla``), which makes
the governed system behave identically to the pre-governor runtime for any
in-contract workload — the efficiency bars do not move; only out-of-quota
bursts see new policy.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

from repro.core.pool import Pool

# Service-rate epsilon for queue/capacity bookkeeping (bytes).
_EPS = 1e-9


@dataclasses.dataclass(frozen=True)
class TenantQuota:
    """Per-tenant resource-isolation contract, declared on ``TenantSpec``.

    ``max_gbps``   hard cap on the provision target the tenant may scale to
                   (None = uncapped); defaults to the SLA contract.
    ``max_units``  hard cap on placed resource units (None = uncapped).
    ``burst_gbps`` token-bucket depth: Gbps-ticks of credit the tenant may
                   spend to exceed ``max_gbps`` transiently.
    ``burst_refill_gbps``  credit refilled per tick, up to the depth.
    ``weight``     DWRR / contention-share weight (default 1.0).
    """

    max_gbps: Optional[float] = None
    max_units: Optional[int] = None
    burst_gbps: float = 0.0
    burst_refill_gbps: float = 0.0
    weight: float = 1.0


def quota_from_sla(sla) -> TenantQuota:
    """The default quota: the contract is the cap, priority is the weight."""
    return TenantQuota(max_gbps=sla.target_gbps,
                       weight=float(max(1, sla.priority)))


@dataclasses.dataclass
class AdmissionVerdict:
    admitted: bool
    reason: str = ""


@dataclasses.dataclass
class ScaleVerdict:
    """The governor's answer to "this tenant wants to re-target".

    ``target_gbps``  the granted provision target (quota/burst/headroom
                     clamped — may be below the ask: a partial grant).
    ``rescale``      whether the runtime should call ``adaptive_scale`` now.
    ``pressure``     offered+queued load is eating into placed capacity.
    ``granted_frac`` granted / asked growth (1.0 when nothing was clamped).
    ``burst_credit_spent``  Gbps-ticks drawn from the token bucket.
    ``brownout``     the grant was clamped by an active brownout (degraded
                     partial service while parked tenants wait for capacity).
    ``reason``       audit label naming the clamps that shaped the grant
                     ("granted" when nothing clamped; otherwise a comma-
                     joined subset of quota_clamp/burst/brownout/
                     headroom_clamp/unit_quota/pressure).
    """

    target_gbps: float
    rescale: bool
    pressure: bool = False
    granted_frac: float = 1.0
    burst_credit_spent: float = 0.0
    brownout: bool = False
    reason: str = "granted"


class ResourceGovernor:
    """One policy object for every capacity/priority decision in the pool.

    ``enabled=False`` turns quota enforcement, burst accounting, and
    weighted sharing OFF (every verdict is permissive, DWRR runs with equal
    weights) — the A/B baseline for the flash-crowd isolation benchmark.
    Note this removes the contract clamp too: the pre-governor runtime's
    ``min(contract, ...)`` *was* an implicit quota (the default
    ``quota_from_sla`` reproduces it exactly), so the disabled governor
    models a pool with no notion of entitlement at all — tenants may
    re-target arbitrarily far past contract, which is precisely the
    unguarded baseline the isolation A/B measures against. The migration
    do-no-harm guard stays active even when disabled: it is a correctness
    guard, not a QoS policy.
    """

    def __init__(self, enabled: bool = True, pressure_frac: float = 0.92):
        self.enabled = enabled
        self.pressure_frac = pressure_frac
        # Observability context (ISSUE 7): when attached, every verdict this
        # governor issues lands in the decision-audit trace with its reason
        # and the ledger state that produced it. None = silent (no-op).
        self.obs = None
        # Shard attribution (ISSUE 8): a sharded controller installs a
        # resolver (tenant -> shard name) so every verdict this governor
        # audits or counts carries the owning shard's label. None = the
        # legacy single-controller layout (no label, traces unchanged).
        self.shard_resolver = None
        # Vectorized scheduling kernel (ISSUE 8): when attached, the DWRR
        # dispatch runs as one jitted array program over all tenants
        # (core.sched_kernel) instead of the scalar dict walk below — which
        # stays as the pinned reference oracle.
        self._kernel = None
        self.quotas: Dict[str, TenantQuota] = {}
        self.credits: Dict[str, float] = {}      # burst tokens (Gbps-ticks)
        self._pool: Optional[Pool] = None
        # DWRR state: persistent per-tenant deficit + ring order.
        self._deficit: Dict[str, float] = {}
        self._ring: List[str] = []
        # Per-tick free-unit ledger (resource kind -> units), snapshotted by
        # begin_tick and drawn down by scale grants within the tick.
        self._headroom: Optional[Dict[str, int]] = None
        # Brownout level (None = off): while tenants are parked after a
        # failure, grants are clamped toward this fraction of contract so the
        # survivors shed headroom the parked tenants can re-admit into.
        self._brownout: Optional[float] = None

    # -- registration ----------------------------------------------------------
    def bind(self, pool: Pool) -> None:
        """Attach the pool whose quota-ledger rows this governor maintains."""
        self._pool = pool

    def attach_obs(self, obs) -> None:
        """Attach the observability context verdicts are audited into."""
        self.obs = obs

    def attach_kernel(self, kernel) -> None:
        """Attach a ``sched_kernel.VectorizedScheduler``: subsequent
        ``dwrr_schedule`` calls run the jitted array program (None
        detaches, restoring the scalar reference path)."""
        self._kernel = kernel

    def _shard_of(self, tenant: Optional[str]) -> Optional[str]:
        if tenant is None or self.shard_resolver is None:
            return None
        return self.shard_resolver(tenant)

    def _audit(self, name: str, tenant: Optional[str] = None,
               **detail) -> None:
        if self.obs is not None:
            shard = self._shard_of(tenant)
            if shard is not None:
                detail.setdefault("shard", shard)
            self.obs.trace.event(name, tenant=tenant, **detail)

    def register(self, tenant: str, quota: Optional[TenantQuota] = None) -> None:
        q = quota or TenantQuota()
        self.quotas[tenant] = q
        self.credits[tenant] = q.burst_gbps
        if self._pool is not None:
            self._pool.set_quota(tenant, max_units=q.max_units,
                                 max_gbps=q.max_gbps, weight=q.weight)

    def forget(self, tenant: str) -> None:
        self.quotas.pop(tenant, None)
        self.credits.pop(tenant, None)
        self._deficit.pop(tenant, None)
        if tenant in self._ring:
            self._ring.remove(tenant)
        if self._pool is not None:
            self._pool.clear_quota(tenant)

    def quota(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, TenantQuota())

    def weight(self, tenant: str) -> float:
        if not self.enabled:
            return 1.0
        return max(1e-9, self.quota(tenant).weight)

    # -- per-tick bookkeeping --------------------------------------------------
    def begin_tick(self, pool: Optional[Pool] = None,
                   active: Iterable[str] = (),
                   tick: Optional[int] = None) -> None:
        """Refill burst credits and snapshot the free-unit headroom ledger
        that this tick's scale grants will draw against. ``tick`` (when the
        caller knows it) stamps the observability trace so verdicts issued
        this tick land at the right place in the audit log."""
        if tick is not None and self.obs is not None:
            self.obs.set_tick(tick)
        for t in active:
            q = self.quota(t)
            if q.burst_gbps > 0.0:
                self.credits[t] = min(
                    q.burst_gbps,
                    self.credits.get(t, 0.0) + q.burst_refill_gbps)
        pool = pool or self._pool
        if pool is None:
            self._headroom = None
            return
        kinds = set()
        for name in pool.names():
            kinds.update(pool[name].free)
        self._headroom = {k: pool.free_total(k) for k in kinds}

    def headroom_snapshot(self) -> Dict[str, int]:
        """The current per-kind free-unit ledger (post any grants drawn this
        tick) — read by the flight recorder's per-tick snapshot. Empty
        before the first ``begin_tick``."""
        return dict(self._headroom) if self._headroom else {}

    # -- brownout --------------------------------------------------------------
    def set_brownout(self, level: Optional[float]) -> None:
        """Enter/leave degraded partial-grant mode. ``level`` is the base
        fraction of contract the *lowest-weight* tenant is clamped toward;
        None (or >= 1.0) clears the brownout entirely."""
        if level is None or level >= 1.0:
            self._brownout = None
        else:
            self._brownout = max(0.05, level)

    def brownout_factor(self, tenant: str) -> float:
        """Per-tenant grant multiplier under brownout: weight-proportional
        degradation, ``b + (1 - b) * w / w_max`` — the heaviest contract keeps
        full service, the lightest degrades to the base level ``b``. 1.0 when
        no brownout is active."""
        if not self.enabled or self._brownout is None:
            return 1.0
        wmax = max((q.weight for q in self.quotas.values()), default=1.0)
        b = self._brownout
        return b + (1.0 - b) * self.weight(tenant) / max(wmax, 1e-9)

    # -- admission -------------------------------------------------------------
    def admission_target(self, tenant: str, target_gbps: float) -> float:
        """Clamp a submission's throughput target to the tenant's quota
        (consulted by ``MeiliController.submit``)."""
        q = self.quota(tenant)
        if not self.enabled or q.max_gbps is None:
            return target_gbps
        if target_gbps > q.max_gbps:
            self._audit("admission_clamp", tenant=tenant,
                        asked_gbps=target_gbps, granted_gbps=q.max_gbps,
                        reason="target above quota")
        return min(target_gbps, q.max_gbps)

    def admission_verdict(self, tenant: str, allocation) -> AdmissionVerdict:
        """Strict-admission check (moved from the tenant registry): a tenant
        whose contracted target could not be fully placed is rejected."""
        if not allocation.satisfied():
            unmet = {s: u for s, u in allocation.unmet.items() if u > 0}
            self._audit("admission_verdict", tenant=tenant, admitted=False,
                        reason=f"unplaceable at contract: {unmet}")
            return AdmissionVerdict(False, f"unplaceable at contract: {unmet}")
        self._audit("admission_verdict", tenant=tenant, admitted=True,
                    reason="placed at contract")
        return AdmissionVerdict(True)

    # -- scaling ---------------------------------------------------------------
    def _quota_cap_gbps(self, tenant: str, desired: float) -> Tuple[float, float]:
        """(granted cap, burst credit spent): the hard quota plus whatever
        the token bucket can cover this tick."""
        q = self.quota(tenant)
        if not self.enabled or q.max_gbps is None or desired <= q.max_gbps:
            return desired, 0.0
        burn = min(desired - q.max_gbps, self.credits.get(tenant, 0.0))
        return q.max_gbps + burn, burn

    def scale_verdict(self, tenant: str, *, est_gbps: float,
                      offered_gbps: float, contract_gbps: float,
                      current_gbps: float, achievable_gbps: float,
                      unit_gbps: float = 0.0,
                      stage_kinds: Sequence[str] = (),
                      held_units: int = 0,
                      headroom: float = 1.15, floor_frac: float = 0.2,
                      rescale_threshold: float = 0.1,
                      cooldown_active: bool = False,
                      forced: bool = False) -> ScaleVerdict:
        """The capacity decision the runtime's autoscaler used to inline.

        ``offered_gbps`` is offered + queued drain rate (backlog-aware: the
        reactive loop scales on what is waiting, not just what arrived).
        ``unit_gbps``/``stage_kinds``/``held_units`` let the governor convert
        a Gbps grant into a unit draw against the headroom ledger and the
        ``max_units`` quota; pass 0/() to skip unit accounting.
        ``stage_kinds`` is one entry PER STAGE (repeats meaningful): an app
        with two crypto stages needs two crypto units per pipeline of growth.
        """
        reasons: List[str] = []
        desired = max(floor_frac * contract_gbps, est_gbps * headroom)
        # Capacity pressure: load (incl. queued) is eating into the *placed*
        # capacity — re-target above it before the backlog compounds.
        pressure = offered_gbps > self.pressure_frac * max(achievable_gbps,
                                                           1e-9)
        if pressure:
            desired = max(desired, offered_gbps * headroom)
            reasons.append("pressure")
        cap, burn = self._quota_cap_gbps(tenant, desired)
        granted = min(desired, cap)
        if granted < desired - _EPS:
            reasons.append("quota_clamp")
        if burn > 0.0:
            reasons.append("burst")

        # Brownout clamp: while tenants are parked post-failure, survivors
        # are granted only a weight-proportional fraction of contract (never
        # below the floor) so their scale-downs free the units the parked
        # tenants need to re-admit. Burst credit cannot buy out a brownout.
        browned = False
        bfac = self.brownout_factor(tenant)
        if bfac < 1.0:
            bcap = max(floor_frac * contract_gbps, bfac * contract_gbps)
            if granted > bcap + _EPS:
                granted, browned, burn = bcap, True, 0.0
                reasons.append("brownout")

        # Partial grant under contention: growth beyond the pool's free-unit
        # headroom (or the tenant's max_units quota) is not granted — the
        # tenant queues instead of thrashing the allocator with futile
        # rescales that would strip headroom other tenants are entitled to.
        # The ledger draw is computed here but only committed below, once
        # the verdict actually triggers a rescale: a no-op verdict must not
        # phantom-reserve units against later tenants in the same tick.
        draw: Dict[str, int] = {}
        grow = granted - current_gbps
        if grow > _EPS and unit_gbps > 0.0 and stage_kinds:
            mult: Dict[str, int] = {}           # kind -> stages of that kind
            for kind in stage_kinds:
                mult[kind] = mult.get(kind, 0) + 1
            pipes_want = int(math.ceil(grow / unit_gbps))
            pipes_ok = pipes_want
            if self._headroom is not None:
                for kind, m in mult.items():
                    pipes_ok = min(pipes_ok,
                                   max(0, self._headroom.get(kind, 0)) // m)
            if pipes_ok < pipes_want:
                reasons.append("headroom_clamp")
            pipes_ledger = pipes_ok
            q = self.quota(tenant)
            if self.enabled and q.max_units is not None:
                room = max(0, q.max_units - held_units)
                pipes_ok = min(pipes_ok, room // max(1, len(stage_kinds)))
            if pipes_ok < pipes_ledger:
                reasons.append("unit_quota")
            if pipes_ok < pipes_want:
                granted = current_gbps + pipes_ok * unit_gbps
            if granted > current_gbps + _EPS:
                draw = {kind: pipes_ok * m for kind, m in mult.items()}

        asked_grow = max(0.0, desired - current_gbps)
        got_grow = max(0.0, granted - current_gbps)
        frac = got_grow / asked_grow if asked_grow > _EPS else 1.0
        gap = abs(granted - current_gbps) / max(contract_gbps, 1e-9)
        scaling_up = granted > current_gbps + 1e-9
        # Fast-attack: scale-UP is never cooldown-blocked (a blocked scale-up
        # is an SLO violation waiting to happen); the cooldown only rate-
        # limits scale-downs so troughs don't thrash the allocator.
        rescale = bool(
            forced
            or (scaling_up and (pressure or gap > rescale_threshold))
            or (not scaling_up and not cooldown_active
                and gap > rescale_threshold))
        # Commit side effects only for verdicts that execute: a no-op verdict
        # must neither phantom-reserve headroom units nor drain the burst
        # bucket (credit pays for grants actually taken, not for asks).
        if rescale and scaling_up:
            if draw and self._headroom is not None:
                for kind, units in draw.items():
                    self._headroom[kind] = self._headroom.get(kind, 0) - units
            if burn > 0.0:
                q = self.quota(tenant)
                over = q.max_gbps if q.max_gbps is not None else granted
                used = max(0.0, min(burn, granted - over))
                self.credits[tenant] = max(
                    0.0, self.credits.get(tenant, 0.0) - used)
                burn = used
        else:
            burn = 0.0
        reason = ",".join(reasons) if reasons else "granted"
        self._audit("scale_verdict", tenant=tenant, reason=reason,
                    desired_gbps=desired, granted_gbps=granted,
                    current_gbps=current_gbps, rescale=rescale,
                    pressure=pressure, granted_frac=frac, brownout=browned,
                    burst_credit_spent=burn,
                    burst_credit_left=self.credits.get(tenant, 0.0),
                    headroom=dict(self._headroom) if self._headroom else {})
        if self.obs is not None:
            labels = {"tenant": tenant, "reason": reason}
            shard = self._shard_of(tenant)
            if shard is not None:
                labels["shard"] = shard
            self.obs.metrics.counter("governor_scale_verdicts_total",
                                     **labels).inc()
        return ScaleVerdict(target_gbps=granted, rescale=rescale,
                            pressure=pressure, granted_frac=frac,
                            burst_credit_spent=burn, brownout=browned,
                            reason=reason)

    # -- defrag / migration ----------------------------------------------------
    def migration_verdict(self, *, hops_before: int, hops_after: int,
                          achievable_before: float, achievable_after: float,
                          nics_before: int, nics_after: int,
                          require_improvement: bool = True) -> bool:
        """Do-no-harm guard (moved from ``MeiliController.migrate``): a
        re-placement must not lose capacity or locality, and — unless the
        caller pinned the targets — must strictly improve packing. Active
        even when the governor is disabled: this is correctness, not QoS."""
        harmless = (hops_after <= hops_before
                    and achievable_after >= achievable_before - 1e-9)
        improves = (nics_after < nics_before or hops_after < hops_before)
        allowed = harmless and (improves or not require_improvement)
        self._audit("migration_verdict", allowed=allowed,
                    reason=("allowed" if allowed
                            else ("harmful" if not harmless
                                  else "no improvement")),
                    hops_before=hops_before, hops_after=hops_after,
                    achievable_before=achievable_before,
                    achievable_after=achievable_after,
                    nics_before=nics_before, nics_after=nics_after)
        return allowed

    def defrag_order(self, scored: Iterable) -> List:
        """Order defrag candidates: worst fragmentation first; at equal
        score, disturb the lowest-weight tenant first (migration costs the
        tenant an SLO-grace window — spend that on cheap contracts)."""
        return sorted(scored, key=lambda sc: (-sc.score,
                                              self.weight(sc.tenant),
                                              sc.tenant))

    # -- priority ordering (failover re-placement, scale grants) ---------------
    def priority_order(self, tenants: Iterable[str]) -> List[str]:
        """Heaviest weight first; ties break by tenant NAME, not dict
        insertion order (ISSUE 8 determinism fix: sharded and legacy
        controllers iterate tenants in different orders, so any
        registration-order dependence would make their decisions diverge).
        Used for failover re-placement and for the order scale grants draw
        down the per-tick headroom ledger: under scarcity the contracts
        the pool values most are served first."""
        return sorted(tenants, key=lambda t: (-self.weight(t), t))

    failover_order = priority_order

    def replacement_demand(self, tenant: str, lost: Dict[str, int],
                           held_units: int) -> Dict[str, int]:
        """Clamp a failover re-placement so the tenant does not come back
        above its ``max_units`` quota (quotas may shrink while deployed).
        Room is dealt round-robin across the lost stages — a greedy clamp
        could hand everything to the first stage and zero a later one,
        killing the tenant when an even split would keep every stage alive."""
        q = self.quota(tenant)
        if not self.enabled or q.max_units is None:
            return dict(lost)
        room = max(0, q.max_units - held_units)
        out = {s: 0 for s in lost}
        while room > 0:
            wanting = [s for s, u in lost.items() if out[s] < u]
            if not wanting:
                break
            for s in wanting:
                if room <= 0:
                    break
                out[s] += 1
                room -= 1
        return out

    # -- DWRR dispatch ---------------------------------------------------------
    def dwrr_schedule(self, queue_bytes: Dict[str, float],
                      rate_caps: Optional[Dict[str, float]] = None,
                      capacity_bytes: Optional[float] = None,
                      max_rounds: int = 1024
                      ) -> Tuple[List[str], Dict[str, float]]:
        """One tick of deficit-weighted round-robin over tenant ingress
        queues. Returns (dispatch order, served bytes per tenant).

        ``queue_bytes``  per-tenant queue depth (backlog + this tick's
                         arrivals) — the telemetry backlog as ingress depth.
        ``rate_caps``    per-tenant service ceiling for the tick in bytes
                         (placed capacity x dt); None = uncapped.
        ``capacity_bytes``  shared ingress budget; None = uncapped (every
                         queue drains to its own rate cap, as before the
                         governor — DWRR then only decides the order).

        Deficits persist across ticks; a tenant whose queue empties loses
        its deficit (classic DRR), so weights shape *long-run* service under
        saturation: weights 2:1:1 converge to ~2:1:1 served bytes.

        With a kernel attached (``attach_kernel``) the whole tick runs as
        one jitted array program over stacked tenant rows
        (``core.sched_kernel``); this scalar body is the pinned reference
        oracle the kernel is property-tested against.
        """
        if self._kernel is not None:
            return self._kernel.schedule(
                queue_bytes, rate_caps, capacity_bytes,
                weights={t: self.weight(t) for t in queue_bytes},
                max_rounds=max_rounds)
        queues = {t: max(0.0, q) for t, q in queue_bytes.items()}
        caps = {t: (rate_caps.get(t, math.inf) if rate_caps else math.inf)
                for t in queues}
        # Ring maintenance: keep relative order, drop leavers, append
        # arrivals in pinned priority order — weight descending then name
        # (ISSUE 8 determinism fix: dict insertion order must not leak
        # into who gets the head-of-ring edge).
        self._ring = [t for t in self._ring if t in queues]
        in_ring = set(self._ring)
        for t in sorted((t for t in queues if t not in in_ring),
                        key=lambda t: (-self.weight(t), t)):
            self._ring.append(t)

        if capacity_bytes is None:
            # Uncapped shared link: no contention to arbitrate — every queue
            # drains to its own rate cap and DWRR only decides the dispatch
            # order (most-owed first: weighted backlog descending).
            served = {t: min(queues[t], caps[t]) for t in queues}
            order = sorted(queues,
                           key=lambda t: (-queues[t] * self.weight(t), t))
            return order, served

        served = {t: 0.0 for t in queues}
        order: List[str] = []
        budget = max(0.0, capacity_bytes)
        total_w = sum(self.weight(t) for t in queues) or 1.0
        # Adaptive quantum: ~8 full rounds exhaust the budget, so weights
        # stay expressed (one giant quantum would hand the whole budget to
        # whoever the ring visits first) while rounds stay bounded.
        quantum = budget / (8.0 * total_w + 1e-9)

        def runnable(t: str) -> bool:
            return queues[t] > _EPS and served[t] < caps[t] - _EPS

        for _ in range(max_rounds):
            if budget <= _EPS or not any(runnable(t) for t in self._ring):
                break
            for t in list(self._ring):
                if not runnable(t):
                    self._deficit[t] = 0.0       # DRR: idle queues forfeit
                    continue
                self._deficit[t] = (self._deficit.get(t, 0.0)
                                    + quantum * self.weight(t))
                take = min(queues[t], self._deficit[t],
                           caps[t] - served[t], budget)
                if take > _EPS:
                    if t not in order:
                        order.append(t)
                    queues[t] -= take
                    served[t] += take
                    self._deficit[t] -= take
                    budget -= take
                if budget <= _EPS:
                    break
            # Rotate so arrival order confers no standing head-of-line edge.
            if self._ring:
                self._ring.append(self._ring.pop(0))
        # Unserved tenants trail in pinned priority order (same determinism
        # fix as the ring: no dict-order dependence in the dispatch order).
        seen = set(order)
        for t in sorted((t for t in queues if t not in seen),
                        key=lambda t: (-self.weight(t), t)):
            order.append(t)
        return order, served
