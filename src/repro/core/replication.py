"""Algorithm 1 — Partial pipeline replication (paper §5.1.1).

The paper's key data-plane idea: instead of replicating whole pipelines,
recursively split the pipeline at its minimum-latency stage `d`; every stage
`i` in the sub-pipeline *preceding* `d` is replicated ceil(L_i / L_d) times so
that the preceding stages match `d`'s processing capacity and `d` runs with no
bubbles; `d` itself gets one replica; recurse on the suffix.

Faithful to the pseudocode (variable names included). `find_min_stage`
breaks ties toward the earliest stage, which yields the most conservative
(smallest) replication factors for the prefix.
"""
from __future__ import annotations

import math
from typing import Dict, List, Sequence, Tuple


def find_min_stage(stages: Sequence[str], latency: Dict[str, float]) -> int:
    """Index of the minimum-latency stage (first on ties)."""
    best, best_lat = 0, float("inf")
    for i, s in enumerate(stages):
        if latency[s] < best_lat:
            best, best_lat = i, latency[s]
    return best


def partition(stages: Sequence[str], d: int) -> Tuple[List[str], List[str]]:
    """Split around stage index d: (S_pre strictly before d, S_post strictly after)."""
    return list(stages[:d]), list(stages[d + 1:])


def num_replication(stages: Sequence[str], latency: Dict[str, float]) -> Dict[str, int]:
    """Algorithm 1: per-stage replication counts R.

    Args:
      stages: pipeline stage names, in order.
      latency: average per-sequence processing latency of each stage
        (offline profiling, paper §6.1).

    Returns:
      R: stage name -> number of replications.
    """
    for s in stages:
        if latency[s] <= 0:
            raise ValueError(f"stage {s} has non-positive latency {latency[s]}")
    R: Dict[str, int] = {}
    S = list(stages)
    while S:
        d = find_min_stage(S, latency)
        d_name = S[d]
        S_pre, S_post = partition(S, d)
        for s in S_pre:
            R[s] = math.ceil(latency[s] / latency[d_name])
        R[d_name] = 1
        S = S_post
    return R


def num_pipelines(R: Dict[str, int]) -> int:
    """Paper §5.1.2: 'The number of pipelines equals the maximum value in R.'"""
    return max(R.values()) if R else 0


def pipeline_throughput(stages: Sequence[str], latency: Dict[str, float],
                        R: Dict[str, int] | None = None) -> float:
    """Steady-state sequences/sec of one (partially replicated) pipeline.

    A stage with replication r and latency L sustains r / L sequences/sec;
    the pipeline rate is the min over stages (the residual bottleneck).
    With R from Algorithm 1 every stage sustains at least 1/min(L), so the
    pipeline runs at the short-stage rate within each sub-pipeline.
    """
    if R is None:
        R = {s: 1 for s in stages}
    return min(R[s] / latency[s] for s in stages)


def efficiency(stages: Sequence[str], latency: Dict[str, float],
               R: Dict[str, int]) -> float:
    """Fraction of allocated stage-resource-time doing useful work.

    With throughput T (seq/s), stage s does useful work T * L_s seconds per
    second across its R_s replicas => utilization T * L_s / R_s. Resource
    efficiency is the resource-weighted mean utilization (each replica is one
    resource unit, paper Fig 2/3 notion of utilization).
    """
    T = pipeline_throughput(stages, latency, R)
    used = sum(T * latency[s] for s in stages)
    alloc = sum(R[s] for s in stages)
    return used / alloc


def full_replication(stages: Sequence[str], copies: int) -> Dict[str, int]:
    """The baseline the paper argues against (Fig 7b): replicate everything."""
    return {s: copies for s in stages}
