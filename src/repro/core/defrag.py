"""Online re-placement / defragmentation planning (ROADMAP follow-on).

Algorithm 2's locality preference is only as good as the pool looked at
submission time: tenant churn (departures, scale-downs, failovers) punches
holes into the packing, consecutive stages drift onto disjoint NICs, and the
~4.5 µs hop penalty starts dominating tail latency (the DPU measurement
study, arXiv 2301.06070, finds exactly this cross-NIC hop to be the largest
offload cost). This module scores that decay per deployment and plans a
re-placement onto a compact target NIC set; the controller executes the plan
make-before-break (``MeiliController.migrate``) so the ledger sees a plain
commit + release cycle and traffic never loses its placed capacity.

Fragmentation score per deployment (dimensionless, higher = worse):

    score = (nics_used - minimal_nics)          # excess spread
          + hop_pairs                            # consecutive stages split
          + stranded_bw / link_bw                # bandwidth paying full
                                                 # crossing price on
                                                 # colocation-free NICs

``plan_migration`` packs the deployment's *current* unit counts (capacity is
preserved, never resized here) onto the smallest free-capacity NIC prefix
that admits a full placement. Planning is pure — nothing here mutates the
pool; the commit/guard/rollback protocol lives in the controller.
"""
from __future__ import annotations

import dataclasses
from typing import TYPE_CHECKING, Dict, List, Optional, Sequence, Tuple

from repro.core.allocation import Allocation, resource_alloc
from repro.core.pool import Pool

if TYPE_CHECKING:                                    # pragma: no cover
    from repro.core.controller import Deployment


def disjoint_pairs(alloc: Allocation,
                   stages: Sequence[str]) -> List[Tuple[str, str]]:
    """Consecutive stage pairs placed on fully disjoint NIC sets — each such
    pair forces every hand-off across the network (paper §8.5 hop penalty).
    The single definition of the predicate: the defrag guard and the
    latency model (service/telemetry.hop_penalties) both build on it."""
    pairs = []
    for a, b in zip(stages, stages[1:]):
        na = set(alloc.nics_for(a))
        nb = set(alloc.nics_for(b))
        if na and nb and not (na & nb):
            pairs.append((a, b))
    return pairs


def hop_pair_count(alloc: Allocation, stages: Sequence[str]) -> int:
    return len(disjoint_pairs(alloc, stages))


def minimal_nics(dep: "Deployment", pool: Pool) -> int:
    """Capacity lower bound on the NICs this deployment needs: for each
    resource kind, its total units over the largest per-NIC capacity in the
    pool; the max over kinds (kinds can share NICs, so this is a floor)."""
    need = dep.app.resource_needs()
    demand: Dict[str, int] = {}
    for s in dep.profile.stages:
        kind = need[s]
        demand[kind] = demand.get(kind, 0) + dep.allocation.units(s)
    floor = 1
    for kind, units in demand.items():
        if units <= 0:
            continue
        per_nic = max((pool[n].spec.capacity(kind) for n in pool.names()),
                      default=0)
        if per_nic > 0:
            floor = max(floor, -(-units // per_nic))
    return floor


def stranded_bw_gbps(dep: "Deployment") -> float:
    """Bandwidth charges held on NICs where the deployment colocates no
    consecutive stage pair: every hand-off in or out of such a NIC crosses
    the link, so its whole charge pays the full crossing price."""
    stages = dep.profile.stages
    stranded = 0.0
    for n, row in dep.allocation.A.items():
        placed = [s for s in stages if row.get(s, 0) > 0]
        if not placed:
            continue
        colocated = any(row.get(a, 0) > 0 and row.get(b, 0) > 0
                        for a, b in zip(stages, stages[1:]))
        if not colocated:
            stranded += dep.allocation.bw_charge.get(n, 0.0)
    return stranded


@dataclasses.dataclass
class FragmentationScore:
    app: str
    tenant: str
    nics_used: int
    min_nics: int
    hop_pairs: int
    stranded_bw_gbps: float
    score: float


def fragmentation_score(dep: "Deployment", pool: Pool) -> FragmentationScore:
    nics_used = dep.allocation.num_nics_used()
    floor = minimal_nics(dep, pool)
    hops = hop_pair_count(dep.allocation, dep.profile.stages)
    stranded = stranded_bw_gbps(dep)
    link = max((pool[n].spec.bandwidth_gbps for n in pool.nics), default=1.0)
    score = max(0, nics_used - floor) + hops + stranded / max(link, 1e-9)
    return FragmentationScore(app=dep.app.name,
                              tenant=dep.tenant or dep.app.name,
                              nics_used=nics_used, min_nics=floor,
                              hop_pairs=hops, stranded_bw_gbps=stranded,
                              score=score)


@dataclasses.dataclass(frozen=True)
class MigrationImpact:
    """What a shadow re-placement would change — the pure inputs the QoS
    governor's do-no-harm verdict (``ResourceGovernor.migration_verdict``)
    decides on. Computed before any commit so a rejection costs nothing."""

    hops_before: int
    hops_after: int
    achievable_before: float
    achievable_after: float
    nics_before: int
    nics_after: int


def migration_impact(dep: "Deployment", shadow: Allocation,
                     achievable_after: float) -> MigrationImpact:
    stages = dep.profile.stages
    return MigrationImpact(
        hops_before=hop_pair_count(dep.allocation, stages),
        hops_after=hop_pair_count(shadow, stages),
        achievable_before=dep.achievable_gbps,
        achievable_after=achievable_after,
        nics_before=dep.allocation.num_nics_used(),
        nics_after=shadow.num_nics_used())


def _pack_order(dep: "Deployment", pool: Pool) -> List[str]:
    """Candidate destination NICs, best packing candidates first: most free
    units of the kinds this deployment needs, then most free bandwidth."""
    need = dep.app.resource_needs()
    kinds = set(need.values())

    def key(n: str):
        st = pool[n]
        return (-sum(st.available(k) for k in kinds), -st.free_bw_gbps)

    return sorted(pool.names(), key=key)


def plan_migration(dep: "Deployment", pool: Pool) -> Optional[Allocation]:
    """Shadow re-placement of the deployment's current units onto the
    smallest admissible NIC prefix (make-phase input for the controller).

    Only *free* capacity counts — the deployment still holds its source
    units while the destination is allocated, so a plan that needs the
    space the deployment itself occupies is simply not admissible yet.
    Returns None when no prefix places the full demand.
    """
    demand = {s: dep.allocation.units(s) for s in dep.profile.stages}
    if not any(demand.values()):
        return None
    need = dep.app.resource_needs()
    order = _pack_order(dep, pool)
    for k in range(1, len(order) + 1):
        shadow = resource_alloc(dep.profile.stages, demand, dep.profile.t_s,
                                pool, need, only_nics=order[:k])
        if shadow.satisfied():
            return shadow
    return None
