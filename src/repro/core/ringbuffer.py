"""Lock-free per-pipeline ring buffers (paper §5.1.2), JAX-native.

The paper allocates each (sub-)pipeline dedicated ingress / egress /
inter-stage rings out of a per-application packet-buffer pool so that
parallel pipelines never contend on a shared buffer. On TPU the same
structure is a fixed-capacity device array with monotonic head/tail
cursors; the SPMD single-writer discipline makes it lock-free by
construction. Cursors are monotonic int32 and indexed modulo capacity,
so occupancy is simply ``tail - head``.

Functional style: every operation returns a new Ring (JAX pytree).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class Ring:
    """Fixed-capacity FIFO over an arbitrary pytree of row-arrays."""

    def __init__(self, data: Any, head: jnp.ndarray, tail: jnp.ndarray, cap: int):
        self.data = data      # pytree of (cap, ...) arrays
        self.head = head      # int32 scalar, monotonic pop cursor
        self.tail = tail      # int32 scalar, monotonic push cursor
        self.cap = int(cap)

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.head, self.tail), self.cap

    @classmethod
    def tree_unflatten(cls, cap, children):
        data, head, tail = children
        return cls(data, head, tail, cap)

    # -- queries -------------------------------------------------------------
    @property
    def occupancy(self) -> jnp.ndarray:
        return self.tail - self.head

    @property
    def space(self) -> jnp.ndarray:
        return self.cap - self.occupancy


def make_ring(proto: Any, cap: int) -> Ring:
    """Allocate a ring whose rows match `proto` (a pytree of per-row arrays)."""
    data = jax.tree.map(lambda a: jnp.zeros((cap,) + tuple(a.shape), a.dtype), proto)
    return Ring(data, jnp.int32(0), jnp.int32(0), cap)


def push(ring: Ring, rows: Any, n: jnp.ndarray | int | None = None) -> Ring:
    """Append the first `n` rows of `rows` (default: all). Caller must ensure
    space; on overflow the oldest unread entries are overwritten (the paper's
    rings are sized by the controller so this does not occur in steady state —
    tests assert via `space`)."""
    k = jax.tree.leaves(rows)[0].shape[0]
    if n is None:
        n = k
    idx = (ring.tail + jnp.arange(k, dtype=jnp.int32)) % ring.cap
    keep = jnp.arange(k) < n

    def upd(buf, new):
        expand = (slice(None),) + (None,) * (new.ndim - 1)
        cur = buf[idx]
        merged = jnp.where(keep[expand], new, cur)
        return buf.at[idx].set(merged)

    data = jax.tree.map(upd, ring.data, rows)
    return Ring(data, ring.head, ring.tail + jnp.asarray(n, jnp.int32), ring.cap)


def pop(ring: Ring, k: int) -> Tuple[Ring, Any, jnp.ndarray]:
    """Remove up to `k` rows. Returns (ring, rows, valid_mask); rows beyond the
    current occupancy are garbage and masked out by `valid_mask`."""
    avail = ring.occupancy
    n = jnp.minimum(jnp.int32(k), avail)
    idx = (ring.head + jnp.arange(k, dtype=jnp.int32)) % ring.cap
    rows = jax.tree.map(lambda buf: buf[idx], ring.data)
    valid = jnp.arange(k) < n
    return Ring(ring.data, ring.head + n, ring.tail, ring.cap), rows, valid


def peek(ring: Ring, k: int) -> Tuple[Any, jnp.ndarray]:
    idx = (ring.head + jnp.arange(k, dtype=jnp.int32)) % ring.cap
    rows = jax.tree.map(lambda buf: buf[idx], ring.data)
    valid = jnp.arange(k) < ring.occupancy
    return rows, valid
