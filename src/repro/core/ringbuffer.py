"""Lock-free per-pipeline ring buffers (paper §5.1.2), JAX-native.

The paper allocates each (sub-)pipeline dedicated ingress / egress /
inter-stage rings out of a per-application packet-buffer pool so that
parallel pipelines never contend on a shared buffer. On TPU the same
structure is a fixed-capacity device array with monotonic head/tail
cursors; the SPMD single-writer discipline makes it lock-free by
construction. Cursors are monotonic int32 and indexed modulo capacity,
so occupancy is simply ``tail - head``.

Functional style: every operation returns a new Ring (JAX pytree).

Two layouts share the Ring class:

  * single-lane (``make_ring``/``push``/``pop``): leaves are (cap, ...),
    cursors are scalars — one ring per pipeline, allocated ad hoc;
  * stacked multi-lane (``make_rings``/``push_many``/``pop_many``): leaves
    are (lanes, cap, ...), cursors are (lanes,) — every pipeline's ingress
    ring lives in ONE device allocation so the fused data-plane program
    (core.executor) pushes/pops all pipelines in a single traced op with no
    per-pipeline dispatch. Lane i is pipeline i; the single-writer SPMD
    discipline per lane keeps it lock-free exactly as before.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


@jax.tree_util.register_pytree_node_class
class Ring:
    """Fixed-capacity FIFO over an arbitrary pytree of row-arrays."""

    def __init__(self, data: Any, head: jnp.ndarray, tail: jnp.ndarray, cap: int):
        self.data = data      # pytree of (cap, ...) arrays
        self.head = head      # int32 scalar, monotonic pop cursor
        self.tail = tail      # int32 scalar, monotonic push cursor
        self.cap = int(cap)

    # -- pytree plumbing ----------------------------------------------------
    def tree_flatten(self):
        return (self.data, self.head, self.tail), self.cap

    @classmethod
    def tree_unflatten(cls, cap, children):
        data, head, tail = children
        return cls(data, head, tail, cap)

    # -- queries -------------------------------------------------------------
    @property
    def occupancy(self) -> jnp.ndarray:
        return self.tail - self.head

    @property
    def space(self) -> jnp.ndarray:
        return self.cap - self.occupancy


def make_ring(proto: Any, cap: int) -> Ring:
    """Allocate a ring whose rows match `proto` (a pytree of per-row arrays)."""
    data = jax.tree.map(lambda a: jnp.zeros((cap,) + tuple(a.shape), a.dtype), proto)
    return Ring(data, jnp.int32(0), jnp.int32(0), cap)


def push(ring: Ring, rows: Any, n: jnp.ndarray | int | None = None) -> Ring:
    """Append the first `n` rows of `rows` (default: all). Caller must ensure
    space; on overflow the oldest unread entries are overwritten (the paper's
    rings are sized by the controller so this does not occur in steady state —
    tests assert via `space`)."""
    k = jax.tree.leaves(rows)[0].shape[0]
    if n is None:
        n = k
    idx = (ring.tail + jnp.arange(k, dtype=jnp.int32)) % ring.cap
    keep = jnp.arange(k) < n

    def upd(buf, new):
        expand = (slice(None),) + (None,) * (new.ndim - 1)
        cur = buf[idx]
        merged = jnp.where(keep[expand], new, cur)
        return buf.at[idx].set(merged)

    data = jax.tree.map(upd, ring.data, rows)
    return Ring(data, ring.head, ring.tail + jnp.asarray(n, jnp.int32), ring.cap)


def pop(ring: Ring, k: int) -> Tuple[Ring, Any, jnp.ndarray]:
    """Remove up to `k` rows. Returns (ring, rows, valid_mask); rows beyond the
    current occupancy are garbage and masked out by `valid_mask`."""
    avail = ring.occupancy
    n = jnp.minimum(jnp.int32(k), avail)
    idx = (ring.head + jnp.arange(k, dtype=jnp.int32)) % ring.cap
    rows = jax.tree.map(lambda buf: buf[idx], ring.data)
    valid = jnp.arange(k) < n
    return Ring(ring.data, ring.head + n, ring.tail, ring.cap), rows, valid


# -- stacked multi-lane rings (one allocation for N pipelines) ---------------

def make_rings(proto: Any, cap: int, lanes: int) -> Ring:
    """Allocate `lanes` independent rings in one stacked Ring; rows match
    `proto` (a pytree of per-row arrays)."""
    data = jax.tree.map(
        lambda a: jnp.zeros((lanes, cap) + tuple(a.shape), a.dtype), proto)
    # head and tail must be distinct buffers: the fused dispatch donates the
    # whole Ring, and XLA rejects donating one buffer through two arguments.
    return Ring(data, jnp.zeros((lanes,), jnp.int32),
                jnp.zeros((lanes,), jnp.int32), cap)


def push_many(ring: Ring, rows: Any, n: jnp.ndarray) -> Ring:
    """Append rows[i, :n[i]] to lane i, for all lanes at once.

    `rows` leaves are (lanes, M, ...); `n` is (lanes,) int32. Slots beyond
    n[i] are left untouched (masked merge), so lanes may carry different
    occupancies through one fixed-shape call. Caller ensures M <= cap and
    per-lane space >= n[i] (steady state in the executor: rings drain to
    empty every round). The single-lane `push` vmapped over lanes — one
    copy of the cursor/mask arithmetic.
    """
    return jax.vmap(push)(ring, rows, n)


def pop_many(ring: Ring, k: int) -> Tuple[Ring, Any, jnp.ndarray]:
    """Remove up to `k` rows from every lane: `pop` vmapped over lanes.
    Returns (ring, rows, valid): rows leaves are (lanes, k, ...); valid is
    (lanes, k) with rows beyond a lane's occupancy masked out (their content
    is garbage)."""
    return jax.vmap(lambda r: pop(r, k))(ring)


def peek(ring: Ring, k: int) -> Tuple[Any, jnp.ndarray]:
    idx = (ring.head + jnp.arange(k, dtype=jnp.int32)) % ring.cap
    rows = jax.tree.map(lambda buf: buf[idx], ring.data)
    valid = jnp.arange(k) < ring.occupancy
    return rows, valid
