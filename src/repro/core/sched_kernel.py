"""Vectorized per-tick scheduling kernel: tenants as rows of stacked arrays.

The scalar control path (``ResourceGovernor.dwrr_schedule``, the runtime's
backlog math, ``TelemetryLog``'s per-tenant reduction) walks a Python dict
per tenant per tick — fine at 6 tenants, a wall at the 1000-tenant /
500-NIC scale the ROADMAP targets. Following *Wave* (offload the resource-
management fast path to the device), this module re-expresses the per-tick
fast path as a dense array program over ALL tenants at once:

  ``dwrr_step``          one jitted deficit-weighted round-robin tick. The
                         scalar reference serves tenants sequentially within
                         a round; the kernel exploits that within one round
                         the budget consumed before visit position *i* is
                         ``cumsum(desired)[:i]`` — so each round is one
                         vectorized expression and the round loop is a
                         ``lax.while_loop`` with no per-tenant host work.
  ``dwrr_uncapped``      the order-only mode (``ingress_gbps=None``): every
                         queue drains to its own cap, DWRR only ranks.
  ``refill_credits``     burst token-bucket refill, all buckets at once.
  ``queue_drain``        the backlog/queue-drain math from
                         ``measure_tenant_tick`` (arrivals, served, carry).
  ``scale_decisions``    the quota/pressure/brownout clamps of
                         ``scale_verdict`` as a dense program: the fast path
                         computes every tenant's grant and flags the sparse
                         set that needs a host-side rescale.
  ``telemetry_accumulate``  running per-tenant sums/maxes — the
                         ``TelemetryLog`` reduction as one fused update.

Array layout: one row per tenant, rows pinned in the governor's
deterministic priority order (weight descending, then name — the ISSUE-8
tie-break), padded to the next power of two so churn does not recompile.
Deficits live *in the kernel state* (device-side on an accelerator host):
they persist across ticks and are only materialized to the host for the
audit trace, never in the hot loop.

A note on Pallas: this host is CPU-only (``jax.devices() == [CpuDevice]``),
where a hand-written Pallas kernel runs in interpret mode and *loses* to
XLA's fused loop emission for these (N,)-shaped programs. The kernels here
are plain jitted lax programs — the array layout is already the one a
Pallas TPU kernel would take (rows × pow2 lanes), so the port is a
backend swap, not a redesign.

The scalar path in ``core/qos.py`` stays the pinned reference oracle:
``tests/test_sched_kernel.py`` property-tests every kernel against it.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Must match core.qos._EPS: the kernels replicate the scalar oracle's
# epsilon decisions (take > eps, budget > eps, runnable checks) exactly.
_EPS = 1e-9

# Kernel (re)trace counter: incremented at TRACE time only (the Python body
# of a jitted function runs once per compilation), so steady-state ticks
# leave it untouched — the tier-1 smoke asserts exactly that.
_TRACE_COUNTS: Dict[str, int] = {}


def _count_trace(name: str) -> None:
    _TRACE_COUNTS[name] = _TRACE_COUNTS.get(name, 0) + 1


def trace_counts() -> Dict[str, int]:
    """Compilations per kernel since ``reset_trace_counts`` (steady state
    must not grow these)."""
    return dict(_TRACE_COUNTS)


def reset_trace_counts() -> None:
    _TRACE_COUNTS.clear()


def pad_rows(n: int, minimum: int = 8) -> int:
    """Pow-2 row bucketing: tenant churn re-pads instead of re-tracing."""
    size = minimum
    while size < n:
        size *= 2
    return size


# -- DWRR ----------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("max_rounds",))
def dwrr_step(queues: jnp.ndarray, weights: jnp.ndarray,
              deficits: jnp.ndarray, caps: jnp.ndarray, mask: jnp.ndarray,
              budget: jnp.ndarray, ring_offset: jnp.ndarray,
              max_rounds: int = 1024):
    """One capped DWRR tick over stacked tenant rows.

    Mirrors the scalar ``ResourceGovernor.dwrr_schedule`` capped branch:
    per round, visit rows in ring order (base order rolled by
    ``ring_offset + round``); runnable rows earn ``quantum * weight`` of
    deficit and take ``min(queue, deficit, cap - served, budget_left)``;
    idle rows forfeit their deficit; the round loop stops when the budget
    or the runnable set is exhausted. Within a round the sequential budget
    is vectorized via the cumulative-desired identity (see module doc).

    Returns ``(served, new_deficits, stamps, rounds)`` where ``stamps[i]``
    is the global visit position of row *i*'s first non-zero take (-1 =
    never served) — the host derives the dispatch order from it.
    """
    _count_trace("dwrr_step")
    n = queues.shape[0]
    idx = jnp.arange(n)
    active0 = mask > 0.0
    total_w = jnp.sum(jnp.where(active0, weights, 0.0))
    total_w = jnp.where(total_w > 0.0, total_w, 1.0)
    budget0 = jnp.maximum(0.0, budget)
    quantum = budget0 / (8.0 * total_w + 1e-9)

    # The ring permutation is a pure cyclic shift, so the loop runs in the
    # rotating *ring frame*: every carry array is pre-rolled so that the
    # current round's visit order is plain index order, and each round ends
    # with a roll-by-one (two contiguous slices — no gather/scatter with
    # arbitrary indices, which is what would make each round O(n) strided).
    def ring(x):
        return jnp.roll(x, -ring_offset)

    def cond(carry):
        q, served, d, w, c, m, stamps, b, r = carry
        runnable_any = jnp.any(m & (q > _EPS) & (served < c - _EPS))
        return (r < max_rounds) & (b > _EPS) & runnable_any

    def body(carry):
        q, served, d, w, c, m, stamps, b, r = carry
        runnable = m & (q > _EPS) & (served < c - _EPS)
        d_inc = jnp.where(runnable, d + quantum * w, d)
        desired = jnp.where(
            runnable,
            jnp.minimum(jnp.minimum(q, d_inc), c - served), 0.0)
        prev = jnp.concatenate(
            [jnp.zeros((1,), desired.dtype), jnp.cumsum(desired)[:-1]])
        # Sequential-budget identity: rows before the truncation point take
        # their full desired, the truncated row takes the remainder, rows
        # after take nothing — exactly the scalar walk's outcome.
        take = jnp.clip(b - prev, 0.0, desired)
        take = jnp.where(take > _EPS, take, 0.0)
        # The scalar walk breaks AFTER the row that exhausts the budget:
        # later rows are unvisited (no deficit earn, no idle forfeit).
        visited = (b - prev) > _EPS
        d_new = jnp.where(visited & runnable, d_inc - take,
                          jnp.where(visited & ~runnable & m, 0.0, d))
        stamps = jnp.where((take > _EPS) & (stamps < 0),
                           r * n + idx, stamps)
        roll1 = lambda x: jnp.roll(x, -1)  # noqa: E731 — next round's frame
        return (roll1(q - take), roll1(served + take), roll1(d_new),
                roll1(w), roll1(c), roll1(m), roll1(stamps),
                b - jnp.sum(take), r + 1)

    init = (ring(jnp.maximum(queues, 0.0)), ring(jnp.zeros_like(queues)),
            ring(deficits), ring(weights), ring(caps), ring(active0),
            ring(jnp.full((n,), -1, dtype=jnp.int32)),
            budget0, jnp.zeros((), dtype=jnp.int32))
    out = jax.lax.while_loop(cond, body, init)
    _, served, deficits, _, _, _, stamps, _, rounds = out
    # After R rounds the frame is shifted by ring_offset + R: undo once.
    unroll = ring_offset + rounds
    served = jnp.roll(served, unroll)
    deficits = jnp.roll(deficits, unroll)
    stamps = jnp.roll(stamps, unroll)
    return served, deficits, stamps, rounds


@jax.jit
def dwrr_uncapped(queues: jnp.ndarray, weights: jnp.ndarray,
                  caps: jnp.ndarray, mask: jnp.ndarray):
    """Order-only mode (``capacity_bytes=None``): each queue drains to its
    own cap; the returned key ranks dispatch most-owed-first (weighted
    backlog descending — the scalar path's exact sort key)."""
    _count_trace("dwrr_uncapped")
    q = jnp.maximum(queues, 0.0)
    served = jnp.where(mask > 0.0, jnp.minimum(q, caps), 0.0)
    return served, q * weights


# -- burst buckets / backlog ---------------------------------------------------

@jax.jit
def refill_credits(credits: jnp.ndarray, depth: jnp.ndarray,
                   refill: jnp.ndarray) -> jnp.ndarray:
    """Token-bucket refill for every tenant at once (scalar reference:
    the ``begin_tick`` credit loop)."""
    _count_trace("refill_credits")
    out = jnp.minimum(depth, credits + refill)
    return jnp.where(depth > 0.0, out, credits)


@jax.jit
def queue_drain(offered_pps: jnp.ndarray, backlog_pkts: jnp.ndarray,
                cap_pps: jnp.ndarray, served_pkts: jnp.ndarray,
                dt_s: jnp.ndarray):
    """The backlog/queue-drain math of ``measure_tenant_tick`` (arrivals,
    service, carried backlog, achieved pps), all tenants at once."""
    _count_trace("queue_drain")
    arriving = jnp.maximum(offered_pps, 0.0) * dt_s \
        + jnp.maximum(backlog_pkts, 0.0)
    served = jnp.minimum(arriving, jnp.maximum(cap_pps, 0.0) * dt_s)
    served = jnp.minimum(served, jnp.maximum(served_pkts, 0.0))
    new_backlog = arriving - served
    achieved_pps = jnp.where(dt_s > 0.0, served / dt_s, 0.0)
    return served, new_backlog, achieved_pps


# -- governor fast path --------------------------------------------------------

@jax.jit
def scale_decisions(est_gbps: jnp.ndarray, offered_gbps: jnp.ndarray,
                    contract_gbps: jnp.ndarray, current_gbps: jnp.ndarray,
                    achievable_gbps: jnp.ndarray, quota_gbps: jnp.ndarray,
                    credits: jnp.ndarray, weights: jnp.ndarray,
                    brownout: jnp.ndarray, wmax: jnp.ndarray,
                    headroom: jnp.ndarray, floor_frac: jnp.ndarray,
                    pressure_frac: jnp.ndarray,
                    rescale_threshold: jnp.ndarray):
    """The Gbps clamps of ``ResourceGovernor.scale_verdict`` as one dense
    program: desired/pressure/quota+burst/brownout, then the rescale flag.

    ``quota_gbps`` uses +inf for "uncapped"; ``brownout`` is the base level
    (>= 1.0 means off). Unit/headroom-ledger accounting stays host-side:
    the flagged rows are the sparse set the host walks — the whole point of
    the split (O(tenants) device work, O(rescales) host work).
    """
    _count_trace("scale_decisions")
    desired = jnp.maximum(floor_frac * contract_gbps, est_gbps * headroom)
    pressure = offered_gbps > pressure_frac * jnp.maximum(achievable_gbps,
                                                          1e-9)
    desired = jnp.where(pressure,
                        jnp.maximum(desired, offered_gbps * headroom),
                        desired)
    over = jnp.maximum(0.0, desired - quota_gbps)
    burn = jnp.minimum(over, jnp.maximum(credits, 0.0))
    cap = jnp.where(jnp.isfinite(quota_gbps), quota_gbps + burn, desired)
    granted = jnp.minimum(desired, cap)
    # Brownout: weight-proportional clamp toward b * contract; burst credit
    # cannot buy out a brownout (burn zeroed on clamped rows).
    bfac = brownout + (1.0 - brownout) * weights / jnp.maximum(wmax, 1e-9)
    bfac = jnp.where(brownout >= 1.0, 1.0, bfac)
    bcap = jnp.maximum(floor_frac * contract_gbps, bfac * contract_gbps)
    browned = (bfac < 1.0) & (granted > bcap + _EPS)
    granted = jnp.where(browned, bcap, granted)
    burn = jnp.where(browned, 0.0, burn)
    gap = jnp.abs(granted - current_gbps) / jnp.maximum(contract_gbps, 1e-9)
    scaling_up = granted > current_gbps + 1e-9
    rescale = (scaling_up & (pressure | (gap > rescale_threshold))) \
        | (~scaling_up & (gap > rescale_threshold))
    return granted, rescale, pressure, browned, burn


@jax.jit
def telemetry_accumulate(state, offered_gbps, achieved_gbps, backlog_pkts,
                         units, mask):
    """One fused update of the per-tenant running reduction the scalar
    ``TelemetryLog.summary`` loop performs at end of run: counts, sums for
    the means, maxes for the peaks."""
    _count_trace("telemetry_accumulate")
    count, s_off, s_ach, mx_back, s_units = state
    m = mask
    return (count + m,
            s_off + offered_gbps * m,
            s_ach + achieved_gbps * m,
            jnp.maximum(mx_back, jnp.where(m > 0, backlog_pkts, -jnp.inf)),
            s_units + units * m)


def telemetry_state(n: int):
    """Fresh accumulator state for ``telemetry_accumulate`` (n rows)."""
    z = jnp.zeros((n,), dtype=jnp.float32)
    return (z, z, z, jnp.full((n,), -jnp.inf, dtype=jnp.float32), z)


# -- per-tenant reduction for TelemetryLog.summary (host-side, one-shot) -------

def telemetry_reduce_np(idx: np.ndarray, n_tenants: int,
                        means: Dict[str, np.ndarray],
                        maxes: Dict[str, np.ndarray]
                        ) -> Tuple[np.ndarray, Dict[str, np.ndarray],
                                   Dict[str, np.ndarray]]:
    """Segment-reduce per-record fields to per-tenant stats in one pass:
    ``idx`` maps each record to its tenant row. Returns (counts, per-field
    means, per-field maxes). Replaces the O(tenants x ticks) dict loops in
    ``TelemetryLog.summary`` — called once per report, numpy is the right
    backend (no reuse to amortize a device transfer against)."""
    counts = np.bincount(idx, minlength=n_tenants).astype(float)
    safe = np.maximum(counts, 1.0)
    out_means = {k: np.bincount(idx, weights=np.asarray(v, dtype=float),
                                minlength=n_tenants) / safe
                 for k, v in means.items()}
    out_maxes = {}
    for k, v in maxes.items():
        acc = np.full(n_tenants, -np.inf)
        np.maximum.at(acc, idx, np.asarray(v, dtype=float))
        out_maxes[k] = acc
    return counts, out_means, out_maxes


# -- dict-world adapter --------------------------------------------------------

class VectorizedScheduler:
    """Stateful adapter between the governor's dict world and the stacked-
    array kernels. Owns the persistent kernel state: row mapping (pinned
    priority order: weight descending, then name), deficits, the ring
    offset, padded to pow-2 rows so churn re-pads instead of re-tracing.

    ``schedule`` is a drop-in for the scalar ``dwrr_schedule`` body —
    same (order, served) contract — used when the governor runs with an
    attached kernel (``RuntimeConfig.vectorized_sched`` /
    ``ResourceGovernor.attach_kernel``).
    """

    def __init__(self, max_rounds: int = 1024):
        self.max_rounds = max_rounds
        self.names: List[str] = []
        self._row: Dict[str, int] = {}
        self._padded = 0
        self._weights = np.zeros(0, dtype=np.float32)
        self._mask = np.zeros(0, dtype=np.float32)
        self._deficits = jnp.zeros(0, dtype=jnp.float32)
        self._ring_offset = 0

    # -- membership ------------------------------------------------------------
    def sync(self, weights: Dict[str, float]) -> None:
        """(Re)build the row mapping when membership or weights changed.
        Deficits carry over by name; leavers are dropped (the scalar path
        forgets their deficit too)."""
        names = sorted(weights, key=lambda t: (-weights[t], t))
        if (names == self.names
                and all(np.float32(weights[t]) == self._weights[self._row[t]]
                        for t in names)):
            return
        old_def = {t: float(np.asarray(self._deficits)[self._row[t]])
                   for t in self.names if t in weights}
        self.names = names
        self._row = {t: i for i, t in enumerate(names)}
        self._padded = pad_rows(len(names))
        self._weights = np.zeros(self._padded, dtype=np.float32)
        self._mask = np.zeros(self._padded, dtype=np.float32)
        for t, i in self._row.items():
            self._weights[i] = weights[t]
            self._mask[i] = 1.0
        deficits = np.zeros(self._padded, dtype=np.float32)
        for t, d in old_def.items():
            deficits[self._row[t]] = d
        self._deficits = jnp.asarray(deficits)
        self._ring_offset = 0

    def deficit(self, tenant: str) -> float:
        """Host view of a device-resident deficit (audit/debug only)."""
        i = self._row.get(tenant)
        return float(np.asarray(self._deficits)[i]) if i is not None else 0.0

    # -- the per-tick call -----------------------------------------------------
    def schedule(self, queue_bytes: Dict[str, float],
                 rate_caps: Optional[Dict[str, float]],
                 capacity_bytes: Optional[float],
                 weights: Dict[str, float],
                 max_rounds: Optional[int] = None
                 ) -> Tuple[List[str], Dict[str, float]]:
        self.sync(weights)
        n = self._padded
        q = np.zeros(n, dtype=np.float32)
        caps = np.full(n, np.inf, dtype=np.float32)
        for t, v in queue_bytes.items():
            i = self._row[t]
            q[i] = max(0.0, v)
            if rate_caps is not None and t in rate_caps:
                caps[i] = rate_caps[t]

        if capacity_bytes is None:
            served_a, key = dwrr_uncapped(jnp.asarray(q), self._weights,
                                          jnp.asarray(caps), self._mask)
            served_np = np.asarray(served_a)
            key_np = np.asarray(key)
            order = sorted(queue_bytes,
                           key=lambda t: (-float(key_np[self._row[t]]), t))
            return order, {t: float(served_np[self._row[t]])
                           for t in queue_bytes}

        served_a, self._deficits, stamps, rounds = dwrr_step(
            jnp.asarray(q), jnp.asarray(self._weights), self._deficits,
            jnp.asarray(caps), jnp.asarray(self._mask),
            jnp.float32(max(0.0, capacity_bytes)),
            jnp.int32(self._ring_offset),
            max_rounds=max_rounds or self.max_rounds)
        self._ring_offset = (self._ring_offset + int(rounds)) % max(1, n)
        served_np = np.asarray(served_a)
        stamps_np = np.asarray(stamps)
        stamped = [(int(stamps_np[self._row[t]]), t) for t in queue_bytes
                   if stamps_np[self._row[t]] >= 0]
        order = [t for _, t in sorted(stamped)]
        seen = set(order)
        # Unserved tenants trail in pinned priority order — the scalar
        # path's post-fix fill with the ISSUE-8 deterministic tie-break.
        order += [t for t in self.names if t in queue_bytes
                  and t not in seen]
        return order, {t: float(served_np[self._row[t]])
                       for t in queue_bytes}
