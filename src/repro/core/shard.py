"""Sharded control plane: per-rack ControlShards under a thin global facade.

Following *Wave* (resource management offloaded next to the data path) and
the OVS slow-path/fast-path split, the controller is split along the pool's
failure domains: each ``ControlShard`` owns the disjoint NIC subset of one
rack and handles admission, scale growth, and failover re-placement for the
tenants placed within it. Shards exchange state through an explicit
eventual-consistency step — ``reconcile()`` refreshes each shard's
*headroom digest* (free units + bandwidth, by kind) at a bounded staleness
(``staleness_ticks``), and cross-rack decisions consult the digests, never
another shard's live pool rows.

Consequences the tests pin down:

  * Placement is shard-local first: a tenant's growth and failover
    re-placement are restricted to its owning shard's NICs; only when the
    shard cannot fit the demand does the facade spill pool-wide, audited
    as a ``cross_rack_placement`` decision with the ``shard`` label
    (``DecisionTrace.why`` then explains the placement end to end).
  * Failure domains map to shard ownership: a NIC's shard is its rack,
    gray-drain targets prefer the sick NIC's shard, and fault records
    carry the owning shard.
  * Bit-compatibility contract: with ONE shard the facade is the legacy
    ``MeiliController`` — same placements, same trace event sequence (the
    ``shard`` labels aside), same telemetry. ``tests/test_shard.py``
    byte-compares the two.

Stale digests are a feature, not a bug: the digest may claim headroom the
pool no longer has (another shard placed into the window). The spill path
absorbs the miss — placement falls back to pool truth — so staleness costs
a cross-rack hop, never correctness.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Optional, Set

from repro.core.allocation import resource_alloc
from repro.core.controller import Deployment, MeiliController
from repro.core.pool import Pool
from repro.core.qos import ResourceGovernor
from repro.obs import Obs


class ControlShard:
    """One rack's control-plane slice: the NIC subset it owns, the tenants
    placed within it, and its (possibly stale) headroom digest."""

    def __init__(self, name: str, nics: List[str]):
        self.name = name
        self.nics = list(nics)
        self.tenants: Set[str] = set()
        self.digest: Dict[str, int] = {}       # kind -> free units
        self.digest_bw_gbps: float = 0.0
        self.digest_tick: int = -1             # when the digest was taken

    def refresh(self, pool: Pool, tick: int) -> None:
        """Re-snapshot the digest from pool truth (the reconcile step)."""
        free: Dict[str, int] = {}
        bw = 0.0
        for n in self.nics:
            st = pool[n]
            if not st.alive:
                continue
            for kind, units in st.free.items():
                free[kind] = free.get(kind, 0) + units
            bw += st.free_bw_gbps
        self.digest = free
        self.digest_bw_gbps = bw
        self.digest_tick = tick

    def digest_fit(self, demand_by_kind: Dict[str, int]) -> bool:
        """Does the digest CLAIM the demand fits? (Eventually consistent —
        the answer may be stale; the spill path absorbs wrong yeses.)"""
        return all(self.digest.get(kind, 0) >= units
                   for kind, units in demand_by_kind.items())

    def score(self, demand_by_kind: Dict[str, int]) -> float:
        """Headroom score for placement choice: the binding kind's slack
        ratio (how many copies of the demand the digest claims to hold)."""
        ratios = [self.digest.get(kind, 0) / units
                  for kind, units in demand_by_kind.items() if units > 0]
        return min(ratios) if ratios else float(sum(self.digest.values()))


class ShardedController(MeiliController):
    """Thin global facade over per-rack ControlShards.

    The facade still owns the global ``deployments`` map and the pool
    ledger (pool truth stays single-writer through commit/release); what
    shards own is *decision scope*: which NICs a tenant's placements may
    touch, and which shard's label every verdict about it carries.
    """

    def __init__(self, pool: Pool,
                 clock: Callable[[], float] = time.monotonic,
                 governor: Optional[ResourceGovernor] = None,
                 obs: Optional[Obs] = None,
                 staleness_ticks: int = 4):
        super().__init__(pool, clock, governor, obs)
        racks = sorted({st.spec.rack for st in pool.nics.values()})
        self.shards: Dict[str, ControlShard] = {
            r: ControlShard(r, pool.rack_members(r)) for r in racks}
        self.staleness_ticks = max(1, int(staleness_ticks))
        self._owner: Dict[str, str] = {}       # tenant -> shard name
        self.last_shard: Dict[str, str] = {}   # sticky through park/evict
        self._tick = 0
        # Governor verdicts carry the owning shard's label from here on.
        self.governor.shard_resolver = self.shard_of
        for sh in self.shards.values():
            sh.refresh(pool, -1)

    # -- shard facade hooks ----------------------------------------------------
    def shard_of(self, tenant: Optional[str]) -> Optional[str]:
        if tenant is None:
            return None
        return self._owner.get(tenant) or self.last_shard.get(tenant)

    def shard_of_nic(self, nic: Optional[str]) -> Optional[str]:
        if nic is None or nic not in self.pool.nics:
            return None
        return self.pool.nics[nic].spec.rack

    def reconcile(self, tick: Optional[int] = None) -> None:
        """The eventual-consistency step: refresh every digest whose age
        reached the staleness bound. Between reconciles shards decide on
        the stale snapshot — that is the consistency model, and the spill
        path is what makes it safe. Multi-shard refreshes are audited as a
        ``reconcile`` span (single-shard reconciliation is vacuous and
        stays silent: the 1-shard trace is the legacy trace)."""
        if tick is not None:
            self._tick = tick
        tick = self._tick
        stale = [sh for _, sh in sorted(self.shards.items())
                 if tick - sh.digest_tick >= self.staleness_ticks]
        if not stale:
            return
        if len(self.shards) <= 1:
            for sh in stale:
                sh.refresh(self.pool, tick)
            return
        with self.obs.trace.span(
                "reconcile", tick=tick,
                shards=[sh.name for sh in stale]) as sp:
            ages = {sh.name: tick - sh.digest_tick for sh in stale}
            for sh in stale:
                sh.refresh(self.pool, tick)
            sp.note(staleness_bound=self.staleness_ticks, ages=ages,
                    digests={sh.name: dict(sh.digest) for sh in stale})

    # -- placement routing -----------------------------------------------------
    def _demand_by_kind(self, stages, demand: Dict[str, int],
                        need: Dict[str, str]) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for s in stages:
            u = demand.get(s, 0)
            if u > 0:
                kind = need[s]
                out[kind] = out.get(kind, 0) + u
        return out

    def _choose_shard(self, tenant: str, by_kind: Dict[str, int]) -> str:
        """Admission-time shard choice, from digests alone (cross-rack
        state is only ever consulted through the reconcile snapshot)."""
        return max(sorted(self.shards),
                   key=lambda r: self.shards[r].score(by_kind))

    def _alloc_for(self, tenant: str, stages, demand: Dict[str, int],
                   t_s, need: Dict[str, str], op: str = "place"):
        by_kind = self._demand_by_kind(stages, demand, need)
        shard = self._owner.get(tenant)
        if shard is None:
            shard = self._choose_shard(tenant, by_kind)
            self._owner[tenant] = shard
            self.shards[shard].tenants.add(tenant)
            self.last_shard[tenant] = shard
        local = self.shards[shard].nics
        alloc = resource_alloc(stages, demand, t_s, self.pool, need,
                               only_nics=local)
        if alloc.satisfied() or len(self.shards) <= 1:
            return alloc
        # Cross-rack spill: the shard (or its stale digest) could not fit
        # the demand — re-place pool-wide and audit the verdict so
        # ``why(tenant, tick)`` explains the cross-rack placement.
        unmet = {s: u for s, u in alloc.unmet.items() if u > 0}
        spilled = resource_alloc(stages, demand, t_s, self.pool, need)
        self.obs.trace.event(
            "cross_rack_placement", tenant=tenant, shard=shard, op=op,
            unmet_local=unmet,
            digest_claimed_fit=self.shards[shard].digest_fit(by_kind),
            reason="shard headroom exhausted; placed pool-wide")
        return spilled

    # -- ownership maintenance -------------------------------------------------
    def _account(self, dep: Deployment) -> None:
        super()._account(dep)
        tenant = dep.tenant or dep.app.name
        units_by_rack: Dict[str, int] = {}
        for nic, row in dep.allocation.A.items():
            held = sum(u for u in row.values() if u > 0)
            if held > 0:
                rack = self.pool.nics[nic].spec.rack
                units_by_rack[rack] = units_by_rack.get(rack, 0) + held
        if not units_by_rack:
            return
        owner = max(sorted(units_by_rack),
                    key=lambda r: units_by_rack[r])
        prev = self._owner.get(tenant)
        if owner != prev:
            if prev is not None:
                self.shards[prev].tenants.discard(tenant)
            self._owner[tenant] = owner
            self.shards[owner].tenants.add(tenant)
            self.last_shard[tenant] = owner
            if prev is not None and len(self.shards) > 1:
                # Migration/failover moved the placement's center of mass
                # across racks: ownership follows the units.
                self.obs.trace.event("shard_handoff", tenant=tenant,
                                     shard=owner, shard_from=prev,
                                     units_by_rack=units_by_rack)

    def terminate(self, app_name: str) -> None:
        dep = self.deployments.get(app_name)
        tenant = (dep.tenant or app_name) if dep is not None else app_name
        super().terminate(app_name)
        owner = self._owner.pop(tenant, None)
        if owner is not None:
            self.shards[owner].tenants.discard(tenant)
            self.last_shard[tenant] = owner

    # -- flight recorder -------------------------------------------------------
    def flight_state(self) -> Dict[str, dict]:
        """Shard-labeled flight snapshot (ISSUE 10): every NIC row carries
        its owning shard and each shard reports its digest age + tenant
        count — so an incident bundle taken under the sharded controller
        reconstructs which failure domain the incident lived in."""
        state = super().flight_state()
        for n, row in state["nics"].items():
            row["shard"] = self.shard_of_nic(n)
        state["shards"] = {
            name: {"digest_tick": sh.digest_tick,
                   "tenants": len(sh.tenants),
                   "digest_bw_gbps": sh.digest_bw_gbps}
            for name, sh in sorted(self.shards.items())}
        return state

    # -- gray-drain routing ----------------------------------------------------
    def drain_nic_candidates(self, nic: str,
                             exclude: Optional[set] = None) -> List[List[str]]:
        """Drains route through the owning shard first: keeping the
        re-placement inside the sick NIC's failure domain preserves the
        rack's locality and leaves the other shards' headroom untouched —
        the pool-wide healthy set is the fallback."""
        base = super().drain_nic_candidates(nic, exclude)
        shard = self.shard_of_nic(nic)
        if shard is None or len(self.shards) <= 1:
            return base
        local = [n for n in base[0]
                 if self.pool.nics[n].spec.rack == shard]
        if local and local != base[0]:
            return [local] + base
        return base
