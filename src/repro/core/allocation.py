"""Algorithms 2 & 3 — Locality-aware resource allocation (paper §6.1, App. E).

Meili Controller places each pipeline stage's replicas onto pool members
(SmartNICs / TPU device groups) with a three-level NIC preference:

  (1) NICs already hosting the *preceding* stage s+ (locality: consecutive
      stages on one NIC avoid inter-stage traffic on the network),
  (2) NICs with the most available bandwidth,
  (3) NICs with the most available resources for this stage.

Bandwidth accounting follows Algorithm 3: when s colocates with s+, the
bandwidth s+ consumed is credited back (local hand-off does not cross the
link twice); allocations are capped so allocated-throughput <= available
bandwidth, splitting across NICs otherwise (`allocate_on_bw`). The credit
is applied at most once per (NIC, stage) pair — the allocation loop may
revisit a NIC for the same stage, and re-crediting would conjure bandwidth.

Every Allocation records its per-NIC **net bandwidth charge** (`bw_charge`):
exactly what `resource_alloc` subtracted from each NIC's free bandwidth,
colocation credits and bandwidth-capped placements included. `commit` takes
that charge from the pool and `release` credits back exactly that — never
the naive `units * t_s` sum, which over-credits whenever colocated stages
shared bandwidth (the drift this module used to mask with a capacity clamp).

The paper applies the three preferences lexicographically ("three steps",
§6.1); we implement them as one stable lexicographic sort. Termination
guard added for pool exhaustion (paper: "best-effort placement").
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.core.pool import Pool


@dataclasses.dataclass
class Allocation:
    """Result of resource_alloc: the paper's allocation matrix A plus leftovers."""

    A: Dict[str, Dict[str, int]]          # nic -> stage -> allocated units
    unmet: Dict[str, int]                  # stage -> units that could not be placed
    bw_after: Dict[str, float]             # nic -> remaining bandwidth (Gbps)
    # nic -> net Gbps this allocation took from the NIC's free bandwidth
    # (colocation credits and bandwidth-capped placements already netted out).
    # This is the authoritative ledger entry: release credits exactly this.
    bw_charge: Dict[str, float] = dataclasses.field(default_factory=dict)

    def nics_for(self, stage: str) -> List[str]:
        return [n for n, row in self.A.items() if row.get(stage, 0) > 0]

    def units(self, stage: str) -> int:
        return sum(row.get(stage, 0) for row in self.A.values())

    def satisfied(self) -> bool:
        return not any(self.unmet.values())

    def num_nics_used(self) -> int:
        return sum(1 for row in self.A.values() if any(v > 0 for v in row.values()))

    def merge(self, extra: "Allocation") -> None:
        """Fold an incremental allocation (scale-up / failover replacement /
        migration make-phase) into this one: unit rows add, bandwidth charges
        add, and the remaining-bandwidth view adopts the newer computation."""
        for n, row in extra.A.items():
            for s, u in row.items():
                if u > 0:
                    self.A.setdefault(n, {})[s] = \
                        self.A.get(n, {}).get(s, 0) + u
        for n, c in extra.bw_charge.items():
            if c > 0.0:
                self.bw_charge[n] = self.bw_charge.get(n, 0.0) + c
        self.bw_after.update(extra.bw_after)


def _alloc_get(A: Dict[str, Dict[str, int]], n: str, s: Optional[str]) -> int:
    if s is None:
        return 0
    return A.get(n, {}).get(s, 0)


def find_next_nic(N: Sequence[str],
                  r_nic: Dict[str, int],
                  b_nic: Dict[str, float],
                  A: Dict[str, Dict[str, int]],
                  s: str, s_prev: Optional[str],
                  excluded: frozenset = frozenset()) -> Optional[str]:
    """Algorithm 2, lines 15-28: pick the next NIC for stage s."""
    # location_sort -> bw_sort -> resource_sort, lexicographic (see module doc).
    order = sorted(
        N,
        key=lambda n: (
            -(1 if _alloc_get(A, n, s_prev) > 0 else 0),  # (1) locality w.r.t. s+
            -b_nic[n],                                     # (2) available bandwidth
            -r_nic[n],                                     # (3) available resources
        ),
    )
    for n in order:
        if n in excluded:
            continue
        if r_nic[n] <= 0:
            continue  # no available resource (line 20-22)
        if _alloc_get(A, n, s_prev) <= 0 and b_nic[n] <= 0:
            continue  # no sharable BW from s+ and no available BW (line 23-26)
        return n
    return None


def _update_bw(b_nic: Dict[str, float], t_s: Dict[str, float],
               n: str, s: str, newly: int) -> None:
    """Charge the bandwidth consumed by `newly` units of stage s on NIC n."""
    b_nic[n] = max(0.0, b_nic[n] - newly * t_s[s])


def _allocate_on_bw(r_s: Dict[str, int], t_s: Dict[str, float],
                    r_nic: Dict[str, int], b_nic: Dict[str, float],
                    A: Dict[str, Dict[str, int]], n: str, s: str) -> int:
    """Algorithm 3, lines 31-36: allocate only up to the bandwidth limit.

    Boundary extension to the paper's pseudocode: a unit whose peak
    throughput exceeds the NIC's remaining bandwidth (floor == 0) may still
    be placed when bandwidth remains — it simply runs bandwidth-capped
    (otherwise such stages could never be placed at all)."""
    d = int(math.floor(b_nic[n] / t_s[s]))
    if d == 0 and b_nic[n] > 0:
        d = 1
    d = min(d, r_nic[n], r_s[s])
    A.setdefault(n, {})[s] = A.get(n, {}).get(s, 0) + d
    r_nic[n] -= d
    r_s[s] -= d
    _update_bw(b_nic, t_s, n, s, d)
    return d


def alloc_one_nic(r_s: Dict[str, int], t_s: Dict[str, float],
                  r_nic: Dict[str, int], b_nic: Dict[str, float],
                  A: Dict[str, Dict[str, int]],
                  n: str, s: str, s_prev: Optional[str],
                  credited: Optional[Set[Tuple[str, str]]] = None) -> int:
    """Algorithm 3 (App. E): allocate stage s's units on the chosen NIC n.

    Returns the number of units placed (0 => NIC unusable for s right now).
    `credited` tracks (nic, stage) pairs whose colocation credit has already
    been applied: the allocation loop can revisit a NIC for the same stage
    (bandwidth exhausted but cores left), and re-applying the credit would
    mint bandwidth out of nothing and over-allocate past the link.
    """
    credit = 0.0
    if _alloc_get(A, n, s_prev) > 0 and (credited is None
                                         or (n, s) not in credited):
        # s+ and s colocate on n => s may reuse the bandwidth s+ consumed
        # (the hand-off is local; credit it back). Algorithm 3 lines 10-12.
        credit = _alloc_get(A, n, s_prev) * t_s[s_prev]
        b_nic[n] += credit
        if credited is not None:
            credited.add((n, s))

    if r_s[s] >= r_nic[n]:
        if r_nic[n] * t_s[s] <= b_nic[n]:
            d = r_nic[n]
            A.setdefault(n, {})[s] = A.get(n, {}).get(s, 0) + d
            r_s[s] -= d
            r_nic[n] = 0
            _update_bw(b_nic, t_s, n, s, d)
            return d
        d = _allocate_on_bw(r_s, t_s, r_nic, b_nic, A, n, s)
    else:
        if r_s[s] * t_s[s] <= b_nic[n]:
            d = r_s[s]
            A.setdefault(n, {})[s] = A.get(n, {}).get(s, 0) + d
            r_nic[n] -= d
            r_s[s] = 0
            _update_bw(b_nic, t_s, n, s, d)
            return d
        d = _allocate_on_bw(r_s, t_s, r_nic, b_nic, A, n, s)
    if d == 0 and credit > 0.0:
        # Nothing placed after all (cannot happen while the forced d=1
        # boundary extension holds, since the credit leaves b_nic > 0 —
        # but a phantom credit surviving a failed placement would silently
        # understate bw_charge, so roll it back defensively).
        b_nic[n] -= credit
        if credited is not None:
            credited.discard((n, s))
    return d


def resource_alloc(S: Sequence[str],
                   r_s: Dict[str, int],
                   t_s: Dict[str, float],
                   pool: Pool,
                   need: Dict[str, str],
                   only_nics: Optional[Sequence[str]] = None) -> Allocation:
    """Algorithm 2: place every stage's required units onto the pool.

    Args:
      S: pipeline stages in order.
      r_s: total per-stage required units (controller demand calc, §6.1).
      t_s: profiled per-unit stage throughput in Gbps.
      pool: the NIC pool (only `alive` members are considered).
      need: stage -> resource kind it consumes ("cpu" or an accelerator name).
      only_nics: restrict placement to this subset of the pool — used by the
        defragmenter to pack a deployment onto a chosen compact target set.

    Returns an Allocation; `unmet` is non-empty iff the pool could not satisfy
    the demand (best-effort placement, paper §6.1).
    """
    N = pool.names()
    if only_nics is not None:
        allowed = set(only_nics)
        N = [n for n in N if n in allowed]
    remaining = {s: int(r_s[s]) for s in S}
    bw_before = {n: pool[n].free_bw_gbps for n in N}
    b_nic = dict(bw_before)
    A: Dict[str, Dict[str, int]] = {n: {} for n in N}
    credited: Set[Tuple[str, str]] = set()
    # Per-stage availability view: r_nic[n] depends on the resource kind the
    # *current* stage needs, so rebuild per stage; shared kinds (two CPU
    # stages) see each other's consumption through `taken`.
    taken: Dict[str, Dict[str, int]] = {n: {} for n in N}

    for idx, s in enumerate(S):
        s_prev = S[idx - 1] if idx > 0 else None
        kind = need[s]
        r_nic = {n: max(0, pool[n].available(kind) - taken[n].get(kind, 0)) for n in N}
        excluded: set = set()
        while remaining[s] > 0:
            n = find_next_nic(N, r_nic, b_nic, A, s, s_prev, frozenset(excluded))
            if n is None:
                break  # pool exhausted -> best-effort
            placed = alloc_one_nic(remaining, t_s, r_nic, b_nic, A, n, s,
                                   s_prev, credited)
            if placed == 0:
                excluded.add(n)  # bandwidth floor(d)=0: NIC unusable for s
                continue
            taken[n][kind] = taken[n].get(kind, 0) + placed

    return Allocation(A=A, unmet={s: remaining[s] for s in S if remaining[s] > 0},
                      bw_after=b_nic,
                      bw_charge={n: max(0.0, bw_before[n] - b_nic[n])
                                 for n in N})


def nic_charge(row: Dict[str, int], S: Sequence[str],
               t_s: Dict[str, float]) -> float:
    """Canonical Algorithm-3 net bandwidth charge for one NIC's stage rows.

    Each placed stage is charged ``units * t_s``; a stage immediately
    following another stage placed on the same NIC credits back the
    predecessor's full charge (the hand-off stays local). Used to compute
    charge *deltas* when a row shrinks — the recorded ``bw_charge`` stays
    the authoritative total.
    """
    charge = 0.0
    for i, s in enumerate(S):
        u = row.get(s, 0)
        if u <= 0:
            continue
        charge += u * t_s[s]
        if i > 0:
            p = S[i - 1]
            if row.get(p, 0) > 0:
                charge -= row[p] * t_s[p]
    return max(0.0, charge)


def commit(pool: Pool, alloc: Allocation, need: Dict[str, str]) -> None:
    """Apply an allocation to the pool (controller deploy step).

    Strict: unit takes and bandwidth charges raise if the pool cannot cover
    them — an allocation computed against stale pool state must fail loudly,
    not silently clamp."""
    for n, row in alloc.A.items():
        for s, units in row.items():
            if units > 0:
                pool[n].take(need[s], units)
        pool[n].take_bw(alloc.bw_charge.get(n, 0.0))


def release(pool: Pool, alloc: Allocation, need: Dict[str, str],
            t_s: Optional[Dict[str, float]] = None) -> None:
    """Reclaim an application's resources on termination (paper §6.1 FCFS).

    Bandwidth is credited from the allocation's recorded per-NIC net charge —
    exactly what commit subtracted — not the naive per-unit sum, which
    over-credits whenever colocated consecutive stages shared bandwidth via
    the Algorithm-3 credit. (`t_s` is kept for signature compatibility; the
    recorded charge already reflects the profiled throughputs.)
    """
    for n, row in alloc.A.items():
        for s, units in row.items():
            if units > 0:
                pool[n].give(need[s], units)
        pool[n].give_bw(alloc.bw_charge.get(n, 0.0))
