"""State engine + state APIs (paper §4.3, §5.1.2, Appendix C).

Each pool member runs a lightweight state engine (SE) holding application
states in a **linked hash table** (4096 buckets, as in the paper's prototype).
State entries mirror the paper's 64-byte layout (s_name, h_key, s_addr,
s_len, lu_time) and expire past a lifespan threshold.

Access patterns (§4.3):
  * "non-external-write"  — writable locally, readable everywhere;
  * "full-access"         — writable/readable by all instances.

Operators: ADD / REMOVE / GET / SET / TRAVERSE / COMPUTE. GET checks local
state first and falls back to a remote read. TRAVERSE pulls whole remote
tables once and traverses locally (the paper's RDMA-batching optimization —
here one gather instead of per-key reads). COMPUTE ships the instruction and
returns aggregated results.

Transport: the paper uses RDMA; between TPU device groups the data-plane
counterpart is a collective (`bounded_sync_deltas` under shard_map /
`jax.lax.psum`), and control-plane reads go through a host `Transport` that
counts ops + bytes so benchmarks can report Fig 20-style costs.

Bounded-inconsistency flow-state sync (§5.1.2, after ExoPlane): every period
T each pipeline merges the *deltas* of all peers since the last sync into its
own value — `v_i' = v_i + Σ_{j≠i}(v_j − s_j)` — so sum-like flow statistics
converge to the global value while staying temporarily inconsistent within T.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NUM_BUCKETS = 4096          # paper §7
LIFESPAN_S = 500.0          # paper Appendix C

NON_EXTERNAL_WRITE = "non-external-write"
FULL_ACCESS = "full-access"


def _h_key(name: str) -> int:
    h = 1469598103934665603
    for ch in name.encode():
        h = ((h ^ ch) * 1099511628211) & 0xFFFFFFFFFFFFFFFF
    return h


@dataclasses.dataclass
class StateEntry:
    """The paper's 64-byte entry: 8B s_name | 32B h_key | 8B s_addr | 8B s_len | 8B lu_time."""

    s_name: str
    h_key: int
    value: Any                       # payload (s_addr/s_len point at it)
    lu_time: float

    @property
    def s_len(self) -> int:
        v = np.asarray(self.value)
        return int(v.size * v.dtype.itemsize)


class LinkedHashTable:
    """Bucketed chained hash table — collision scans make reads slow down as
    occupancy grows, reproducing the paper's Fig 20 read/write asymmetry."""

    def __init__(self, buckets: int = NUM_BUCKETS):
        self.buckets: List[List[StateEntry]] = [[] for _ in range(buckets)]
        self.size = 0

    def _bucket(self, h: int) -> List[StateEntry]:
        return self.buckets[h % len(self.buckets)]

    def put(self, name: str, value: Any, now: Optional[float] = None) -> None:
        h = _h_key(name)
        now = time.monotonic() if now is None else now
        for e in self._bucket(h):
            if e.h_key == h and e.s_name == name:
                e.value, e.lu_time = value, now
                return
        self._bucket(h).append(StateEntry(name, h, value, now))
        self.size += 1

    def get(self, name: str, now: Optional[float] = None) -> Optional[StateEntry]:
        h = _h_key(name)
        for e in self._bucket(h):
            if e.h_key == h and e.s_name == name:
                e.lu_time = time.monotonic() if now is None else now
                return e
        return None

    def remove(self, name: str) -> bool:
        h = _h_key(name)
        b = self._bucket(h)
        for i, e in enumerate(b):
            if e.h_key == h and e.s_name == name:
                del b[i]
                self.size -= 1
                return True
        return False

    def entries(self) -> List[StateEntry]:
        return [e for b in self.buckets for e in b]

    def expire(self, now: float, lifespan: float = LIFESPAN_S) -> int:
        n = 0
        for b in self.buckets:
            keep = [e for e in b if now - e.lu_time <= lifespan]
            n += len(b) - len(keep)
            b[:] = keep
        self.size -= n
        return n


@dataclasses.dataclass
class Transport:
    """RDMA-analog op counter (per-op latency model used by benchmarks)."""

    reads: int = 0
    writes: int = 0
    bytes_read: int = 0
    bytes_written: int = 0

    def read(self, nbytes: int) -> None:
        self.reads += 1
        self.bytes_read += nbytes

    def write(self, nbytes: int) -> None:
        self.writes += 1
        self.bytes_written += nbytes


class StateEngine:
    """One per pool member."""

    def __init__(self, nic: str, buckets: int = NUM_BUCKETS):
        self.nic = nic
        self.table = LinkedHashTable(buckets)


class StateService:
    """The distributed ensemble of per-NIC engines + the state API."""

    def __init__(self, nics: Sequence[str], buckets: int = NUM_BUCKETS):
        self.engines: Dict[str, StateEngine] = {
            n: StateEngine(n, buckets) for n in nics}
        self.patterns: Dict[str, str] = {}
        self.transport = Transport()
        # Monotonic write version: bumped by every mutating state API call.
        # Failover replication compares it against the version it last
        # snapshotted, so unchanged state is never re-traversed (the dirty
        # flag — TRAVERSE over every engine is the expensive op here).
        self.version = 0

    def declare(self, name: str, pattern: str) -> None:
        assert pattern in (NON_EXTERNAL_WRITE, FULL_ACCESS)
        self.patterns[name] = pattern

    # -- full-access ops: apply to all replicas ---------------------------------
    def fstate_add(self, name: str, value: Any) -> None:
        self.version += 1
        for e in self.engines.values():
            e.table.put(name, value)
            self.transport.write(_nbytes(value))

    def fstate_set(self, name: str, value: Any) -> None:
        self.fstate_add(name, value)

    def fstate_remove(self, name: str) -> None:
        self.version += 1
        for e in self.engines.values():
            e.table.remove(name)
            self.transport.write(8)

    # -- non-external-write ops: local write, global read -----------------------
    def ne_set(self, name: str, value: Any, local: str) -> None:
        self.version += 1
        self.engines[local].table.put(name, value)

    def ne_add(self, name: str, value: Any, local: str) -> None:
        self.version += 1
        self.engines[local].table.put(name, value)

    def ne_remove(self, name: str, local: str) -> bool:
        self.version += 1
        return self.engines[local].table.remove(name)

    # -- GET: same in both patterns — local first, then remote READ -------------
    def get(self, name: str, local: str) -> Optional[Any]:
        e = self.engines[local].table.get(name)
        if e is not None:
            return e.value
        for nic, eng in self.engines.items():
            if nic == local:
                continue
            e = eng.table.get(name)
            if e is not None:
                self.transport.read(e.s_len)
                return e.value
        return None

    # -- TRAVERSE: pull whole remote tables once, walk locally ------------------
    def traverse(self, local: str) -> List[StateEntry]:
        out = list(self.engines[local].table.entries())
        for nic, eng in self.engines.items():
            if nic == local:
                continue
            remote = eng.table.entries()
            self.transport.read(sum(e.s_len + 64 for e in remote))
            out.extend(remote)
        return out

    # -- COMPUTE: ship the UCF, aggregate results -------------------------------
    def compute(self, name: str, ucf: Callable[[List[Any]], Any],
                combine: Callable[[List[Any]], Any]) -> Any:
        partials = []
        for nic, eng in self.engines.items():
            e = eng.table.get(name)
            vals = [e.value] if e is not None else []
            partials.append(ucf(vals))
            self.transport.write(64)          # the instruction
            self.transport.read(8)            # the aggregated result
        return combine(partials)

    def expire_all(self, now: float) -> int:
        return sum(e.table.expire(now) for e in self.engines.values())


def _nbytes(value: Any) -> int:
    v = np.asarray(value)
    return int(v.size * v.dtype.itemsize)


# ---------------------------------------------------------------------------
# Bounded-inconsistency sync (§5.1.2) — host and device forms.
# ---------------------------------------------------------------------------

def bounded_sync(values: np.ndarray, snapshots: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray]:
    """Host form. values/snapshots: (P, ...) per-pipeline replicas.

    Returns (merged values, new snapshots): v_i' = v_i + Σ_{j≠i}(v_j − s_j).
    For counter-like states all replicas converge to the global sum.
    """
    deltas = values - snapshots
    total = deltas.sum(axis=0, keepdims=True)
    merged = values + (total - deltas)
    return merged, merged.copy()


def bounded_sync_deltas(value: jnp.ndarray, snapshot: jnp.ndarray,
                        axis_name: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Device form, for use inside shard_map: each pipeline shard holds its
    replica; the delta exchange is one psum over the pipeline axis (the RDMA
    negotiation of the paper becomes a single all-reduce)."""
    delta = value - snapshot
    total = jax.lax.psum(delta, axis_name)
    merged = value + (total - delta)
    return merged, merged
