"""Chaos fault injection, gray-failure detection, and graceful recovery.

Meili's availability story (Appendix D) is one clean NIC crash followed by
snapshot-restore failover. Real pooled deployments see worse: correlated
rack outages, flapping links, and *gray* failures where a NIC silently
underperforms while still reporting full capacity (the DPU-variability
literature documents exactly this across SmartNIC classes). This module is
the harness that drives the existing failover/defrag/QoS machinery through
those fault sequences, plus the recovery policy that turns eviction into
graceful degradation:

  ``FaultPlan``/``ChaosEngine``  a seeded, declarative schedule of timed
      fault events (crash / revive / flap / gray / rack / mid_migration)
      executed against a ``ServiceRuntime`` — replaces the single-shot
      ``fail_at`` hook (kept as a shim).
  ``GrayFailureDetector``  per-NIC suspicion scoring over sustained
      achieved-vs-expected deviation, with exoneration: a NIC is only as
      suspicious as its happiest loaded tenant, so one degraded tenant
      cannot frame a healthy NIC it shares.
  ``RecoveryManager``  dead tenants are parked in a retry queue with
      exponential backoff + jitter and re-admitted through the governor's
      admission machinery when capacity revives; while anyone is parked the
      governor issues *brownout* partial grants so survivors shed the
      headroom the parked tenants need to come back.
  ``sentinel_check``  ledger + stage-liveness + flow-conservation invariants
      run after every chaos event, so drift under compound faults fails
      loudly at the injection site instead of ticks later.

Everything here is runtime-agnostic by duck typing (the runtime argument
needs ``ctrl``/``registry``/``telemetry``/``inject_failure``): the service
layer imports this module, never the reverse.
"""
from __future__ import annotations

import dataclasses
import random
from typing import Dict, List, Optional, Set

# Fault kinds understood by the ChaosEngine.
CRASH = "crash"                  # whole-NIC failure -> Appendix-D failover
REVIVE = "revive"                # repair: NIC / rack / (neither) all failed
FLAP = "flap"                    # crash + scheduled revive after duration_ticks
GRAY = "gray"                    # silent degradation to `fraction` of capacity
RACK = "rack"                    # correlated crash of every NIC in one rack
MID_MIGRATION = "mid_migration"  # crash landed inside a make-before-break window


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault. ``nic`` targets a member (None = busiest for a
    crash), ``rack`` targets a failure domain (RACK, or REVIVE of a whole
    rack), ``fraction`` is the GRAY capacity factor, ``duration_ticks`` is
    the FLAP outage length."""

    tick: int
    kind: str
    nic: Optional[str] = None
    rack: Optional[str] = None
    fraction: float = 1.0
    duration_ticks: int = 0


@dataclasses.dataclass
class FaultPlan:
    """A declarative, deterministic fault schedule (the chaos A/B needs the
    identical sequence on both arms; seed covers future randomized plans)."""

    events: List[FaultEvent]
    seed: int = 0

    def due(self, tick: int) -> List[FaultEvent]:
        return sorted((e for e in self.events if e.tick == tick),
                      key=lambda e: (e.kind, e.nic or "", e.rack or ""))


# ---------------------------------------------------------------------------
# Gray-failure detection
# ---------------------------------------------------------------------------

class GrayFailureDetector:
    """Suspicion scoring over observed service deviation.

    Each tick the runtime hands in, per NIC, the deviation
    ``1 - achieved/expected`` observed by every *loaded* tenant whose
    placement touches that NIC (idle tenants provide no evidence — a NIC
    serving a trough perfectly proves nothing). The NIC's evidence for the
    tick is the **minimum** across observers: exoneration. A single tenant
    degraded for its own reasons (backlog, overload) cannot frame a healthy
    NIC, because any other loaded tenant achieving full service pulls the
    minimum to zero. Suspicion is an EWMA of that evidence; a NIC becomes a
    suspect once suspicion exceeds the threshold for ``min_ticks``
    consecutive evidence-bearing ticks.
    """

    def __init__(self, threshold: float = 0.3, min_ticks: int = 3,
                 alpha: float = 0.5):
        self.threshold = threshold
        self.min_ticks = min_ticks
        self.alpha = alpha
        self.suspicion: Dict[str, float] = {}
        self.streak: Dict[str, int] = {}
        self.probation: Set[str] = set()
        # Observability (ISSUE 7): when a DecisionTrace is attached, every
        # suspicion increment and exoneration lands in the audit log, and
        # the most recent observer set per NIC is kept so a quarantine
        # verdict can name who testified.
        self.trace = None
        self.observers: Dict[str, List[str]] = {}
        # Acquittal watermarks (ISSUE 8): when localization drains one of
        # several identically-convicted NICs, the co-accused are *acquitted*
        # — parked at their current streak, evidence intact — rather than
        # wiped. See ``acquit``.
        self.watch: Dict[str, int] = {}

    def observe(self, blame: Dict[str, List[float]],
                observers: Optional[Dict[str, List[str]]] = None) -> None:
        """``blame``: nic -> deviations from each loaded tenant using it this
        tick. NICs absent from ``blame`` hold their streak (no evidence
        either way); NICs with any zero-deviation observer reset it.
        ``observers`` (optional) names the tenants behind each NIC's
        deviations, recorded for the audit trail."""
        for nic, devs in blame.items():
            if not devs:
                continue
            if observers is not None and nic in observers:
                self.observers[nic] = list(observers[nic])
            dev = min(devs)
            s = self.suspicion.get(nic, 0.0)
            self.suspicion[nic] = (1.0 - self.alpha) * s + self.alpha * dev
            if dev > self.threshold:
                self.streak[nic] = self.streak.get(nic, 0) + 1
                if self.trace is not None:
                    self.trace.event(
                        "gray_suspicion", nic=nic, kind="fault",
                        deviation=dev, suspicion=self.suspicion[nic],
                        streak=self.streak[nic],
                        observers=self.observers.get(nic, []))
            else:
                if self.streak.get(nic, 0) > 0 and self.trace is not None:
                    self.trace.event(
                        "gray_exonerated", nic=nic, kind="fault",
                        deviation=dev, suspicion=self.suspicion[nic],
                        observers=self.observers.get(nic, []))
                self.streak[nic] = 0
                self.watch.pop(nic, None)

    def suspects(self) -> List[str]:
        return sorted(
            n for n, s in self.suspicion.items()
            if s > self.threshold
            and self.streak.get(n, 0) >= self.min_ticks
            and self.streak.get(n, 0) > self.watch.get(n, -1)
            and n not in self.probation)

    def acquit(self, nic: str) -> None:
        """Localization verdict, not exoneration: the drained suspect's
        co-accused keep their suspicion and streak, but cannot convict again
        until *fresh* evidence arrives after the drain. If the shared witness
        recovers once the drained NIC is gone, the co-accused's evidence
        stops (streak held at the watermark, never above) and its tenants'
        full service exonerates it; if the witness still deviates on its
        post-drain placement, the surviving suspect convicts itself on the
        very next evidence tick — the drain made the evidence diagnostic."""
        self.watch[nic] = self.streak.get(nic, 0)

    def clear(self, nic: str) -> None:
        """Repair observed (revive): the NIC starts over with a clean record."""
        self.suspicion.pop(nic, None)
        self.streak.pop(nic, None)
        self.probation.discard(nic)
        self.observers.pop(nic, None)
        self.watch.pop(nic, None)


# ---------------------------------------------------------------------------
# Recovery: park + backoff + re-admission, brownout while parked
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RecoveryConfig:
    """Policy knobs for post-failure tenant recovery.

    ``park=False`` reproduces the eviction-or-nothing baseline (a tenant
    whose placement cannot be restored is gone for good); ``park=True`` is
    the graceful path: retry with exponential backoff + jitter, re-admit
    when capacity revives. ``brownout`` clamps survivors' grants toward
    ``brownout_floor`` x contract (weight-proportionally) while anyone is
    parked, so scale-downs free the units re-admission needs.
    """

    park: bool = True
    base_backoff_ticks: int = 4
    max_backoff_ticks: int = 32
    jitter_frac: float = 0.25
    brownout: bool = True
    brownout_floor: float = 0.4
    seed: int = 0


@dataclasses.dataclass
class ParkedTenant:
    tenant: str
    parked_tick: int
    next_retry: int
    backoff: int
    retries: int = 0


class RecoveryManager:
    """Turns failover's unmet placements into parked-then-readmitted tenants.

    ``sweep`` evicts tenants a failure left dead (some stage at zero units)
    — into the parked retry queue when parking is on, permanently otherwise.
    ``step`` runs the due retries, heaviest-weight first, through the
    registry's re-admission path (quota re-registered, strict admission),
    and keeps the governor's brownout level in sync with the parked set.
    """

    def __init__(self, runtime, cfg: Optional[RecoveryConfig] = None):
        self.rt = runtime
        self.cfg = cfg or RecoveryConfig()
        self.parked: Dict[str, ParkedTenant] = {}
        self.evicted: List[str] = []              # permanent (park disabled)
        self.readmissions: List[tuple] = []       # (tenant, ticks parked)
        self._rng = random.Random(self.cfg.seed)

    # -- eviction of dead tenants ----------------------------------------------
    def sweep(self, tick: int) -> List[str]:
        """Evict every active tenant whose placement lost a whole stage —
        a pipeline with a zero-unit stage serves nothing, and holding its
        surviving units hostage only starves the tenants that could use
        them. Returns the tenants swept this call."""
        swept: List[str] = []
        for name in list(self.rt.registry.active()):
            dep = self.rt.registry.deployment(name)
            if all(dep.allocation.units(s) >= 1 for s in dep.profile.stages):
                continue
            swept.append(name)
            self.rt.registry.evict(name)
            self.rt._drop_plane(name)
            for d in (self.rt._demand, self.rt._backlog, self.rt._granted,
                      self.rt._cooldown):
                d.pop(name, None)
            if self.cfg.park:
                self.rt.registry.parked.add(name)
                self.parked[name] = ParkedTenant(
                    tenant=name, parked_tick=tick,
                    next_retry=tick + self.cfg.base_backoff_ticks,
                    backoff=self.cfg.base_backoff_ticks)
                self.rt.telemetry.record_fault(
                    tick, "parked", tenant=name,
                    shard=self.rt.ctrl.shard_of(name))
            else:
                # Never retried: the rejection note keeps churn's pending()
                # from silently re-admitting what policy just evicted.
                self.rt.registry.rejected[name] = "evicted (recovery disabled)"
                self.evicted.append(name)
                self.rt.telemetry.record_fault(
                    tick, "evicted", tenant=name,
                    shard=self.rt.ctrl.shard_of(name))
        if swept:
            self._update_brownout()
        return swept

    # -- the per-tick retry pass -----------------------------------------------
    def step(self, tick: int) -> None:
        self.sweep(tick)
        gov = self.rt.ctrl.governor
        due = [p for p in self.parked.values() if p.next_retry <= tick]
        for p in sorted(due, key=lambda q: -gov.weight(q.tenant)):
            name = p.tenant
            spec = self.rt.registry.specs[name]
            if spec.depart_tick is not None and spec.depart_tick <= tick:
                # Departed while parked: nothing left to restore.
                del self.parked[name]
                self.rt.registry.parked.discard(name)
                continue
            if self.rt.registry.readmit(name):
                del self.parked[name]
                self.rt.registry.parked.discard(name)
                waited = tick - p.parked_tick
                self.readmissions.append((name, waited))
                self.rt.telemetry.record_fault(
                    tick, "readmitted", tenant=name,
                    detail=f"after {waited} ticks, {p.retries + 1} tries",
                    shard=self.rt.ctrl.shard_of(name))
                self.rt._events[name] = "readmitted"
                self.rt._grace_until[name] = tick + self.rt.cfg.slo_grace_ticks
                self.rt._force_rescale.add(name)
            else:
                p.retries += 1
                p.backoff = min(self.cfg.max_backoff_ticks, p.backoff * 2)
                jitter = self._rng.randint(
                    0, max(0, int(self.cfg.jitter_frac * p.backoff)))
                p.next_retry = tick + p.backoff + jitter
        self._update_brownout()

    def _update_brownout(self) -> None:
        """Brownout level tracks how much contracted capacity is parked:
        survivors degrade (weight-proportionally, via the governor) by the
        share the parked tenants will need back, never below the floor."""
        gov = self.rt.ctrl.governor
        if not (self.cfg.brownout and self.parked):
            gov.set_brownout(None)
            return
        specs = self.rt.registry.specs
        parked_c = sum(specs[n].sla.target_gbps
                       for n in self.parked if n in specs)
        total_c = parked_c + sum(specs[n].sla.target_gbps
                                 for n in self.rt.registry.active()
                                 if n in specs)
        level = max(self.cfg.brownout_floor,
                    1.0 - parked_c / max(total_c, 1e-9))
        gov.set_brownout(level)

    def notify_capacity(self, tick: int) -> None:
        """Capacity returned to the pool (a NIC revived): retry every parked
        tenant on the next tick instead of waiting out the blind timer. The
        backoff state is kept — if the retry still fails, the exponential
        schedule resumes where it left off. Pure timer backoff made
        re-admission miss repair waves entirely: a retry that fired just
        before the revive pushed the next attempt a doubled backoff past it."""
        for p in self.parked.values():
            p.next_retry = min(p.next_retry, tick + 1)

    def mean_time_to_recover(self) -> Optional[float]:
        """Mean ticks parked across all re-admissions (None if none yet)."""
        if not self.readmissions:
            return None
        return sum(w for _, w in self.readmissions) / len(self.readmissions)


# ---------------------------------------------------------------------------
# Invariant sentinel
# ---------------------------------------------------------------------------

def sentinel_check(runtime) -> None:
    """Run after every chaos event: any drift fails at the injection site.

    Checks (1) the pool ledger (free + held == capacity, bandwidth within
    epsilon, dead NICs included), (2) stage liveness — every *active* tenant
    has at least one placed unit per stage (the recovery sweep must run
    first: it is what removes the legitimately-dead), and (3) flow
    conservation — every flow-table entry maps to a pipeline that exists,
    and no ingress backlog went negative.
    """
    runtime.ctrl.check_ledger()
    problems: List[str] = []
    for name in runtime.registry.active():
        dep = runtime.registry.deployment(name)
        for s in dep.profile.stages:
            if dep.allocation.units(s) < 1:
                problems.append(f"{name}/{s}: zero placed units")
        pids = {p.pid for p in dep.to.pipelines}
        for f, pid in dep.to.flow_table.items():
            if pid not in pids:
                problems.append(f"{name}: flow {f} -> missing pipeline {pid}")
    for t, b in runtime._backlog.items():
        if b < -1e-9:
            problems.append(f"{t}: negative backlog {b}")
    if problems:
        raise AssertionError("chaos sentinel: " + "; ".join(problems))


# ---------------------------------------------------------------------------
# The engine
# ---------------------------------------------------------------------------

class ChaosEngine:
    """Executes a FaultPlan against a bound ServiceRuntime, one tick at a
    time. After every fired event the recovery sweep runs (evict-or-park the
    dead) and the invariant sentinel validates the whole control plane."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self.rt = None
        self.fired: List[FaultEvent] = []
        self._revive_at: Dict[int, List[FaultEvent]] = {}

    def bind(self, runtime) -> None:
        self.rt = runtime

    def step(self, tick: int) -> None:
        # Scheduled flap revives fire before new faults: an event injecting
        # at the same tick sees the repaired pool, not the transient.
        for ev in self._revive_at.pop(tick, []):
            self._fire(tick, ev)
        for ev in self.plan.due(tick):
            self._fire(tick, ev)

    # -- dispatch ---------------------------------------------------------------
    def _fire(self, tick: int, ev: FaultEvent) -> None:
        rt = self.rt
        pool = rt.ctrl.pool
        if ev.kind == CRASH:
            self._crash(tick, ev.nic)
        elif ev.kind == FLAP:
            nic = self._crash(tick, ev.nic, kind=FLAP)
            if nic is not None:
                self._revive_at.setdefault(
                    tick + max(1, ev.duration_ticks), []).append(
                        FaultEvent(tick=tick, kind=REVIVE, nic=nic))
        elif ev.kind == REVIVE:
            # nic targets one member, rack a whole domain; neither = a full
            # repair wave — every NIC still down (crash victims included,
            # whichever the trajectory picked) is replaced.
            if ev.rack:
                members = pool.rack_members(ev.rack)
            elif ev.nic:
                members = [ev.nic]
            else:
                members = [n for n in pool.nics if not pool[n].alive]
            for n in members:
                pool.revive(n)
                rt.note_revive(n)
            rt.telemetry.record_fault(tick, REVIVE, nic=",".join(members))
        elif ev.kind == GRAY:
            # Ground truth only: the detector must find this from achieved
            # throughput, never by reading the pool's gray factor.
            pool.mark_gray(ev.nic, ev.fraction)
            rt.telemetry.record_fault(tick, GRAY, nic=ev.nic,
                                      detail=f"frac={ev.fraction:g}",
                                      shard=rt.ctrl.shard_of_nic(ev.nic))
        elif ev.kind == RACK:
            for n in pool.rack_members(ev.rack):
                if pool[n].alive:
                    self._crash(tick, n, note=False)
            members = pool.rack_members(ev.rack)
            rt.telemetry.record_fault(
                tick, RACK, nic=ev.rack,
                shard=rt.ctrl.shard_of_nic(members[0]) if members else None)
        elif ev.kind == MID_MIGRATION:
            self._mid_migration(tick)
        else:
            raise ValueError(f"unknown fault kind: {ev.kind!r}")
        self.fired.append(ev)
        rt.recovery.sweep(tick)
        try:
            sentinel_check(rt)
        except AssertionError:
            # Sentinel tripped: auto-dump the flight recorder's incident
            # bundle (ISSUE 10) so the failing state is preserved. The dump
            # is exception-safe (``dump_safe`` never raises — a failed dump
            # logs a ``flight_dump_failed`` trace event instead) and the
            # original sentinel error always propagates unmasked.
            fl = getattr(rt, "flight", None)
            if fl is not None:
                fl.dump_safe(trigger="sentinel_failure", tick=tick)
            raise

    def _crash(self, tick: int, nic: Optional[str], note: bool = True,
               kind: str = CRASH) -> Optional[str]:
        failed, _ = self.rt.inject_failure(nic)
        if note and failed is not None:
            # Failure domains map to shard ownership: the record carries
            # the owning shard so the fault log localizes by rack.
            self.rt.telemetry.record_fault(
                tick, kind, nic=failed,
                shard=self.rt.ctrl.shard_of_nic(failed))
        return failed

    def _mid_migration(self, tick: int) -> None:
        """Arm the controller's one-shot hook, then force a migration: the
        injected crash lands between make-before-break begin and finish —
        flows buffered, ledger already swapped to the destination — the
        nastiest window the failover path can be hit in."""
        rt = self.rt

        def on_swap(app_name: str) -> None:
            dep = rt.ctrl.deployments[app_name]
            nics = sorted(dep.nics_used())
            if nics:
                rt.telemetry.record_fault(tick, MID_MIGRATION, nic=nics[0],
                                          tenant=dep.tenant,
                                          shard=rt.ctrl.shard_of_nic(nics[0]))
                rt.inject_failure(nics[0])

        rt.ctrl.mid_migration_hook = on_swap
        alive = rt.ctrl.pool.names()
        for name in sorted(rt.ctrl.deployments):
            if rt.ctrl.migrate(name, only_nics=alive, forced=True,
                               require_improvement=False) is not None:
                break
        if rt.ctrl.mid_migration_hook is not None:
            # No admissible migration anywhere: disarm and log the no-op so
            # the A/B's event accounting stays honest.
            rt.ctrl.mid_migration_hook = None
            rt.telemetry.record_fault(tick, MID_MIGRATION, detail="no-op")
