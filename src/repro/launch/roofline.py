"""Roofline-term extraction from compiled dry-run artifacts.

compute term    = HLO_FLOPs / (chips × peak)      [cost_analysis, per device]
memory term     = HLO_bytes / (chips × HBM_bw)
collective term = collective_bytes / (chips × link_bw)

cost_analysis() gives per-partition FLOPs/bytes (SPMD module). Collective
bytes are not in cost_analysis: we parse the *optimized* (post-SPMD) HLO from
compiled.as_text() and sum operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (async -start forms counted
once). Operand shapes are read from the inline types in the op's argument
list, so the totals are per-device bytes.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, Optional

import numpy as np

from repro import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,4096,128]{2,1,0}
_SHAPE_RE = re.compile(r"\b([a-z]+\d*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*(.*?)\s"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-category bytes of every collective in the (per-device) optimized
    HLO. Optimized HLO lists only the RESULT type inline (operands are name
    references), so sizes are result-shape bytes — exact for all-reduce /
    all-to-all / collective-permute, the gathered size for all-gather, the
    scattered size for reduce-scatter. NOTE: collectives inside while (scan)
    bodies are counted ONCE here; launch/decompose.py applies the trip-count
    multipliers."""
    out = {c: 0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        stripped = line.strip()
        m = _OP_RE.search(stripped)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # async completion: counted at -start
        kind = m.group(2)
        result_str = m.group(1)
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _SHAPE_RE.findall(result_str))
        out[kind] += nbytes
        out["count"] += 1
    out["total"] = sum(out[c] for c in _COLLECTIVES)
    return out


@dataclasses.dataclass
class Roofline:
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    chips: int
    model_flops: float                 # 6·N·D (train) or 2·N_active·tokens

    @property
    def t_compute(self) -> float:
        return self.flops_per_device / hw.PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_device / hw.HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_device / hw.ICI_BW_PER_LINK

    @property
    def dominant(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / total HLO FLOPs — catches remat/redundancy waste."""
        tot = self.flops_per_device * self.chips
        return self.model_flops / tot if tot else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of chip peak sustained if the step runs at the dominant
        term's duration: useful model FLOPs / (chips·peak·t_bound)."""
        denom = self.chips * hw.PEAK_FLOPS_BF16 * self.t_bound
        return self.model_flops / denom if denom else 0.0

    def to_dict(self) -> Dict:
        return {
            "flops_per_device": self.flops_per_device,
            "bytes_per_device": self.bytes_per_device,
            "coll_bytes_per_device": self.coll_bytes_per_device,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "t_compute": self.t_compute,
            "t_memory": self.t_memory,
            "t_collective": self.t_collective,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
        }


def model_flops(total_params: int, active_params: int, kind: str,
                tokens: int) -> float:
    """6·N·D for training; 2·N_active·D forward-only (prefill/decode)."""
    if kind == "train":
        return 6.0 * active_params * tokens
    return 2.0 * active_params * tokens


def build(compiled, chips: int, mflops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older API returned [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0) or 0.0)
    nbytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    coll = collective_bytes(compiled.as_text())
    return Roofline(flops_per_device=flops, bytes_per_device=nbytes,
                    coll_bytes_per_device=float(coll["total"]), chips=chips,
                    model_flops=mflops)
