"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes ("data", "model").
Multi-pod:  (2, 16, 16) = 512 chips, axes ("pod", "data", "model") — the pod
axis is pure data parallelism over DCN (params replicated across pods,
gradients all-reduced; optionally int8-compressed, parallel/compression.py).

A function, not a module-level constant: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Small mesh over whatever devices exist (tests / examples on CPU)."""
    n = len(jax.devices())
    model = min(model, n)
    return jax.make_mesh((n // model, model), ("data", "model"))


def dp_degree(mesh) -> int:
    d = 1
    for ax in ("pod", "data"):
        if ax in mesh.axis_names:
            d *= mesh.shape[ax]
    return d
