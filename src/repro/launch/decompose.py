"""Roofline decomposition: piece-wise lowering with correct multiplicities.

XLA's HloCostAnalysis counts while-loop (lax.scan) bodies ONCE, so the
full-step dry-run underreports FLOPs/bytes/collective-bytes by the trip
counts (layer scan × grad-accumulation × CE chunks × flash KV blocks). This
module lowers each *piece* of the step separately — per-segment layer body
(fwd+bwd with remat), embed, loss head, optimizer — with internal scans
unrolled (kernels.ops.set_unroll_scans) so every iteration is counted, then
combines:

    total = Σ_piece cost(piece) × multiplicity(piece)

Sequence scaling: train/prefill bodies are measured at S₁=1024 and S₂=2048
and fitted to cost(S) = a·S + b·S² (attention is quadratic, everything else
linear; the fit recovers both exactly), then evaluated at the target S.
Decode pieces have no sequence scans and are lowered at the true cache depth
directly. As a bonus the per-segment costs are exactly the per-stage
latencies Meili's Algorithm 1 needs (serving/planner.py reuses them).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro import hw
from repro.configs.base import ArchConfig, ShapeConfig
from repro.kernels import ops as kops
from repro.launch import roofline as rl
from repro.launch.steps import choose_microbatch
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod
from repro.models.registry import Model
from repro.parallel.sharding import (default_rules, set_activation_sharding,
                                     spec_for, tree_specs)

Tree = Any
S_FIT = (1024, 2048)


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def _strip_layer_dim(struct: Tree, axes: Tree) -> Tuple[Tree, Tree]:
    s = jax.tree.map(lambda t: jax.ShapeDtypeStruct(t.shape[1:], t.dtype),
                     struct)
    a = jax.tree.map(lambda t: t[1:], axes, is_leaf=_is_axes_leaf)
    return s, a


def _shardings(axes: Tree, struct: Tree, rules, mesh) -> Tree:
    specs = tree_specs(axes, struct, rules, mesh)
    return jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                        is_leaf=lambda x: isinstance(x, PartitionSpec))


def _cost_of(fn: Callable, structs: tuple, shardings: tuple, mesh) -> Dict:
    jitted = jax.jit(fn, in_shardings=shardings)
    compiled = jitted.lower(*structs).compile()
    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = rl.collective_bytes(compiled.as_text())
    return {"flops": float(cost.get("flops", 0.0) or 0.0),
            "bytes": float(cost.get("bytes accessed", 0.0) or 0.0),
            "coll": float(coll["total"]),
            "coll_by_kind": {k: coll[k] for k in
                             ("all-reduce", "all-gather", "reduce-scatter",
                              "all-to-all", "collective-permute")}}


def _fit_quadratic(c1: float, c2: float, s1: int, s2: int, s_target: int
                   ) -> float:
    """cost(S)=a·S+b·S² through (s1,c1),(s2,c2); clamp b>=0 (noise floor)."""
    denom = s2 * s2 * s1 - s1 * s1 * s2
    b = (c2 * s1 - c1 * s2) / denom
    if b < 0:
        return c2 / s2 * s_target          # linear through the larger point
    a = (c1 - b * s1 * s1) / s1
    return max(0.0, a * s_target + b * s_target * s_target)


def _fit_dict(d1: Dict, d2: Dict, s1: int, s2: int, s_target: int) -> Dict:
    out = {}
    for k in ("flops", "bytes", "coll"):
        out[k] = _fit_quadratic(d1[k], d2[k], s1, s2, s_target)
    out["coll_by_kind"] = {
        k: _fit_quadratic(d1["coll_by_kind"][k], d2["coll_by_kind"][k],
                          s1, s2, s_target)
        for k in d1["coll_by_kind"]}
    return out


def _acc(total: Dict, piece: Dict, mult: float) -> None:
    for k in ("flops", "bytes", "coll"):
        total[k] += piece[k] * mult
    for k, v in piece["coll_by_kind"].items():
        total["coll_by_kind"][k] = total["coll_by_kind"].get(k, 0.0) + v * mult


def _zero() -> Dict:
    return {"flops": 0.0, "bytes": 0.0, "coll": 0.0, "coll_by_kind": {}}


# ---------------------------------------------------------------------------
# Piece builders (decoder-LM family)
# ---------------------------------------------------------------------------

def _train_body_fn(cfg, seg, S: int, impl: str = "blocked"):
    def fn(bp, x, hbar):
        B = x.shape[0]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))

        def fwd(bpp, xx):
            h = xx
            for i, spec in enumerate(seg.body):
                h, _ = lm_mod._apply_layer(cfg, spec, bpp[i], h, positions,
                                           impl)
            return h

        fwd_c = jax.checkpoint(fwd) if cfg.remat else fwd
        h, vjp = jax.vjp(fwd_c, bp, x)
        dp, dx = vjp(hbar)
        return h, dp, dx
    return fn


def _prefill_body_fn(cfg, seg, S: int, impl: str = "blocked"):
    def fn(bp, x):
        B = x.shape[0]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None],
                                     (B, S))
        h = x
        kvs = []
        for i, spec in enumerate(seg.body):
            h, kv = lm_mod._apply_layer(cfg, spec, bp[i], h, positions, impl,
                                        collect_kv=True)
            kvs.append(kv)
        return h, tuple(kvs)
    return fn


def _decode_body_fn(cfg, seg, impl: str = "blocked"):
    from repro.models import attention as attn_mod
    from repro.models import ssm as ssm_mod
    from repro.models.layers import make_norm, mlp
    from repro.models import moe as moe_mod
    _, norm_apply = make_norm(cfg)

    def fn(bp, caches, x, pos):
        h = x
        new_cs = []
        for i, spec in enumerate(seg.body):
            p, c = bp[i], caches[i]
            hn = norm_apply(p.get("norm1"), h)
            if spec.mixer in ("attn", "attn_local"):
                window = cfg.window if spec.mixer == "attn_local" else None
                y, ck, cv = attn_mod.attn_decode(p["attn"], hn, cfg,
                                                 cache_k=c["k"],
                                                 cache_v=c["v"], pos=pos,
                                                 window=window, impl=impl)
                new_cs.append({"k": ck, "v": cv})
            else:
                y, nc = ssm_mod.mamba_decode(p["mamba"], hn, c, cfg)
                new_cs.append(nc)
            h = h + y
            if spec.ffn != "none":
                hn = norm_apply(p.get("norm2"), h)
                y = mlp(p["mlp"], hn) if spec.ffn == "mlp" else \
                    moe_mod.moe_ffn(p["moe"], hn[:, None], cfg)[:, 0]
                h = h + y
        return h, tuple(new_cs)
    return fn


def _loss_head_fn(cfg, S: int, impl: str = "blocked"):
    def fn(params_small, x_final, tokens):
        from repro.models.layers import make_norm
        _, norm_apply = make_norm(cfg)

        def fwd(ps, xx):
            emb = ps["embed"]["table"][tokens]          # embed lookup counted
            xx = xx + 0.0 * emb                          # keep it live
            xx = norm_apply(ps.get("final_norm"), xx)
            # chunked CE identical to lm_loss's inner loop
            w = ps["embed"]["table"].T if cfg.tie_embeddings else \
                ps["head"]["w"]
            chunk = min(512, S - 1)
            n = (S - 1) // chunk
            xs = xx[:, :n * chunk]
            tg = tokens[:, 1:1 + n * chunk]

            def step(acc, i):
                from repro.parallel.sharding import constrain_act
                xc = jax.lax.dynamic_slice_in_dim(xs, i * chunk, chunk, 1)
                tc = jax.lax.dynamic_slice_in_dim(tg, i * chunk, chunk, 1)
                lg = constrain_act((xc @ w).astype(jnp.float32),
                                   ("loss_batch", "seq", "vocab"))
                lse = jax.nn.logsumexp(lg, axis=-1)
                ids = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
                picked = jnp.sum(jnp.where(ids == tc[..., None], lg, 0.0),
                                 axis=-1)
                return acc + jnp.sum(lse - picked), None

            tot, _ = jax.lax.scan(step, jnp.float32(0.0), jnp.arange(n),
                                  unroll=kops._unroll(n))
            return tot / xs.shape[0]

        loss, vjp = jax.vjp(fwd, params_small, x_final)
        dp, dx = vjp(jnp.float32(1.0))
        return loss, dp, dx
    return fn


def _opt_fn():
    from repro.optim import adamw_update

    def fn(params, grads, mu, nu):
        from repro.optim.adamw import AdamWState
        st = AdamWState(mu=mu, nu=nu, count=jnp.zeros((), jnp.int32))
        p2, st2, stats = adamw_update(params, grads, st, 1e-4)
        return p2, st2.mu, st2.nu, stats["grad_norm"]
    return fn


# ---------------------------------------------------------------------------
# Main entry
# ---------------------------------------------------------------------------

def decompose_cell(model: Model, shape: ShapeConfig, mesh, rules=None,
                   verbose: bool = False) -> Dict:
    """Corrected per-device roofline totals for one (arch × shape) cell."""
    cfg = model.cfg
    rules = rules or default_rules()
    set_activation_sharding(rules, mesh)
    dtype = jnp.bfloat16
    kops.set_unroll_scans(True)
    try:
        if cfg.family == "encdec":
            totals, pieces = _decompose_encdec(model, shape, mesh, rules,
                                               dtype)
        elif shape.kind == "decode":
            totals, pieces = _decompose_decode(model, shape, mesh, rules,
                                               dtype)
        else:
            totals, pieces = _decompose_lm(model, shape, mesh, rules, dtype)
    finally:
        kops.set_unroll_scans(False)
    total_params, active = model.param_counts()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode"
                                   else 1)
    mflops = rl.model_flops(total_params, active, shape.kind, tokens)
    roof = rl.Roofline(flops_per_device=totals["flops"],
                       bytes_per_device=totals["bytes"],
                       coll_bytes_per_device=totals["coll"],
                       chips=mesh.size, model_flops=mflops)
    return {"totals": totals, "pieces": pieces, "roofline": roof.to_dict()}


def _seg_param_pieces(model: Model, mesh, rules, dtype):
    p_struct, p_axes = model.param_struct(dtype)
    out = []
    for i in range(len(p_struct["segments"])):
        seg_struct = p_struct["segments"][i]
        seg_axes = p_axes["segments"][i]
        s, a = zip(*[_strip_layer_dim(ss, aa)
                     for ss, aa in zip(seg_struct, seg_axes)])
        out.append((tuple(s), tuple(a)))
    return p_struct, p_axes, out


def _decompose_lm(model: Model, shape: ShapeConfig, mesh, rules, dtype):
    cfg = model.cfg
    schedule = lm_mod.build_schedule(cfg)
    accum = choose_microbatch(cfg, shape.global_batch, mesh, rules) \
        if shape.kind == "train" else 1
    B = shape.global_batch // accum
    S = shape.seq_len
    totals, pieces = _zero(), {}
    p_struct, p_axes, seg_pieces = _seg_param_pieces(model, mesh, rules, dtype)

    act_axes = ("batch", "seq", None)
    for i, seg in enumerate(schedule):
        bp_struct, bp_axes = seg_pieces[i]
        bp_shard = _shardings(bp_axes, bp_struct, rules, mesh)
        fits = []
        for s_m in S_FIT:
            x_s = jax.ShapeDtypeStruct((B, s_m, cfg.d_model), dtype)
            x_sh = NamedSharding(mesh, spec_for(act_axes, x_s.shape, rules,
                                                mesh))
            if shape.kind == "train":
                fn = _train_body_fn(cfg, seg, s_m)
                c = _cost_of(fn, (bp_struct, x_s, x_s),
                             (bp_shard, x_sh, x_sh), mesh)
            else:
                fn = _prefill_body_fn(cfg, seg, s_m)
                c = _cost_of(fn, (bp_struct, x_s), (bp_shard, x_sh), mesh)
            fits.append(c)
        c_t = _fit_dict(fits[0], fits[1], S_FIT[0], S_FIT[1], S)
        mult = seg.count * accum
        pieces[f"segment{i}"] = {**c_t, "mult": mult}
        _acc(totals, c_t, mult)

    # embed + final norm + chunked-CE head (fwd+bwd), fitted over S
    small_struct = {"embed": p_struct["embed"],
                    "final_norm": p_struct["final_norm"]}
    small_axes = {"embed": p_axes["embed"], "final_norm": p_axes["final_norm"]}
    if not cfg.tie_embeddings:
        small_struct["head"] = p_struct["head"]
        small_axes["head"] = p_axes["head"]
    sp_shard = _shardings(small_axes, small_struct, rules, mesh)
    fits = []
    for s_m in S_FIT:
        x_s = jax.ShapeDtypeStruct((B, s_m, cfg.d_model), dtype)
        t_s = jax.ShapeDtypeStruct((B, s_m), jnp.int32)
        x_sh = NamedSharding(mesh, spec_for(act_axes, x_s.shape, rules, mesh))
        t_sh = NamedSharding(mesh, spec_for(("batch", "seq"), t_s.shape,
                                            rules, mesh))
        if shape.kind == "train":
            fn = _loss_head_fn(cfg, s_m)
            c = _cost_of(fn, (small_struct, x_s, t_s),
                         (sp_shard, x_sh, t_sh), mesh)
        else:
            def head_fn(ps, x):
                from repro.models.layers import make_norm
                _, norm_apply = make_norm(cfg)
                xx = norm_apply(ps.get("final_norm"), x[:, -1])
                w = ps["embed"]["table"].T if cfg.tie_embeddings else \
                    ps["head"]["w"]
                emb = ps["embed"]["table"][jnp.zeros((x.shape[0], s_m),
                                                     jnp.int32)]
                return xx @ w + 0.0 * emb[:, 0, :1]
            c = _cost_of(head_fn, (small_struct, x_s), (sp_shard, x_sh), mesh)
        fits.append(c)
    c_t = _fit_dict(fits[0], fits[1], S_FIT[0], S_FIT[1], S)
    pieces["embed_loss"] = {**c_t, "mult": accum}
    _acc(totals, c_t, accum)

    # optimizer (train only): exact, once
    if shape.kind == "train":
        g_dtype = jnp.bfloat16 if cfg.bf16_optimizer_state else jnp.float32
        g_struct = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, g_dtype), p_struct)
        m_struct = g_struct
        p_shard = _shardings(p_axes, p_struct, rules, mesh)
        g_shard = p_shard
        c = _cost_of(_opt_fn(), (p_struct, g_struct, m_struct, m_struct),
                     (p_shard, g_shard, g_shard, g_shard), mesh)
        pieces["optimizer"] = {**c, "mult": 1}
        _acc(totals, c, 1)
    return totals, pieces


def _decompose_decode(model: Model, shape: ShapeConfig, mesh, rules, dtype):
    cfg = model.cfg
    schedule = lm_mod.build_schedule(cfg)
    B, S = shape.global_batch, shape.seq_len
    totals, pieces = _zero(), {}
    p_struct, p_axes, seg_pieces = _seg_param_pieces(model, mesh, rules, dtype)
    c_struct = jax.eval_shape(lambda: model.init_cache(B, S, dtype)[0])
    c_axes = model.cache_axes()

    for i, seg in enumerate(schedule):
        bp_struct, bp_axes = seg_pieces[i]
        bp_shard = _shardings(bp_axes, bp_struct, rules, mesh)
        cs, ca = zip(*[_strip_layer_dim(ss, aa)
                       for ss, aa in zip(c_struct["segments"][i],
                                         c_axes["segments"][i])])
        cs, ca = tuple(cs), tuple(ca)
        c_shard = _shardings(ca, cs, rules, mesh)
        x_s = jax.ShapeDtypeStruct((B, cfg.d_model), dtype)
        x_sh = NamedSharding(mesh, spec_for(("batch", None), x_s.shape,
                                            rules, mesh))
        pos_s = jax.ShapeDtypeStruct((), jnp.int32)
        pos_sh = NamedSharding(mesh, PartitionSpec())
        fn = _decode_body_fn(cfg, seg)
        c = _cost_of(fn, (bp_struct, cs, x_s, pos_s),
                     (bp_shard, c_shard, x_sh, pos_sh), mesh)
        pieces[f"segment{i}"] = {**c, "mult": seg.count}
        _acc(totals, c, seg.count)

    # embed + head piece (exact)
    small_struct = {"embed": p_struct["embed"],
                    "final_norm": p_struct["final_norm"]}
    small_axes = {"embed": p_axes["embed"], "final_norm": p_axes["final_norm"]}
    if not cfg.tie_embeddings:
        small_struct["head"] = p_struct["head"]
        small_axes["head"] = p_axes["head"]
    sp_shard = _shardings(small_axes, small_struct, rules, mesh)

    def head_fn(ps, tokens, x):
        from repro.models.layers import make_norm
        _, norm_apply = make_norm(cfg)
        emb = ps["embed"]["table"][tokens]
        xx = norm_apply(ps.get("final_norm"), x + 0.0 * emb)
        w = ps["embed"]["table"].T if cfg.tie_embeddings else ps["head"]["w"]
        return xx @ w

    t_s = jax.ShapeDtypeStruct((B,), jnp.int32)
    t_sh = NamedSharding(mesh, spec_for(("batch",), (B,), rules, mesh))
    x_s = jax.ShapeDtypeStruct((B, cfg.d_model), dtype)
    x_sh = NamedSharding(mesh, spec_for(("batch", None), x_s.shape, rules,
                                        mesh))
    c = _cost_of(head_fn, (small_struct, t_s, x_s), (sp_shard, t_sh, x_sh),
                 mesh)
    pieces["embed_head"] = {**c, "mult": 1}
    _acc(totals, c, 1)
    return totals, pieces


def _decompose_encdec(model: Model, shape: ShapeConfig, mesh, rules, dtype):
    cfg = model.cfg
    totals, pieces = _zero(), {}
    p_struct, _ = model.param_struct(dtype)
    axes = model._axes_tree(dtype)
    B = shape.global_batch
    accum = choose_microbatch(cfg, shape.global_batch, mesh, rules) \
        if shape.kind == "train" else 1
    B = shape.global_batch // accum
    act_axes = ("batch", "seq", None)

    enc_s, enc_a = _strip_layer_dim(p_struct["enc"], axes["enc"])
    dec_s, dec_a = _strip_layer_dim(p_struct["dec"], axes["dec"])
    enc_sh = _shardings(enc_a, enc_s, rules, mesh)
    dec_sh = _shardings(dec_a, dec_s, rules, mesh)
    from repro.models.layers import make_norm, mlp
    from repro.models import attention as attn_mod
    _, norm_apply = make_norm(cfg)

    if shape.kind == "decode":
        S = shape.seq_len
        c_struct = jax.eval_shape(lambda: model.init_cache(B, S, dtype)[0])
        ca = model.cache_axes()
        strip = lambda key: _strip_layer_dim(c_struct[key],
                                             ca[key])
        sk_s, sk_a = strip("self_k")
        ck_s, ck_a = strip("cross_k")
        sk_sh = NamedSharding(mesh, spec_for(sk_a, sk_s.shape, rules, mesh))
        ck_sh = NamedSharding(mesh, spec_for(ck_a, ck_s.shape, rules, mesh))

        def dec_body(lp, sk, sv, ck, cv, x, pos):
            hn = norm_apply(lp.get("norm1"), x)
            y, sk, sv = attn_mod.attn_decode(lp["self"], hn, cfg, cache_k=sk,
                                             cache_v=sv, pos=pos)
            h = x + y
            hn = norm_apply(lp.get("norm2"), h)
            y, _, _ = attn_mod.attn_decode(lp["cross"], hn, cfg, cache_k=ck,
                                           cache_v=cv, pos=pos, cross=True)
            h = h + y
            return h + mlp(lp["mlp"], norm_apply(lp.get("norm3"), h)), sk, sv

        x_s = jax.ShapeDtypeStruct((B, cfg.d_model), dtype)
        x_sh = NamedSharding(mesh, spec_for(("batch", None), x_s.shape,
                                            rules, mesh))
        pos_s = jax.ShapeDtypeStruct((), jnp.int32)
        c = _cost_of(dec_body,
                     (dec_s, sk_s, sk_s, ck_s, ck_s, x_s, pos_s),
                     (dec_sh, sk_sh, sk_sh, ck_sh, ck_sh, x_sh,
                      NamedSharding(mesh, PartitionSpec())), mesh)
        pieces["dec_body"] = {**c, "mult": cfg.dec_layers}
        _acc(totals, c, cfg.dec_layers)

        def head_fn(tbl, tokens, x):
            emb = tbl[tokens]
            return (x + 0.0 * emb) @ tbl.T

        tbl_s = p_struct["embed"]["table"]
        tbl_sh = NamedSharding(mesh, spec_for(("vocab", "embed"), tbl_s.shape,
                                              rules, mesh))
        t_s = jax.ShapeDtypeStruct((B,), jnp.int32)
        c = _cost_of(head_fn, (tbl_s, t_s, x_s),
                     (tbl_sh, NamedSharding(mesh, spec_for(("batch",), (B,),
                                                           rules, mesh)),
                      x_sh), mesh)
        pieces["head"] = {**c, "mult": 1}
        _acc(totals, c, 1)
        return totals, pieces

    # train / prefill: enc body + dec body (with cross-attn) fitted over S.
    S_half = shape.seq_len // 2

    def enc_body(lp, x):
        def fwd(lpp, xx):
            B_, S_, _ = xx.shape
            positions = jnp.broadcast_to(
                jnp.arange(S_, dtype=jnp.int32)[None], (B_, S_))
            y = attn_mod.attn_apply(lpp["attn"],
                                    norm_apply(lpp.get("norm1"), xx), cfg,
                                    positions=positions, causal=False)
            h = xx + y
            return h + mlp(lpp["mlp"], norm_apply(lpp.get("norm2"), h))
        if shape.kind != "train":
            return fwd(lp, x)
        fwd_c = jax.checkpoint(fwd) if cfg.remat else fwd
        h, vjp = jax.vjp(fwd_c, lp, x)
        return h, vjp(h)

    def dec_body(lp, x, enc_out):
        def fwd(lpp, xx, eo):
            B_, S_, _ = xx.shape
            positions = jnp.broadcast_to(
                jnp.arange(S_, dtype=jnp.int32)[None], (B_, S_))
            y = attn_mod.attn_apply(lpp["self"],
                                    norm_apply(lpp.get("norm1"), xx), cfg,
                                    positions=positions, causal=True)
            h = xx + y
            y = attn_mod.attn_apply(lpp["cross"],
                                    norm_apply(lpp.get("norm2"), h), cfg,
                                    positions=positions, causal=False,
                                    kv_x=eo)
            h = h + y
            return h + mlp(lpp["mlp"], norm_apply(lpp.get("norm3"), h))
        if shape.kind != "train":
            return fwd(lp, x, enc_out)
        fwd_c = jax.checkpoint(fwd) if cfg.remat else fwd
        h, vjp = jax.vjp(fwd_c, lp, x, enc_out)
        return h, vjp(h)

    for name, body, params_s, params_sh, n_layers, extra in (
            ("enc_body", enc_body, enc_s, enc_sh, cfg.enc_layers, False),
            ("dec_body", dec_body, dec_s, dec_sh, cfg.dec_layers, True)):
        fits = []
        for s_m in S_FIT:
            x_s = jax.ShapeDtypeStruct((B, s_m, cfg.d_model), dtype)
            x_sh = NamedSharding(mesh, spec_for(act_axes, x_s.shape, rules,
                                                mesh))
            if extra:
                c = _cost_of(body, (params_s, x_s, x_s),
                             (params_sh, x_sh, x_sh), mesh)
            else:
                c = _cost_of(body, (params_s, x_s), (params_sh, x_sh), mesh)
            fits.append(c)
        c_t = _fit_dict(fits[0], fits[1], S_FIT[0], S_FIT[1], S_half)
        pieces[name] = {**c_t, "mult": n_layers * accum}
        _acc(totals, c_t, n_layers * accum)

    # loss head over decoder positions (train) / last-logits (prefill)
    tbl_s = p_struct["embed"]["table"]
    tbl_sh = NamedSharding(mesh, spec_for(("vocab", "embed"), tbl_s.shape,
                                          rules, mesh))
    fits = []
    for s_m in S_FIT:
        x_s = jax.ShapeDtypeStruct((B, s_m, cfg.d_model), dtype)
        t_s = jax.ShapeDtypeStruct((B, s_m), jnp.int32)
        x_sh = NamedSharding(mesh, spec_for(act_axes, x_s.shape, rules, mesh))
        t_sh = NamedSharding(mesh, spec_for(("batch", "seq"), t_s.shape,
                                            rules, mesh))

        def loss_fn(tbl, x, tokens, s_m=s_m):
            def fwd(tb, xx):
                chunk = min(512, s_m - 1)
                n = (s_m - 1) // chunk
                xs = xx[:, :n * chunk]
                tg = tokens[:, 1:1 + n * chunk]

                def step(acc, i):
                    from repro.parallel.sharding import constrain_act
                    xc = jax.lax.dynamic_slice_in_dim(xs, i * chunk, chunk, 1)
                    tc = jax.lax.dynamic_slice_in_dim(tg, i * chunk, chunk, 1)
                    lg = constrain_act((xc @ tb.T).astype(jnp.float32),
                                       ("loss_batch", "seq", "vocab"))
                    lse = jax.nn.logsumexp(lg, axis=-1)
                    ids = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
                    picked = jnp.sum(
                        jnp.where(ids == tc[..., None], lg, 0.0), axis=-1)
                    return acc + jnp.sum(lse - picked), None

                tot, _ = jax.lax.scan(step, jnp.float32(0.0), jnp.arange(n),
                                      unroll=kops._unroll(n))
                return tot

            if shape.kind != "train":
                return fwd(tbl, x)
            loss, vjp = jax.vjp(fwd, tbl, x)
            return loss, vjp(jnp.float32(1.0))

        c = _cost_of(loss_fn, (tbl_s, x_s, t_s), (tbl_sh, x_sh, t_sh), mesh)
        fits.append(c)
    c_t = _fit_dict(fits[0], fits[1], S_FIT[0], S_FIT[1], S_half)
    pieces["loss"] = {**c_t, "mult": accum}
    _acc(totals, c_t, accum)

    if shape.kind == "train":
        p_all, a_all = model.param_struct(dtype)
        g_struct = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), p_all)
        p_shard = _shardings(a_all, p_all, rules, mesh)
        c = _cost_of(_opt_fn(), (p_all, g_struct, g_struct, g_struct),
                     (p_shard, p_shard, p_shard, p_shard), mesh)
        pieces["optimizer"] = {**c, "mult": 1}
        _acc(totals, c, 1)
    return totals, pieces
