"""Render EXPERIMENTS.md tables from experiments/dryrun JSON records.

  PYTHONPATH=src python -m repro.launch.report experiments/dryrun
"""
from __future__ import annotations

import glob
import json
import os
import sys


def fmt_bytes(b):
    if b is None:
        return "-"
    return f"{b / 1e9:.2f}"


def load(d):
    recs = {}
    for f in glob.glob(os.path.join(d, "*.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r.get("mesh", "skip"))] = r
    return recs


def dryrun_table(recs, mesh="single"):
    rows = ["| arch | shape | status | compile s | temp GB/chip | accum | "
            "HLO GFLOP/dev | coll GB/dev |",
            "|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        if m == "skip":
            rows.append(f"| {a} | {s} | SKIP ({r['reason'][:42]}…) | - | - | "
                        f"- | - | - |")
            continue
        if m != mesh:
            continue
        if r["status"] != "ok":
            rows.append(f"| {a} | {s} | **FAIL** | - | - | - | - | - |")
            continue
        roof = r["roofline"]
        rows.append(
            f"| {a} | {s} | ok | {r['compile_s']:.0f} | "
            f"{fmt_bytes(r['memory']['temp_size_bytes'])} | "
            f"{r.get('accum', '-')} | {roof['flops_per_device']/1e9:.1f} | "
            f"{roof['coll_bytes_per_device']/1e9:.2f} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="single"):
    rows = ["| arch | shape | t_comp s | t_mem s | t_coll s | dominant | "
            "useful | roofline frac | one-line lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (a, s, m), r in sorted(recs.items()):
        if m != mesh or r.get("status") != "ok":
            continue
        roof = r["roofline"]
        lever = _lever(roof, r)
        rows.append(
            f"| {a} | {s} | {roof['t_compute']:.3f} | {roof['t_memory']:.3f} "
            f"| {roof['t_collective']:.3f} | {roof['dominant']} | "
            f"{roof['useful_flops_ratio']:.3f} | "
            f"{roof['roofline_fraction']:.4f} | {lever} |")
    return "\n".join(rows)


def _lever(roof, r):
    d = roof["dominant"]
    if d == "collective":
        return ("shrink FSDP all-gathers / overlap collectives with compute "
                "(Pallas-fused layers need fewer round trips)")
    if d == "memory":
        return ("fuse blocked-attention chain on TPU (Pallas keeps the tile "
                "in VMEM; XLA-counted HLO bytes drop)")
    return "increase per-chip arithmetic intensity (larger microbatch)"


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"
    recs = load(d)
    for mesh in ("single", "multi"):
        if not any(m == mesh for (_, _, m) in recs):
            continue
        print(f"\n### Dry-run — {mesh} pod\n")
        print(dryrun_table(recs, mesh))
        print(f"\n### Roofline — {mesh} pod\n")
        print(roofline_table(recs, mesh))


if __name__ == "__main__":
    main()
