"""End-to-end training driver (runs on this host's devices; same code path
lowers on the production mesh).

Features: deterministic resumable data pipeline, AdamW + schedule (WSD for
minicpm), grad-accumulation, periodic checkpointing with atomic commit,
crash/elastic restart (--resume), simulated failure injection (--fail-at)
to exercise the failover path end-to-end.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
      --steps 300 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLMDataset, host_shard_iterator
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import build
from repro.optim.adamw import AdamWState


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--fail-at", type=int, default=0,
                    help="simulate a crash after N steps (tests failover)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = cfg.replace(microbatch=min(cfg.microbatch, 2))
    model = build(cfg)
    mesh = make_host_mesh()
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    dtype = jnp.float32 if args.dtype == "float32" else jnp.bfloat16

    key = jax.random.PRNGKey(0)
    params, _ = model.init(key, dtype)
    step_fn, opt_init = make_train_step(model, shape, mesh, base_lr=args.lr,
                                        warmup=20, total_steps=args.steps)
    opt_state = opt_init(params)
    start = 0
    if args.resume and latest_step(args.ckpt_dir) is not None:
        (params, opt_state), start = restore_checkpoint(
            args.ckpt_dir, (params, opt_state))
        print(f"[train] resumed from step {start}")

    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq + 1)
    it = host_shard_iterator(ds, args.batch, 0, 1, start_step=start)
    ckpt = CheckpointManager(args.ckpt_dir, every=args.ckpt_every)
    jit_step = jax.jit(step_fn, donate_argnums=(0, 1))

    t0 = time.time()
    losses = []
    for step in range(start, args.steps):
        batch = next(it)
        tokens = jnp.asarray(batch["tokens"][:, :args.seq])
        params, opt_state, loss, gnorm = jit_step(
            params, opt_state, {"tokens": tokens}, jnp.int32(step))
        losses.append(float(loss))
        if step % args.log_every == 0:
            dt = time.time() - t0
            print(f"[train] step {step:5d} loss {float(loss):.4f} "
                  f"gnorm {float(gnorm):.3f} ({dt:.1f}s)")
        ckpt.maybe_save(step + 1, (params, opt_state))
        if args.fail_at and step + 1 == args.fail_at:
            print(f"[train] simulating crash at step {step + 1}")
            return 17
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({time.time() - t0:.1f}s)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
