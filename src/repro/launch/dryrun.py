import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

This proves the distribution config is coherent without hardware:
`jax.jit(step, in_shardings=…).lower(**ShapeDtypeStructs).compile()` must
succeed on the (16,16) single-pod mesh AND the (2,16,16) multi-pod mesh for
every assigned architecture and input shape. memory_analysis() proves the
step fits 16 GB/chip; cost_analysis() + the optimized HLO feed §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen2.5-32b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs import ARCHS, SHAPES, get_arch
from repro.configs.base import SUBQUADRATIC, skipped_cells
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (batch_shardings, build_shardings,
                                cache_shardings, choose_microbatch,
                                make_prefill_step, make_serve_step,
                                make_train_step, opt_state_struct_and_sharding)
from repro.models import build
from repro.parallel.sharding import (rules_for, set_activation_sharding,
                                     spec_for)


def _mesh_for(kind: str):
    if kind == "single":
        devs = jax.devices()[:256]
        return jax.make_mesh((16, 16), ("data", "model"), devices=devs)
    return make_production_mesh(multi_pod=True)


def run_cell(arch: str, shape_name: str, mesh_kind: str, rules=None,
             verbose: bool = True) -> dict:
    cfg = get_arch(arch)
    shape = SHAPES[shape_name]
    model = build(cfg)
    mesh = _mesh_for(mesh_kind)
    chips = mesh.size
    rules = rules or rules_for(cfg, mesh)
    set_activation_sharding(rules, mesh)   # model-code logical constraints
    dtype = jnp.bfloat16
    t0 = time.time()

    p_struct, p_shard, _ = build_shardings(model, mesh, rules, dtype)
    b_struct, b_shard = batch_shardings(model, shape, mesh, rules, dtype)
    total, active = model.param_counts()

    if shape.kind == "train":
        step_fn, _ = make_train_step(model, shape, mesh, rules)
        o_struct, o_shard = opt_state_struct_and_sharding(
            model, mesh, p_shard, p_struct, dtype)
        scalar_sh = NamedSharding(mesh, PartitionSpec())
        jitted = jax.jit(
            step_fn,
            in_shardings=(p_shard, o_shard, b_shard, scalar_sh),
            out_shardings=(p_shard, o_shard, scalar_sh, scalar_sh),
            donate_argnums=(0, 1))
        lowered = jitted.lower(p_struct, o_struct, b_struct,
                               jax.ShapeDtypeStruct((), jnp.int32))
        tokens = shape.global_batch * shape.seq_len
        mflops = rl.model_flops(total, active, "train", tokens)
        extra = {"accum": step_fn.accum}
    elif shape.kind == "prefill":
        prefill_fn = make_prefill_step(model, max_len=shape.seq_len)
        c_struct, c_shard = cache_shardings(model, shape, mesh, rules, dtype)
        lg_spec = spec_for(("batch", "vocab"),
                           (shape.global_batch, cfg.vocab), rules, mesh)
        out_sh = (NamedSharding(mesh, lg_spec), c_shard) \
            if cfg.family != "encdec" else None
        jitted = jax.jit(prefill_fn, in_shardings=(p_shard, b_shard),
                         out_shardings=out_sh)
        lowered = jitted.lower(p_struct, b_struct)
        tokens = shape.global_batch * shape.seq_len
        mflops = rl.model_flops(total, active, "prefill", tokens)
        extra = {}
    else:  # decode
        serve_fn = make_serve_step(model)
        c_struct, c_shard = cache_shardings(model, shape, mesh, rules, dtype)
        tok_sh = {k: v for k, v in b_shard.items()}
        lg_spec = spec_for(("batch", "vocab"),
                           (shape.global_batch, cfg.vocab), rules, mesh)
        jitted = jax.jit(serve_fn,
                         in_shardings=(p_shard, c_shard, tok_sh["tokens"]),
                         out_shardings=(NamedSharding(mesh, lg_spec), c_shard),
                         donate_argnums=(1,))
        lowered = jitted.lower(p_struct, c_struct, b_struct["tokens"])
        tokens = shape.global_batch  # one new token per sequence
        mflops = rl.model_flops(total, active, "decode", tokens)
        extra = {}

    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    mem_rec = {
        "argument_size_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_size_bytes": getattr(mem, "output_size_in_bytes", None),
        "temp_size_bytes": getattr(mem, "temp_size_in_bytes", None),
        "generated_code_size_bytes":
            getattr(mem, "generated_code_size_in_bytes", None),
        "alias_size_bytes": getattr(mem, "alias_size_in_bytes", None),
    }
    # Raw full-step numbers (while bodies counted once — see decompose.py).
    roof_raw = rl.build(compiled, chips, mflops)
    coll = rl.collective_bytes(compiled.as_text())
    # Corrected roofline via piece-wise decomposition with trip counts.
    from repro.launch.decompose import decompose_cell
    t2 = time.time()
    dec = decompose_cell(model, shape, mesh, rules)
    t_decompose = time.time() - t2
    roof = dec["roofline"]
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": chips,
        "status": "ok", "params_total": total, "params_active": active,
        "tokens_per_step": tokens, "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "decompose_s": round(t_decompose, 1), "memory": mem_rec,
        "collectives_full_step_raw": coll,
        "roofline_full_step_raw": roof_raw.to_dict(),
        "roofline": roof, "pieces": {
            k: {kk: vv for kk, vv in v.items() if kk != "coll_by_kind"}
            for k, v in dec["pieces"].items()}, **extra,
    }
    if verbose:
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: OK "
              f"(compile {t_compile:.0f}s, dominant={roof['dominant']}, "
              f"roofline={roof['roofline_fraction']:.3f}, "
              f"useful={roof['useful_flops_ratio']:.3f})")
        print("  memory_analysis:", {k: v for k, v in mem_rec.items()
                                     if v is not None})
        print("  terms(s): compute=%.4f memory=%.4f collective=%.4f"
              % (roof["t_compute"], roof["t_memory"], roof["t_collective"]))
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--missing", action="store_true",
                    help="run only cells without an ok record yet")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    cells = []
    if args.all or args.missing:
        for a in ARCHS:
            for s in SHAPES:
                if s == "long_500k" and a not in SUBQUADRATIC:
                    continue
                cells.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        for mk in meshes:
            path = os.path.join(args.out, f"{arch}__{shape}__{mk}.json")
            if args.missing and os.path.exists(path):
                try:
                    if json.load(open(path)).get("status") == "ok":
                        continue
                except Exception:  # noqa: BLE001
                    pass
            try:
                rec = run_cell(arch, shape, mk)
            except Exception as e:  # noqa: BLE001 — record and continue
                traceback.print_exc()
                rec = {"arch": arch, "shape": shape, "mesh": mk,
                       "status": "fail", "error": repr(e)}
                failures += 1
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            sys.stdout.flush()
    # Record the documented skips so the table is complete.
    for a in ARCHS:
        for (aa, ss, why) in skipped_cells(a):
            path = os.path.join(args.out, f"{aa}__{ss}__skip.json")
            with open(path, "w") as f:
                json.dump({"arch": aa, "shape": ss, "status": "skipped",
                           "reason": why}, f, indent=1)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
