"""Serving driver: Meili-planned replicated decode pipelines.

Plans per-segment replication with Algorithm 1 (from measured per-segment
decode latencies), builds N pipeline instances, and serves a batch of
requests with flow-sticky admission.

Usage:
  PYTHONPATH=src python -m repro.launch.serve --arch gemma3-1b --reduced \
      --requests 32 --tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch
from repro.models import build
from repro.models import lm as lm_mod
from repro.serving.engine import Request, ServingEngine
from repro.serving.planner import plan_serving, segment_stage_names


def measure_segment_latencies(model, params, batch: int, max_len: int):
    """Wall-clock one decode pass per segment (host profiling)."""
    cfg = model.cfg
    cache, _ = model.init_cache(batch, max_len, jnp.float32)
    names = segment_stage_names(cfg)
    from repro.launch.decompose import _decode_body_fn
    lat = {}
    schedule = lm_mod.build_schedule(cfg)
    p_segments = params["segments"]
    for i, seg in enumerate(schedule):
        fn = jax.jit(_decode_body_fn(cfg, seg))
        bp = jax.tree.map(lambda t: t[0], tuple(p_segments[i]))
        cs = jax.tree.map(lambda t: t[0], tuple(cache["segments"][i]))
        x = jnp.zeros((batch, cfg.d_model), jnp.float32)
        pos = jnp.int32(1)
        jax.block_until_ready(fn(bp, cs, x, pos))
        t0 = time.perf_counter()
        for _ in range(3):
            jax.block_until_ready(fn(bp, cs, x, pos))
        lat[names[i]] = (time.perf_counter() - t0) / 3 * seg.count
    return lat


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args(argv)

    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = cfg.reduced().replace(remat=False)
    model = build(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), jnp.float32)

    lat = measure_segment_latencies(model, params, args.slots, args.max_len)
    plan = plan_serving(model, lat)
    print("[serve] Meili plan:")
    print(plan.summary())

    engine = ServingEngine(model, params, num_pipelines=plan.num_pipelines,
                           slots_per_pipeline=args.slots,
                           max_len=args.max_len)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for rid in range(args.requests):
        prompt = rng.integers(2, cfg.vocab, size=4).tolist()
        engine.submit(Request(rid=rid, prompt=prompt,
                              max_new_tokens=args.tokens))
    done = engine.run(max_steps=args.max_len - 8)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)}/{args.requests} requests, "
          f"{total_tokens} tokens in {dt:.1f}s "
          f"({total_tokens / dt:.1f} tok/s across "
          f"{plan.num_pipelines} pipelines)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
