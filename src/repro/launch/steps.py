"""Jittable train / prefill / serve steps with resolved shardings.

`make_train_step` builds the fwd+bwd+AdamW step with gradient-accumulation
microbatching (count chosen per arch + mesh divisibility); `make_serve_step`
builds the one-token decode step (cache donated); `make_prefill_step` the
full-sequence cache build. `build_shardings` resolves every leaf through the
logical-axis rules so the same code serves the smoke tests (1 CPU device),
the single-pod (16,16) and the multi-pod (2,16,16) dry-runs.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ArchConfig, ShapeConfig
from repro.launch.mesh import dp_degree
from repro.models.registry import Model
from repro.optim import adamw_init, adamw_update, make_schedule
from repro.parallel.sharding import (LogicalRules, batch_dp_degree,
                                     default_rules, rules_for, spec_for,
                                     tree_specs)

Tree = Any


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def choose_microbatch(cfg: ArchConfig, global_batch: int, mesh,
                      rules: Optional[LogicalRules] = None) -> int:
    """Largest accumulation count <= cfg.microbatch such that the per-step
    batch still spreads over the full data-parallel degree the rules can
    reach (dp_heavy archs shard batch over data x model => accum collapses
    to keep B_step == dp)."""
    rules = rules or default_rules()
    dp = batch_dp_degree(rules, mesh, global_batch)
    for m in range(min(cfg.microbatch, global_batch), 0, -1):
        if global_batch % m != 0:
            continue
        b_step = global_batch // m
        if b_step % dp == 0:
            return m
    return 1


def build_shardings(model: Model, mesh, rules: Optional[LogicalRules] = None,
                    dtype=jnp.bfloat16):
    """(param ShapeDtypeStructs, param NamedShardings, axes tree)."""
    rules = rules or default_rules()
    shapes, axes = model.param_struct(dtype)
    specs = tree_specs(axes, shapes, rules, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, PartitionSpec))
    return shapes, shardings, axes


def batch_shardings(model: Model, shape: ShapeConfig, mesh,
                    rules: Optional[LogicalRules] = None, dtype=jnp.bfloat16):
    rules = rules or default_rules()
    specs_sd, in_axes = model.input_specs(shape, dtype)
    shardings = {
        k: NamedSharding(mesh, spec_for(in_axes[k], specs_sd[k].shape, rules,
                                        mesh))
        for k in specs_sd}
    return specs_sd, shardings


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def make_train_step(model: Model, shape: ShapeConfig, mesh,
                    rules: Optional[LogicalRules] = None,
                    base_lr: float = 3e-4, warmup: int = 100,
                    total_steps: int = 10000):
    """Returns (train_step, opt_init) — pure functions ready for jax.jit."""
    cfg = model.cfg
    rules = rules or default_rules()
    lr_fn = make_schedule(cfg.schedule, base_lr, warmup, total_steps)
    accum = choose_microbatch(cfg, shape.global_batch, mesh, rules)
    grad_dtype = jnp.bfloat16 if cfg.bf16_optimizer_state else jnp.float32

    def train_step(params: Tree, opt_state, batch: Dict[str, jnp.ndarray],
                   step: jnp.ndarray):
        def micro_loss(p, mb):
            return model.loss(p, mb)

        def split(v):
            return v.reshape((accum, v.shape[0] // accum) + v.shape[1:])

        micro = {k: split(v) for k, v in batch.items()}

        def acc_body(g_acc, mb):
            loss, g = jax.value_and_grad(micro_loss)(params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(grad_dtype), g_acc, g)
            return g_acc, loss

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, grad_dtype), params)
        grads, losses = jax.lax.scan(acc_body, g0, micro)
        grads = jax.tree.map(lambda g: g / accum, grads)
        lr = lr_fn(step)
        params, opt_state, stats = adamw_update(params, grads, opt_state, lr)
        return params, opt_state, losses.mean(), stats["grad_norm"]

    def opt_init(params):
        return adamw_init(params, jnp.bfloat16 if cfg.bf16_optimizer_state
                          else jnp.float32)

    train_step.accum = accum  # introspection for logs / EXPERIMENTS.md
    return train_step, opt_init


def opt_state_struct_and_sharding(model: Model, mesh, param_shardings,
                                  param_shapes, dtype):
    """Optimizer state mirrors the params tree (mu/nu) + a scalar count."""
    sdtype = jnp.bfloat16 if model.cfg.bf16_optimizer_state else jnp.float32
    mu = jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, sdtype),
                      param_shapes)
    from repro.optim.adamw import AdamWState
    struct = AdamWState(mu=mu, nu=mu,
                        count=jax.ShapeDtypeStruct((), jnp.int32))
    shard = AdamWState(mu=param_shardings, nu=param_shardings,
                       count=NamedSharding(mesh, PartitionSpec()))
    return struct, shard


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def make_serve_step(model: Model):
    def serve_step(params: Tree, cache: Tree, tokens: jnp.ndarray):
        return model.decode_step(params, cache, tokens)
    return serve_step


def make_prefill_step(model: Model, max_len: int):
    def prefill_step(params: Tree, batch: Dict[str, jnp.ndarray]):
        return model.prefill(params, batch, max_len=max_len)
    return prefill_step


def cache_shardings(model: Model, shape: ShapeConfig, mesh,
                    rules: Optional[LogicalRules] = None,
                    dtype=jnp.bfloat16):
    """(cache ShapeDtypeStructs, cache NamedShardings)."""
    rules = rules or default_rules()
    B, S = shape.global_batch, shape.seq_len
    struct = jax.eval_shape(lambda: model.init_cache(B, S, dtype)[0])
    axes = model.cache_axes()
    specs = tree_specs(axes, struct, rules, mesh)
    shardings = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                             is_leaf=lambda x: isinstance(x, PartitionSpec))
    return struct, shardings
