"""Streaming percentile estimators for the metrics registry (ISSUE 7).

Two complementary estimators back every latency histogram:

  ``P2Quantile``  the Jain & Chlamtac P-squared estimator: five markers,
      O(1) memory, O(1) per observation. Exact until the 5th sample, then a
      piecewise-parabolic approximation whose error is bounded by the local
      sample density around the target quantile — in practice well under 1%
      of the distribution's span for the unimodal latency shapes the sim
      model produces. This is the *cheap cross-check* estimate.

  ``Reservoir``   seeded uniform reservoir sampling (Vitter's Algorithm R).
      Percentiles are EXACT while the stream fits the capacity; beyond it
      they are unbiased estimates over a uniform sample of size
      ``capacity``, with standard-order-statistic error
      O(sqrt(q(1-q)/capacity)) — at the default 4096 that is ~0.16%
      around the median and ~0.05% at p99 in rank space. This is the
      *measured-distribution* path the acceptance bar quotes.

The registry reports the reservoir quantile as the headline number; the P²
value can ride along as a cross-check series (a large disagreement flags a
multimodal distribution the reservoir undersampled).

Determinism: the reservoir takes an explicit seed so two arms of an A/B
fed identical streams retain identical samples.
"""
from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np


class P2Quantile:
    """Jain & Chlamtac (1985) P² single-quantile streaming estimator."""

    def __init__(self, q: float):
        assert 0.0 < q < 1.0
        self.q = q
        self._heights: List[float] = []          # marker heights (sorted)
        self._pos: List[float] = []              # actual marker positions
        self._want: List[float] = []             # desired marker positions
        self._inc: List[float] = []              # desired-position increments
        self.count = 0

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        h = self._heights
        if len(h) < 5:
            h.append(x)
            h.sort()
            if len(h) == 5:
                q = self.q
                self._pos = [1.0, 2.0, 3.0, 4.0, 5.0]
                self._want = [1.0, 1.0 + 2.0 * q, 1.0 + 4.0 * q,
                              3.0 + 2.0 * q, 5.0]
                self._inc = [0.0, q / 2.0, q, (1.0 + q) / 2.0, 1.0]
            return
        # Find the cell k the observation falls into; clamp the extremes.
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = 0
            while k < 3 and not (h[k] <= x < h[k + 1]):
                k += 1
        for i in range(k + 1, 5):
            self._pos[i] += 1.0
        for i in range(5):
            self._want[i] += self._inc[i]
        # Adjust interior markers with the piecewise-parabolic (P²) update.
        for i in (1, 2, 3):
            d = self._want[i] - self._pos[i]
            if ((d >= 1.0 and self._pos[i + 1] - self._pos[i] > 1.0)
                    or (d <= -1.0 and self._pos[i - 1] - self._pos[i] < -1.0)):
                s = 1.0 if d >= 1.0 else -1.0
                hp = self._parabolic(i, s)
                if not (h[i - 1] < hp < h[i + 1]):
                    hp = self._linear(i, s)
                h[i] = hp
                self._pos[i] += s

    def _parabolic(self, i: int, s: float) -> float:
        h, n = self._heights, self._pos
        return h[i] + s / (n[i + 1] - n[i - 1]) * (
            (n[i] - n[i - 1] + s) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
            + (n[i + 1] - n[i] - s) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))

    def _linear(self, i: int, s: float) -> float:
        h, n = self._heights, self._pos
        j = i + int(s)
        return h[i] + s * (h[j] - h[i]) / (n[j] - n[i])

    def value(self) -> Optional[float]:
        """The current estimate (exact below 5 samples; None when empty)."""
        if not self._heights:
            return None
        if self.count < 5:
            arr = np.asarray(sorted(self._heights))
            return float(np.percentile(arr, 100.0 * self.q))
        return self._heights[2]


def _merge_sorted(a: np.ndarray, b: np.ndarray) -> np.ndarray:
    """Merge two sorted arrays in O(n+k) — ``np.insert`` semantics without
    its generic-indexing overhead."""
    out = np.empty(a.size + b.size, dtype=float)
    pos = a.searchsorted(b) + np.arange(b.size)
    mask = np.ones(out.size, dtype=bool)
    mask[pos] = False
    out[pos] = b
    out[mask] = a
    return out


class Reservoir:
    """Seeded uniform reservoir (Algorithm R) with a sorted core and a small
    pending buffer.

    The retained sample set lives in a SORTED numpy array, so a quantile is
    an index + linear interpolation (bit-identical to ``np.quantile``'s
    default method). Ingested chunks are not merged immediately: they sit
    in a pending list and are folded into the core every ``capacity // 8``
    samples, so the O(capacity) merge cost is amortized across ticks. A
    quantile asked while samples are pending is still EXACT — the target
    ranks of core ∪ pending can only fall in a (pending+2)-wide window of
    the core, so sorting pending plus that window answers the query without
    paying for the merge. This is what keeps the always-on measured-
    percentile path inside the benchmark's wall-clock budget: the service
    runtime feeds every tenant's per-tick latency samples through here and
    reads p99 back out each tick.

    Eviction past capacity uses the reservoir-merge formulation: for each
    flushed chunk, the number of chunk elements entering the sample is
    drawn hypergeometrically (the exact law of a uniform capacity-subset of
    old-stream ∪ chunk), chunk entrants are chosen uniformly, and as many
    uniformly-random retained samples are dropped. Chunk-size-independent,
    fully vectorized, and preserves the uniform-sample guarantee.
    """

    def __init__(self, capacity: int = 4096, seed: int = 0):
        assert capacity > 0
        self.capacity = capacity
        self._rng = np.random.default_rng(seed)
        self._arr = np.empty(0, dtype=float)   # sorted retained core
        self._pend: List[np.ndarray] = []      # unflushed chunks (stream order)
        self._pend_n = 0
        self._flush_at = max(1, capacity // 8)
        self.count = 0                 # stream length seen

    @property
    def exact(self) -> bool:
        """True while quantiles are exact (no sample has been evicted)."""
        return self.count <= self.capacity

    def observe(self, x: float) -> None:
        self.observe_many(np.asarray([x], dtype=float))

    def observe_many(self, xs: Sequence[float]) -> None:
        xs = np.asarray(xs, dtype=float).ravel()
        if xs.size == 0:
            return
        self.count += int(xs.size)
        self._pend.append(xs)
        self._pend_n += int(xs.size)
        # Past capacity the pending window would bias quantiles (pending is
        # the exact recent stream, the core a uniform sample of everything)
        # so sampling happens eagerly there; below capacity flushing is pure
        # amortization and waits for a full batch.
        if self._pend_n >= self._flush_at or not self.exact:
            self._flush()

    def _flush(self) -> None:
        if not self._pend_n:
            return
        xs = (np.concatenate(self._pend) if len(self._pend) > 1
              else self._pend[0])
        self._pend = []
        self._pend_n = 0
        room = self.capacity - self._arr.size
        if room > 0:
            k = min(room, int(xs.size))
            # Fill phase takes the first k STREAM elements (Algorithm R's
            # deterministic prefix), not the k smallest.
            self._arr = _merge_sorted(self._arr, np.sort(xs[:k]))
            xs = xs[k:]
        if not xs.size:
            return
        n_old = self.count - int(xs.size)
        m = int(self._rng.hypergeometric(xs.size, n_old, self.capacity))
        if m == 0:
            return
        keep = np.sort(self._rng.choice(xs, size=m, replace=False))
        victims = self._rng.choice(self.capacity, size=m, replace=False)
        self._arr = _merge_sorted(np.delete(self._arr, victims), keep)

    def _interp(self, s: np.ndarray, pos: float) -> float:
        lo = int(pos)
        hi = min(lo + 1, s.size - 1)
        return float(s[lo] + (pos - lo) * (s[hi] - s[lo]))

    def quantile(self, q: float) -> Optional[float]:
        if self.count == 0:
            return None
        if not self._pend_n:
            return self._interp(self._arr, q * (self._arr.size - 1))
        # Exact quantile over core ∪ pending without merging: the elements
        # at union ranks [r_lo, r_hi] lie in core[r_lo - |pend| : r_hi + 1]
        # or in pending, so sorting that window suffices.
        pend = (np.sort(np.concatenate(self._pend)) if len(self._pend) > 1
                else np.sort(self._pend[0]))
        core = self._arr
        n = core.size + pend.size
        pos = q * (n - 1)
        r_lo = int(pos)
        r_hi = min(r_lo + 1, n - 1)
        lo = max(0, r_lo - pend.size)
        window = np.sort(np.concatenate(
            [core[lo:min(core.size, r_hi + 1)], pend]))
        v_lo = window[r_lo - lo]
        v_hi = window[r_hi - lo]
        return float(v_lo + (pos - r_lo) * (v_hi - v_lo))

    def samples(self) -> np.ndarray:
        self._flush()
        return self._arr.copy()
