"""Flight recorder (ISSUE 10): a bounded ring of per-tick pool snapshots
that is cheap in steady state and dumps a self-contained incident bundle
when something goes wrong.

Every tick the runtime hands the recorder a high-resolution snapshot —
ingress queue depths, DWRR grants, the governor's headroom ledger, gray
suspicion scores, flow-cache hit counters, remaining SLO budgets, active
alerts, and the controller's per-NIC/per-shard flight state — appended to
a seeded bounded ring (``capacity`` ticks; large per-tenant maps are
thinned to ``max_entries`` by a seeded deterministic sample so a
1000-tenant pool cannot bloat the ring). No trace events, no device
syncs, no I/O: steady-state cost is dict building.

``dump()`` writes ``flight_<tick>.jsonl`` — a header record, every ring
snapshot, the trailing trace window, and the metric *deltas* since the
last dump — whenever ``sentinel_check`` fails or a page-severity burn
alert fires. The bundle is self-contained: a postmortem needs no live
process, only the file.

``dump_safe()`` is the exception-safe wrapper the trigger paths use
(ISSUE 10 bugfix): a failed dump (unwritable directory, full disk) logs a
``flight_dump_failed`` trace event and returns None — it NEVER raises, so
it can never mask the sentinel error that triggered it. With no dump
directory configured it is a silent no-op (recording stays on; dumping is
opt-in).
"""
from __future__ import annotations

import collections
import json
import pathlib
import random
from typing import Any, Deque, Dict, List, Optional

from repro.obs import Obs
from repro.obs.metrics import Histogram


class FlightRecorder:
    def __init__(self, obs: Obs, capacity: int = 64, seed: int = 0,
                 out_dir=None, trace_window_ticks: int = 16,
                 max_entries: int = 32):
        self.obs = obs
        self.capacity = max(1, capacity)
        self.seed = seed
        self.out_dir = out_dir
        self.trace_window_ticks = max(1, trace_window_ticks)
        self.max_entries = max(1, max_entries)
        self.ring: Deque[Dict[str, Any]] = collections.deque(
            maxlen=self.capacity)
        self.dumps: List[str] = []
        self._rng = random.Random(seed)
        # Metric watermark for delta bundles: (name, labels) -> value/count
        # at the last dump (empty = deltas are absolute values).
        self._mark: Dict[tuple, float] = {}

    # -- recording -------------------------------------------------------------
    def _thin(self, d: Dict[str, Any]) -> Dict[str, Any]:
        """Bound a per-tenant/per-NIC map: past ``max_entries`` keys, keep a
        seeded deterministic sample (same seed + same data -> same choice)."""
        if len(d) <= self.max_entries:
            return dict(d)
        keys = self._rng.sample(sorted(d), self.max_entries)
        out = {k: d[k] for k in sorted(keys)}
        out["_thinned_from"] = len(d)
        return out

    def snapshot(self, tick: int, runtime) -> Dict[str, Any]:
        """Append one per-tick snapshot built from live runtime state."""
        gray = getattr(runtime, "gray", None)
        slo = getattr(runtime, "slo", None)
        alerts = getattr(runtime, "alerts", None)
        caches: Dict[str, Dict[str, int]] = {}
        for tenant, dp in sorted(getattr(runtime, "_planes", {}).items()):
            st = dp.flow_cache_stats() if hasattr(dp, "flow_cache_stats") \
                else None
            if st:
                caches[tenant] = {k: st[k] for k in ("hits", "misses")
                                  if k in st}
        # Raw copies only — no rounding, no sorting: snapshot() runs every
        # tick and is the layer's hot path; json's sort_keys orders the
        # dump, and full-precision floats just make the bundle marginally
        # bigger. dict() copies are C-speed.
        snap = {
            "tick": tick,
            "queues_pkts": self._thin(runtime._backlog),
            "grants_gbps": self._thin(runtime._granted),
            "headroom_units": runtime.ctrl.governor.headroom_snapshot(),
            "suspicion": (dict(gray.suspicion) if gray is not None else {}),
            "probation": sorted(gray.probation) if gray is not None else [],
            "budgets_remaining": ({t: b.remaining_frac()
                                   for t, b in slo.budgets.items()}
                                  if slo is not None else {}),
            "alerts_active": ([list(k) for k in alerts.active()]
                              if alerts is not None else []),
            "cache_stats": caches,
            "flight_state": runtime.ctrl.flight_state(),
        }
        self.ring.append(snap)
        return snap

    # -- dumping ---------------------------------------------------------------
    def _metric_deltas(self) -> List[dict]:
        out: List[dict] = []
        for (name, labels), m in sorted(self.obs.metrics._metrics.items()):
            cur = float(m.count if isinstance(m, Histogram) else m.value)
            prev = self._mark.get((name, labels), 0.0)
            if cur != prev:
                out.append({"name": name, "labels": dict(labels),
                            "kind": m.kind, "delta": cur - prev,
                            "value": cur})
            self._mark[(name, labels)] = cur
        return out

    def dump(self, trigger: str, tick: int, out_dir=None) -> str:
        """Write the ``flight_<tick>.jsonl`` bundle; returns its path.
        Raises on I/O failure — callers on error paths use ``dump_safe``."""
        base = out_dir if out_dir is not None else self.out_dir
        if base is None:
            raise ValueError("flight recorder has no dump directory")
        out = pathlib.Path(base)
        out.mkdir(parents=True, exist_ok=True)
        path = out / f"flight_{tick}.jsonl"
        since = tick - self.trace_window_ticks
        tail = [e for e in self.obs.trace.events if e.tick >= since]
        lines = [json.dumps({
            "record": "header", "trigger": trigger, "tick": tick,
            "capacity": self.capacity, "seed": self.seed,
            "snapshots": len(self.ring), "trace_events": len(tail),
            "trace_since_tick": since}, sort_keys=True)]
        for snap in self.ring:
            lines.append(json.dumps({"record": "snapshot", **snap},
                                    sort_keys=True))
        for e in tail:
            # Hand-built dict, not asdict/to_json: asdict deep-copies every
            # event recursively and a serialize/parse/re-serialize round
            # trip is worse still — both dominate dump latency on long
            # traces. json only READS detail, so the live dict is safe.
            lines.append(json.dumps(
                {"record": "trace", "seq": e.seq, "tick": e.tick,
                 "kind": e.kind, "name": e.name, "tenant": e.tenant,
                 "nic": e.nic, "span_id": e.span_id,
                 "parent_id": e.parent_id, "phase": e.phase,
                 "t_s": e.t_s, "detail": e.detail},
                sort_keys=True))
        for rec in self._metric_deltas():
            lines.append(json.dumps({"record": "metric_delta", **rec},
                                    sort_keys=True))
        path.write_text("\n".join(lines) + "\n")
        self.dumps.append(str(path))
        self.obs.trace.event("flight_dump", kind="mark", tick=tick,
                             trigger=trigger, snapshots=len(self.ring),
                             trace_events=len(tail))
        return str(path)

    def dump_safe(self, trigger: str, tick: int,
                  out_dir=None) -> Optional[str]:
        """Dump, but never raise: the trigger (a failed sentinel, a page
        alert) must keep propagating its OWN error, not the dump's. With no
        directory configured this is a silent no-op."""
        if out_dir is None and self.out_dir is None:
            return None
        try:
            return self.dump(trigger, tick, out_dir=out_dir)
        except Exception as exc:     # noqa: BLE001 — must not mask trigger
            try:
                self.obs.trace.event(
                    "flight_dump_failed", kind="mark", tick=tick,
                    trigger=trigger, error=f"{type(exc).__name__}: {exc}")
            except Exception:        # noqa: BLE001 — absolute last resort
                pass
            return None


def load_bundle(path) -> Dict[str, List[dict]]:
    """Read a ``flight_<tick>.jsonl`` bundle back, grouped by record type
    (``header`` / ``snapshot`` / ``trace`` / ``metric_delta``)."""
    out: Dict[str, List[dict]] = {"header": [], "snapshot": [],
                                  "trace": [], "metric_delta": []}
    with pathlib.Path(path).open() as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            rec = json.loads(line)
            out.setdefault(rec.get("record", "unknown"), []).append(rec)
    return out
