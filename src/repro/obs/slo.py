"""SLO error-budget engine (ISSUE 10): telemetry becomes judgment.

Each tenant's SLA (``service.tenants.TenantSLA``) defines a per-tick
service-level indicator: the tick is *good* when achieved throughput holds
``min_tput_frac`` of the serviceable contract (``min(offered, target)``)
AND the measured p99 (``p99_measured_s``; legacy ``p99_s`` as fallback
while the histogram is empty) stays under the latency target. The *error
budget* over a rolling ``horizon_ticks`` window is ``budget_frac`` of the
window — the fraction of ticks the tenant is contractually allowed to be
bad — and the **burn rate** over any sub-window is

    burn(W) = bad_ticks(W) / W / budget_frac

i.e. 1.0 means "spending the budget exactly as fast as the contract
allows"; the multi-window alert manager (``obs.alerts``) pages on
sustained multiples of that.

Grace ticks (post-failover/migration windows) DO burn budget: grace is the
pool forgiving *itself* in ``slo_report`` accounting, but the tenant still
experienced the degradation — which is exactly what makes the burn-rate
alert an early warning: it fires on in-grace burn *before* the first
violating tick the SLO report would count. Warmup ticks burn nothing (the
model is still settling; ``slo_report`` skips them too).

``why_slo(tenant)`` joins the budget ledger to the decision trace: it pulls
the whole burn window through the range form of ``DecisionTrace.why`` (one
span-closed query, ISSUE 10 satellite) and returns the burned ticks, the
remaining budget, and the causally-ordered events that spent it.
"""
from __future__ import annotations

import collections
import dataclasses
import itertools
from typing import Callable, Deque, Dict, List, Optional

from repro.obs import Obs


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Per-tenant budget terms, derived from the TenantSpec SLA."""

    target_gbps: float
    p99_target_s: float
    min_tput_frac: float        # achieved >= frac * min(offered, target)
    budget_frac: float          # allowed bad-tick fraction of the horizon
    horizon_ticks: int

    @classmethod
    def from_sla(cls, sla, horizon_ticks: int) -> "SLOPolicy":
        return cls(
            target_gbps=sla.target_gbps,
            p99_target_s=sla.p99_latency_s,
            # Older TenantSLA instances predate the budget fields.
            min_tput_frac=getattr(sla, "min_tput_frac", 0.9),
            budget_frac=getattr(sla, "budget_frac", 0.05),
            horizon_ticks=horizon_ticks)


@dataclasses.dataclass(slots=True)
class BurnSample:
    """One BURNED tick in a tenant's budget ledger. Good ticks are not
    materialised — their full telemetry already lives in the TenantTick
    log; the budget keeps only its judgments (the 0/1 window) plus the
    evidence for each tick it judged bad."""

    tick: int
    bad: bool
    p99_s: float
    achieved_gbps: float
    expected_gbps: float
    in_grace: bool
    reason: str = ""            # "", "tput", "p99", "tput+p99"


class TenantBudget:
    """Rolling-horizon budget ledger for one tenant."""

    def __init__(self, policy: SLOPolicy):
        self.policy = policy
        self.window: Deque[int] = collections.deque(
            maxlen=policy.horizon_ticks)
        self.samples: List[BurnSample] = []    # burned ticks only
        self.bad_total = 0
        self.first_tick: Optional[int] = None  # first/last scored tick
        self.last_tick: Optional[int] = None
        self.prev_bad = False                  # last scored tick burned?
        self._window_bad = 0        # running sum of the deque (hot path)
        # Burn-tick ring for the alert manager's windows: the ticks of the
        # burns inside the widest tracked window, ascending. Steady state
        # (no recent burn) keeps it empty, so record_tick() pays ONE
        # emptiness check instead of per-window bookkeeping, and
        # burn_rates() derives every tracked window's count from this one
        # short deque only while actually burning.
        self._burn_ticks: Deque[int] = collections.deque()
        self._tracked: set = set()
        self._max_tracked = 0
        self._allow = max(policy.budget_frac * policy.horizon_ticks, 1e-9)

    def track_windows(self, windows) -> None:
        """Serve these windows from the burn-tick ring. Windows wider than
        the horizon are capped at it — the sample window itself never
        holds more than ``horizon_ticks`` entries, so the walking path
        they replace had the same cap."""
        maxlen = self.window.maxlen or 0
        new = [w for w in windows if w not in self._tracked]
        if not new:
            return
        self._tracked.update(new)
        mt = min(max(self._tracked), maxlen) if maxlen \
            else max(self._tracked)
        if mt != self._max_tracked:
            self._max_tracked = mt
            # Rebuild from the trailing window (scored ticks are
            # consecutive, so offset i from the right is last_tick - i).
            bt = self._burn_ticks
            bt.clear()
            if self.last_tick is not None:
                span = min(mt, len(self.window))
                for i, v in enumerate(itertools.islice(
                        reversed(self.window), span)):
                    if v:
                        bt.appendleft(self.last_tick - i)

    def record_tick(self, tick: int, bad: bool) -> None:
        """Score one tick into the window + burn-tick ring. The caller
        appends a BurnSample to ``samples`` only when ``bad`` (good ticks
        allocate nothing — this runs per tenant per tick)."""
        win = self.window
        if len(win) == win.maxlen:
            self._window_bad -= win[0]   # maxlen evicts silently
        if self.first_tick is None:
            self.first_tick = tick
        self.last_tick = tick
        self.prev_bad = bad
        bt = self._burn_ticks
        if bad:
            win.append(1)
            self.bad_total += 1
            self._window_bad += 1
            if self._max_tracked:
                bt.append(tick)
        else:
            win.append(0)
        if bt and bt[0] <= tick - self._max_tracked:
            horizon = tick - self._max_tracked
            while bt and bt[0] <= horizon:
                bt.popleft()

    def push(self, sample: BurnSample) -> None:
        """Back-compat single-call form of ``record_tick`` + ledger."""
        self.record_tick(sample.tick, sample.bad)
        if sample.bad:
            self.samples.append(sample)

    def burned(self) -> int:
        """Bad ticks inside the rolling horizon."""
        return self._window_bad

    def allowance(self) -> float:
        """Bad ticks the horizon's budget permits."""
        return self.policy.budget_frac * self.policy.horizon_ticks

    def remaining_frac(self) -> float:
        """Fraction of the rolling budget still unspent (clamped at 0)."""
        r = 1.0 - self._window_bad / self._allow
        return r if r > 0.0 else 0.0

    def burn_rate(self, window_ticks: int) -> float:
        """Observed burn over the trailing ``window_ticks``, as a multiple
        of the allowed steady-state burn (1.0 = spending on schedule)."""
        w = max(1, min(window_ticks, len(self.window))) \
            if self.window else max(1, window_ticks)
        # no list copy: walk the trailing w entries from the right
        bad = sum(itertools.islice(reversed(self.window), w))
        return (bad / w) / max(self.policy.budget_frac, 1e-9)

    def burn_rates(self, windows) -> Dict[int, float]:
        """``burn_rate`` for several windows at once — the alert manager
        needs every rule's long + confirm window each tick. Tracked
        windows (``track_windows``) count the burn-tick ring (a handful
        of entries, and only non-empty while burning); untracked ones
        share ONE right-to-left walk. ``windows`` must be ascending; the
        math is identical to ``burn_rate`` per window."""
        n = len(self.window)
        maxlen = self.window.maxlen or 0
        inv = 1.0 / max(self.policy.budget_frac, 1e-9)
        tracked = self._tracked
        bt = self._burn_ticks
        last = self.last_tick
        out: Dict[int, float] = {}
        it = None
        bad = seen = 0
        for w in windows:
            eff = max(1, min(w, n)) if n else max(1, w)
            if w in tracked and last is not None:
                cut = last - min(w, maxlen or w)
                c = 0
                for t in reversed(bt):
                    if t > cut:
                        c += 1
                    else:
                        break
            else:
                if it is None:
                    it = reversed(self.window)
                while seen < eff:
                    bad += next(it)
                    seen += 1
                c = bad
            out[w] = (c / eff) * inv
        return out

    def burned_ticks(self) -> List[int]:
        return [s.tick for s in self.samples]


class SLOEngine:
    """The per-tick judge: scores TenantTicks against SLA-derived budgets,
    exports remaining-budget gauges, and answers ``why_slo``."""

    def __init__(self, obs: Obs, horizon_ticks: int = 64,
                 warmup_ticks: int = 0,
                 shard_resolver: Optional[Callable] = None):
        self.obs = obs
        self.horizon_ticks = horizon_ticks
        self.warmup_ticks = warmup_ticks
        self.shard_resolver = shard_resolver
        self.budgets: Dict[str, TenantBudget] = {}
        # Hot path runs once per tenant per tick: resolve the labeled
        # metric series once per tenant, not once per call.
        self._gauges: Dict[str, object] = {}
        self._counters: Dict[str, object] = {}
        self._tracked_windows: tuple = ()

    def track_windows(self, windows) -> None:
        """Register alert-rule windows so every budget (existing and
        future) maintains running counters for them (see
        ``TenantBudget.track_windows``)."""
        self._tracked_windows = tuple(sorted(
            set(self._tracked_windows) | set(windows)))
        for b in self.budgets.values():
            b.track_windows(self._tracked_windows)

    def budget(self, tenant: str, sla) -> TenantBudget:
        b = self.budgets.get(tenant)
        if b is None:
            b = TenantBudget(SLOPolicy.from_sla(sla, self.horizon_ticks))
            if self._tracked_windows:
                b.track_windows(self._tracked_windows)
            self.budgets[tenant] = b
        return b

    def observe(self, tt, sla) -> bool:
        """Score one TenantTick; returns whether it burned budget. Emits a
        ``slo_burn`` trace event at the START of each burn streak (a
        per-burned-tick event would dominate the layer's own overhead
        budget under sustained chaos; the burned-tick ledger lives in
        ``samples``/``burn_reasons``, and good ticks stay in the telemetry
        log) and keeps the ``slo_budget_remaining``/``slo_burned_ticks``
        series current."""
        b = self.budget(tt.tenant, sla)
        pol = b.policy
        p99 = tt.p99_measured_s if tt.p99_measured_s > 0.0 else tt.p99_s
        expect = min(tt.offered_gbps, pol.target_gbps)
        tput_bad = tt.achieved_gbps < pol.min_tput_frac * expect - 1e-12
        p99_bad = p99 > pol.p99_target_s
        warm = tt.tick < self.warmup_ticks
        bad = (tput_bad or p99_bad) and not warm
        streak_start = bad and not b.prev_bad
        b.record_tick(tt.tick, bad)
        if bad:
            reason = ("tput+p99" if tput_bad and p99_bad
                      else "tput" if tput_bad else "p99")
            b.samples.append(BurnSample(
                tick=tt.tick, bad=True, p99_s=p99,
                achieved_gbps=tt.achieved_gbps, expected_gbps=expect,
                in_grace=tt.in_grace, reason=reason))
        g = self._gauges.get(tt.tenant)
        if g is None:
            g = self._gauges[tt.tenant] = self.obs.metrics.gauge(
                "slo_budget_remaining", tenant=tt.tenant)
        r = b.remaining_frac()
        if g.value != r:        # steady state: unchanged, skip the set
            g.set(r)
        if bad:
            c = self._counters.get(tt.tenant)
            if c is None:
                c = self._counters[tt.tenant] = self.obs.metrics.counter(
                    "slo_burned_ticks_total", tenant=tt.tenant)
            c.inc()
            if streak_start:
                detail = dict(reason=reason, p99_s=p99,
                              p99_target_s=pol.p99_target_s,
                              achieved_gbps=tt.achieved_gbps,
                              expected_gbps=expect, in_grace=tt.in_grace,
                              budget_remaining=b.remaining_frac())
                shard = (self.shard_resolver(tt.tenant)
                         if self.shard_resolver is not None else None)
                if shard is not None:
                    detail["shard"] = shard
                self.obs.trace.event("slo_burn", tenant=tt.tenant,
                                     tick=tt.tick, **detail)
        return bad

    def burn_rate(self, tenant: str, window_ticks: int) -> float:
        b = self.budgets.get(tenant)
        return b.burn_rate(window_ticks) if b is not None else 0.0

    def why_slo(self, tenant: str) -> dict:
        """The budget narrative: how much burned, when, and the trace spans
        and decisions that spent it — one span-closed range query over the
        whole burn window."""
        b = self.budgets.get(tenant)
        if b is None or b.last_tick is None:
            return {"tenant": tenant, "tracked": False}
        burned = b.burned_ticks()
        lo = burned[0] if burned else b.first_tick
        hi = burned[-1] if burned else b.last_tick
        events = self.obs.trace.why(tenant, tick_lo=lo, tick_hi=hi)
        story = [{"seq": e.seq, "tick": e.tick, "kind": e.kind,
                  "name": e.name, "nic": e.nic, "phase": e.phase,
                  "detail": dict(e.detail)} for e in events]
        return {
            "tenant": tenant,
            "tracked": True,
            "policy": dataclasses.asdict(b.policy),
            "burned_ticks": burned,
            "burned_in_window": b.burned(),
            "allowance_ticks": b.allowance(),
            "remaining_frac": b.remaining_frac(),
            "burn_window": [lo, hi],
            "burn_reasons": {s.tick: s.reason for s in b.samples},
            "events": story,
        }
