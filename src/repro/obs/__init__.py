"""Pool-wide observability (ISSUE 7): metrics registry + decision-audit trace.

``Obs`` is the bundle every layer shares: a ``MetricsRegistry`` (counters /
gauges / histograms with measured streaming percentiles) and a
``DecisionTrace`` (the causally-ordered decision/fault/span event log). The
controller creates one by default and hands it to its governor; the service
runtime reuses the controller's so all layers write one log. Recording is
always on — events are list appends and histogram observes, cheap enough
that the chaos benchmark's wall-clock budget (<5% overhead) holds — and
export is explicit (``dump``).
"""
from __future__ import annotations

import pathlib
from typing import Iterable, Optional

from repro.obs.metrics import (Counter, Gauge, Histogram,  # noqa: F401
                               MetricsRegistry)
from repro.obs.percentiles import P2Quantile, Reservoir    # noqa: F401
from repro.obs.trace import (DECISION, FAULT, MARK, RECONCILE,  # noqa: F401
                             SPAN, DecisionTrace, Span, TraceEvent)


class Obs:
    """One observability context: metrics + trace, shared across layers."""

    def __init__(self, seed: int = 0, clock=None):
        self.metrics = MetricsRegistry(seed=seed)
        self.trace = (DecisionTrace(clock=clock) if clock is not None
                      else DecisionTrace())

    def set_tick(self, tick: int) -> None:
        self.trace.set_tick(tick)

    # -- data-plane snapshot ---------------------------------------------------
    def snapshot_compile_caches(self, planes: Iterable = ()) -> None:
        """Pull the process-wide compile-cache hit/miss counters
        (core.graph) and per-plane dispatch stats into registry gauges, so
        an exported artifact carries the zero-steady-state-recompile
        evidence beside the latency series."""
        from repro.core import graph
        for cache, stats in graph.compile_cache_stats().items():
            for field, v in stats.items():
                self.metrics.gauge("compile_cache_" + field,
                                   cache=cache).set(v)
        calls = compiles = 0
        for dp in planes:
            calls += dp.dispatch_stats.get("calls", 0)
            compiles += dp.dispatch_stats.get("compiles", 0)
        if calls or compiles:
            self.metrics.gauge("dataplane_dispatch_calls").set(calls)
            self.metrics.gauge("dataplane_dispatch_compiles").set(compiles)

    # -- artifact export -------------------------------------------------------
    def dump(self, out_dir, prefix: str = "") -> dict:
        """Write ``trace.jsonl``, ``metrics.jsonl``, and ``metrics.prom``
        under ``out_dir`` (created if missing); returns the paths."""
        out = pathlib.Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        p = (prefix + "." if prefix else "")
        paths = {
            "trace": out / f"{p}trace.jsonl",
            "metrics": out / f"{p}metrics.jsonl",
            "prom": out / f"{p}metrics.prom",
        }
        self.trace.dump_jsonl(paths["trace"])
        self.metrics.dump_jsonl(paths["metrics"])
        paths["prom"].write_text(self.metrics.render_prometheus())
        return {k: str(v) for k, v in paths.items()}


def load_trace(path) -> DecisionTrace:
    """Load a dumped ``trace.jsonl`` artifact back into a queryable trace."""
    return DecisionTrace.load_jsonl(path)


# SLO / alerting / flight-recorder layer (ISSUE 10). Imported last: these
# modules use ``from repro.obs import Obs``, which needs the class above to
# exist during this package's own initialization.
from repro.obs.alerts import (AlertTransition, BurnAlertManager,  # noqa: E402,F401
                              BurnRule, DEFAULT_RULES, FIRING, PAGE,
                              RESOLVED, WARN)
from repro.obs.flight import FlightRecorder, load_bundle  # noqa: E402,F401
from repro.obs.slo import (BurnSample, SLOEngine, SLOPolicy,  # noqa: E402,F401
                           TenantBudget)
