"""Multi-window multi-burn-rate alerting over SLO error budgets (ISSUE 10).

The SRE-workbook recipe, scaled from wall-time to ticks: an alert rule
pairs a *long* window (sustained burn — did this persist?) with a short
*confirm* window (is it still happening *now*?), and a condition fires
only when the burn rate over BOTH exceeds the rule's threshold. Two rules
by default:

  * ``page``  — fast long window ("1h-equivalent"), high burn multiple:
    the budget is being spent so fast the contract breaks within the
    rolling horizon unless someone acts.
  * ``warn``  — slow long window ("6h-equivalent"), lower multiple:
    sustained low-grade burn worth a look, not a wake-up.

(The wall-time equivalence is documented in DESIGN.md: at ``dt_s`` = 50 ms
a literal hour would be 72 000 ticks — far past any run — so windows are
expressed directly in ticks with the 1h:6h *ratio* preserved.)

Lifecycle: ``firing`` -> ``resolved`` with dedup (a firing alert never
re-fires) and hold-down (the condition must stay clear ``holddown_ticks``
consecutive evaluations before resolving, so a burn flickering around the
threshold cannot flap the alert). Every transition lands in the decision
trace (``slo_alert`` events, shard-labeled when a resolver is attached)
and in the metrics (``slo_alert_transitions_total``, ``slo_alerts_active``).

Transitions also drive the runtime's early-warning hook: ``on_page``
callbacks fire on every page-severity ``firing`` transition — the service
runtime uses this to pre-arm the gray-failure detector and request a
proactive ``scale_verdict`` consult before the contract actually breaks.

Determinism contract (tested): tenants and rules are evaluated in sorted /
declaration order and alert identity is (tenant, severity) — replaying the
same seeded scenario yields a byte-identical transition sequence, on the
legacy and the 1-shard sharded controller alike.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.obs import Obs
from repro.obs.slo import SLOEngine

PAGE = "page"
WARN = "warn"

FIRING = "firing"
RESOLVED = "resolved"


@dataclasses.dataclass(frozen=True)
class BurnRule:
    """One multi-window burn condition: burn(long) and burn(confirm) must
    BOTH reach ``burn_threshold`` for the rule to hold."""

    severity: str
    window_ticks: int           # the long window
    confirm_ticks: int          # the short "still happening" window
    burn_threshold: float


DEFAULT_RULES: Tuple[BurnRule, ...] = (
    BurnRule(PAGE, window_ticks=8, confirm_ticks=2, burn_threshold=4.0),
    BurnRule(WARN, window_ticks=24, confirm_ticks=6, burn_threshold=2.0),
)


@dataclasses.dataclass
class AlertTransition:
    tick: int
    tenant: str
    severity: str
    state: str                  # firing | resolved
    burn_long: float
    burn_short: float

    def key(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class _ActiveAlert:
    fired_tick: int
    clear_streak: int = 0       # consecutive evaluations below threshold


class BurnAlertManager:
    """Evaluates the burn rules once per tick against the SLO engine."""

    def __init__(self, engine: SLOEngine, obs: Obs,
                 rules: Sequence[BurnRule] = DEFAULT_RULES,
                 holddown_ticks: int = 4,
                 shard_resolver: Optional[Callable] = None):
        self.engine = engine
        self.obs = obs
        self.rules = tuple(rules)
        self.holddown_ticks = max(1, holddown_ticks)
        self.shard_resolver = shard_resolver
        self.transitions: List[AlertTransition] = []
        self.on_page: List[Callable[[str, AlertTransition], None]] = []
        self._active: Dict[Tuple[str, str], _ActiveAlert] = {}
        # step() runs every tick: resolve the gauge series once, and
        # precompute the ascending union of every rule's windows so each
        # tenant's burns come from a single walk (TenantBudget.burn_rates)
        self._active_gauge = obs.metrics.gauge("slo_alerts_active")
        self._windows = tuple(sorted(
            {w for r in self.rules
             for w in (r.window_ticks, r.confirm_ticks)}))
        # Budgets keep running bad-counts for exactly these windows, so
        # the per-tick evaluation is dict reads, not sample walks.
        engine.track_windows(self._windows)
        self._tenant_order: List[str] = []   # sorted; refreshed on growth
        self._active_per_tenant: Dict[str, int] = {}

    # -- evaluation ------------------------------------------------------------
    def step(self, tick: int) -> List[AlertTransition]:
        """One evaluation pass; returns the transitions it produced."""
        out: List[AlertTransition] = []
        budgets = self.engine.budgets
        if len(self._tenant_order) != len(budgets):
            self._tenant_order = sorted(budgets)   # budgets only grow
        for tenant in self._tenant_order:
            b = budgets[tenant]
            # An empty burn-tick ring means zero bad ticks inside the
            # widest tracked window, hence zero burn on every rule window
            # (they all nest inside it), so no rule can fire — and with no
            # active alert to resolve, the tenant needs no evaluation at
            # all. This is the steady-state fast path.
            if (not b._burn_ticks
                    and not self._active_per_tenant.get(tenant)):
                continue
            burns = b.burn_rates(self._windows)
            for rule in self.rules:
                burn_long = burns[rule.window_ticks]
                burn_short = burns[rule.confirm_ticks]
                hot = (burn_long >= rule.burn_threshold
                       and burn_short >= rule.burn_threshold)
                key = (tenant, rule.severity)
                st = self._active.get(key)
                if hot:
                    if st is None:
                        # fire (dedup: an already-firing alert stays put)
                        self._active[key] = _ActiveAlert(fired_tick=tick)
                        self._active_per_tenant[tenant] = \
                            self._active_per_tenant.get(tenant, 0) + 1
                        tr = self._transition(tick, tenant, rule.severity,
                                              FIRING, burn_long, burn_short)
                        out.append(tr)
                        if rule.severity == PAGE:
                            for fn in self.on_page:
                                fn(tenant, tr)
                    else:
                        st.clear_streak = 0
                elif st is not None:
                    st.clear_streak += 1
                    if st.clear_streak >= self.holddown_ticks:
                        del self._active[key]
                        self._active_per_tenant[tenant] -= 1
                        out.append(self._transition(
                            tick, tenant, rule.severity, RESOLVED,
                            burn_long, burn_short))
        self._active_gauge.set(len(self._active))
        return out

    def _transition(self, tick: int, tenant: str, severity: str,
                    state: str, burn_long: float,
                    burn_short: float) -> AlertTransition:
        tr = AlertTransition(tick=tick, tenant=tenant, severity=severity,
                             state=state, burn_long=burn_long,
                             burn_short=burn_short)
        self.transitions.append(tr)
        detail = dict(severity=severity, state=state,
                      burn_long=round(burn_long, 6),
                      burn_short=round(burn_short, 6))
        shard = (self.shard_resolver(tenant)
                 if self.shard_resolver is not None else None)
        if shard is not None:
            detail["shard"] = shard
        self.obs.trace.event("slo_alert", tenant=tenant, tick=tick, **detail)
        self.obs.metrics.counter("slo_alert_transitions_total",
                                 severity=severity, state=state).inc()
        return tr

    # -- inspection ------------------------------------------------------------
    def active(self) -> List[Tuple[str, str]]:
        return sorted(self._active)

    def sequence(self) -> str:
        """Canonical JSON of the full transition history — ticks, tenants,
        severities, states, and burn rates, in occurrence order. Two runs
        of the same seeded scenario must produce byte-identical strings
        (no wall-clock anywhere in an AlertTransition)."""
        return json.dumps([t.key() for t in self.transitions],
                          sort_keys=True)
