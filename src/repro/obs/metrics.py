"""Metrics registry: counters, gauges, histograms with streaming quantiles.

One ``MetricsRegistry`` per observability context (``obs.Obs``). Metrics are
keyed by (name, sorted label items), so ``registry.counter("x", tenant="a")``
and ``tenant="b"`` are independent series of one family — the Prometheus
label model, without the client library.

Histograms carry BOTH percentile estimators from ``obs.percentiles``: the
seeded reservoir (exact until capacity, then uniform-sample estimates — the
headline "measured" number) and a set of P² markers (O(1) cross-check
series). ``quantile()`` returns the reservoir value.

Exposition: ``render_prometheus()`` emits the text format (counters/gauges
as-is; histograms as Prometheus summaries — ``{quantile="0.99"}`` rows plus
``_count``/``_sum``); ``to_records()``/``dump_jsonl()`` emit one JSON object
per series for artifact files.
"""
from __future__ import annotations

import json
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.obs.percentiles import P2Quantile, Reservoir

LabelKey = Tuple[Tuple[str, str], ...]

DEFAULT_QUANTILES = (0.5, 0.9, 0.99)


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    kind = "counter"

    def __init__(self, name: str, labels: LabelKey):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        assert v >= 0.0, "counters only go up"
        self.value += v


class Gauge:
    kind = "gauge"

    def __init__(self, name: str, labels: LabelKey):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Histogram:
    kind = "histogram"

    def __init__(self, name: str, labels: LabelKey,
                 quantiles: Sequence[float] = DEFAULT_QUANTILES,
                 reservoir_capacity: int = 4096, seed: int = 0,
                 p2: bool = False):
        self.name = name
        self.labels = labels
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.quantiles = tuple(quantiles)
        self.reservoir = Reservoir(reservoir_capacity, seed=seed)
        # The P² cross-check estimators are opt-in: they are O(1) memory but
        # per-sample Python updates, and the reservoir path is already exact
        # until capacity — always-on hot series (the runtime's per-tenant
        # latency stream) stay vectorized, diagnostic series can ask for the
        # second opinion.
        self._p2 = {q: P2Quantile(q) for q in self.quantiles} if p2 else {}

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        self.min = x if self.min is None else min(self.min, x)
        self.max = x if self.max is None else max(self.max, x)
        self.reservoir.observe(x)
        for est in self._p2.values():
            est.observe(x)

    def observe_many(self, xs: Iterable[float]) -> None:
        import numpy as np
        arr = np.asarray(list(xs) if not hasattr(xs, "ravel") else xs,
                         dtype=float).ravel()
        if arr.size == 0:
            return
        self.count += int(arr.size)
        self.sum += float(arr.sum())
        lo, hi = float(arr.min()), float(arr.max())
        self.min = lo if self.min is None else min(self.min, lo)
        self.max = hi if self.max is None else max(self.max, hi)
        self.reservoir.observe_many(arr)
        for est in self._p2.values():
            for x in arr.tolist():
                est.observe(x)

    def quantile(self, q: float) -> Optional[float]:
        """The measured quantile (reservoir path: exact until capacity)."""
        return self.reservoir.quantile(q)

    def p2_quantile(self, q: float) -> Optional[float]:
        """The O(1) P² cross-check estimate (tracked quantiles only)."""
        est = self._p2.get(q)
        return est.value() if est is not None else None

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Get-or-create registry of labeled metric series."""

    def __init__(self, seed: int = 0, reservoir_capacity: int = 4096):
        self.seed = seed
        self.reservoir_capacity = reservoir_capacity
        self._metrics: Dict[Tuple[str, LabelKey], Metric] = {}

    def _get(self, cls, name: str, labels: Dict[str, str], **kw) -> Metric:
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[1], **kw)
            self._metrics[key] = m
        assert isinstance(m, cls), (
            f"metric {name} already registered as {m.kind}")
        return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  quantiles: Sequence[float] = DEFAULT_QUANTILES,
                  p2: bool = False, **labels: str) -> Histogram:
        # Per-series seed derived from the registry seed + identity so two
        # registries built alike retain identical reservoirs.
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = Histogram(name, key[1], quantiles=quantiles,
                          reservoir_capacity=self.reservoir_capacity,
                          seed=hash((self.seed,) + key) & 0x7FFFFFFF,
                          p2=p2)
            self._metrics[key] = m
        assert isinstance(m, Histogram)
        return m

    def get(self, name: str, **labels: str) -> Optional[Metric]:
        return self._metrics.get((name, _label_key(labels)))

    def series(self, name: str) -> List[Metric]:
        return [m for (n, _), m in sorted(self._metrics.items())
                if n == name]

    # -- exposition ------------------------------------------------------------
    def render_prometheus(self) -> str:
        lines: List[str] = []
        seen_type: set = set()
        for (name, labels), m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                if name not in seen_type:
                    lines.append(f"# TYPE {name} summary")
                    seen_type.add(name)
                base = dict(labels)
                for q in m.quantiles:
                    v = m.quantile(q)
                    if v is None:
                        continue
                    lk = _label_key({**base, "quantile": repr(q)})
                    lines.append(f"{name}{_label_str(lk)} {v:.9g}")
                lines.append(f"{name}_count{_label_str(labels)} {m.count}")
                lines.append(f"{name}_sum{_label_str(labels)} {m.sum:.9g}")
            else:
                if name not in seen_type:
                    lines.append(f"# TYPE {name} {m.kind}")
                    seen_type.add(name)
                lines.append(f"{name}{_label_str(labels)} {m.value:.9g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_records(self) -> List[dict]:
        out: List[dict] = []
        for (name, labels), m in sorted(self._metrics.items()):
            rec = {"name": name, "labels": dict(labels), "kind": m.kind}
            if isinstance(m, Histogram):
                rec.update(count=m.count, sum=m.sum, min=m.min, max=m.max,
                           mean=m.mean,
                           quantiles={repr(q): m.quantile(q)
                                      for q in m.quantiles},
                           exact=m.reservoir.exact)
                if m._p2:              # cross-check only when tracked
                    rec["p2"] = {repr(q): m.p2_quantile(q)
                                 for q in m.quantiles}
            else:
                rec["value"] = m.value
            out.append(rec)
        return out

    def dump_jsonl(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("w") as f:
            for rec in self.to_records():
                f.write(json.dumps(rec) + "\n")
