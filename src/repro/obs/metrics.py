"""Metrics registry: counters, gauges, histograms with streaming quantiles.

One ``MetricsRegistry`` per observability context (``obs.Obs``). Metrics are
keyed by (name, sorted label items), so ``registry.counter("x", tenant="a")``
and ``tenant="b"`` are independent series of one family — the Prometheus
label model, without the client library.

Histograms carry BOTH percentile estimators from ``obs.percentiles``: the
seeded reservoir (exact until capacity, then uniform-sample estimates — the
headline "measured" number) and a set of P² markers (O(1) cross-check
series). ``quantile()`` returns the reservoir value.

Exposition: ``render_prometheus()`` emits spec-conformant text format
(ISSUE 10): counters carry the ``_total`` suffix exactly once, histograms
render as true Prometheus histograms — cumulative ``_bucket{le="..."}``
rows up to ``le="+Inf"`` plus ``_count``/``_sum`` — so the export parses
under promtool-style linting. Reservoir/P² quantiles stay queryable in
code and in the JSONL records (``to_records()``/``dump_jsonl()``).
"""
from __future__ import annotations

import bisect
import json
import pathlib
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

from repro.obs.percentiles import P2Quantile, Reservoir

LabelKey = Tuple[Tuple[str, str], ...]

DEFAULT_QUANTILES = (0.5, 0.9, 0.99)

# Default bucket ladder: a 1-2.5-5 log ladder from 5 µs to 10 s covering
# the latency-in-seconds series this registry mostly carries, with sane
# coverage for other unit scales (counts, Gbps) in the upper decades.
DEFAULT_BUCKETS = (
    5e-6, 1e-5, 2.5e-5, 5e-5, 1e-4, 2.5e-4, 5e-4,
    1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0)


def _fmt(v: float) -> str:
    return f"{v:g}"


def _label_key(labels: Dict[str, str]) -> LabelKey:
    return tuple(sorted((k, str(v)) for k, v in labels.items()))


def _label_str(key: LabelKey) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Counter:
    kind = "counter"

    def __init__(self, name: str, labels: LabelKey):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def inc(self, v: float = 1.0) -> None:
        assert v >= 0.0, "counters only go up"
        self.value += v


class Gauge:
    kind = "gauge"

    def __init__(self, name: str, labels: LabelKey):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)

    def inc(self, v: float = 1.0) -> None:
        self.value += v


class Histogram:
    kind = "histogram"

    def __init__(self, name: str, labels: LabelKey,
                 quantiles: Sequence[float] = DEFAULT_QUANTILES,
                 reservoir_capacity: int = 4096, seed: int = 0,
                 p2: bool = False,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        self.name = name
        self.labels = labels
        self.count = 0
        self.sum = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self.quantiles = tuple(quantiles)
        # Explicit bucket bounds (ISSUE 10): per-bucket (non-cumulative)
        # observation counts; exposition renders them cumulatively with a
        # trailing +Inf bucket equal to ``count``.
        self.buckets = tuple(sorted(buckets))
        self.bucket_counts = [0] * len(self.buckets)
        self.reservoir = Reservoir(reservoir_capacity, seed=seed)
        # The P² cross-check estimators are opt-in: they are O(1) memory but
        # per-sample Python updates, and the reservoir path is already exact
        # until capacity — always-on hot series (the runtime's per-tenant
        # latency stream) stay vectorized, diagnostic series can ask for the
        # second opinion.
        self._p2 = {q: P2Quantile(q) for q in self.quantiles} if p2 else {}

    def observe(self, x: float) -> None:
        x = float(x)
        self.count += 1
        self.sum += x
        self.min = x if self.min is None else min(self.min, x)
        self.max = x if self.max is None else max(self.max, x)
        idx = bisect.bisect_left(self.buckets, x)
        if idx < len(self.buckets):
            self.bucket_counts[idx] += 1
        self.reservoir.observe(x)
        for est in self._p2.values():
            est.observe(x)

    def observe_many(self, xs: Iterable[float]) -> None:
        import numpy as np
        arr = np.asarray(list(xs) if not hasattr(xs, "ravel") else xs,
                         dtype=float).ravel()
        if arr.size == 0:
            return
        self.count += int(arr.size)
        self.sum += float(arr.sum())
        lo, hi = float(arr.min()), float(arr.max())
        self.min = lo if self.min is None else min(self.min, lo)
        self.max = hi if self.max is None else max(self.max, hi)
        idx = np.searchsorted(np.asarray(self.buckets), arr, side="left")
        per = np.bincount(idx, minlength=len(self.buckets) + 1)
        for i in range(len(self.buckets)):
            self.bucket_counts[i] += int(per[i])
        self.reservoir.observe_many(arr)
        for est in self._p2.values():
            for x in arr.tolist():
                est.observe(x)

    def quantile(self, q: float) -> Optional[float]:
        """The measured quantile (reservoir path: exact until capacity)."""
        return self.reservoir.quantile(q)

    def p2_quantile(self, q: float) -> Optional[float]:
        """The O(1) P² cross-check estimate (tracked quantiles only)."""
        est = self._p2.get(q)
        return est.value() if est is not None else None

    def cumulative_buckets(self) -> List[Tuple[str, int]]:
        """``(le, cumulative_count)`` pairs ending in ``("+Inf", count)`` —
        the exposition shape of the explicit bucket bounds."""
        out: List[Tuple[str, int]] = []
        cum = 0
        for b, c in zip(self.buckets, self.bucket_counts):
            cum += c
            out.append((_fmt(b), cum))
        out.append(("+Inf", self.count))
        return out

    @property
    def mean(self) -> Optional[float]:
        return self.sum / self.count if self.count else None


Metric = Union[Counter, Gauge, Histogram]


class MetricsRegistry:
    """Get-or-create registry of labeled metric series."""

    def __init__(self, seed: int = 0, reservoir_capacity: int = 4096):
        self.seed = seed
        self.reservoir_capacity = reservoir_capacity
        self._metrics: Dict[Tuple[str, LabelKey], Metric] = {}

    def _get(self, cls, name: str, labels: Dict[str, str], **kw) -> Metric:
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[1], **kw)
            self._metrics[key] = m
        assert isinstance(m, cls), (
            f"metric {name} already registered as {m.kind}")
        return m

    def counter(self, name: str, **labels: str) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: str) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str,
                  quantiles: Sequence[float] = DEFAULT_QUANTILES,
                  p2: bool = False,
                  buckets: Sequence[float] = DEFAULT_BUCKETS,
                  **labels: str) -> Histogram:
        # Per-series seed derived from the registry seed + identity so two
        # registries built alike retain identical reservoirs.
        key = (name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = Histogram(name, key[1], quantiles=quantiles,
                          reservoir_capacity=self.reservoir_capacity,
                          seed=hash((self.seed,) + key) & 0x7FFFFFFF,
                          p2=p2, buckets=buckets)
            self._metrics[key] = m
        assert isinstance(m, Histogram)
        return m

    def get(self, name: str, **labels: str) -> Optional[Metric]:
        return self._metrics.get((name, _label_key(labels)))

    def series(self, name: str) -> List[Metric]:
        return [m for (n, _), m in sorted(self._metrics.items())
                if n == name]

    # -- exposition ------------------------------------------------------------
    def render_prometheus(self) -> str:
        """Spec-conformant text exposition (ISSUE 10): histograms render as
        cumulative ``_bucket{le=...}`` series ending in ``+Inf`` plus
        ``_count``/``_sum``; counters carry ``_total`` exactly once (series
        already named ``*_total`` are not suffixed again)."""
        lines: List[str] = []
        seen_type: set = set()
        for (name, labels), m in sorted(self._metrics.items()):
            if isinstance(m, Histogram):
                if name not in seen_type:
                    lines.append(f"# TYPE {name} histogram")
                    seen_type.add(name)
                base = dict(labels)
                for le, cum in m.cumulative_buckets():
                    lk = _label_key({**base, "le": le})
                    lines.append(f"{name}_bucket{_label_str(lk)} {cum}")
                lines.append(f"{name}_count{_label_str(labels)} {m.count}")
                lines.append(f"{name}_sum{_label_str(labels)} {m.sum:.9g}")
            else:
                out_name = name
                if m.kind == "counter" and not name.endswith("_total"):
                    out_name = name + "_total"
                if out_name not in seen_type:
                    lines.append(f"# TYPE {out_name} {m.kind}")
                    seen_type.add(out_name)
                lines.append(f"{out_name}{_label_str(labels)} {m.value:.9g}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_records(self) -> List[dict]:
        out: List[dict] = []
        for (name, labels), m in sorted(self._metrics.items()):
            rec = {"name": name, "labels": dict(labels), "kind": m.kind}
            if isinstance(m, Histogram):
                rec.update(count=m.count, sum=m.sum, min=m.min, max=m.max,
                           mean=m.mean,
                           quantiles={repr(q): m.quantile(q)
                                      for q in m.quantiles},
                           buckets=dict(m.cumulative_buckets()),
                           exact=m.reservoir.exact)
                if m._p2:              # cross-check only when tracked
                    rec["p2"] = {repr(q): m.p2_quantile(q)
                                 for q in m.quantiles}
            else:
                rec["value"] = m.value
            out.append(rec)
        return out

    def dump_jsonl(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("w") as f:
            for rec in self.to_records():
                f.write(json.dumps(rec) + "\n")
