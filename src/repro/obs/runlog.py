"""Structured run-logger for the benchmark harness (ISSUE 7 satellite).

The figure benchmarks historically reported through bare ``print`` of
``name,us_per_call,derived`` CSV rows. ``RunLogger`` keeps that console
contract (every row still echoes to stdout so existing pipelines parse
unchanged) while capturing each row as a structured record and — when an
output directory is given (``--emit-obs``) — writing per-run artifacts:

  ``rows.jsonl``    every emitted row as {"name", "us_per_call", "derived"}
  ``meta.json``     run metadata (argv-ish config, wall-clock, row count)
  ``<sub>/...``     any ``Obs`` contexts attached via ``artifact()``
                    (trace.jsonl + metrics.jsonl + metrics.prom each)
"""
from __future__ import annotations

import json
import pathlib
import time
from typing import Any, Dict, List, Optional


class RunLogger:
    def __init__(self, name: str, out_dir: Optional[str] = None, echo=print):
        self.name = name
        self.out_dir = pathlib.Path(out_dir) if out_dir else None
        self.echo = echo
        self.rows: List[dict] = []
        self.meta: Dict[str, Any] = {"run": name,
                                     "started": time.strftime(
                                         "%Y-%m-%dT%H:%M:%S")}
        self.artifacts: Dict[str, dict] = {}
        self._t0 = time.perf_counter()

    # -- the print-compatible row channel --------------------------------------
    def emit(self, line: str) -> None:
        """Accepts the benchmarks' CSV row strings (``name,us,derived``);
        anything unparseable is kept verbatim as a note row."""
        if self.echo is not None:
            self.echo(line)
        parts = str(line).split(",", 2)
        if len(parts) == 3:
            try:
                us = float(parts[1])
            except ValueError:
                us = None
            self.rows.append({"name": parts[0], "us_per_call": us,
                              "derived": parts[2]})
        else:
            self.rows.append({"note": str(line)})

    def note(self, **kv: Any) -> None:
        self.meta.update(kv)

    # -- obs artifact attachment -----------------------------------------------
    def artifact(self, obs, sub: str) -> Optional[dict]:
        """Dump an ``Obs`` context under ``<out_dir>/<sub>/``; no-op (returns
        None) when the logger has no output directory."""
        if self.out_dir is None:
            return None
        paths = obs.dump(self.out_dir / sub)
        self.artifacts[sub] = paths
        return paths

    # -- flush ------------------------------------------------------------------
    def close(self) -> Optional[pathlib.Path]:
        if self.out_dir is None:
            return None
        self.out_dir.mkdir(parents=True, exist_ok=True)
        with (self.out_dir / "rows.jsonl").open("w") as f:
            for r in self.rows:
                f.write(json.dumps(r) + "\n")
        self.meta.update(rows=len(self.rows),
                         wall_s=time.perf_counter() - self._t0,
                         artifacts=self.artifacts)
        (self.out_dir / "meta.json").write_text(
            json.dumps(self.meta, indent=2) + "\n")
        return self.out_dir
