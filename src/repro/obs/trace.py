"""Decision-audit trace: one causally-ordered event log for the whole pool.

Every *decision* in the control plane — governor verdicts (admission /
scale / migration / failover ordering), chaos faults, gray-failure
suspicion/exoneration/quarantine transitions, recovery park/readmit — lands
here as a point event, and every controller operation (submit / scale /
migrate / failover) as a timed *span* whose begin/end events bracket
whatever nested work it caused (a mid-migration crash produces a failover
span INSIDE the migrate span). Events carry (seq, tick, tenant, nic), so an
operator question like "why was t-fw clamped at tick 412?" is one
``trace.why("t-fw", 412)`` call.

Causal order is the append order (``seq`` is a monotone counter); ticks are
stamped from whatever the runtime last ``set_tick``-ed, so layers that do
not know the tick (governor, controller) still land in the right place.

The log round-trips through JSONL (``dump_jsonl``/``load_jsonl``): a loaded
trace answers every query identically to the live one — benchmarks dump it
as a run artifact and post-mortem tests reconstruct fault stories from the
file alone.
"""
from __future__ import annotations

import contextlib
import dataclasses
import json
import pathlib
import time
from typing import Any, Callable, Dict, Iterator, List, Optional

# Event kinds.
DECISION = "decision"      # a policy verdict with a reason
FAULT = "fault"            # injected fault / detector transition / recovery
SPAN = "span"              # begin/end of a timed controller operation
MARK = "mark"              # free-form annotation

# Well-known span name (ISSUE 8): the sharded controller's cross-shard
# headroom-digest refresh. ``trace.spans(name=RECONCILE)`` lists every
# reconciliation with its staleness ages and refreshed digests, and a
# ``cross_rack_placement`` decision between two reconcile spans is
# explained by the digest staleness the spans bracket.
RECONCILE = "reconcile"


@dataclasses.dataclass
class TraceEvent:
    seq: int
    tick: int
    kind: str                       # decision | fault | span | mark
    name: str                       # e.g. "scale_verdict", "gray_suspicion"
    tenant: Optional[str] = None
    nic: Optional[str] = None
    span_id: Optional[int] = None   # the span this event opens/closes
    parent_id: Optional[int] = None  # enclosing span (None = top level)
    phase: str = ""                 # "begin"/"end" for span events
    t_s: float = 0.0                # wall-clock stamp (trace clock)
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), sort_keys=True)


@dataclasses.dataclass
class Span:
    """A reconstructed begin/end pair (see ``DecisionTrace.spans``)."""

    span_id: int
    name: str
    tenant: Optional[str]
    nic: Optional[str]
    parent_id: Optional[int]
    tick_begin: int
    tick_end: Optional[int]
    duration_s: Optional[float]
    detail: Dict[str, Any]
    children: List[int] = dataclasses.field(default_factory=list)


class _SpanHandle:
    """Yielded by ``span()``: lets the body attach outcome detail that lands
    on the end event (e.g. whether a migration actually committed)."""

    def __init__(self, span_id: int):
        self.span_id = span_id
        self.extra: Dict[str, Any] = {}

    def note(self, **kv: Any) -> None:
        self.extra.update(kv)


class DecisionTrace:
    def __init__(self, clock: Callable[[], float] = time.monotonic):
        self.events: List[TraceEvent] = []
        self.clock = clock
        self.now_tick = -1              # -1 = before the first runtime tick
        self._seq = 0
        self._next_span = 1
        self._stack: List[int] = []     # open span ids (innermost last)

    # -- recording -------------------------------------------------------------
    def set_tick(self, tick: int) -> None:
        self.now_tick = tick

    def _append(self, kind: str, name: str, tenant: Optional[str],
                nic: Optional[str], tick: Optional[int],
                span_id: Optional[int], phase: str,
                detail: Dict[str, Any]) -> TraceEvent:
        ev = TraceEvent(
            seq=self._seq,
            tick=self.now_tick if tick is None else tick,
            kind=kind, name=name, tenant=tenant, nic=nic,
            span_id=span_id,
            parent_id=self._stack[-1] if self._stack else None,
            phase=phase, t_s=self.clock(), detail=_jsonable(detail))
        self._seq += 1
        self.events.append(ev)
        return ev

    def event(self, name: str, tenant: Optional[str] = None,
              nic: Optional[str] = None, kind: str = DECISION,
              tick: Optional[int] = None, **detail: Any) -> TraceEvent:
        return self._append(kind, name, tenant, nic, tick, None, "", detail)

    @contextlib.contextmanager
    def span(self, name: str, tenant: Optional[str] = None,
             nic: Optional[str] = None, tick: Optional[int] = None,
             **detail: Any) -> Iterator[_SpanHandle]:
        sid = self._next_span
        self._next_span += 1
        begin = self._append(SPAN, name, tenant, nic, tick, sid, "begin",
                             detail)
        handle = _SpanHandle(sid)
        self._stack.append(sid)
        try:
            yield handle
        finally:
            self._stack.pop()
            # parent_id of the end event = the span itself being closed is
            # not on the stack anymore; keep the begin's parent for symmetry.
            end = TraceEvent(
                seq=self._seq, tick=self.now_tick if tick is None else tick,
                kind=SPAN, name=name, tenant=tenant, nic=nic, span_id=sid,
                parent_id=begin.parent_id, phase="end", t_s=self.clock(),
                detail=_jsonable({**detail, **handle.extra,
                                  "duration_s": self.clock() - begin.t_s}))
            self._seq += 1
            self.events.append(end)

    # -- queries ---------------------------------------------------------------
    def query(self, name: Optional[str] = None, tenant: Optional[str] = None,
              nic: Optional[str] = None, tick: Optional[int] = None,
              kind: Optional[str] = None, since: Optional[int] = None,
              until: Optional[int] = None) -> List[TraceEvent]:
        """Filter the log (None = wildcard); result is in causal order."""
        out = []
        for e in self.events:
            if name is not None and e.name != name:
                continue
            if tenant is not None and e.tenant != tenant:
                continue
            if nic is not None and e.nic != nic:
                continue
            if tick is not None and e.tick != tick:
                continue
            if kind is not None and e.kind != kind:
                continue
            if since is not None and e.tick < since:
                continue
            if until is not None and e.tick > until:
                continue
            out.append(e)
        return out

    def why(self, tenant: str, tick: Optional[int] = None, *,
            tick_lo: Optional[int] = None,
            tick_hi: Optional[int] = None) -> List[TraceEvent]:
        """Every decision/fault/span event touching ``tenant`` at ``tick``
        (or within ``[tick_lo, tick_hi]``) — the audit answer to "why did
        the pool do that to this tenant?".

        The range form (ISSUE 10) is *span-closed*: if either half of a
        begin/end span pair lands in the window, its partner is included
        too, so a burn-window query never returns a dangling span. Result
        stays in causal (seq) order."""
        if tick is not None:
            tick_lo = tick_hi = tick
        lo = tick_lo if tick_lo is not None else float("-inf")
        hi = tick_hi if tick_hi is not None else float("inf")
        sel = [e for e in self.events
               if e.tenant == tenant and lo <= e.tick <= hi]
        sids = {e.span_id for e in sel
                if e.kind == SPAN and e.span_id is not None}
        if sids:
            have = {e.seq for e in sel}
            closers = [e for e in self.events
                       if e.kind == SPAN and e.span_id in sids
                       and e.seq not in have]
            if closers:
                sel = sorted(sel + closers, key=lambda e: e.seq)
        return sel

    def spans(self, name: Optional[str] = None,
              tenant: Optional[str] = None) -> List[Span]:
        """Reconstruct spans from begin/end pairs, children linked by
        ``parent_id``. Unclosed spans have tick_end/duration None."""
        by_id: Dict[int, Span] = {}
        for e in self.events:
            if e.kind != SPAN:
                continue
            if e.phase == "begin":
                by_id[e.span_id] = Span(
                    span_id=e.span_id, name=e.name, tenant=e.tenant,
                    nic=e.nic, parent_id=e.parent_id, tick_begin=e.tick,
                    tick_end=None, duration_s=None, detail=dict(e.detail))
            elif e.phase == "end" and e.span_id in by_id:
                sp = by_id[e.span_id]
                sp.tick_end = e.tick
                sp.duration_s = e.detail.get("duration_s")
                sp.detail.update(e.detail)
        for sp in by_id.values():
            if sp.parent_id in by_id:
                by_id[sp.parent_id].children.append(sp.span_id)
        out = [sp for sp in by_id.values()
               if (name is None or sp.name == name)
               and (tenant is None or sp.tenant == tenant)]
        return sorted(out, key=lambda s: s.span_id)

    # -- JSONL round trip ------------------------------------------------------
    def dump_jsonl(self, path) -> None:
        path = pathlib.Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        with path.open("w") as f:
            for e in self.events:
                f.write(e.to_json() + "\n")

    @classmethod
    def load_jsonl(cls, path) -> "DecisionTrace":
        trace = cls()
        with pathlib.Path(path).open() as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                d = json.loads(line)
                trace.events.append(TraceEvent(**d))
        if trace.events:
            trace._seq = max(e.seq for e in trace.events) + 1
            trace._next_span = max(
                (e.span_id for e in trace.events if e.span_id is not None),
                default=0) + 1
        return trace


def _jsonable(d: Dict[str, Any]) -> Dict[str, Any]:
    """Coerce detail values to JSON-stable forms (sets/tuples -> lists)."""
    out: Dict[str, Any] = {}
    for k, v in d.items():
        if isinstance(v, (set, frozenset)):
            out[k] = sorted(v)
        elif isinstance(v, tuple):
            out[k] = list(v)
        elif hasattr(v, "item"):            # numpy scalar
            out[k] = v.item()
        else:
            out[k] = v
    return out
