"""AdamW with optional bf16 moment states (jamba-398B single-pod fit) and
global-norm clipping. States are plain pytrees mirroring the params tree, so
they inherit the params' logical sharding axes (FSDP'd optimizer = ZeRO)."""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Tree = Any


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: Tree
    nu: Tree
    count: jnp.ndarray


def adamw_init(params: Tree, state_dtype=jnp.float32) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, state_dtype)
    return AdamWState(mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def clip_by_global_norm(grads: Tree, max_norm: float) -> Tuple[Tree, jnp.ndarray]:
    leaves = jax.tree.leaves(grads)
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in leaves))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale
                                   ).astype(g.dtype), grads), gn


def adamw_update(params: Tree, grads: Tree, state: AdamWState, lr,
                 b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
                 weight_decay: float = 0.1, max_grad_norm: float = 1.0
                 ) -> Tuple[Tree, AdamWState, Dict[str, jnp.ndarray]]:
    grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        gf = g.astype(jnp.float32)
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * gf
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    out = jax.tree.map(upd, params, grads, state.mu, state.nu)
    new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return new_p, AdamWState(new_m, new_v, count), {"grad_norm": gnorm}
