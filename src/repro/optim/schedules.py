"""LR schedules: cosine and MiniCPM's WSD (warmup-stable-decay)."""
from __future__ import annotations

import jax.numpy as jnp


def cosine_schedule(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = 0.5 * base_lr * (1.0 + jnp.cos(jnp.pi * prog))
        return jnp.where(step < warmup, warm, cos)
    return lr


def wsd_schedule(base_lr: float, warmup: int, total: int,
                 decay_frac: float = 0.1, floor: float = 0.1):
    """MiniCPM WSD: linear warmup -> constant plateau -> short exponential-ish
    decay over the last `decay_frac` of training to `floor`·base_lr."""
    decay_start = int(total * (1.0 - decay_frac))

    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        prog = jnp.clip((step - decay_start) / max(total - decay_start, 1),
                        0.0, 1.0)
        decay = base_lr * (floor ** prog)
        out = jnp.where(step < warmup, warm,
                        jnp.where(step < decay_start, base_lr, decay))
        return out
    return lr


def make_schedule(kind: str, base_lr: float, warmup: int, total: int):
    if kind == "wsd":
        return wsd_schedule(base_lr, warmup, total)
    return cosine_schedule(base_lr, warmup, total)
