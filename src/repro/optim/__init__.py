from repro.optim.adamw import (AdamWState, adamw_init, adamw_update,
                               clip_by_global_norm)
from repro.optim.schedules import cosine_schedule, wsd_schedule, make_schedule
