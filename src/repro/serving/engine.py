"""Serving engine: continuous-batched decode driven by the Meili data plane.

Requests are flows (paper §5.1.2): each request's tokens stay on its assigned
pipeline instance; when a pipeline saturates, new requests spill to the
instance with the most available capacity; completed sequences free slots
(continuous batching). The TrafficOrchestrator does admission + placement;
per-instance KV caches play the per-pipeline ring-buffer role (fixed-capacity,
single-writer).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import Model


@dataclasses.dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new_tokens: int = 16
    out: List[int] = dataclasses.field(default_factory=list)

    @property
    def done(self) -> bool:
        return len(self.out) >= self.max_new_tokens


class PipelineInstance:
    """One replicated pipeline: a slot-ed KV cache + decode step."""

    def __init__(self, model: Model, params, slots: int, max_len: int,
                 dtype=jnp.float32):
        self.model = model
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.cache, _ = model.init_cache(slots, max_len, dtype)
        self.active: Dict[int, Request] = {}     # slot -> request
        self.free = list(range(slots))
        self._step = jax.jit(
            lambda p, c, t: model.decode_step(p, c, t, impl="blocked"))

    @property
    def available(self) -> int:
        return len(self.free)

    def admit(self, req: Request) -> bool:
        if not self.free:
            return False
        slot = self.free.pop()
        self.active[slot] = req
        return True

    def step(self) -> None:
        if not self.active:
            return
        tokens = np.zeros((self.slots,), np.int32)
        for slot, req in self.active.items():
            seq = req.prompt + req.out
            tokens[slot] = seq[-1]
        logits, self.cache = self._step(self.params, self.cache,
                                        jnp.asarray(tokens))
        nxt = np.asarray(jnp.argmax(logits, axis=-1))
        finished = []
        for slot, req in self.active.items():
            req.out.append(int(nxt[slot]))
            if req.done:
                finished.append(slot)
        for slot in finished:
            del self.active[slot]
            self.free.append(slot)


class ServingEngine:
    """N pipeline instances + flow-sticky admission (Meili TO semantics)."""

    def __init__(self, model: Model, params, num_pipelines: int,
                 slots_per_pipeline: int = 8, max_len: int = 128,
                 dtype=jnp.float32):
        self.pipelines = [
            PipelineInstance(model, params, slots_per_pipeline, max_len,
                             dtype)
            for _ in range(num_pipelines)]
        self.pending: List[Request] = []
        self.completed: List[Request] = []

    def submit(self, req: Request) -> None:
        self.pending.append(req)

    def step(self) -> None:
        # Admission: highest-available-capacity pipeline first (paper §5.2).
        still = []
        for req in self.pending:
            cand = max(self.pipelines, key=lambda p: p.available)
            if not cand.admit(req):
                still.append(req)
        self.pending = still
        for p in self.pipelines:
            before = list(p.active.values())
            p.step()
            for req in before:
                if req.done and req not in self.completed:
                    self.completed.append(req)

    def run(self, max_steps: int = 256) -> List[Request]:
        for _ in range(max_steps):
            if not self.pending and all(not p.active for p in self.pipelines):
                break
            self.step()
        return self.completed
