"""Meili-planned LM serving: the paper's algorithms applied to model stages.

An LM's layer schedule (lm.build_schedule) is a heterogeneous pipeline —
attention vs Mamba vs MoE segments have very different per-token latencies,
exactly the situation Algorithm 1 was designed for. The planner:

  1. profiles per-segment decode latency (roofline cost model on the target
     chip via launch/decompose piece costs, or wall-clock on this host),
  2. runs Algorithm 1 -> per-segment replication factors R,
  3. runs Algorithm 2 over a pool of device groups -> placement,
  4. returns a ServingPlan the engine uses to partition request traffic
     across replicated pipeline instances with the TrafficOrchestrator.

This is the paper's SNICaaS control loop with LM segments as the tenant
application — the bridge between the reproduction and the TPU substrate.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import allocation as alloc_mod
from repro.core import replication as repl
from repro.core.pool import CPU, Pool
from repro.models import lm as lm_mod
from repro.models.registry import Model


@dataclasses.dataclass
class ServingPlan:
    stages: List[str]
    latencies: Dict[str, float]           # per-segment per-batch latency (s)
    R: Dict[str, int]
    num_pipelines: int
    allocation: Optional[alloc_mod.Allocation]
    throughput_gain: float                # vs single pipeline

    def summary(self) -> str:
        lines = [f"stages: {self.stages}", f"R: {self.R}",
                 f"pipelines: {self.num_pipelines}",
                 f"throughput gain: {self.throughput_gain:.2f}x"]
        if self.allocation is not None:
            for s in self.stages:
                lines.append(f"  {s} -> {self.allocation.nics_for(s)}")
        return "\n".join(lines)


def segment_stage_names(cfg) -> List[str]:
    sched = lm_mod.build_schedule(cfg)
    names = []
    for i, seg in enumerate(sched):
        kinds = "+".join(sorted({f"{s.mixer}/{s.ffn}" for s in seg.body}))
        names.append(f"seg{i}[{kinds}]x{seg.count}")
    return names


def plan_serving(model: Model, latencies: Dict[str, float],
                 pool: Optional[Pool] = None,
                 unit_throughput_gbps: Optional[Dict[str, float]] = None
                 ) -> ServingPlan:
    """latencies: per-stage (segment) per-batch latency from profiling."""
    stages = list(latencies.keys())
    R = repl.num_replication(stages, latencies)
    n_pipes = repl.num_pipelines(R)
    base = repl.pipeline_throughput(stages, latencies,
                                    {s: 1 for s in stages})
    scaled = repl.pipeline_throughput(stages, latencies, R)
    alloc = None
    if pool is not None:
        t_s = unit_throughput_gbps or {s: 1.0 for s in stages}
        need = {s: CPU for s in stages}
        alloc = alloc_mod.resource_alloc(stages, R, t_s, pool, need)
    return ServingPlan(stages=stages, latencies=latencies, R=R,
                       num_pipelines=n_pipes, allocation=alloc,
                       throughput_gain=scaled / base if base else 0.0)
