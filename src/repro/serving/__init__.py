from repro.serving.planner import plan_serving, ServingPlan
from repro.serving.engine import ServingEngine, Request
