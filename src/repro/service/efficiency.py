"""Deployment-mode comparator: pooled vs standalone vs microservice (§8).

The paper's headline consolidation claim: the pooled SmartNIC service is
~3× more resource-efficient than standalone per-tenant NICs and ~1.4× more
than microservice deployments. We reproduce the *protocol*: the same tenant
mix and the same deterministic traffic run under three provisioning models,
and efficiency = (achieved Gbps · ticks) / (reserved resource units · ticks):

  pooled        one shared pool, Algorithm 2/3 placement, closed-loop
                autoscaling; reserved = units currently committed;
  standalone    every tenant owns whole NICs (the smallest dedicated set
                that places its contract); reserved = ALL units of those
                NICs, always — the NICs cannot be shared, so idle cores and
                dark accelerators are still paid for;
  microservice  shared pool + stage-granular placement, but per-stage
                replica counts are FIXED at the contracted peak (no
                elasticity) — the disaggregated-container baseline.

Standalone therefore pays NIC-quantization waste (ISG alone pins a BF-2 for
regex plus Pensandos for sha/aes) and microservice pays peak-provisioning
waste across diurnal troughs and burst gaps; pooled pays neither.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core.controller import MeiliController
from repro.core.pool import CPU, NicSpec, Pool, paper_cluster
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import (TenantRegistry, TenantSpec, contracts,
                                   default_tenant_mix)
from repro.service.workload import make_scenario

MODES = ("pooled", "standalone", "microservice")


def _nic_units(spec: NicSpec) -> int:
    return spec.cores + sum(spec.accelerators.values())


def provision_standalone(spec: TenantSpec, inventory: List[NicSpec]
                         ) -> Tuple[MeiliController, List[NicSpec]]:
    """Dedicate the smallest whole-NIC set (greedy) that places the tenant's
    contract; NICs are consumed from the shared inventory."""
    spec.app.name = spec.name     # deployments keyed by tenant, as in admit()
    need = spec.app.resource_needs()
    taken: List[NicSpec] = []
    while True:
        # Grow the dedicated set until a trial submission places the full
        # contract; each round prefers NICs supplying the kinds the previous
        # trial left unmet (accelerators are the scarce axis, then cores).
        if taken:
            ctrl = MeiliController(Pool([copy.deepcopy(n) for n in taken]))
            dep = ctrl.submit(spec.app, spec.sla.target_gbps, spec.profile,
                              tenant=spec.name)
            if dep.allocation.satisfied() or not inventory:
                # satisfied, or inventory exhausted -> best-effort (the
                # paper's point: some mixes are simply infeasible standalone)
                return ctrl, taken
            unmet_kinds = {need[s] for s in dep.allocation.unmet}
        else:
            if not inventory:
                # Nothing left to dedicate: submit on an empty pool so the
                # caller still gets a (fully unmet) deployment to account.
                ctrl = MeiliController(Pool([]))
                ctrl.submit(spec.app, spec.sla.target_gbps, spec.profile,
                            tenant=spec.name)
                return ctrl, taken
            unmet_kinds = set(need.values())

        def score(n: NicSpec) -> tuple:
            accel = sum(n.accelerators.get(k, 0)
                        for k in unmet_kinds if k != CPU)
            cores = n.cores if CPU in unmet_kinds else 0
            return (-accel, -cores, -n.cores)

        nic = min(inventory, key=score)
        inventory.remove(nic)
        taken.append(nic)


def _run_shared_mode(mix: List[TenantSpec], scenario: str, ticks: int,
                     cfg: RuntimeConfig, autoscale: bool, seed: int,
                     fail_at: Optional[Tuple[int, Optional[str]]] = None
                     ) -> dict:
    """Pooled / microservice: one shared paper cluster; microservice is the
    same placement machinery with elasticity disabled (fixed peak replicas)."""
    cfg = dataclasses.replace(cfg, autoscale=autoscale)
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario(scenario, contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    rt.run(ticks, fail_at=fail_at)
    ach, res = rt.telemetry.totals()
    return {
        "achieved_gbps_ticks": ach,
        "reserved_unit_ticks": res,
        "slo": rt.slo_report(),
        "summary": rt.telemetry.summary(),
        "alive_tenants": rt.alive_tenants(),
        "events": [e for e in ctrl.events
                   if e["event"] in ("scale", "failover")],
        "runtime": rt,
    }


def _run_standalone(mix: List[TenantSpec], scenario: str, ticks: int,
                    cfg: RuntimeConfig, seed: int) -> dict:
    """Standalone: one dedicated mini-pool + controller per tenant; reserved
    units are the whole dedicated NICs, not just the committed slices."""
    cfg = dataclasses.replace(cfg, autoscale=False, dataplane_every=0)
    inventory = [st.spec for st in paper_cluster().nics.values()]
    wl_all = make_scenario(scenario, contracts(mix), seed=seed)
    total_ach = 0.0
    total_res = 0.0
    slo: Dict[str, dict] = {}
    summary: Dict[str, dict] = {}
    dedicated: Dict[str, int] = {}
    for spec in mix:
        ctrl, taken = provision_standalone(spec, inventory)
        registry = TenantRegistry(ctrl)
        # already submitted by provision_standalone: adopt the deployment
        registry.specs[spec.name] = spec
        registry.admitted[spec.name] = ctrl.deployments[spec.name]
        rt = ServiceRuntime(ctrl, registry, wl_all, cfg)
        rt.run(ticks)
        ach, _ = rt.telemetry.totals()
        total_ach += ach
        nic_units = sum(_nic_units(n) for n in taken)
        dedicated[spec.name] = nic_units
        total_res += nic_units * ticks          # whole NICs, every tick
        slo.update(rt.slo_report())
        summary.update(rt.telemetry.summary())
    return {
        "achieved_gbps_ticks": total_ach,
        "reserved_unit_ticks": total_res,
        "slo": slo,
        "summary": summary,
        "dedicated_units": dedicated,
        "alive_tenants": [s.name for s in mix],
    }


def run_comparison(mix: Optional[List[TenantSpec]] = None,
                   scenarios: Tuple[str, ...] = ("bursty", "diurnal"),
                   ticks: int = 120,
                   cfg: Optional[RuntimeConfig] = None,
                   fail_scenario: Optional[str] = "bursty",
                   fail_tick_frac: float = 0.55,
                   seed: int = 0) -> dict:
    """Run the tenant mix through every mode and scenario; returns the
    Fig-13-style efficiency ratios plus per-scenario SLO and failover records.

    The NIC failure is injected only into the pooled run of `fail_scenario`
    (the baselines have no failover story to exercise — standalone tenants
    simply lose their NIC in the paper)."""
    mix = mix if mix is not None else default_tenant_mix()
    cfg = cfg or RuntimeConfig()
    agg = {m: {"ach": 0.0, "res": 0.0} for m in MODES}
    out: dict = {"scenarios": {}, "tenants": contracts(mix)}

    for scenario in scenarios:
        fail_at = (int(ticks * fail_tick_frac), None) \
            if scenario == fail_scenario else None
        pooled = _run_shared_mode(mix, scenario, ticks, cfg, autoscale=True,
                                  seed=seed, fail_at=fail_at)
        micro_cfg = dataclasses.replace(cfg, dataplane_every=0)
        micro = _run_shared_mode(mix, scenario, ticks, micro_cfg,
                                 autoscale=False, seed=seed)
        alone = _run_standalone(mix, scenario, ticks, cfg, seed=seed)

        for mode, r in (("pooled", pooled), ("microservice", micro),
                        ("standalone", alone)):
            agg[mode]["ach"] += r["achieved_gbps_ticks"]
            agg[mode]["res"] += r["reserved_unit_ticks"]

        rec: dict = {}
        for mode, r in (("pooled", pooled), ("microservice", micro),
                        ("standalone", alone)):
            rec[mode] = {
                "achieved_gbps_mean": r["achieved_gbps_ticks"] / ticks,
                "reserved_units_mean": r["reserved_unit_ticks"] / ticks,
                "slo": r["slo"],
                "slo_pass": all(v["pass"] for v in r["slo"].values()),
                "summary": r["summary"],
            }
        if fail_at is not None:
            failover_events = [e for e in pooled["events"]
                               if e["event"] == "failover"]
            rec["failover"] = {
                "injected_tick": fail_at[0],
                "failed_nic": failover_events[0]["nic"]
                if failover_events else None,
                "impacted": sorted({e["tenant"] for e in failover_events}),
                "tenants_alive_after": len(pooled["alive_tenants"]),
                "survived": len(pooled["alive_tenants"]) == len(mix),
            }
        if "dedicated_units" in alone:
            rec["standalone"]["dedicated_units"] = alone["dedicated_units"]
        out["scenarios"][scenario] = rec

    eff = {m: (agg[m]["ach"] / agg[m]["res"] if agg[m]["res"] else 0.0)
           for m in MODES}
    out["efficiency"] = eff
    out["ratios"] = {
        "pooled_vs_standalone": (eff["pooled"] / eff["standalone"]
                                 if eff["standalone"] else float("inf")),
        "pooled_vs_microservice": (eff["pooled"] / eff["microservice"]
                                   if eff["microservice"] else float("inf")),
    }
    return out
