"""Tenant model, SLAs, and admission control (Meili-Serve).

A *tenant* is one customer of the NIC-pool service: an application chain
(``MeiliApp``), an offline profile, and an SLA (contracted peak throughput,
p99 latency SLO, priority). The registry routes admissions through
``MeiliController.submit`` — Algorithm 1 derives replication, Algorithm 2/3
place units — and enforces strict admission: a tenant whose contracted peak
cannot be placed is rolled back and rejected rather than silently degraded
(the paper's FCFS submission model, §6.1, with priority classes layered on
top: higher priority admits first; FCFS within a class).

``default_tenant_mix`` is the 6-tenant evaluation mix (one tenant per paper
app, Appendix F) used by the resource-efficiency benchmark.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.apps.nf import ALL_APPS
from repro.apps.profiles import paper_profile
from repro.core.controller import Deployment, MeiliController
from repro.core.graph import MeiliApp
from repro.core.profiler import AppProfile
from repro.core.qos import TenantQuota, quota_from_sla


class AdmissionError(RuntimeError):
    """Raised when a tenant's contracted target cannot be placed."""


@dataclasses.dataclass(frozen=True)
class TenantSLA:
    target_gbps: float            # contracted peak throughput
    p99_latency_s: float          # latency SLO on the sim-model p99
    priority: int = 1             # higher admits first (FCFS within a class)
    # Error-budget terms (ISSUE 10): a tick is SLI-good when achieved
    # throughput holds min_tput_frac of min(offered, target) and p99 stays
    # under the latency target; budget_frac of the rolling horizon may be
    # bad before the contract is broken. Defaults keep older call sites
    # (positional construction) behaviorally identical.
    min_tput_frac: float = 0.9    # SLI throughput floor (fraction of contract)
    budget_frac: float = 0.05     # allowed bad-tick fraction of the horizon


@dataclasses.dataclass
class TenantSpec:
    name: str
    app: MeiliApp
    profile: AppProfile
    sla: TenantSLA
    backup_nic: Optional[str] = None   # Appendix-D failover replication target
    arrive_tick: int = 0               # churn: when the tenant shows up
    depart_tick: Optional[int] = None  # churn: when it leaves (None = never)
    # QoS quota (ISSUE 4): caps + burst credits + fair-share weight enforced
    # by the ResourceGovernor. None derives the default from the SLA — the
    # contract is the cap, the priority is the weight (quota_from_sla).
    quota: Optional[TenantQuota] = None

    def effective_quota(self) -> TenantQuota:
        return self.quota if self.quota is not None else quota_from_sla(self.sla)


class TenantRegistry:
    """Catalog of tenants + admission control over one MeiliController."""

    def __init__(self, controller: MeiliController):
        self.controller = controller
        self.specs: Dict[str, TenantSpec] = {}
        self.admitted: Dict[str, Deployment] = {}
        self.rejected: Dict[str, str] = {}    # tenant -> reason
        # Evicted-but-retrying tenants (chaos recovery): excluded from
        # churn's pending() so re-admission happens only through the
        # RecoveryManager's backoff schedule, never as a silent re-arrival.
        self.parked: set = set()

    def register(self, spec: TenantSpec) -> None:
        if spec.name in self.specs:
            raise ValueError(f"tenant {spec.name} already registered")
        # Deployments are keyed by app name; give every tenant its own key so
        # two tenants may run the same application independently.
        spec.app.name = spec.name
        self.specs[spec.name] = spec
        # Declare the tenant's quota to the governor up front: admission,
        # scaling, and dispatch all consult the same policy rows.
        self.controller.governor.register(spec.name, spec.effective_quota())

    def admit(self, name: str, strict: bool = True) -> Deployment:
        spec = self.specs[name]
        if name in self.admitted:
            return self.admitted[name]
        dep = self.controller.submit(spec.app, spec.sla.target_gbps,
                                     spec.profile, backup_nic=spec.backup_nic,
                                     tenant=name)
        verdict = self.controller.governor.admission_verdict(name,
                                                             dep.allocation)
        if strict and not verdict.admitted:
            self.controller.terminate(spec.app.name)
            self.rejected[name] = verdict.reason
            raise AdmissionError(f"{name}: {self.rejected[name]}")
        self.admitted[name] = dep
        return dep

    def admit_all(self, strict: bool = True) -> List[str]:
        """Admit every registered tenant due at tick 0, highest priority
        first (FCFS within a priority class = registration order)."""
        out = []
        for name in self.pending(tick=0):
            try:
                self.admit(name, strict=strict)
                out.append(name)
            except AdmissionError:
                pass
        return out

    def evict(self, name: str) -> None:
        if name in self.admitted:
            self.controller.terminate(name)
            self.controller.governor.forget(name)
            del self.admitted[name]

    def readmit(self, name: str) -> bool:
        """Retry admission for a parked (previously evicted) tenant.

        Eviction forgot the tenant's governor quota, so it is re-registered
        first; a failed retry cleans up after itself — the quota is forgotten
        again and the rejection note ``admit`` wrote is cleared, so a later
        retry is not mistaken for a permanent rejection. Returns True when
        the tenant is back in service."""
        spec = self.specs[name]
        self.controller.governor.register(name, spec.effective_quota())
        try:
            self.admit(name, strict=True)
        except AdmissionError:
            self.rejected.pop(name, None)
            self.controller.governor.forget(name)
            return False
        self.parked.discard(name)
        return True

    def pending(self, tick: int) -> List[str]:
        """Registered, not yet admitted/rejected, due to arrive by `tick`."""
        due = [n for n, s in self.specs.items()
               if n not in self.admitted and n not in self.rejected
               and n not in self.parked
               and s.arrive_tick <= tick
               and (s.depart_tick is None or s.depart_tick > tick)]
        return sorted(due, key=lambda n: (-self.specs[n].sla.priority,
                                          list(self.specs).index(n)))

    def departing(self, tick: int) -> List[str]:
        return [n for n in self.admitted
                if self.specs[n].depart_tick is not None
                and self.specs[n].depart_tick <= tick]

    def active(self) -> List[str]:
        return list(self.admitted)

    def deployment(self, name: str) -> Deployment:
        return self.controller.deployments[name]


# -- the default 6-tenant evaluation mix --------------------------------------

# (app key, contract Gbps, p99 SLO, priority). Contracts are sized so the mix
# comfortably multiplexes onto the paper cluster in pooled mode while the
# standalone mode must dedicate most of the rack (ISG alone pins one BF-2 for
# regex plus two Pensandos for sha+aes).
DEFAULT_MIX = (
    ("ID", 8.0, 400e-6, 2),
    ("ICG", 8.0, 400e-6, 1),
    ("ISG", 5.0, 600e-6, 2),
    ("FW", 10.0, 600e-6, 1),
    ("FM", 8.0, 600e-6, 1),
    ("LLB", 12.0, 300e-6, 2),
)

BACKUP_NICS = ("bf1-0", "bf1-1", "bf1-2", "bf1-3")


def default_tenant_mix(impl: Optional[str] = "ref") -> List[TenantSpec]:
    apps = ALL_APPS(impl=impl)
    mix = []
    for i, (key, gbps, p99, prio) in enumerate(DEFAULT_MIX):
        mix.append(TenantSpec(
            name=f"t-{key.lower()}", app=apps[key],
            profile=paper_profile(key),
            sla=TenantSLA(target_gbps=gbps, p99_latency_s=p99, priority=prio),
            backup_nic=BACKUP_NICS[i % len(BACKUP_NICS)]))
    return mix


def contracts(mix: List[TenantSpec]) -> Dict[str, float]:
    return {s.name: s.sla.target_gbps for s in mix}


def churn_tenant_mix(ticks: int = 96, impl: Optional[str] = "ref"
                     ) -> List[TenantSpec]:
    """A churn-heavy variant of the evaluation mix: two first-wave tenants
    depart mid-run and a second wave arrives into the holes they leave.
    Deterministic; arrival/departure ticks scale with the run length so the
    same mix works for smoke and full benchmark runs."""
    mix = default_tenant_mix(impl=impl)
    # First wave: ICG and FM leave, opening mid-run holes in the packing.
    mix[1] = dataclasses.replace(mix[1], depart_tick=max(2, int(0.30 * ticks)))
    mix[4] = dataclasses.replace(mix[4], depart_tick=max(3, int(0.45 * ticks)))
    # Second wave: fresh tenants (their own app instances — deployments are
    # keyed per tenant) arriving staggered into the fragmented pool.
    wave2 = (
        ("ID", 6.0, 400e-6, 1, 0.35),
        ("FW", 8.0, 600e-6, 1, 0.50),
        ("LLB", 8.0, 300e-6, 2, 0.60),
    )
    for i, (key, gbps, p99, prio, frac) in enumerate(wave2):
        apps = ALL_APPS(impl=impl)
        mix.append(TenantSpec(
            name=f"t-{key.lower()}-w2", app=apps[key],
            profile=paper_profile(key),
            sla=TenantSLA(target_gbps=gbps, p99_latency_s=p99, priority=prio),
            backup_nic=BACKUP_NICS[i % len(BACKUP_NICS)],
            arrive_tick=max(1, int(frac * ticks))))
    return mix
