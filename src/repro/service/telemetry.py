"""Per-tenant / per-NIC telemetry for the service loop (Meili-Serve).

Latency comes from the calibrated discrete-event model (``core.sim``): each
tick simulates a window of packet arrivals at the tenant's offered rate
through its *placed* replica set (``dep.r_s``), with the paper's ~4.5 µs hop
penalty added wherever the allocation puts consecutive stages on disjoint
NICs (§8.5, Table 1). Sustained over-demand accumulates in a per-tenant
backlog whose drain time is added to the reported percentiles, so
under-provisioning shows up as latency SLO violations the autoscaler must
fix — the closed loop the runtime implements.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.apps.profiles import HOP_US, PKT_BITS
from repro.core import sim
from repro.core.controller import Deployment
from repro.core.defrag import disjoint_pairs


@dataclasses.dataclass
class TenantTick:
    tick: int
    tenant: str
    offered_gbps: float
    achieved_gbps: float
    p50_s: float
    p99_s: float                 # legacy estimator: sim percentile + backlog formula
    units: int                   # resource units attributed to the tenant
    slo_ok: bool
    in_grace: bool = False       # post-failover/migration grace (no SLO acct)
    event: str = ""              # "scale" / "failover" / "migrate" / ...
    hop_pairs: int = 0           # consecutive stages on disjoint NICs
    nics_used: int = 0           # NICs this tenant's placement spans
    granted_gbps: float = 0.0    # governor-granted provision target (QoS)
    backlog_pkts: float = 0.0    # ingress queue depth carried out of the tick
    p99_measured_s: float = 0.0  # measured p99 over the run's sample stream
                                 # (obs histogram; 0 until samples exist)


@dataclasses.dataclass
class ClusterTick:
    tick: int
    reserved_units: int
    achieved_gbps: float
    nic_util: Dict[str, float]   # resource kind -> pool utilization
    nics_used: int = 0           # distinct NICs carrying any placement
    hop_pairs: int = 0           # Σ per-tenant disjoint consecutive pairs


@dataclasses.dataclass
class FaultRecord:
    """One chaos/recovery event: injected faults (crash/flap/gray/rack/...)
    and the recovery reactions (parked/readmitted/evicted/degraded/
    gray_probation/gray_quarantined/failover_skipped)."""

    tick: int
    kind: str
    nic: Optional[str] = None
    tenant: Optional[str] = None
    detail: str = ""
    shard: Optional[str] = None  # owning failure domain (sharded controller)


class TelemetryLog:
    """Run log of tenant/cluster/fault records.

    ``warmup_ticks`` set at construction is the shared default horizon for
    every accessor that excludes warmup (``slo_report``/``slo_tick_count``/
    ``summary``) — callers may still override per call, but the log itself
    now knows the run's warmup so the accessors agree by default. When a
    ``DecisionTrace`` is attached, every fault record is mirrored into it
    as a ``kind="fault"`` event, so chaos injections and recovery
    transitions land in the same causally-ordered audit log as governor
    verdicts and controller spans.
    """

    def __init__(self, trace=None, warmup_ticks: int = 0):
        self.tenant_ticks: List[TenantTick] = []
        self.cluster_ticks: List[ClusterTick] = []
        self.fault_events: List[FaultRecord] = []
        self.trace = trace
        self.warmup_ticks = warmup_ticks
        # One-pass per-tenant grouping, built incrementally: accessors used
        # to rescan all ticks per tenant per call (O(tenants x ticks) every
        # report); the index appends only what arrived since the last call.
        self._groups: Dict[str, List[TenantTick]] = {}
        self._grouped_upto = 0
        # Live consumers of the tenant-tick stream (ISSUE 10): the SLO
        # engine subscribes so every recorded tick is scored exactly once,
        # at the moment the runtime records it.
        self._subscribers: List = []

    def subscribe(self, fn) -> None:
        """Register ``fn(TenantTick)`` to run on every ``record``."""
        self._subscribers.append(fn)

    def _grouped(self) -> Dict[str, List[TenantTick]]:
        for t in self.tenant_ticks[self._grouped_upto:]:
            self._groups.setdefault(t.tenant, []).append(t)
        self._grouped_upto = len(self.tenant_ticks)
        return self._groups

    def record(self, t: TenantTick) -> None:
        self.tenant_ticks.append(t)
        for fn in self._subscribers:
            fn(t)

    def record_cluster(self, c: ClusterTick) -> None:
        self.cluster_ticks.append(c)

    def record_fault(self, tick: int, kind: str, nic: Optional[str] = None,
                     tenant: Optional[str] = None, detail: str = "",
                     shard: Optional[str] = None) -> None:
        self.fault_events.append(FaultRecord(tick=tick, kind=kind, nic=nic,
                                             tenant=tenant, detail=detail,
                                             shard=shard))
        if self.trace is not None:
            extra = {"shard": shard} if shard is not None else {}
            self.trace.event(kind, tenant=tenant, nic=nic, kind="fault",
                             tick=tick, detail=detail, **extra)

    def faults(self, kind: Optional[str] = None) -> List[FaultRecord]:
        if kind is None:
            return list(self.fault_events)
        return [f for f in self.fault_events if f.kind == kind]

    def series(self, tenant: str) -> List[TenantTick]:
        return list(self._grouped().get(tenant, ()))

    def _warmup(self, warmup_ticks: Optional[int]) -> int:
        return self.warmup_ticks if warmup_ticks is None else warmup_ticks

    # -- SLO accounting -------------------------------------------------------
    def slo_report(self, warmup_ticks: Optional[int] = None,
                   max_violation_frac: float = 0.05) -> Dict[str, dict]:
        """Per-tenant SLO compliance over the run; ticks inside a post-failover
        grace window or the warmup are not counted against the tenant."""
        warmup = self._warmup(warmup_ticks)
        out: Dict[str, dict] = {}
        for tenant, s in self._grouped().items():
            r = {"ticks": 0, "violations": 0}
            for t in s:
                if t.tick < warmup or t.in_grace:
                    continue
                r["ticks"] += 1
                r["violations"] += 0 if t.slo_ok else 1
            if r["ticks"]:
                out[tenant] = r
        for tenant, r in out.items():
            r["violation_frac"] = (r["violations"] / r["ticks"]
                                   if r["ticks"] else 0.0)
            r["pass"] = r["violation_frac"] <= max_violation_frac
        return out

    def slo_tick_count(self, warmup_ticks: Optional[int] = None) -> int:
        """Tenant-ticks of SLO-compliant service (post-warmup, non-grace) —
        the chaos A/B's primary served-value metric: a parked tenant scores
        zero for every tick it sits out, a browned-out one for every tick
        the partial grant dips below SLO."""
        warmup = self._warmup(warmup_ticks)
        return sum(1 for t in self.tenant_ticks
                   if t.tick >= warmup and not t.in_grace and t.slo_ok)

    def summary(self, warmup_ticks: Optional[int] = None) -> Dict[str, dict]:
        """Per-tenant run statistics over post-warmup ticks (the same
        horizon ``slo_report`` uses, so the two reports describe the same
        window by default).

        One segment-reduction pass over stacked record arrays
        (``sched_kernel.telemetry_reduce_np``) instead of the per-tenant
        dict loops — O(records) regardless of tenant count. The old loop
        survives as ``summary_scalar``, the pinned reference oracle."""
        from repro.core.sched_kernel import telemetry_reduce_np
        warmup = self._warmup(warmup_ticks)
        recs = [t for t in self.tenant_ticks if t.tick >= warmup]
        if not recs:
            return {}
        names = sorted({t.tenant for t in recs})
        row = {t: i for i, t in enumerate(names)}
        idx = np.fromiter((row[t.tenant] for t in recs), dtype=np.int64,
                          count=len(recs))
        counts, means, maxes = telemetry_reduce_np(
            idx, len(names),
            means={
                "offered_gbps_mean": [t.offered_gbps for t in recs],
                "achieved_gbps_mean": [t.achieved_gbps for t in recs],
                "units_mean": [t.units for t in recs],
                "hop_pairs_mean": [t.hop_pairs for t in recs],
                "nics_used_mean": [t.nics_used for t in recs],
            },
            maxes={
                "p99_s_max": [t.p99_s for t in recs],
                "p99_measured_s_max": [t.p99_measured_s for t in recs],
            })
        out: Dict[str, dict] = {}
        for tenant, i in row.items():
            if counts[i] <= 0:
                continue
            rec = {"ticks": int(counts[i])}
            rec.update({k: float(v[i]) for k, v in means.items()})
            rec.update({k: float(v[i]) for k, v in maxes.items()})
            out[tenant] = rec
        return {t: out[t] for t in sorted(out)}

    def summary_scalar(self, warmup_ticks: Optional[int] = None
                       ) -> Dict[str, dict]:
        """The original per-tenant dict-loop reduction, kept as the pinned
        reference oracle for the vectorized ``summary`` above."""
        warmup = self._warmup(warmup_ticks)
        out: Dict[str, dict] = {}
        for tenant in sorted(self._grouped()):
            s = [t for t in self._grouped()[tenant] if t.tick >= warmup]
            if not s:
                continue
            out[tenant] = {
                "ticks": len(s),
                "offered_gbps_mean": float(np.mean([t.offered_gbps for t in s])),
                "achieved_gbps_mean": float(np.mean([t.achieved_gbps for t in s])),
                "p99_s_max": float(max(t.p99_s for t in s)),
                "p99_measured_s_max": float(max(t.p99_measured_s for t in s)),
                "units_mean": float(np.mean([t.units for t in s])),
                "hop_pairs_mean": float(np.mean([t.hop_pairs for t in s])),
                "nics_used_mean": float(np.mean([t.nics_used for t in s])),
            }
        return out

    def locality(self, from_tick: int = 0) -> Dict[str, float]:
        """Cluster-level fragmentation view over ticks >= from_tick: mean
        NICs carrying placements and mean total disjoint-pair count — the
        two quantities defragmentation is supposed to pull back down."""
        window = [c for c in self.cluster_ticks if c.tick >= from_tick]
        if not window:
            return {"nics_used_mean": 0.0, "hop_pairs_mean": 0.0}
        return {
            "nics_used_mean": float(np.mean([c.nics_used for c in window])),
            "hop_pairs_mean": float(np.mean([c.hop_pairs for c in window])),
        }

    def totals(self) -> Tuple[float, float]:
        """(Σ achieved Gbps·ticks, Σ reserved units·ticks) over the run —
        the numerator/denominator of the resource-efficiency metric."""
        ach = sum(c.achieved_gbps for c in self.cluster_ticks)
        res = sum(c.reserved_units for c in self.cluster_ticks)
        return ach, float(res)


# -- the per-tick measurement model -------------------------------------------

def hop_penalties(dep: Deployment) -> Dict[Tuple[str, str], float]:
    """Paper §8.5 hop penalty for consecutive stages placed on disjoint NICs
    (pair detection shared with the defrag scorer: core.defrag)."""
    return {pair: HOP_US * 1e-6
            for pair in disjoint_pairs(dep.allocation, dep.profile.stages)}


def measure_tenant_tick(dep: Deployment, offered_gbps: float, dt_s: float,
                        backlog_pkts: float, max_sim_seqs: int = 96,
                        hop_pen: Optional[Dict[Tuple[str, str], float]] = None,
                        served_pkts: Optional[float] = None,
                        capacity_scale: float = 1.0,
                        return_samples: bool = False):
    """One tick of the latency/throughput model.

    Returns (p50_s, p99_s, achieved_gbps, new_backlog_pkts). Achieved rate is
    capped by the deployment's placed capacity — and, when the governor's
    DWRR scheduler granted this tenant a service share (``served_pkts``), by
    that grant. ``capacity_scale`` degrades the placed capacity without the
    allocator knowing (a gray failure: the runtime passes the pool's gray
    factor over the NICs the placement spans) — achieved throughput drops,
    backlog grows, and only that observable behavior can betray the sick
    NIC. The backlog models demand the placement could not serve this tick
    (drained when capacity exceeds offered load again); it is the ingress
    queue depth the governor schedules against next tick.

    With ``return_samples=True`` a fifth element is returned: the tick's
    individual per-sequence latency samples (backlog delay included), the
    raw stream the observability layer's histograms measure exact
    percentiles over — as opposed to the legacy p99 above, which is a
    percentile of one tick's simulated window plus a backlog *formula*.
    """
    cap_pps = (max(0.0, dep.achievable_gbps) * 1e9 / PKT_BITS
               * min(1.0, max(0.0, capacity_scale)))
    off_pps = max(0.0, offered_gbps) * 1e9 / PKT_BITS
    arriving = off_pps * dt_s + backlog_pkts
    served = min(arriving, cap_pps * dt_s)
    if served_pkts is not None:
        served = min(served, max(0.0, served_pkts))
    new_backlog = arriving - served
    achieved_gbps = (served / dt_s) * PKT_BITS / 1e9 if dt_s > 0 else 0.0

    if off_pps <= 0.0 or served <= 0.0:
        if return_samples:
            return 0.0, 0.0, achieved_gbps, new_backlog, np.zeros(0)
        return 0.0, 0.0, achieved_gbps, new_backlog

    # Per-packet stage latencies from the profile (l_s is per sequence batch).
    batch_pkts = dep.profile.batch_bits() / PKT_BITS
    l_pkt = {s: dep.profile.l_s[s] / batch_pkts for s in dep.profile.stages}
    R = {s: max(1, dep.r_s.get(s, 0)) for s in dep.profile.stages}
    n = int(min(max_sim_seqs, max(4, off_pps * dt_s)))
    res = sim.simulate(dep.profile.stages, l_pkt, R, num_seqs=n,
                       arrival_interval=1.0 / off_pps,
                       hop_penalty=(hop_pen if hop_pen is not None
                                    else hop_penalties(dep)))
    lat = np.asarray(res.latencies)
    # Queue carried over from earlier ticks delays everything behind it.
    backlog_delay = new_backlog / cap_pps if cap_pps > 0 else 0.0
    p50 = float(np.percentile(lat, 50)) + backlog_delay
    p99 = float(np.percentile(lat, 99)) + backlog_delay
    if return_samples:
        return p50, p99, achieved_gbps, new_backlog, lat + backlog_delay
    return p50, p99, achieved_gbps, new_backlog
