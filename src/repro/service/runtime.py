"""The discrete-time service loop + closed-loop autoscaler (Meili-Serve).

Each tick the runtime:

  1. handles tenant churn (admissions due this tick, departures, rejected
     admissions are logged and retried never — strict admission control);
  2. injects a NIC failure if one is scheduled, driving the controller's
     Appendix-D failover; impacted tenants get a re-place retry and a short
     SLO grace window;
  3. per active tenant: reads the tick's offered load, runs the autoscaler
     (the paper's §8.4 scale response is milliseconds — below one tick — so
     scaling acts within the tick it is decided), optionally pushes a
     representative PacketBatch through the tenant's fused ParallelDataPlane
     (tagged with the tenant for dispatch-stats attribution), and records
     telemetry from the calibrated latency model;
  4. snapshots cluster-level reserved units + utilization, and periodically
     replicates state to backup NICs (Appendix D).

The autoscaler is fast-attack / slow-decay: demand estimates jump to the
observed load (offered + queued backlog drain — the reactive loop scales on
what is waiting, not just what arrived) instantly but decay with EWMA
smoothing. Every capacity decision routes through the controller's
``ResourceGovernor`` (core.qos): the governor's ``ScaleVerdict`` applies
the tenant's quota, burst credits, and the pool's per-tick headroom ledger
(a partial grant under contention), and the runtime merely executes it via
``adaptive_scale``. Per-tick dispatch is the governor's deficit-weighted
round-robin over tenant ingress queues: the telemetry backlog is the queue
depth scheduled against, so an over-quota tenant queues behind its own
deficit instead of triggering pool-wide rescales.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Set, Tuple

import jax

from repro.apps.profiles import PKT_BITS
from repro.core.controller import MeiliController
from repro.core.executor import ParallelDataPlane
from repro.core.faults import (CRASH, ChaosEngine, FaultEvent, FaultPlan,
                               GrayFailureDetector, RecoveryConfig,
                               RecoveryManager)
from repro.obs import (PAGE, WARN, BurnAlertManager, BurnRule, FlightRecorder,
                       Obs, SLOEngine)
from repro.obs.alerts import FIRING
from repro.service.tenants import AdmissionError, TenantRegistry
from repro.service.telemetry import (ClusterTick, TelemetryLog, TenantTick,
                                     hop_penalties, measure_tenant_tick)
from repro.service.workload import ScenarioWorkload

PKT_BYTES_F = PKT_BITS / 8.0


@dataclasses.dataclass
class RuntimeConfig:
    dt_s: float = 0.05                # simulated tick duration
    autoscale: bool = True
    headroom: float = 1.15            # provision = demand-estimate * headroom
    decay: float = 0.45               # EWMA decay on the way down
    floor_frac: float = 0.2           # never scale below floor_frac * contract
    rescale_threshold: float = 0.1    # relative gap that triggers a scale call
    scale_cooldown_ticks: int = 2
    dataplane_every: int = 1          # run the fused data plane every N ticks (0 = off)
    max_pkts_per_tick: int = 192
    pkt_bytes: int = 192
    replicate_every: int = 8          # Appendix-D replication cadence
    slo_tol: float = 0.1              # achieved >= (1-tol) * min(offered, contract)
    slo_grace_ticks: int = 3          # post-failover/migration grace window
    defrag_every: int = 0             # run a defrag pass every N ticks (0 = off)
    defrag_max_moves: int = 1         # migrations per defrag pass
    defrag_min_score: float = 1.0     # fragmentation score that justifies a move
    warmup_ticks: int = 2
    max_violation_frac: float = 0.05
    max_sim_seqs: int = 96
    # Shared ingress budget the governor's DWRR splits across tenants
    # (Gbps). None = uncapped: every tenant drains to its own placed
    # capacity and DWRR only decides the dispatch order (pre-QoS behavior).
    ingress_gbps: Optional[float] = None
    # Gray-failure detection (chaos layer): suspicion scoring on sustained
    # achieved-vs-expected deviation; suspects go on probation and are
    # drained via forced migration, then quarantined.
    gray_detect: bool = False
    gray_threshold: float = 0.3       # suspicion level + per-tick deviation bar
    gray_min_ticks: int = 3           # consecutive evidence ticks before drain
    gray_min_load_frac: float = 0.5   # offered/achievable for a tick to count
                                      # as evidence (idle tenants prove nothing)
    # Vectorized scheduling kernel (ISSUE 8): run the per-tick DWRR as one
    # jitted array program over stacked tenant rows (core.sched_kernel)
    # instead of the scalar dict walk. Default OFF: the scalar path is the
    # pinned reference oracle the kernel is property-tested against.
    vectorized_sched: bool = False
    # SLO error-budget engine + multi-window burn-rate alerting + flight
    # recorder (ISSUE 10). Default OFF so every pre-existing scenario is
    # bit-identical; when on, each recorded TenantTick is scored against
    # the tenant's SLA-derived budget, burn rules are evaluated per tick,
    # and a page-severity alert pre-arms the gray detector (lower per-NIC
    # evidence bar) + requests a proactive scale consult.
    slo_enabled: bool = False
    slo_horizon_ticks: int = 64       # rolling error-budget horizon
    alert_fast_window: int = 8        # "1h-equivalent" page window (ticks)
    alert_fast_confirm: int = 2
    alert_slow_window: int = 24       # "6h-equivalent" warn window (ticks)
    alert_slow_confirm: int = 6
    alert_page_burn: float = 4.0      # page-rule burn-rate multiple
    alert_warn_burn: float = 2.0      # warn-rule burn-rate multiple
    alert_holddown_ticks: int = 4     # clear streak required to resolve
    alert_prearm_ticks: int = 8       # page pre-arms implicated NICs this long
    alert_prearm_factor: float = 0.5  # × gray_min_load_frac while pre-armed
    # False = shadow mode: alerts still fire, trace, export metrics, and
    # auto-dump flight bundles, but pages take NO action (no detector
    # pre-arm, no forced scale consult). The observe-only deployment an
    # operator runs before trusting alert-driven automation — and what the
    # overhead A/B times, so mitigation work is not billed as recording.
    alert_actions: bool = True
    flight_capacity: int = 64         # snapshot ring length (ticks)
    flight_trace_window: int = 16     # trailing trace ticks in a dump bundle
    flight_dir: Optional[str] = None  # None = record, never auto-dump


class ServiceRuntime:
    def __init__(self, controller: MeiliController, registry: TenantRegistry,
                 workload: ScenarioWorkload,
                 cfg: Optional[RuntimeConfig] = None,
                 recovery: Optional[RecoveryConfig] = None,
                 obs: Optional[Obs] = None):
        self.ctrl = controller
        self.registry = registry
        self.workload = workload
        self.cfg = cfg or RuntimeConfig()
        # One observability context for the whole stack: reuse the
        # controller's (which the governor already audits into) unless the
        # caller supplies one. The telemetry log mirrors fault records into
        # the same trace, so chaos events, recovery transitions, governor
        # verdicts, and controller spans share one causal order.
        self.obs = obs or controller.obs
        self.telemetry = TelemetryLog(trace=self.obs.trace,
                                      warmup_ticks=self.cfg.warmup_ticks)
        self.tick_now = 0
        self._planes: Dict[str, ParallelDataPlane] = {}
        # Dispatch attribution carried across plane rebuilds (scale/failover
        # drops a tenant's plane; its counters must not vanish with it).
        self._dp_stats: Dict[str, Dict[str, int]] = {}
        self._demand: Dict[str, float] = {}      # EWMA demand estimate
        self._cooldown: Dict[str, int] = {}
        self._backlog: Dict[str, float] = {}     # ingress queue depth (pkts)
        self._granted: Dict[str, float] = {}     # last governor grant (Gbps)
        self._grace_until: Dict[str, int] = {}
        self._force_rescale: Set[str] = set()
        self._events: Dict[str, str] = {}        # tenant -> event this tick
        # Recovery policy: the default reproduces eviction-or-nothing (a
        # tenant whose placement cannot be restored is permanently evicted);
        # pass a RecoveryConfig with park=True for graceful degradation +
        # backoff re-admission.
        self.recovery = RecoveryManager(
            self, recovery or RecoveryConfig(park=False, brownout=False))
        self.gray = (GrayFailureDetector(threshold=self.cfg.gray_threshold,
                                         min_ticks=self.cfg.gray_min_ticks)
                     if self.cfg.gray_detect else None)
        # Sequential-probe bookkeeping: drained suspect -> the co-accused it
        # was convicted alongside, for vindication (see _drain_suspects).
        self._probe_history: Dict[str, List[str]] = {}
        if self.gray is not None:
            self.gray.trace = self.obs.trace
        # SLO / alerting / flight layer (ISSUE 10). The pre-arm ledger
        # exists unconditionally — with no alerts it stays empty and the
        # gray evidence bar is exactly the legacy one.
        self._gray_prearm: Dict[str, int] = {}   # nic -> armed until tick
        self.slo: Optional[SLOEngine] = None
        self.alerts: Optional[BurnAlertManager] = None
        self.flight: Optional[FlightRecorder] = None
        if self.cfg.slo_enabled:
            cfg = self.cfg
            self.slo = SLOEngine(self.obs,
                                 horizon_ticks=cfg.slo_horizon_ticks,
                                 warmup_ticks=cfg.warmup_ticks,
                                 shard_resolver=self.ctrl.shard_of)
            rules = (BurnRule(PAGE, cfg.alert_fast_window,
                              cfg.alert_fast_confirm, cfg.alert_page_burn),
                     BurnRule(WARN, cfg.alert_slow_window,
                              cfg.alert_slow_confirm, cfg.alert_warn_burn))
            self.alerts = BurnAlertManager(
                self.slo, self.obs, rules=rules,
                holddown_ticks=cfg.alert_holddown_ticks,
                shard_resolver=self.ctrl.shard_of)
            if cfg.alert_actions:
                self.alerts.on_page.append(self._on_page_alert)
            self.telemetry.subscribe(self._slo_feed)
            self.flight = FlightRecorder(
                self.obs, capacity=cfg.flight_capacity,
                out_dir=cfg.flight_dir,
                trace_window_ticks=cfg.flight_trace_window)
        if self.cfg.vectorized_sched:
            from repro.core.sched_kernel import VectorizedScheduler
            controller.governor.attach_kernel(VectorizedScheduler())
        controller.add_hook(self._on_event)

    # -- SLO feed + early-warning hook (ISSUE 10) ------------------------------
    def _slo_feed(self, tt: TenantTick) -> None:
        """Telemetry subscriber: score every recorded tick against the
        tenant's SLA-derived error budget, exactly once."""
        spec = self.registry.specs.get(tt.tenant)
        if spec is not None and self.slo is not None:
            self.slo.observe(tt, spec.sla)

    def _on_page_alert(self, tenant: str, tr) -> None:
        """A page-severity burn alert is the early warning the runtime acts
        on BEFORE the contract breaks: pre-arm the gray detector on the
        tenant's NICs (the per-NIC evidence bar drops by
        ``alert_prearm_factor`` so a sick-but-lightly-loaded NIC can still
        testify) and request a proactive scale consult next tick."""
        dep = self.ctrl.deployments.get(tenant)
        nics = sorted(dep.nics_used()) if dep is not None else []
        until = self.tick_now + self.cfg.alert_prearm_ticks
        for n in nics:
            self._gray_prearm[n] = max(self._gray_prearm.get(n, -1), until)
        self._force_rescale.add(tenant)
        self.obs.trace.event("gray_prearm", tenant=tenant, nics=nics,
                             until_tick=until,
                             burn_long=round(tr.burn_long, 6),
                             burn_short=round(tr.burn_short, 6))

    # -- controller feedback ---------------------------------------------------
    def _on_event(self, ev: dict) -> None:
        tenant = ev.get("tenant") or ev.get("app")
        if tenant is None:
            return
        if ev["event"] in ("scale", "failover", "migrate"):
            # Placement changed: the tenant's data plane is rebuilt lazily
            # with the new pipeline count (compiled programs are shared
            # process-wide, so this is cheap).
            self._drop_plane(tenant)
            self._events[tenant] = ev["event"]
        if ev["event"] == "failover":
            self._grace_until[tenant] = self.tick_now + self.cfg.slo_grace_ticks
            self._force_rescale.add(tenant)
        if ev["event"] == "migrate":
            # Flows buffered through the make-before-break hand-off: give the
            # tenant the same short SLO grace a failover gets.
            self._grace_until[tenant] = self.tick_now + self.cfg.slo_grace_ticks

    def _drop_plane(self, tenant: str) -> None:
        dp = self._planes.pop(tenant, None)
        if dp is None:
            return
        for t, per in dp.dispatch_stats.get("by_tenant", {}).items():
            acc = self._dp_stats.setdefault(t, {"calls": 0, "packets": 0})
            acc["calls"] += per["calls"]
            acc["packets"] += per["packets"]

    def dataplane_stats(self) -> Dict[str, Dict[str, int]]:
        """Per-tenant dispatch attribution over the whole run: accumulated
        counters of dropped planes plus the live ones."""
        out = {t: dict(v) for t, v in self._dp_stats.items()}
        for dp in self._planes.values():
            for t, per in dp.dispatch_stats.get("by_tenant", {}).items():
                acc = out.setdefault(t, {"calls": 0, "packets": 0})
                acc["calls"] += per["calls"]
                acc["packets"] += per["packets"]
        return out

    def _plane(self, tenant: str) -> ParallelDataPlane:
        dp = self._planes.get(tenant)
        if dp is None:
            dep = self.registry.deployment(tenant)
            cap = self.ctrl._pipeline_capacity(dep.profile, dep.num_pipelines)
            dp = ParallelDataPlane(dep.app, num_pipelines=dep.num_pipelines,
                                   capacity_per_pipeline=cap,
                                   metrics=self.obs.metrics,
                                   trace=self.obs.trace)
            self._planes[tenant] = dp
        return dp

    # -- closed-loop autoscaler (capacity decisions live in the governor) ------
    def _queued_gbps(self, tenant: str) -> float:
        """The backlog as a drain rate: queued packets expressed in Gbps if
        they were to drain within one tick — the autoscaler's pressure
        signal covers offered + queued, not offered alone."""
        return (self._backlog.get(tenant, 0.0) * PKT_BITS
                / max(self.cfg.dt_s, 1e-9) / 1e9)

    def _autoscale(self, tenant: str, offered: float) -> None:
        spec = self.registry.specs[tenant]
        dep = self.registry.deployment(tenant)
        cfg = self.cfg
        load = offered + self._queued_gbps(tenant)
        prev = self._demand.get(tenant, load)
        est = load if load >= prev else (
            (1.0 - cfg.decay) * prev + cfg.decay * load)
        self._demand[tenant] = est
        if not cfg.autoscale:
            self._granted[tenant] = dep.target_gbps
            return
        need = dep.app.resource_needs()
        verdict = self.ctrl.governor.scale_verdict(
            tenant, est_gbps=est, offered_gbps=load,
            contract_gbps=spec.sla.target_gbps,
            current_gbps=dep.target_gbps,
            achievable_gbps=dep.achievable_gbps,
            unit_gbps=dep.profile.t_p,
            stage_kinds=sorted(need.values()),    # one entry PER stage
            held_units=self.ctrl.pool.reserved_units(tenant),
            headroom=cfg.headroom, floor_frac=cfg.floor_frac,
            rescale_threshold=cfg.rescale_threshold,
            cooldown_active=self._cooldown.get(tenant, 0) > 0,
            forced=tenant in self._force_rescale)
        self._granted[tenant] = verdict.target_gbps
        if verdict.brownout:
            # Degraded partial grant while parked tenants wait for capacity:
            # surfaced both per-tick (tenant event) and in the fault log.
            self._events.setdefault(tenant, "degraded")
            self.telemetry.record_fault(self.tick_now, "degraded",
                                        tenant=tenant,
                                        shard=self.ctrl.shard_of(tenant))
        if verdict.rescale:
            self.ctrl.adaptive_scale(tenant, verdict.target_gbps)
            self._cooldown[tenant] = cfg.scale_cooldown_ticks
            self._force_rescale.discard(tenant)
        else:
            # Clamp at zero: letting the counter march negative would make a
            # later cooldown reset meaningless after long quiet stretches.
            self._cooldown[tenant] = max(0, self._cooldown.get(tenant, 0) - 1)

    # -- failure injection -----------------------------------------------------
    def inject_failure(self, nic: Optional[str] = None
                       ) -> Tuple[Optional[str], List[str]]:
        """Fail one NIC (the busiest allocated one if unspecified) and run
        the controller's Appendix-D failover. When no NIC is named and no
        allocations exist anywhere (e.g. every tenant already evicted), the
        injection is a no-op — a ``failover_skipped`` fault event is logged
        and the tick loop continues instead of aborting the run."""
        if nic is None:
            load: Dict[str, int] = {}
            for dep in self.ctrl.deployments.values():
                for n, row in dep.allocation.A.items():
                    if self.ctrl.pool[n].alive:
                        load[n] = load.get(n, 0) + sum(row.values())
            if not load:
                self.telemetry.record_fault(self.tick_now, "failover_skipped",
                                            detail="no allocated NICs")
                return None, []
            nic = max(load, key=load.get)
        impacted = self.ctrl.handle_failure(nic)
        return nic, impacted

    def note_revive(self, nic: str) -> None:
        """A repaired NIC returned to the pool: the gray detector forgets any
        suspicion/probation so the NIC starts over with a clean record, and
        parked tenants get an immediate retry against the new capacity."""
        if self.gray is not None:
            self.gray.clear(nic)
        self._probe_history.pop(nic, None)
        if self.recovery is not None:
            self.recovery.notify_capacity(self.tick_now)

    # -- gray-failure detection ------------------------------------------------
    def _drain_suspects(self, tick: int) -> None:
        """Put each newly-suspect NIC on probation and drain it: forced
        migration of every deployment touching it onto healthy NICs (worth
        extra hops — the do-no-harm guard is bypassed), falling back to a
        hard failover for placements the healthy pool cannot re-home whole.
        Either way the NIC ends quarantined (dead to the allocator) until a
        revive repairs it.

        At most ONE quarantine per tick: when the only loaded observer of a
        sick NIC spans several NICs, its deviation convicts the whole
        placement identically — the evidence cannot localize. Drain the
        worst suspect and *acquit* the co-accused: their evidence is kept
        but parked at its current streak, so a genuinely sick survivor
        re-convicts itself on the first post-drain evidence tick (the
        witness was re-placed off the drained NIC — deviation that persists
        now points at the survivor alone), while a healthy one sees its
        evidence stop and is exonerated as soon as its tenants recover."""
        suspects = self.gray.suspects()
        if not suspects:
            return

        def at_stake(n: str) -> int:
            # Units the pool currently has riding on the suspect. When
            # suspicion is exactly tied (one witness convicting its whole
            # placement), drain the most-loaded suspect first: with a flat
            # prior over the tied suspects, expected damage removed by the
            # drain scales with the load the NIC carries.
            return sum(sum(row.values())
                       for dep in self.ctrl.deployments.values()
                       for m, row in dep.allocation.A.items() if m == n)

        for nic in [max(suspects,
                        key=lambda n: (self.gray.suspicion.get(n, 0.0),
                                       at_stake(n), n))]:
            co_accused = [n for n in suspects if n != nic]
            for other in co_accused:
                self.gray.acquit(other)
            # Vindication: this conviction came from evidence that persisted
            # AFTER an earlier probe drained a co-suspect on the same
            # testimony — the witness was re-placed and still deviates, so
            # the earlier drain hit an innocent NIC. Give it back.
            for prior, accused in list(self._probe_history.items()):
                if nic in accused and not self.ctrl.pool[prior].alive:
                    self.ctrl.pool.revive(prior)
                    self.gray.clear(prior)
                    del self._probe_history[prior]
                    self.obs.trace.event("gray_vindicated", nic=prior,
                                         convicted=nic)
                    self.telemetry.record_fault(
                        tick, "gray_vindicated", nic=prior,
                        detail=f"evidence persisted, convicted {nic}",
                        shard=self.ctrl.shard_of_nic(prior))
                    self.recovery.notify_capacity(tick)
            if co_accused:
                self._probe_history[nic] = co_accused
            self.gray.probation.add(nic)
            # The quarantine verdict, with everything an operator needs to
            # audit it: why this NIC, on whose testimony, who was acquitted.
            self.obs.trace.event(
                "quarantine_verdict", nic=nic,
                reason=(f"suspicion {self.gray.suspicion.get(nic, 0.0):.3f} "
                        f"> {self.gray.threshold:g} for "
                        f">= {self.gray.min_ticks} evidence ticks"),
                suspicion=self.gray.suspicion.get(nic, 0.0),
                streak=self.gray.streak.get(nic, 0),
                observers=self.gray.observers.get(nic, []),
                co_accused=co_accused)
            self.telemetry.record_fault(tick, "gray_probation", nic=nic,
                                        shard=self.ctrl.shard_of_nic(nic))
            with self.obs.trace.span("gray_drain", nic=nic) as sp:
                # Drain targets route through the controller: a sharded
                # facade prefers the sick NIC's shard-local healthy set
                # (failure domain = shard), falling back pool-wide.
                candidates = self.ctrl.drain_nic_candidates(
                    nic, exclude=self.gray.probation)
                victims = [name for name, dep in self.ctrl.deployments.items()
                           if nic in dep.nics_used()]
                for name in victims:
                    for healthy in candidates:
                        if self.ctrl.migrate(
                                name, only_nics=healthy, forced=True,
                                require_improvement=False) is not None:
                            break
                still = [name for name, dep in self.ctrl.deployments.items()
                         if nic in dep.nics_used()]
                if still:
                    self.inject_failure(nic)
                    self.telemetry.record_fault(tick, "gray_quarantined",
                                                nic=nic,
                                                detail="escalated to failover",
                                                shard=self.ctrl.shard_of_nic(nic))
                else:
                    self.ctrl.pool.mark_failed(nic)
                    self.telemetry.record_fault(tick, "gray_quarantined",
                                                nic=nic,
                                                shard=self.ctrl.shard_of_nic(nic))
                sp.note(victims=victims, escalated=bool(still))
            self.recovery.sweep(tick)

    # -- churn -----------------------------------------------------------------
    def _churn(self, tick: int) -> None:
        for name in self.registry.departing(tick):
            self.registry.evict(name)
            self._drop_plane(name)
            self._events[name] = "depart"
        for name in self.registry.pending(tick):
            try:
                self.registry.admit(name)
                self._events[name] = "admit"
            except AdmissionError:
                self._events[name] = "admission_rejected"

    # -- the loop --------------------------------------------------------------
    def run(self, num_ticks: int,
            fail_at: Optional[Tuple[int, Optional[str]]] = None,
            chaos: Optional[ChaosEngine] = None) -> TelemetryLog:
        cfg = self.cfg
        if fail_at is not None and chaos is None:
            # Legacy shim: the single-shot failure hook becomes a one-event
            # chaos plan (same injection point, same failover path).
            chaos = ChaosEngine(FaultPlan(
                [FaultEvent(tick=fail_at[0], kind=CRASH, nic=fail_at[1])]))
        if chaos is not None:
            chaos.bind(self)
        for _ in range(num_ticks):
            tick = self.tick_now
            self.obs.set_tick(tick)
            # Shard reconciliation (ISSUE 8): refresh headroom digests that
            # reached the staleness bound BEFORE this tick's admissions
            # consult them. No-op on the unsharded controller.
            self.ctrl.reconcile(tick)
            self._churn(tick)
            if chaos is not None:
                chaos.step(tick)
            # Recovery pass: evict-or-park tenants the faults left dead, run
            # due re-admission retries, keep the brownout level current.
            self.recovery.step(tick)
            if (cfg.defrag_every and tick > 0
                    and tick % cfg.defrag_every == 0):
                # Background re-placement between ticks: migrate the most
                # fragmented deployments onto compact NIC sets (make-before-
                # break inside the controller; tenants get SLO grace via the
                # migrate event hook above).
                self.ctrl.defragment(max_migrations=cfg.defrag_max_moves,
                                     min_score=cfg.defrag_min_score)

            gov = self.ctrl.governor
            active = [t for t in self.registry.active()
                      if t in self.workload.specs]
            gov.begin_tick(self.ctrl.pool, active, tick=tick)

            # Pass 1 — demand estimation + governor-granted scaling, in
            # priority order: under contention the headroom ledger is drawn
            # down heaviest-weight-first, so partial grants favor the
            # contracts the pool values most.
            offered_now: Dict[str, float] = {
                t: self.workload.offered_gbps(t, tick) for t in active}
            for tenant in gov.priority_order(active):
                self._autoscale(tenant, offered_now[tenant])

            # Pass 2 — the governor's DWRR over ingress queues decides the
            # dispatch order and, when a shared ingress budget is set, each
            # tenant's service share for the tick (backlog = queue depth).
            queues: Dict[str, float] = {}
            rate_caps: Dict[str, float] = {}
            gray_scale: Dict[str, float] = {}
            for tenant in active:
                dep = self.registry.deployment(tenant)
                arriving = (offered_now[tenant] * 1e9 / PKT_BITS * cfg.dt_s
                            + self._backlog.get(tenant, 0.0))
                queues[tenant] = arriving * PKT_BYTES_F
                # A gray NIC bottlenecks every pipeline chained through it:
                # the service ceiling (not the allocator's view) degrades.
                gray_scale[tenant] = self.ctrl.pool.capacity_frac(
                    dep.nics_used())
                rate_caps[tenant] = (max(0.0, dep.achievable_gbps)
                                     * gray_scale[tenant]
                                     * 1e9 / 8.0 * cfg.dt_s)
            ingress = (None if cfg.ingress_gbps is None
                       else cfg.ingress_gbps * 1e9 / 8.0 * cfg.dt_s)
            order, served_bytes = gov.dwrr_schedule(queues, rate_caps,
                                                    capacity_bytes=ingress)

            cluster_achieved = 0.0
            cluster_nics: set = set()
            cluster_hops = 0
            blame: Dict[str, List[float]] = {}   # nic -> observed deviations
            witnesses: Dict[str, List[str]] = {}  # nic -> testifying tenants
            for tenant in order:
                spec = self.registry.specs[tenant]
                offered = offered_now[tenant]
                dep = self.registry.deployment(tenant)

                if cfg.dataplane_every and tick % cfg.dataplane_every == 0:
                    batch = self.workload.batch_for(
                        tenant, tick, max_pkts=cfg.max_pkts_per_tick,
                        pkt_bytes=cfg.pkt_bytes)
                    if batch is not None:
                        jax.block_until_ready(
                            self._plane(tenant).process(batch, tenant=tenant))

                hop_pen = hop_penalties(dep)   # once per tenant per tick
                p50, p99, achieved, backlog, samples = measure_tenant_tick(
                    dep, offered, cfg.dt_s,
                    self._backlog.get(tenant, 0.0), cfg.max_sim_seqs,
                    hop_pen=hop_pen,
                    served_pkts=served_bytes[tenant] / PKT_BYTES_F,
                    capacity_scale=gray_scale.get(tenant, 1.0),
                    return_samples=True)
                self._backlog[tenant] = backlog
                cluster_achieved += achieved
                # Measured percentiles (ISSUE 7): the raw per-sequence
                # latency samples stream into a per-tenant histogram; the
                # p99 reported beside the legacy estimator is an exact (or
                # P²-approximate past reservoir capacity) percentile of the
                # run's whole sample distribution so far.
                hist = self.obs.metrics.histogram("tenant_latency_s",
                                                  tenant=tenant)
                if samples.size:
                    hist.observe_many(samples)
                p99_measured = hist.quantile(0.99) if hist.count else 0.0

                expect = min(offered, spec.sla.target_gbps)
                slo_ok = (achieved >= (1.0 - cfg.slo_tol) * expect
                          and p99 <= spec.sla.p99_latency_s)
                in_grace = tick < self._grace_until.get(tenant, -1)
                tenant_nics = dep.nics_used()
                tenant_hops = len(hop_pen)
                if self.gray is not None:
                    # Evidence only from loaded tenants: a tick whose offered
                    # load exercises a meaningful fraction of placed capacity
                    # either blames every NIC in the placement (service fell
                    # short) or exonerates them all (full service).
                    want = min(offered, max(0.0, dep.achievable_gbps))
                    # A tenant the shared-ingress DWRR budget starved this
                    # tick cannot testify: its shortfall is the scheduler's
                    # doing, not its NICs' — contention deviation would
                    # frame every NIC in the placement at once.
                    starved = (ingress is not None
                               and served_bytes.get(tenant, 0.0) + 1.0
                               < min(queues[tenant], rate_caps[tenant]))
                    if want > 0.1 and not in_grace and not starved:
                        dev = max(0.0, 1.0 - achieved / want)
                        ach_ref = max(dep.achievable_gbps, 1e-9)
                        for n in tenant_nics:
                            # Per-NIC evidence bar (ISSUE 10): a page-severity
                            # burn alert pre-arms the implicated NICs, cutting
                            # the "loaded enough to testify" bar so the
                            # detector gathers evidence sooner. With nothing
                            # pre-armed this is exactly the legacy
                            # whole-placement gray_min_load_frac check.
                            bar = cfg.gray_min_load_frac
                            if self._gray_prearm.get(n, -1) > tick:
                                bar *= cfg.alert_prearm_factor
                            if offered >= bar * ach_ref:
                                blame.setdefault(n, []).append(dev)
                                witnesses.setdefault(n, []).append(tenant)
                cluster_nics.update(tenant_nics)
                cluster_hops += tenant_hops
                self.telemetry.record(TenantTick(
                    tick=tick, tenant=tenant, offered_gbps=offered,
                    achieved_gbps=achieved, p50_s=p50, p99_s=p99,
                    units=self.ctrl.pool.reserved_units(tenant),
                    slo_ok=slo_ok, in_grace=in_grace,
                    event=self._events.pop(tenant, ""),
                    hop_pairs=tenant_hops, nics_used=len(tenant_nics),
                    granted_gbps=self._granted.get(tenant, dep.target_gbps),
                    backlog_pkts=backlog, p99_measured_s=p99_measured))

                if (spec.backup_nic is not None
                        and cfg.replicate_every
                        and tick % cfg.replicate_every == 0):
                    self.ctrl.replicate_for_failover(tenant)

            self.telemetry.record_cluster(ClusterTick(
                tick=tick, reserved_units=self.ctrl.pool.reserved_units(),
                achieved_gbps=cluster_achieved,
                nic_util={r: self.ctrl.pool.utilization(r)
                          for r in ("cpu", "regex", "crypto", "compression")},
                nics_used=len(cluster_nics), hop_pairs=cluster_hops))
            # Alert evaluation BEFORE the gray pass: a page that fires this
            # tick pre-arms the detector (via on_page) and its trace events
            # precede any quarantine verdict the evidence later produces.
            page_fired = False
            if self.alerts is not None:
                for tr in self.alerts.step(tick):
                    if tr.severity == PAGE and tr.state == FIRING:
                        page_fired = True
            if self.gray is not None and blame:
                self.gray.observe(blame, observers=witnesses)
                self._drain_suspects(tick)
            if self.flight is not None:
                # Snapshot end-of-tick state (grants, queues, headroom,
                # suspicion, budgets) into the ring; a page-severity alert
                # auto-dumps the incident bundle with this tick included.
                self.flight.snapshot(tick, self)
                if page_fired:
                    self.flight.dump_safe(trigger="page_alert", tick=tick)
            self._events.clear()
            self.tick_now += 1
        return self.telemetry

    # -- liveness --------------------------------------------------------------
    def alive_tenants(self) -> List[str]:
        """Tenants whose every stage still has at least one placed unit."""
        out = []
        for name in self.registry.active():
            dep = self.registry.deployment(name)
            if all(dep.allocation.units(s) >= 1 for s in dep.profile.stages):
                out.append(name)
        return out

    def slo_report(self) -> Dict[str, dict]:
        return self.telemetry.slo_report(self.cfg.warmup_ticks,
                                         self.cfg.max_violation_frac)
