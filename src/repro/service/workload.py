"""Scenario-driven multi-tenant traffic generation (Meili-Serve).

Every generator is seeded and deterministic: the offered-rate series is a
pure function of (spec, tick) plus a seeded jitter draw, and per-tick packet
batches come from ``np.random.default_rng((seed, tenant_idx, tick))`` so two
runs of the same scenario are bit-identical (the efficiency comparator runs
the SAME traffic against all three deployment modes).

Patterns:
  constant  — flat at peak_gbps;
  bursty    — on/off square wave (duty cycle, phase-staggered per tenant);
  diurnal   — raised-cosine day/night cycle between trough_frac and 1.0;
  flash     — square wave like bursty, but the "on" window multiplies peak
              by surge_frac (>1 = a flash crowd exceeding the contract);
              run un-staggered it models correlated cross-tenant bursts
              with no multiplexing headroom;
Flow sizes are heavy-tailed (Pareto weights over the tenant's flow space),
so a few elephant flows carry most packets and the TO's spill path stays
exercised. Tenant churn (arrive/depart) lives on TenantSpec and is driven by
the runtime, not the traffic process.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from repro.apps.packets import pareto_flow_weights, synth_packets_weighted
from repro.core.graph import PacketBatch

# Flow-id address-space stride between tenants (flow tables never collide).
FLOW_BASE_STRIDE = 1 << 20


@dataclasses.dataclass(frozen=True)
class TrafficSpec:
    pattern: str = "constant"     # constant | bursty | diurnal
    peak_gbps: float = 10.0
    trough_frac: float = 0.25     # off/night rate as a fraction of peak
    period_ticks: int = 32
    duty: float = 0.5             # bursty: fraction of the period spent "on"
    phase_ticks: int = 0
    jitter_frac: float = 0.03     # deterministic multiplicative jitter
    num_flows: int = 24
    tail_alpha: float = 1.3       # Pareto shape (smaller = heavier tail)
    surge_frac: float = 1.0       # flash: on-window multiplier over peak
    flow_churn_per_tick: int = 0  # megaflow: flow-id window slide per tick


class ScenarioWorkload:
    def __init__(self, specs: Dict[str, TrafficSpec], seed: int = 0,
                 flow_base_stride: int = FLOW_BASE_STRIDE):
        self.specs = dict(specs)
        self.seed = seed
        self.flow_base_stride = flow_base_stride
        self._idx = {t: i for i, t in enumerate(self.specs)}
        self._weights = {
            t: pareto_flow_weights(sp.num_flows, sp.tail_alpha,
                                   seed=(seed * 1000003 + self._idx[t]))
            for t, sp in self.specs.items()}

    def tenants(self):
        return list(self.specs)

    # -- offered rate ---------------------------------------------------------
    def offered_gbps(self, tenant: str, tick: int) -> float:
        sp = self.specs[tenant]
        t = (tick + sp.phase_ticks) % max(1, sp.period_ticks)
        if sp.pattern == "constant":
            rate = sp.peak_gbps
        elif sp.pattern == "bursty":
            on = t < sp.duty * sp.period_ticks
            rate = sp.peak_gbps if on else sp.peak_gbps * sp.trough_frac
        elif sp.pattern == "diurnal":
            x = 0.5 * (1.0 - math.cos(2.0 * math.pi * t / sp.period_ticks))
            rate = sp.peak_gbps * (sp.trough_frac + (1.0 - sp.trough_frac) * x)
        elif sp.pattern == "flash":
            on = t < sp.duty * sp.period_ticks
            rate = (sp.peak_gbps * sp.surge_frac if on
                    else sp.peak_gbps * sp.trough_frac)
        else:
            raise ValueError(f"unknown traffic pattern {sp.pattern!r}")
        if sp.jitter_frac > 0:
            rng = np.random.default_rng((self.seed, self._idx[tenant], tick))
            rate *= 1.0 + sp.jitter_frac * (2.0 * rng.random() - 1.0)
        return max(0.0, rate)

    # -- representative packet batch -----------------------------------------
    def batch_for(self, tenant: str, tick: int, max_pkts: int = 192,
                  pkt_bytes: int = 192) -> Optional[PacketBatch]:
        """A scaled-down representative batch for the fused data plane: size
        proportional to the tick's offered rate, flows heavy-tailed, flow-id
        space disjoint per tenant."""
        sp = self.specs[tenant]
        offered = self.offered_gbps(tenant, tick)
        if offered <= 0.0 or sp.peak_gbps <= 0.0:
            return None
        n = max(8, int(round(max_pkts * offered / sp.peak_gbps)))
        # Megaflow churn: slide the flow-id window by flow_churn_per_tick
        # ids per tick — each tick retires that many old flows and births
        # that many new ones (short-lived-flow turnover; the Pareto weight
        # profile is stationary relative to the window).
        drift = sp.flow_churn_per_tick * tick
        return synth_packets_weighted(
            batch=n, num_flows=sp.num_flows, weights=self._weights[tenant],
            seed=(self.seed, self._idx[tenant], tick), pkt_bytes=pkt_bytes,
            flow_base=self._idx[tenant] * self.flow_base_stride + drift)


# -- scenario catalog ---------------------------------------------------------

def _staggered(contracts: Dict[str, float], seed: int, **kw) -> ScenarioWorkload:
    specs = {}
    for i, (t, peak) in enumerate(contracts.items()):
        specs[t] = TrafficSpec(peak_gbps=peak,
                               phase_ticks=i * kw.get("stagger", 0), **{
                                   k: v for k, v in kw.items()
                                   if k != "stagger"})
    return ScenarioWorkload(specs, seed=seed)


def steady(contracts: Dict[str, float], seed: int = 0) -> ScenarioWorkload:
    """Flat load at ~70% of contract — the sanity scenario."""
    return _staggered({t: 0.7 * c for t, c in contracts.items()}, seed,
                      pattern="constant")


def bursty(contracts: Dict[str, float], seed: int = 0) -> ScenarioWorkload:
    """On/off square waves at contract peak, phases staggered across tenants
    so the pool multiplexes offsetting bursts (the consolidation win)."""
    return _staggered(contracts, seed, pattern="bursty", duty=0.45,
                      period_ticks=16, trough_frac=0.15, stagger=3)


def diurnal(contracts: Dict[str, float], seed: int = 0) -> ScenarioWorkload:
    """Day/night raised-cosine cycles, staggered like tenants in different
    timezones; troughs at 20% of contract."""
    return _staggered(contracts, seed, pattern="diurnal", period_ticks=48,
                      trough_frac=0.2, stagger=8)


def churn(contracts: Dict[str, float], seed: int = 0) -> ScenarioWorkload:
    """Deep on/off waves with short periods — maximum scale-cycle pressure.

    Paired with a churning tenant mix (arrivals/departures on TenantSpec,
    see ``tenants.churn_tenant_mix``) this is the scenario that decays
    Algorithm-2 locality: every trough shrinks allocations, every burst
    re-grows them into whatever holes departures left behind."""
    return _staggered(contracts, seed, pattern="bursty", duty=0.4,
                      period_ticks=20, trough_frac=0.15, stagger=4)


def flash_crowd(contracts: Dict[str, float], seed: int = 0,
                crowd: Optional[str] = None,
                surge: float = 2.5) -> ScenarioWorkload:
    """Correlated cross-tenant bursts with NO multiplexing headroom: every
    tenant peaks in the same window (no stagger), and one *crowd* tenant
    (default: the largest contract) surges to ``surge``x its contract —
    demand its quota does not cover. The QoS isolation scenario: without a
    governor the crowd's over-scaling strips the headroom the in-quota
    tenants need to re-climb out of their troughs; with the governor the
    crowd queues behind its own quota and degrades only itself."""
    if crowd is None:
        crowd = max(contracts, key=lambda t: (contracts[t], t))
    specs = {}
    for t, peak in contracts.items():
        specs[t] = TrafficSpec(pattern="flash", peak_gbps=peak,
                               period_ticks=24, duty=0.5, trough_frac=0.25,
                               phase_ticks=0,     # correlated: all together
                               surge_frac=surge if t == crowd else 1.0)
    return ScenarioWorkload(specs, seed=seed)


def adversarial_churn(contracts: Dict[str, float],
                      seed: int = 0) -> ScenarioWorkload:
    """Admission pressure at peak: correlated near-contract load (high duty,
    no stagger, shallow troughs) so churn arrivals — wave-2 tenants of
    ``churn_tenant_mix`` land mid-run — must be admitted while the pool is
    as full as it ever gets. Strict admission + the governor's headroom
    ledger decide who gets in; nobody already admitted may be harmed."""
    specs = {}
    for t, peak in contracts.items():
        specs[t] = TrafficSpec(pattern="bursty", peak_gbps=peak,
                               period_ticks=16, duty=0.75, trough_frac=0.5,
                               phase_ticks=0)     # correlated peaks
    return ScenarioWorkload(specs, seed=seed)


def chaos(contracts: Dict[str, float], seed: int = 0) -> ScenarioWorkload:
    """Moderate staggered load for the fault-injection A/B: square waves at
    ~3/4 of contract with real troughs. The stressor here is the fault plan,
    not the traffic — the load leaves enough headroom that recovery (backoff
    re-admission, brownout partial grants) has capacity to re-place into
    when NICs revive, while peaks are high enough that a gray NIC's silent
    degradation shows up as sustained achieved-vs-expected deviation."""
    return _staggered({t: 0.75 * c for t, c in contracts.items()}, seed,
                      pattern="bursty", duty=0.5, period_ticks=16,
                      trough_frac=0.3, stagger=3)


def megaflow(contracts: Dict[str, float], seed: int = 0,
             concurrent_flows: int = 100_000,
             churn_frac: float = 0.005) -> ScenarioWorkload:
    """CDN / mobile-gateway regime: 10⁵–10⁶ concurrent short-lived flows
    with heavy per-tick churn (ISSUE 9). Steady near-peak rate so batches
    are dense; each tick ``churn_frac`` of the flow window turns over —
    the traffic the megaflow cache exists for. Mice-dominated: tail_alpha
    is high (near-uniform mice, 1-2 packets per flow per batch) so the
    whole flow window is genuinely live — with a CDN-atypical heavy tail
    (alpha ~1.1) most of the window would never be sampled at all and the
    "concurrent flow count" would be fiction. Tenant flow-id spaces use a
    wide stride so 10⁶-flow windows plus drift never collide (and stay
    inside the int32 five-tuple address space for a handful of tenants)."""
    specs = {}
    for t, peak in contracts.items():
        # jitter 0: batch size stays constant tick to tick — the stressor
        # here is flow-space churn, and a drifting batch size would measure
        # eager pad/slice recompiles instead of classification cost.
        specs[t] = TrafficSpec(pattern="constant", peak_gbps=0.9 * peak,
                               num_flows=concurrent_flows, tail_alpha=6.0,
                               jitter_frac=0.0,
                               flow_churn_per_tick=max(
                                   1, int(concurrent_flows * churn_frac)))
    return ScenarioWorkload(specs, seed=seed, flow_base_stride=1 << 28)


SCENARIOS = {"steady": steady, "bursty": bursty, "diurnal": diurnal,
             "churn": churn, "flash_crowd": flash_crowd,
             "adversarial_churn": adversarial_churn, "chaos": chaos,
             "megaflow": megaflow}


def make_scenario(name: str, contracts: Dict[str, float],
                  seed: int = 0, **kw) -> ScenarioWorkload:
    return SCENARIOS[name](contracts, seed=seed, **kw)
