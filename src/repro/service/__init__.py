"""Meili-Serve: the multi-tenant SmartNIC-as-a-Service runtime (ISSUE 2).

Layers a service plane on top of the controller/pool/data-plane stack:

  tenants.py     tenant registry + SLA model + admission control
  workload.py    scenario-driven deterministic traffic generation
  telemetry.py   per-tenant / per-NIC tick telemetry + SLO accounting
  runtime.py     discrete-time service loop + closed-loop autoscaler
  efficiency.py  pooled vs standalone vs microservice comparator (§8, Fig 13)
"""

from repro.service.tenants import (AdmissionError, TenantRegistry, TenantSLA,
                                   TenantSpec, default_tenant_mix)
from repro.service.workload import SCENARIOS, ScenarioWorkload, TrafficSpec
from repro.service.telemetry import TelemetryLog, TenantTick
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.efficiency import MODES, run_comparison
