"""Shared model layers: initializers with logical sharding axes, norms, RoPE,
MLP. Every init returns parallel (params, axes) trees — see
parallel/sharding.py for how logical names resolve to PartitionSpecs.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.parallel.sharding import constrain_act

Tree = Dict


def dense_init(key, in_dim: int, out_dim: int, in_ax: str, out_ax: str,
               dtype, bias: bool = False, scale: Optional[float] = None
               ) -> Tuple[Tree, Tree]:
    s = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    p = {"w": (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * s
               ).astype(dtype)}
    a = {"w": (in_ax, out_ax)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        a["b"] = (out_ax,)
    return p, a


def dense(p: Tree, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(dim: int, dtype) -> Tuple[Tree, Tree]:
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": ("none",)}


def rmsnorm(p: Optional[Tree], x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    y = xf * jax.lax.rsqrt(jnp.mean(xf * xf, axis=-1, keepdims=True) + eps)
    if p is not None:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def layernorm_nonparam(x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    """OLMo's non-parametric LayerNorm: no scale, no bias."""
    xf = x.astype(jnp.float32)
    mu = xf.mean(axis=-1, keepdims=True)
    var = ((xf - mu) ** 2).mean(axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def make_norm(cfg):
    """Pick the arch's norm (parametric RMS vs OLMo non-parametric LN)."""
    if cfg.nonparam_ln:
        return (lambda dtype: ({}, {})), (lambda p, x: layernorm_nonparam(
            x, cfg.norm_eps))
    return (lambda dtype: rmsnorm_init(cfg.d_model, dtype)), (
        lambda p, x: rmsnorm(p, x, cfg.norm_eps))


# -- RoPE ---------------------------------------------------------------------

def rope_freqs(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float
               ) -> jnp.ndarray:
    """x: (..., S, H, D); positions: (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                  # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos = jnp.cos(ang)[..., None, :]                         # (..., S, 1, D/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# -- SwiGLU MLP ----------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype) -> Tuple[Tree, Tree]:
    k1, k2, k3 = jax.random.split(key, 3)
    pg, ag = dense_init(k1, d_model, d_ff, "embed", "ff", dtype)
    pu, au = dense_init(k2, d_model, d_ff, "embed", "ff", dtype)
    pd, ad = dense_init(k3, d_ff, d_model, "ff", "embed", dtype)
    return ({"gate": pg, "up": pu, "down": pd},
            {"gate": ag, "up": au, "down": ad})


def mlp(p: Tree, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    h = constrain_act(h, ("batch", "seq", "ff"))
    return dense(p["down"], h)


# -- Embedding / head -----------------------------------------------------------

def pad_vocab(vocab: int, multiple: int = 256) -> int:
    """Embedding tables are padded so the vocab dim shards on the model axis
    (e.g. seamless' 256206 / minicpm's 122753 are not divisible by 16).
    Pad logits are masked to NEG_INF in the loss/logits paths."""
    return ((vocab + multiple - 1) // multiple) * multiple


def embed_init(key, vocab: int, d_model: int, dtype) -> Tuple[Tree, Tree]:
    vp = pad_vocab(vocab)
    p = {"table": (jax.random.normal(key, (vp, d_model), jnp.float32)
                   * (1.0 / math.sqrt(d_model))).astype(dtype)}
    return p, {"table": ("vocab", "vocab_embed")}


def embed(p: Tree, tokens: jnp.ndarray) -> jnp.ndarray:
    return p["table"][tokens]


def unembed(p: Tree, x: jnp.ndarray, tied: bool) -> jnp.ndarray:
    w = p["table"].T if tied else p["w"]
    return x @ w
