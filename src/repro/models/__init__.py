"""Model substrate: layers, attention, MoE, SSM, schedules, enc-dec, registry."""

from repro.models.registry import Model, build
