"""Encoder-decoder transformer (seamless-m4t): bidirectional encoder over
stub audio-frame embeddings + causal decoder with cross-attention.

Encoder and decoder are distinct Meili pipeline stages with different
latencies — the paper's partial replication applies across them
(DESIGN.md §4). Sequence budget: a shape cell's seq_len is split evenly
between encoder frames and decoder tokens for train/prefill; decode keeps a
seq_len-deep decoder self-attention cache and a fixed 4096-frame encoder.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models.layers import (dense_init, embed_init, make_norm, mlp,
                                 mlp_init, pad_vocab)
from repro.parallel.sharding import constrain_act

Tree = Dict
ENC_LEN_DECODE = 4096


def _enc_layer_init(key, cfg, dtype):
    norm_init, _ = make_norm(cfg)
    k1, k2 = jax.random.split(key)
    n1, a1 = norm_init(dtype)
    n2, a2 = norm_init(dtype)
    ap, aa = attn_mod.attn_init(k1, cfg, dtype)
    mp, ma = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
    return ({"norm1": n1, "attn": ap, "norm2": n2, "mlp": mp},
            {"norm1": a1, "attn": aa, "norm2": a2, "mlp": ma})


def _dec_layer_init(key, cfg, dtype):
    norm_init, _ = make_norm(cfg)
    k1, k2, k3 = jax.random.split(key, 3)
    p, a = {}, {}
    for nm in ("norm1", "norm2", "norm3"):
        p[nm], a[nm] = norm_init(dtype)
    p["self"], a["self"] = attn_mod.attn_init(k1, cfg, dtype)
    p["cross"], a["cross"] = attn_mod.attn_init(k2, cfg, dtype, cross=True)
    p["mlp"], a["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, dtype)
    return p, a


def _stack(key, count, init_fn):
    keys = jax.random.split(key, count)
    _, a0 = init_fn(keys[0])
    stacked = jax.vmap(lambda k: init_fn(k)[0])(keys)
    is_leaf = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    axes = jax.tree.map(lambda t: ("layers",) + t, a0, is_leaf=is_leaf)
    return stacked, axes


def init_encdec(cfg, key, dtype=jnp.bfloat16) -> Tuple[Tree, Tree]:
    k1, k2, k3 = jax.random.split(key, 3)
    p: Tree = {}
    a: Tree = {}
    p["embed"], a["embed"] = embed_init(k1, cfg.vocab, cfg.d_model, dtype)
    p["enc"], a["enc"] = _stack(k2, cfg.enc_layers,
                                lambda k: _enc_layer_init(k, cfg, dtype))
    p["dec"], a["dec"] = _stack(k3, cfg.dec_layers,
                                lambda k: _dec_layer_init(k, cfg, dtype))
    norm_init, _ = make_norm(cfg)
    p["enc_norm"], a["enc_norm"] = norm_init(dtype)
    p["dec_norm"], a["dec_norm"] = norm_init(dtype)
    return p, a


def encode(cfg, params: Tree, frames: jnp.ndarray, impl=None) -> jnp.ndarray:
    """frames: (B, S_enc, D) stub embeddings -> encoder output."""
    _, norm_apply = make_norm(cfg)
    B, S, _ = frames.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(h, lp):
        y = attn_mod.attn_apply(lp["attn"], norm_apply(lp.get("norm1"), h),
                                cfg, positions=positions, causal=False,
                                impl=impl)
        h = h + y
        h = h + mlp(lp["mlp"], norm_apply(lp.get("norm2"), h))
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, frames, params["enc"])
    return norm_apply(params.get("enc_norm"), x)


def decode_train(cfg, params: Tree, tokens: jnp.ndarray,
                 enc_out: jnp.ndarray, impl=None) -> jnp.ndarray:
    _, norm_apply = make_norm(cfg)
    x = params["embed"]["table"][tokens]
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(h, lp):
        y = attn_mod.attn_apply(lp["self"], norm_apply(lp.get("norm1"), h),
                                cfg, positions=positions, causal=True,
                                impl=impl)
        h = h + y
        y = attn_mod.attn_apply(lp["cross"], norm_apply(lp.get("norm2"), h),
                                cfg, positions=positions, causal=False,
                                kv_x=enc_out, impl=impl)
        h = h + y
        h = h + mlp(lp["mlp"], norm_apply(lp.get("norm3"), h))
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, _ = jax.lax.scan(body_fn, x, params["dec"])
    return norm_apply(params.get("dec_norm"), x)


def encdec_loss(cfg, params: Tree, frames: jnp.ndarray, tokens: jnp.ndarray,
                impl=None, chunk: int = 512) -> jnp.ndarray:
    enc_out = encode(cfg, params, frames, impl)
    x = decode_train(cfg, params, tokens, enc_out, impl)
    xs, tgt = x[:, :-1], tokens[:, 1:]
    B, S, D = xs.shape
    chunk = min(chunk, S)
    n = S // chunk
    xs, tgt = xs[:, :n * chunk], tgt[:, :n * chunk]
    w = params["embed"]["table"].T
    vbias = jnp.where(jnp.arange(pad_vocab(cfg.vocab)) < cfg.vocab,
                      0.0, -1e30).astype(jnp.float32)

    def step(acc, i):
        xc = jax.lax.dynamic_slice_in_dim(xs, i * chunk, chunk, 1)
        tc = jax.lax.dynamic_slice_in_dim(tgt, i * chunk, chunk, 1)
        lg = constrain_act((xc @ w).astype(jnp.float32) + vbias,
                           ("loss_batch", "seq", "vocab"))
        lse = jax.nn.logsumexp(lg, axis=-1)
        ids = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
        picked = jnp.sum(jnp.where(ids == tc[..., None], lg, 0.0), axis=-1)
        return acc + jnp.sum(lse - picked), None

    from repro.kernels import ops as _ops
    total, _ = jax.lax.scan(jax.checkpoint(step), jnp.float32(0.0),
                            jnp.arange(n), unroll=_ops._unroll(n))
    return total / (B * n * chunk)


# -- decode ---------------------------------------------------------------------

def cache_axes_encdec(cfg) -> Tree:
    ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    return {"pos": (), "self_k": ax, "self_v": ax, "cross_k": ax,
            "cross_v": ax}


def init_cache_encdec(cfg, batch: int, max_len: int, dtype=jnp.bfloat16,
                      enc_len: int = ENC_LEN_DECODE) -> Tuple[Tree, Tree]:
    L = cfg.dec_layers
    kself = (L, batch, max_len, cfg.n_kv_heads, cfg.head_dim)
    kcross = (L, batch, enc_len, cfg.n_kv_heads, cfg.head_dim)
    cache = {"pos": jnp.zeros((), jnp.int32),
             "self_k": jnp.zeros(kself, dtype), "self_v": jnp.zeros(kself, dtype),
             "cross_k": jnp.zeros(kcross, dtype),
             "cross_v": jnp.zeros(kcross, dtype)}
    return cache, cache_axes_encdec(cfg)


def decode_step_encdec(cfg, params: Tree, cache: Tree, tokens: jnp.ndarray,
                       impl=None) -> Tuple[jnp.ndarray, Tree]:
    """One decoder token against cached self/cross KV."""
    _, norm_apply = make_norm(cfg)
    x = params["embed"]["table"][tokens]                       # (B, D)
    pos = cache["pos"]

    def body(h, xs):
        lp, sk, sv, ck, cv = xs
        hn = norm_apply(lp.get("norm1"), h)
        y, sk, sv = attn_mod.attn_decode(lp["self"], hn, cfg, cache_k=sk,
                                         cache_v=sv, pos=pos, impl=impl)
        h = h + y
        hn = norm_apply(lp.get("norm2"), h)
        y, _, _ = attn_mod.attn_decode(lp["cross"], hn, cfg, cache_k=ck,
                                       cache_v=cv, pos=pos, cross=True,
                                       impl=impl)
        h = h + y
        h = h + mlp(lp["mlp"], norm_apply(lp.get("norm3"), h))
        return h, (sk, sv)

    xs = (params["dec"], cache["self_k"], cache["self_v"], cache["cross_k"],
          cache["cross_v"])
    x, (new_sk, new_sv) = jax.lax.scan(body, x, xs)
    x = norm_apply(params.get("dec_norm"), x)
    vbias = jnp.where(jnp.arange(pad_vocab(cfg.vocab)) < cfg.vocab,
                      0.0, -1e30).astype(jnp.float32)
    lg = (x @ params["embed"]["table"].T).astype(jnp.float32) + vbias
    new_cache = dict(cache, pos=pos + 1, self_k=new_sk, self_v=new_sv)
    return lg, new_cache
