"""Mixture-of-Experts FFN with sort-based capacity dispatch.

Routing: softmax top-k, renormalized. Dispatch: tokens are replicated k ways,
sorted by expert id, and gathered into a dense (E, C, D) buffer (capacity
C = ceil(T·k/E·cf) rounded to 128); tokens beyond capacity drop (standard
Switch semantics). Expert matmuls run as (E, C, D) x (E, D, F) einsums —
MXU-shaped, expert dim shardable over the model axis (expert parallelism) —
then results scatter-add back with gate weights.

This formulation avoids the O(T·E·C) dispatch-mask tensor of the classic
Mesh-TF MoE and the ragged/grouped matmuls TPUs can't express; the only
non-matmul costs are one argsort over T·k int32 and two gathers.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import dense_init
from repro.parallel.sharding import constrain_act

Tree = Dict


def _expert_matmuls(p: Tree, xe: jnp.ndarray) -> jnp.ndarray:
    """(E, C, D) -> (E, C, D) through the three expert matmuls.

    When the production mesh is installed and the baseline layout applies
    (experts on 'model', capacity on 'data', expert weights FSDP'd on
    'data'), the compute runs under shard_map with explicit weight
    all-gathers: GSPMD's auto resolution of the capacity/FSDP axis conflict
    was measured to REPLICATE the expert matmuls ~16x (jamba prefill:
    2.3e12 vs ideal 3.9e11 flops/dev). shard_map pins per-device flops to
    the ideal 2·E_loc·C_loc·D·F while the gathers appear (honestly) in the
    collective term.
    """
    from repro.parallel.sharding import _ACT, spec_for
    mesh, rules = _ACT["mesh"], _ACT["rules"]
    E, C, D = xe.shape
    use_sm = False
    if mesh is not None and rules is not None and \
            {"data", "model"} <= set(mesh.axis_names):
        xe_spec = spec_for(("experts", "capacity", None), xe.shape, rules, mesh)
        w_spec = spec_for(("experts", "embed", "expert_ff"),
                          p["gate"].shape, rules, mesh)
        d_spec = spec_for(("experts", "expert_ff", "embed"),
                          p["down"].shape, rules, mesh)
        # baseline layout: experts on model, capacity sharded, weights
        # FSDP'd on their embed dim.
        use_sm = (xe_spec[0] == "model" and xe_spec[1] is not None
                  and w_spec[0] == "model" and w_spec[1] is not None
                  and d_spec[0] == "model" and d_spec[2] is not None)
    if not use_sm:
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["gate"])) * \
            jnp.einsum("ecd,edf->ecf", xe, p["up"])
        h = constrain_act(h, ("experts", "capacity", "expert_ff"))
        ye = jnp.einsum("ecf,efd->ecd", h, p["down"])
        return constrain_act(ye, ("experts", "capacity", None))

    from jax.experimental.shard_map import shard_map

    w_axes = w_spec[1] if isinstance(w_spec[1], tuple) else (w_spec[1],)
    d_axes = d_spec[2] if isinstance(d_spec[2], tuple) else (d_spec[2],)

    def body(xe_l, gate_l, up_l, down_l):
        gate_f, up_f, down_f = gate_l, up_l, down_l
        for ax in w_axes:
            gate_f = jax.lax.all_gather(gate_f, ax, axis=1, tiled=True)
            up_f = jax.lax.all_gather(up_f, ax, axis=1, tiled=True)
        for ax in d_axes:
            down_f = jax.lax.all_gather(down_f, ax, axis=2, tiled=True)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe_l, gate_f)) * \
            jnp.einsum("ecd,edf->ecf", xe_l, up_f)
        return jnp.einsum("ecf,efd->ecd", h, down_f)

    f = shard_map(body, mesh=mesh,
                  in_specs=(xe_spec, w_spec, w_spec, d_spec),
                  out_specs=xe_spec, check_rep=False)
    return f(xe, p["gate"], p["up"], p["down"])


def moe_init(key, cfg, dtype) -> Tuple[Tree, Tree]:
    E, D, F = cfg.n_experts, cfg.d_model, cfg.d_ff
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    p = {
        "router": (jax.random.normal(k1, (D, E), jnp.float32) * s).astype(dtype),
        "gate": (jax.random.normal(k2, (E, D, F), jnp.float32) * s).astype(dtype),
        "up": (jax.random.normal(k3, (E, D, F), jnp.float32) * s).astype(dtype),
        "down": (jax.random.normal(k4, (E, F, D), jnp.float32)
                 * (1.0 / math.sqrt(F))).astype(dtype),
    }
    a = {
        "router": ("vocab_embed", "none"),      # tiny: keep replicated
        "gate": ("experts", "embed", "expert_ff"),
        "up": ("experts", "embed", "expert_ff"),
        "down": ("experts", "expert_ff", "embed"),
    }
    return p, a


def _capacity(T: int, k: int, E: int, cf: float) -> int:
    c = int(math.ceil(T * k / E * cf))
    return max(128, ((c + 127) // 128) * 128)


def _dispatch_local(xf, router, cfg):
    """Sort-based capacity dispatch over LOCAL tokens.

    Returns (xe (E, C, D), src (E*C,) source-token+1 (0=empty),
    gate_slot (E*C,) combine weights)."""
    T, D = xf.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(T, k, E, cfg.capacity_factor)
    logits = (xf @ router).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, ids = jax.lax.top_k(probs, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)
    eid = ids.reshape(-1)
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    tok_s = (order // k).astype(jnp.int32)
    gate_s = gate_w.reshape(-1)[order]
    counts = jnp.bincount(eid, length=E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    slot_in_e = jnp.arange(T * k) - starts[eid_s]
    keep = slot_in_e < C
    dest = jnp.where(keep, eid_s * C + slot_in_e, E * C)
    src = jnp.zeros((E * C + 1,), jnp.int32).at[dest].set(tok_s + 1,
                                                          mode="drop")[:E * C]
    valid = src > 0
    xe = jnp.where(valid[:, None], xf[jnp.maximum(src - 1, 0)], 0.0)
    gate_slot = jnp.zeros((E * C + 1,), gate_s.dtype).at[dest].set(
        gate_s, mode="drop")[:E * C]
    gate_slot = gate_slot * valid
    return xe.reshape(E, C, D), src, gate_slot


def _combine_local(ye_flat, src, gate_slot, T, D):
    contrib = (ye_flat * gate_slot[:, None]).astype(ye_flat.dtype)
    return jnp.zeros((T, D), ye_flat.dtype).at[
        jnp.maximum(src - 1, 0)].add(contrib, mode="drop")


def _moe_ep(p: Tree, x: jnp.ndarray, cfg, mesh, rules) -> jnp.ndarray:
    """Expert parallelism under shard_map: LOCAL dispatch per device,
    all_to_all over the model axis to route token buckets to their expert
    shard, local expert matmuls with ZeRO-gathered weights, all_to_all back,
    LOCAL combine. Avoids any global (T, D) scatter/gather — the global
    combine was materializing 34 GB/dev f32[1M, 8192] buffers on jamba
    prefill. Capacity is per-device (standard EP approximation)."""
    from jax.experimental.shard_map import shard_map
    from repro.parallel.sharding import spec_for

    x_spec = spec_for(("batch", "seq", None), x.shape, rules, mesh)
    w_spec = spec_for(("experts", "embed", "expert_ff"), p["gate"].shape,
                      rules, mesh)
    d_spec = spec_for(("experts", "expert_ff", "embed"), p["down"].shape,
                      rules, mesh)
    r_spec = spec_for(("vocab_embed", "none"), p["router"].shape, rules, mesh)
    w_axes = tuple(a for a in ((w_spec[1],) if not isinstance(w_spec[1], tuple)
                               else w_spec[1]) if a)
    d_axes = tuple(a for a in ((d_spec[2],) if not isinstance(d_spec[2], tuple)
                               else d_spec[2]) if a)
    msize = dict(mesh.shape)["model"]
    E = cfg.n_experts

    def body(x_l, router_l, gate_l, up_l, down_l):
        Bl, Sl, D = x_l.shape
        Tl = Bl * Sl
        xe, src, gate_slot = _dispatch_local(x_l.reshape(Tl, D), router_l,
                                             cfg)
        # route buckets to their expert's model shard
        xe = jax.lax.all_to_all(xe, "model", split_axis=0, concat_axis=1,
                                tiled=True)            # (E/m, m*C_l, D)
        gate_f, up_f, down_f = gate_l, up_l, down_l
        for ax in w_axes:
            gate_f = jax.lax.all_gather(gate_f, ax, axis=1, tiled=True)
            up_f = jax.lax.all_gather(up_f, ax, axis=1, tiled=True)
        for ax in d_axes:
            down_f = jax.lax.all_gather(down_f, ax, axis=2, tiled=True)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, gate_f)) * \
            jnp.einsum("ecd,edf->ecf", xe, up_f)
        ye = jnp.einsum("ecf,efd->ecd", h, down_f)
        ye = jax.lax.all_to_all(ye, "model", split_axis=1, concat_axis=0,
                                tiled=True)            # (E, C_l, D)
        out = _combine_local(ye.reshape(-1, D), src, gate_slot, Tl, D)
        return out.reshape(Bl, Sl, D).astype(x_l.dtype)

    f = shard_map(body, mesh=mesh,
                  in_specs=(x_spec, r_spec, w_spec, w_spec, d_spec),
                  out_specs=x_spec, check_rep=False)
    return f(x, p["router"], p["gate"], p["up"], p["down"])


def moe_ffn(p: Tree, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """x: (B, S, D) -> (B, S, D)."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    T = B * S

    # Expert-parallel path: requires the mesh installed, experts divisible
    # by the model axis, and tokens genuinely partitioned across BOTH mesh
    # axes (batch x seq covering data x model) so the local dispatch sees
    # distinct tokens per shard. Decode (S == 1) and host runs fall back to
    # the global-dispatch path below.
    from repro.parallel.sharding import _ACT, spec_for
    mesh, rules = _ACT["mesh"], _ACT["rules"]
    if mesh is not None and rules is not None and \
            {"data", "model"} <= set(mesh.axis_names) and \
            E % dict(mesh.shape)["model"] == 0:
        x_spec = spec_for(("batch", "seq", None), x.shape, rules, mesh)
        flat = []
        for entry in x_spec[:2]:
            if entry is None:
                continue
            flat.extend((entry,) if isinstance(entry, str) else entry)
        if {"data", "model"} <= set(flat):
            return _moe_ep(p, x, cfg, mesh, rules)

    C = _capacity(T, k, E, cfg.capacity_factor)
    xf = x.reshape(T, D)

    logits = (xf @ p["router"]).astype(jnp.float32)            # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, ids = jax.lax.top_k(probs, k)                      # (T, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    eid = ids.reshape(-1)                                      # (T*k,)
    order = jnp.argsort(eid, stable=True)
    eid_s = eid[order]
    tok_s = (order // k).astype(jnp.int32)
    gate_s = gate_w.reshape(-1)[order]

    counts = jnp.bincount(eid, length=E)                       # (T*k per E)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                              jnp.cumsum(counts)[:-1]])
    slot_in_e = jnp.arange(T * k) - starts[eid_s]
    keep = slot_in_e < C
    dest = jnp.where(keep, eid_s * C + slot_in_e, E * C)       # E*C = dropped

    # (E*C,) -> source token index (+1 so 0 = empty), then gather tokens.
    src = jnp.zeros((E * C + 1,), jnp.int32).at[dest].set(tok_s + 1,
                                                          mode="drop")
    src = src[:E * C]
    valid = (src > 0)
    xe = jnp.where(valid[:, None], xf[jnp.maximum(src - 1, 0)], 0.0)
    xe = constrain_act(xe.reshape(E, C, D), ("experts", "capacity", None))

    ye = _expert_matmuls(p, xe).reshape(E * C, D)

    # combine: scatter-add each slot's output back to its token with its gate.
    gate_slot = jnp.zeros((E * C + 1,), gate_s.dtype).at[dest].set(
        gate_s, mode="drop")[:E * C]
    contrib = (ye * (gate_slot * valid)[:, None]).astype(ye.dtype)
    out = jnp.zeros((T, D), ye.dtype).at[jnp.maximum(src - 1, 0)].add(
        contrib, mode="drop")
    return constrain_act(out.reshape(B, S, D).astype(x.dtype),
                         ("batch", "seq", None))


def aux_load_balance_loss(p: Tree, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Switch-style load-balance auxiliary loss (mean fraction x mean prob)."""
    B, S, D = x.shape
    logits = (x.reshape(-1, D) @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    ids = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(ids, cfg.n_experts, dtype=jnp.float32),
                    axis=0)
    return cfg.n_experts * jnp.sum(frac * probs.mean(axis=0))
