"""Uniform model facade over all 10 assigned architectures.

`build(cfg)` returns a Model exposing:
  init / param_struct (eval_shape — no allocation, dry-run safe),
  loss (training), prefill, decode_step, init_cache,
  input_specs(shape) -> ShapeDtypeStruct dict + logical input axes,
  param counts (total & active) for MODEL_FLOPS.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import encdec as encdec_mod
from repro.models import lm as lm_mod

Tree = Dict


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


@dataclasses.dataclass
class Model:
    cfg: ArchConfig

    # -- params ---------------------------------------------------------------
    def init(self, key, dtype=jnp.bfloat16) -> Tuple[Tree, Tree]:
        if self.cfg.family == "encdec":
            return encdec_mod.init_encdec(self.cfg, key, dtype)
        return lm_mod.init_lm(self.cfg, key, dtype)

    def param_struct(self, dtype=jnp.bfloat16) -> Tuple[Tree, Tree]:
        """Shapes/axes without allocating (dry-run path for 398B params)."""
        key = jax.random.PRNGKey(0)
        shapes = jax.eval_shape(lambda: self.init(key, dtype)[0])
        return shapes, self._axes_tree(dtype)

    def _axes_tree(self, dtype=jnp.bfloat16) -> Tree:
        # The axes tree depends only on the model STRUCTURE (schedule,
        # branches), never on dim sizes — build it from a tiny config that
        # preserves n_layers / periods exactly so the tree shape matches.
        cfg = self.cfg
        tiny = cfg.replace(
            d_model=16, d_ff=16 if cfg.d_ff else 0, vocab=32,
            n_heads=2 if cfg.n_heads else 0,
            n_kv_heads=1 if cfg.n_kv_heads else 0,
            d_head=8 if cfg.n_heads else 0,
            n_experts=2 if cfg.n_experts else 0,
            top_k=1 if cfg.top_k else 0,
            ssm_state=4 if cfg.ssm_state else 0,
            ssm_head_dim=8 if cfg.ssm_head_dim else 0)
        key = jax.random.PRNGKey(0)
        if cfg.family == "encdec":
            _, axes = encdec_mod.init_encdec(tiny, key, jnp.float32)
        else:
            _, axes = lm_mod.init_lm(tiny, key, jnp.float32)
        return axes

    def param_counts(self) -> Tuple[int, int]:
        """(total, active) parameter counts. Active discounts non-routed
        experts by top_k/n_experts (MoE MODEL_FLOPS uses 6·N_active·D)."""
        shapes, axes = self.param_struct()
        leaves_s = jax.tree.leaves(shapes)
        leaves_a = jax.tree.leaves(axes, is_leaf=_is_axes_leaf)
        total = active = 0
        for s, a in zip(leaves_s, leaves_a):
            n = int(np.prod(s.shape))
            total += n
            if "experts" in a and self.cfg.n_experts:
                active += n * self.cfg.top_k // self.cfg.n_experts
            else:
                active += n
        return total, active

    # -- steps ------------------------------------------------------------------
    def loss(self, params: Tree, batch: Dict[str, jnp.ndarray],
             impl: Optional[str] = None) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.family == "encdec":
            return encdec_mod.encdec_loss(cfg, params, batch["frames"],
                                          batch["tokens"], impl=impl)
        extra = batch.get("patches")
        return lm_mod.lm_loss(cfg, params, batch["tokens"], extra, impl=impl)

    def forward(self, params: Tree, batch: Dict[str, jnp.ndarray],
                impl: Optional[str] = None) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.family == "encdec":
            enc = encdec_mod.encode(cfg, params, batch["frames"], impl)
            return encdec_mod.decode_train(cfg, params, batch["tokens"], enc,
                                           impl)
        return lm_mod.forward(cfg, params, batch.get("tokens"),
                              batch.get("patches"), impl)

    def prefill(self, params: Tree, batch: Dict[str, jnp.ndarray],
                max_len: int = 0, impl: Optional[str] = None,
                cache_dtype=jnp.bfloat16):
        cfg = self.cfg
        if cfg.family == "encdec":
            enc = encdec_mod.encode(cfg, params, batch["frames"], impl)
            # cross-attn KV computed once here (real serving would cache it);
            # baseline reports prefill = encoder + decoder-prefill cost.
            x = encdec_mod.decode_train(cfg, params, batch["tokens"], enc,
                                        impl)
            from repro.models.layers import pad_vocab
            vbias = jnp.where(jnp.arange(pad_vocab(cfg.vocab)) < cfg.vocab,
                              0.0, -1e30).astype(jnp.float32)
            lg = (x[:, -1] @ params["embed"]["table"].T).astype(jnp.float32)
            return lg + vbias, None
        return lm_mod.prefill(cfg, params, batch.get("tokens"),
                              batch.get("patches"), max_len=max_len, impl=impl,
                              cache_dtype=cache_dtype)

    def init_cache(self, batch: int, max_len: int, dtype=jnp.bfloat16):
        if self.cfg.family == "encdec":
            return encdec_mod.init_cache_encdec(self.cfg, batch, max_len,
                                                dtype)
        return lm_mod.init_cache(self.cfg, batch, max_len, dtype)

    def decode_step(self, params: Tree, cache: Tree, tokens: jnp.ndarray,
                    impl: Optional[str] = None):
        if self.cfg.family == "encdec":
            return encdec_mod.decode_step_encdec(self.cfg, params, cache,
                                                 tokens, impl=impl)
        return lm_mod.decode_step(self.cfg, params, cache, tokens, impl=impl)

    # -- input specs -----------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig, dtype=jnp.bfloat16
                    ) -> Tuple[Dict[str, jax.ShapeDtypeStruct], Dict[str, tuple]]:
        """ShapeDtypeStruct stand-ins + logical axes for every model input."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        if shape.kind in ("train", "prefill"):
            if cfg.family == "encdec":
                half = S // 2
                return ({"frames": jax.ShapeDtypeStruct((B, half, cfg.d_model),
                                                         dtype),
                         "tokens": jax.ShapeDtypeStruct((B, half), jnp.int32)},
                        {"frames": ("batch", "seq", "embed_act"),
                         "tokens": ("batch", "seq")})
            if cfg.family == "vlm":
                tv = cfg.frontend_tokens
                return ({"patches": jax.ShapeDtypeStruct((B, tv, cfg.d_model),
                                                         dtype),
                         "tokens": jax.ShapeDtypeStruct((B, S - tv), jnp.int32)},
                        {"patches": ("batch", "seq", "embed_act"),
                         "tokens": ("batch", "seq")})
            return ({"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)},
                    {"tokens": ("batch", "seq")})
        # decode: one new token against a seq_len cache
        return ({"tokens": jax.ShapeDtypeStruct((B,), jnp.int32)},
                {"tokens": ("batch",)})

    def cache_struct(self, shape: ShapeConfig, dtype=jnp.bfloat16):
        """(ShapeDtypeStruct cache, axes) for decode dry-runs (no alloc)."""
        B, S = shape.global_batch, shape.seq_len
        struct = jax.eval_shape(lambda: self.init_cache(B, S, dtype)[0])
        return struct, self.cache_axes()

    def cache_axes(self):
        if self.cfg.family == "encdec":
            return encdec_mod.cache_axes_encdec(self.cfg)
        return lm_mod.cache_axes(self.cfg)


def build(cfg: ArchConfig) -> Model:
    return Model(cfg)
