"""Decoder LM over a *layer schedule* — the uniform machinery behind 8 of the
10 assigned architectures (dense / moe / ssm / hybrid / vlm).

A schedule is a list of Segments; each Segment has a `body` (an ordered tuple
of LayerSpec — mixer x ffn kinds) repeated `count` times via lax.scan with
stacked parameters. This keeps HLO size ~O(distinct layer kinds), not
O(n_layers): jamba's 72 layers compile as ONE scan over 9 copies of an
8-layer body; gemma3's 5:1 local:global pattern is a 6-layer body x4 plus a
2-layer tail. Bodies are remat'd (jax.checkpoint) for training.

The paper connection (DESIGN.md §4): each Segment is a Meili pipeline *stage*
with its own profiled latency; heterogeneous bodies (attention vs mamba vs
MoE) are exactly the non-uniform stages Algorithm 1 replicates independently.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (dense_init, embed_init, make_norm, mlp,
                                 mlp_init, pad_vocab, rmsnorm)
from repro.parallel.sharding import constrain_act

Tree = Dict


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str          # "attn" | "attn_local" | "mamba"
    ffn: str            # "mlp" | "moe" | "none"


@dataclasses.dataclass(frozen=True)
class Segment:
    body: Tuple[LayerSpec, ...]
    count: int


def build_schedule(cfg) -> List[Segment]:
    L = cfg.n_layers
    if cfg.family == "ssm":
        return [Segment((LayerSpec("mamba", "none"),), L)]
    if cfg.family == "hybrid":
        period, body = cfg.attn_period, []
        for i in range(period):
            mixer = "attn" if i == period // 2 else "mamba"
            ffn = "moe" if (i % cfg.moe_period == 1) else "mlp"
            body.append(LayerSpec(mixer, ffn))
        assert L % period == 0, (L, period)
        return [Segment(tuple(body), L // period)]
    if cfg.family == "moe":
        segs = []
        if cfg.first_dense:
            segs.append(Segment((LayerSpec("attn", "mlp"),), cfg.first_dense))
        segs.append(Segment((LayerSpec("attn", "moe"),), L - cfg.first_dense))
        return segs
    # dense / vlm
    if cfg.local_global_period:
        per = cfg.local_global_period
        body = tuple([LayerSpec("attn_local", "mlp")] * (per - 1)
                     + [LayerSpec("attn", "mlp")])
        segs = [Segment(body, L // per)]
        if L % per:
            segs.append(Segment((LayerSpec("attn_local", "mlp"),), L % per))
        return segs
    return [Segment((LayerSpec("attn", "mlp"),), L)]


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg, spec: LayerSpec, dtype) -> Tuple[Tree, Tree]:
    norm_init, _ = make_norm(cfg)
    k1, k2 = jax.random.split(key)
    p: Tree = {}
    a: Tree = {}
    np_, na_ = norm_init(dtype)
    p["norm1"], a["norm1"] = np_, na_
    if spec.mixer in ("attn", "attn_local"):
        p["attn"], a["attn"] = attn_mod.attn_init(k1, cfg, dtype)
    else:
        p["mamba"], a["mamba"] = ssm_mod.mamba_init(k1, cfg, dtype)
    if spec.ffn != "none":
        np2, na2 = norm_init(dtype)
        p["norm2"], a["norm2"] = np2, na2
        if spec.ffn == "mlp":
            p["mlp"], a["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, dtype)
        else:
            p["moe"], a["moe"] = moe_mod.moe_init(k2, cfg, dtype)
    return p, a


def _is_axes_leaf(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(e, (str, type(None)))
                                        for e in x)


def _stack_init(key, cfg, spec: LayerSpec, count: int, dtype):
    keys = jax.random.split(key, count)
    p0, a0 = _layer_init(keys[0], cfg, spec, dtype)
    stacked = jax.vmap(lambda k: _layer_init(k, cfg, spec, dtype)[0])(keys)
    axes = jax.tree.map(lambda t: ("layers",) + t, a0, is_leaf=_is_axes_leaf)
    del p0
    return stacked, axes


def init_lm(cfg, key, dtype=jnp.bfloat16) -> Tuple[Tree, Tree]:
    schedule = build_schedule(cfg)
    keys = jax.random.split(key, len(schedule) * 8 + 2)
    p: Tree = {"segments": []}
    a: Tree = {"segments": []}
    ep, ea = embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)
    p["embed"], a["embed"] = ep, ea
    if not cfg.tie_embeddings:
        hp, ha = dense_init(keys[1], cfg.d_model, pad_vocab(cfg.vocab),
                            "vocab_embed", "vocab", dtype)
        p["head"], a["head"] = hp, ha
    ki = 2
    for seg in schedule:
        seg_p, seg_a = [], []
        for pos, spec in enumerate(seg.body):
            sp, sa = _stack_init(keys[ki], cfg, spec, seg.count, dtype)
            ki += 1
            seg_p.append(sp)
            seg_a.append(sa)
        p["segments"].append(seg_p)
        a["segments"].append(seg_a)
    norm_init, _ = make_norm(cfg)
    fp, fa = norm_init(dtype)
    p["final_norm"], a["final_norm"] = fp, fa
    return p, a


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _apply_layer(cfg, spec: LayerSpec, p: Tree, x, positions, impl,
                 collect_kv: bool = False):
    _, norm_apply = make_norm(cfg)
    h = norm_apply(p.get("norm1"), x)
    kv = None
    if spec.mixer in ("attn", "attn_local"):
        window = cfg.window if spec.mixer == "attn_local" else None
        out = attn_mod.attn_apply(p["attn"], h, cfg, positions=positions,
                                  causal=True, window=window, impl=impl,
                                  return_kv=collect_kv)
        y, kv = out if collect_kv else (out, None)
    else:
        out = ssm_mod.mamba_apply(p["mamba"], h, cfg, impl=impl,
                                  return_state=collect_kv)
        y, kv = out if collect_kv else (out, None)
    x = constrain_act(x + y, ("batch", "seq", None))
    if spec.ffn != "none":
        h = norm_apply(p.get("norm2"), x)
        y = mlp(p["mlp"], h) if spec.ffn == "mlp" else \
            moe_mod.moe_ffn(p["moe"], h, cfg)
        x = constrain_act(x + y, ("batch", "seq", None))
    return x, kv


def forward(cfg, params: Tree, tokens: Optional[jnp.ndarray],
            extra_embeds: Optional[jnp.ndarray] = None,
            impl: Optional[str] = None) -> jnp.ndarray:
    """Returns final hidden states (B, S, D). tokens may be None for
    pure-embedding inputs; extra_embeds (frontend stub) is prepended."""
    schedule = build_schedule(cfg)
    parts = []
    if extra_embeds is not None:
        parts.append(extra_embeds)
    if tokens is not None:
        parts.append(params["embed"]["table"][tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(
        [parts[0].astype(parts[1].dtype), parts[1]], axis=1)
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    for seg, seg_p in zip(schedule, params["segments"]):
        def body(carry, layer_ps, seg=seg):
            h = carry
            for pos, spec in enumerate(seg.body):
                h, _ = _apply_layer(cfg, spec, layer_ps[pos], h, positions,
                                    impl)
            return h, None
        body_fn = jax.checkpoint(body) if cfg.remat else body
        x, _ = jax.lax.scan(body_fn, x, tuple(seg_p))
    _, norm_apply = make_norm(cfg)
    return norm_apply(params.get("final_norm"), x)


def vocab_bias(cfg, dtype=jnp.float32) -> jnp.ndarray:
    """(pad_vocab,) additive mask: 0 for real tokens, NEG_INF for padding."""
    vp = pad_vocab(cfg.vocab)
    return jnp.where(jnp.arange(vp) < cfg.vocab, 0.0, -1e30).astype(dtype)


def logits(cfg, params: Tree, x: jnp.ndarray) -> jnp.ndarray:
    w = params["embed"]["table"].T if cfg.tie_embeddings else params["head"]["w"]
    return (x @ w).astype(jnp.float32) + vocab_bias(cfg)


def lm_loss(cfg, params: Tree, tokens: jnp.ndarray,
            extra_embeds: Optional[jnp.ndarray] = None,
            impl: Optional[str] = None, chunk: int = 512) -> jnp.ndarray:
    """Next-token CE, computed in sequence chunks so the (S, vocab) logits
    never materialize (vocab stays sharded; the target pick is a masked
    reduction, not a gather — GSPMD-friendly)."""
    x = forward(cfg, params, tokens, extra_embeds, impl)
    offset = 0 if extra_embeds is None else extra_embeds.shape[1]
    xs = x[:, offset:offset + tokens.shape[1] - 1]             # predict text
    tgt = tokens[:, 1:]
    B, S, D = xs.shape
    chunk = min(chunk, S)
    n = S // chunk
    xs, tgt = xs[:, :n * chunk], tgt[:, :n * chunk]
    w = params["embed"]["table"].T if cfg.tie_embeddings else params["head"]["w"]

    def step(acc, i):
        xc = jax.lax.dynamic_slice_in_dim(xs, i * chunk, chunk, 1)
        tc = jax.lax.dynamic_slice_in_dim(tgt, i * chunk, chunk, 1)
        lg = (xc @ w).astype(jnp.float32)                      # (B, c, V)
        lse = jax.nn.logsumexp(lg, axis=-1)
        vocab_ids = jax.lax.broadcasted_iota(jnp.int32, lg.shape, 2)
        picked = jnp.sum(jnp.where(vocab_ids == tc[..., None], lg, 0.0),
                         axis=-1)
        return acc + jnp.sum(lse - picked), None

    from repro.kernels import ops as _ops
    total, _ = jax.lax.scan(step, jnp.float32(0.0), jnp.arange(n),
                            unroll=_ops._unroll(n))
    return total / (B * n * chunk)


# ---------------------------------------------------------------------------
# Decode (serve_step) + cache
# ---------------------------------------------------------------------------

def cache_axes(cfg) -> Tree:
    """Logical-axes tree matching init_cache (no allocation)."""
    schedule = build_schedule(cfg)
    kv_ax = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")
    axes: Tree = {"pos": (), "segments": []}
    for seg in schedule:
        seg_a = []
        for spec in seg.body:
            if spec.mixer in ("attn", "attn_local"):
                seg_a.append({"k": kv_ax, "v": kv_ax})
            else:
                seg_a.append(jax.tree.map(lambda t: ("layers",) + t,
                                          ssm_mod.mamba_cache_axes(),
                                          is_leaf=_is_axes_leaf))
        axes["segments"].append(seg_a)
    return axes


def init_cache(cfg, batch: int, max_len: int, dtype=jnp.bfloat16
               ) -> Tuple[Tree, Tree]:
    """Stacked per-segment caches (+ parallel logical-axes tree)."""
    schedule = build_schedule(cfg)
    cache: Tree = {"pos": jnp.zeros((), jnp.int32), "segments": []}
    for seg in schedule:
        seg_c = []
        for spec in seg.body:
            if spec.mixer in ("attn", "attn_local"):
                kshape = (seg.count, batch, max_len, cfg.n_kv_heads,
                          cfg.head_dim)
                c = {"k": jnp.zeros(kshape, dtype), "v": jnp.zeros(kshape, dtype)}
            else:
                c0 = ssm_mod.mamba_cache_init(cfg, batch, dtype)
                c = jax.tree.map(
                    lambda t: jnp.broadcast_to(t[None], (seg.count,) + t.shape),
                    c0)
            seg_c.append(c)
        cache["segments"].append(seg_c)
    return cache, cache_axes(cfg)


def decode_step(cfg, params: Tree, cache: Tree, tokens: jnp.ndarray,
                impl: Optional[str] = None) -> Tuple[jnp.ndarray, Tree]:
    """One decode step. tokens: (B,) int32. Returns (logits (B, V), cache)."""
    schedule = build_schedule(cfg)
    _, norm_apply = make_norm(cfg)
    x = params["embed"]["table"][tokens]                        # (B, D)
    pos = cache["pos"]
    new_cache: Tree = {"pos": pos + 1, "segments": []}

    for seg, seg_p, seg_c in zip(schedule, params["segments"],
                                 cache["segments"]):
        def body(carry, xs, seg=seg):
            h = carry
            new_cs = []
            for i, spec in enumerate(seg.body):
                p, c = xs[2 * i], xs[2 * i + 1]
                hn = norm_apply(p.get("norm1"), h)
                if spec.mixer in ("attn", "attn_local"):
                    window = cfg.window if spec.mixer == "attn_local" else None
                    y, ck, cv = attn_mod.attn_decode(
                        p["attn"], hn, cfg, cache_k=c["k"], cache_v=c["v"],
                        pos=pos, window=window, impl=impl)
                    new_cs.append({"k": ck, "v": cv})
                else:
                    y, nc = ssm_mod.mamba_decode(p["mamba"], hn, c, cfg)
                    new_cs.append(nc)
                h = h + y
                if spec.ffn != "none":
                    hn = norm_apply(p.get("norm2"), h)
                    y = mlp(p["mlp"], hn) if spec.ffn == "mlp" else \
                        moe_mod.moe_ffn(p["moe"], hn[:, None], cfg)[:, 0]
                    h = h + y
            return h, tuple(new_cs)

        xs = tuple(v for pair in zip(seg_p, seg_c) for v in pair)
        x, updated = jax.lax.scan(body, x, xs)
        new_cache["segments"].append(list(updated))
    x = norm_apply(params.get("final_norm"), x)
    lg = logits(cfg, params, x)
    return lg, new_cache


def prefill(cfg, params: Tree, tokens: Optional[jnp.ndarray],
            extra_embeds: Optional[jnp.ndarray] = None, max_len: int = 0,
            impl: Optional[str] = None, cache_dtype=jnp.bfloat16):
    """Full-sequence forward that also fills a decode cache.

    Note: forward() is re-run per segment with KV collection — implemented as
    one pass that emits (k, v) via scan ys.
    """
    schedule = build_schedule(cfg)
    parts = []
    if extra_embeds is not None:
        parts.append(extra_embeds)
    if tokens is not None:
        parts.append(params["embed"]["table"][tokens])
    x = parts[0] if len(parts) == 1 else jnp.concatenate(
        [parts[0].astype(parts[1].dtype), parts[1]], axis=1)
    B, S, _ = x.shape
    max_len = max_len or S
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    cache: Tree = {"pos": jnp.int32(S), "segments": []}

    for seg, seg_p in zip(schedule, params["segments"]):
        def body(carry, layer_ps, seg=seg):
            h = carry
            kvs = []
            for pos_i, spec in enumerate(seg.body):
                h, kv = _apply_layer(cfg, spec, layer_ps[pos_i], h, positions,
                                     impl, collect_kv=True)
                if spec.mixer in ("attn", "attn_local"):
                    k, v = kv
                    pad = max_len - S
                    k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
                    kvs.append({"k": k.astype(cache_dtype),
                                "v": v.astype(cache_dtype)})
                else:
                    kvs.append(jax.tree.map(
                        lambda t: t.astype(cache_dtype)
                        if t.dtype != jnp.float32 else t, kv))
            return h, tuple(kvs)

        x, kv_stacks = jax.lax.scan(body, x, tuple(seg_p))
        cache["segments"].append(list(kv_stacks))
    _, norm_apply = make_norm(cfg)
    x = norm_apply(params.get("final_norm"), x)
    return logits(cfg, params, x[:, -1]), cache
