"""GQA attention layer: init, full-sequence apply (train/prefill with cache
emission) and single-token decode apply. Flash kernels via kernels/ops.py.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import apply_rope, dense_init
from repro.parallel.sharding import constrain_act

Tree = Dict


def attn_init(key, cfg, dtype, cross: bool = False) -> Tuple[Tree, Tree]:
    H, Hkv, dh, D = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    ks = jax.random.split(key, 4)
    p, a = {}, {}
    specs = {"q": (ks[0], H, "heads"), "k": (ks[1], Hkv, "kv_heads"),
             "v": (ks[2], Hkv, "kv_heads")}
    for name, (k, h, h_ax) in specs.items():
        # stored as (D, H, dh) so heads stay a shardable logical dim
        pp, aa = dense_init(k, D, h * dh, "embed", "tmp", dtype,
                            bias=cfg.qkv_bias)
        pp["w"] = pp["w"].reshape(D, h, dh)
        aa["w"] = ("embed", h_ax, "head_dim")
        if cfg.qkv_bias:
            pp["b"] = pp["b"].reshape(h, dh)
            aa["b"] = (h_ax, "head_dim")
        p[name], a[name] = pp, aa
    po, ao = dense_init(ks[3], H * dh, D, "tmp", "embed", dtype)
    po["w"] = po["w"].reshape(H, dh, D)
    ao["w"] = ("heads", "head_dim", "embed")
    p["o"], a["o"] = po, ao
    return p, a


def _proj(p: Tree, x: jnp.ndarray) -> jnp.ndarray:
    y = jnp.einsum("bsd,dhe->bshe", x, p["w"])
    if "b" in p:
        y = y + p["b"]
    return y


def attn_apply(p: Tree, x: jnp.ndarray, cfg, *, positions: jnp.ndarray,
               causal: bool = True, window: Optional[int] = None,
               kv_x: Optional[jnp.ndarray] = None, impl: Optional[str] = None,
               return_kv: bool = False):
    """Full-sequence attention. kv_x: cross-attention source (enc output)."""
    src = x if kv_x is None else kv_x
    q = constrain_act(_proj(p["q"], x), ("batch", "seq", "heads", "head_dim"))
    k = constrain_act(_proj(p["k"], src),
                      ("batch", "seq", "kv_heads", "head_dim"))
    v = constrain_act(_proj(p["v"], src),
                      ("batch", "seq", "kv_heads", "head_dim"))
    if kv_x is None:                       # self-attention: RoPE both sides
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    out = ops.attention(q, k, v, causal=causal, window=window, impl=impl)
    y = constrain_act(jnp.einsum("bshe,hed->bsd", out, p["o"]["w"]),
                      ("batch", "seq", None))
    if return_kv:
        return y, (k, v)
    return y


def attn_decode(p: Tree, x: jnp.ndarray, cfg, *, cache_k: jnp.ndarray,
                cache_v: jnp.ndarray, pos: jnp.ndarray,
                window: Optional[int] = None, cross: bool = False,
                impl: Optional[str] = None):
    """One-token decode. x: (B, D); cache_k/v: (B, S, Hkv, dh); pos: scalar
    int32 — current write position (tokens so far). Returns (y, cache_k,
    cache_v)."""
    B, D = x.shape
    q = jnp.einsum("bd,dhe->bhe", x, p["q"]["w"])
    if "b" in p["q"]:
        q = q + p["q"]["b"]
    if not cross:
        k_new = jnp.einsum("bd,dhe->bhe", x, p["k"]["w"])
        v_new = jnp.einsum("bd,dhe->bhe", x, p["v"]["w"])
        if "b" in p["k"]:
            k_new = k_new + p["k"]["b"]
            v_new = v_new + p["v"]["b"]
        posv = jnp.full((B, 1), pos, dtype=jnp.int32)
        q = apply_rope(q[:, None], posv, cfg.rope_theta)[:, 0]
        k_new = apply_rope(k_new[:, None], posv, cfg.rope_theta)[:, 0]
        cache_k = jax.lax.dynamic_update_slice_in_dim(
            cache_k, k_new[:, None].astype(cache_k.dtype), pos, axis=1)
        cache_v = jax.lax.dynamic_update_slice_in_dim(
            cache_v, v_new[:, None].astype(cache_v.dtype), pos, axis=1)
        kv_len = jnp.full((B,), pos + 1, jnp.int32)
    else:
        kv_len = jnp.full((B,), cache_k.shape[1], jnp.int32)
    if window is not None:
        lo = jnp.maximum(kv_len - window, 0)
        out = _window_decode(q, cache_k, cache_v, lo, kv_len, impl)
    else:
        out = ops.decode_attention(q, cache_k, cache_v, kv_len, impl=impl)
    y = jnp.einsum("bhe,hed->bd", out, p["o"]["w"])
    return y, cache_k, cache_v


def _window_decode(q, cache_k, cache_v, lo, kv_len, impl):
    """Decode attention over [lo, kv_len): implemented as full decode with
    start masking via a large-negative additive trick in ref path."""
    from repro.kernels.ref import decode_ref
    B, S, Hkv, dh = cache_k.shape
    valid = (jnp.arange(S)[None, :] >= lo[:, None]) & \
            (jnp.arange(S)[None, :] < kv_len[:, None])
    # Use masked softmax directly (O(S) memory — decode is cheap).
    Hq = q.shape[1]
    G = Hq // Hkv
    scale = dh ** -0.5
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, dh) * scale
    logits = jnp.einsum("bhgd,bshd->bhgs", qf, cache_k.astype(jnp.float32))
    logits = jnp.where(valid[:, None, None], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgs,bshd->bhgd", p, cache_v.astype(jnp.float32))
    return out.reshape(B, Hq, dh).astype(q.dtype)
