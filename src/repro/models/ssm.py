"""Mamba-2 block: projections, causal depthwise conv, SSD scan, gating.

Structure (simplified but standard Mamba-2): separate projections for z
(gate), x (inner), B, C (state projections, single group) and dt (per head);
causal depthwise conv over the x/B/C paths; a_t = exp(-softplus(A_log)·dt);
SSD scan via kernels/ops.ssd; RMS-normed gated output projection.

Decode carries (conv tail, ssm state h) per layer.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops
from repro.models.layers import dense_init, rmsnorm, rmsnorm_init
from repro.parallel.sharding import constrain_act

Tree = Dict


def mamba_init(key, cfg, dtype) -> Tuple[Tree, Tree]:
    D, Din, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    K = cfg.conv_kernel
    ks = jax.random.split(key, 8)
    pz, az = dense_init(ks[0], D, Din, "embed", "ff", dtype)
    px, ax = dense_init(ks[1], D, Din, "embed", "ff", dtype)
    pB, aB = dense_init(ks[2], D, N, "embed", "state", dtype)
    pC, aC = dense_init(ks[3], D, N, "embed", "state", dtype)
    pdt, adt = dense_init(ks[4], D, H, "embed", "none", dtype)
    po, ao = dense_init(ks[5], Din, D, "ff", "embed", dtype)
    pn, an = rmsnorm_init(Din, dtype)
    p = {
        "z": pz, "x": px, "B": pB, "C": pC, "dt": pdt, "o": po, "norm": pn,
        "conv_x": (jax.random.normal(ks[6], (K, Din), jnp.float32)
                   * (1.0 / math.sqrt(K))).astype(dtype),
        "conv_BC": (jax.random.normal(ks[7], (K, 2 * N), jnp.float32)
                    * (1.0 / math.sqrt(K))).astype(dtype),
        "A_log": jnp.zeros((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D_skip": jnp.ones((H,), jnp.float32),
    }
    a = {
        "z": az, "x": ax, "B": aB, "C": aC, "dt": adt, "o": ao, "norm": an,
        "conv_x": ("conv", "ff"), "conv_BC": ("conv", "none"),
        "A_log": ("none",), "dt_bias": ("none",), "D_skip": ("none",),
    }
    return p, a


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, S, Ch), w: (K, Ch)."""
    K = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(K):
        out = out + pad[:, i:i + x.shape[1], :].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _gates(p: Tree, xw: jnp.ndarray, cfg):
    """Common path: dt/a from the dt projection. xw: (B,S,D) block input."""
    dt = (xw @ p["dt"]["w"]).astype(jnp.float32) + p["dt_bias"]
    dt = jax.nn.softplus(dt)                                   # (B,S,H)
    a = jnp.exp(-jnp.exp(p["A_log"])[None, None, :] * dt)      # (B,S,H) in (0,1)
    return dt, a


def mamba_apply(p: Tree, xw: jnp.ndarray, cfg, impl=None,
                return_state: bool = False):
    """xw: (B, S, D) (already normed) -> (B, S, D) [, decode cache]."""
    B, S, D = xw.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = constrain_act(xw @ p["z"]["w"], ("batch", "seq", "ff"))  # (B,S,Din)
    xi_pre = constrain_act(xw @ p["x"]["w"], ("batch", "seq", "ff"))
    xi = jax.nn.silu(_causal_conv(xi_pre, p["conv_x"]))
    bc_pre = jnp.concatenate([xw @ p["B"]["w"], xw @ p["C"]["w"]], axis=-1)
    bc = jax.nn.silu(_causal_conv(bc_pre, p["conv_BC"]))
    Bm, Cm = jnp.split(bc, 2, axis=-1)                         # (B,S,N) each
    dt, a = _gates(p, xw, cfg)
    xh = xi.reshape(B, S, H, P)
    b = Bm[:, :, None, :] * dt[..., None]                      # (B,S,H,N)
    c = jnp.broadcast_to(Cm[:, :, None, :], (B, S, H, N))
    y, h_fin = ops.ssd(xh, a, b, c, impl=impl)
    y = y + p["D_skip"][None, None, :, None] * xh
    y = y.reshape(B, S, H * P)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = constrain_act((y.astype(xw.dtype) @ p["o"]["w"]).astype(xw.dtype),
                        ("batch", "seq", None))
    if return_state:
        K = cfg.conv_kernel
        cache = {"conv_x": xi_pre[:, S - (K - 1):, :],
                 "conv_BC": bc_pre[:, S - (K - 1):, :],
                 "h": h_fin}
        return out, cache
    return out


def mamba_cache_init(cfg, batch: int, dtype):
    """Per-layer decode cache: conv tails + ssm state."""
    K = cfg.conv_kernel
    return {
        "conv_x": jnp.zeros((batch, K - 1, cfg.d_inner), dtype),
        "conv_BC": jnp.zeros((batch, K - 1, 2 * cfg.ssm_state), dtype),
        "h": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_state,
                        cfg.ssm_head_dim), jnp.float32),
    }


def mamba_cache_axes():
    return {
        "conv_x": ("batch", "conv", "ff"),
        "conv_BC": ("batch", "conv", "none"),
        "h": ("batch", "none", "cache_state", "none"),
    }


def mamba_decode(p: Tree, xw: jnp.ndarray, cache: Tree, cfg):
    """One-token step. xw: (B, D) normed input. Returns (y (B,D), cache)."""
    B, D = xw.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = xw @ p["z"]["w"]
    xi_new = xw @ p["x"]["w"]                                  # (B,Din)
    bc_new = jnp.concatenate([xw @ p["B"]["w"], xw @ p["C"]["w"]], axis=-1)

    def conv_step(tail, new, w):
        full = jnp.concatenate([tail, new[:, None, :]], axis=1)  # (B,K,Ch)
        out = (full.astype(jnp.float32) *
               w[None].astype(jnp.float32)).sum(axis=1)
        return full[:, 1:, :], out.astype(new.dtype)

    tail_x, xi = conv_step(cache["conv_x"], xi_new, p["conv_x"])
    tail_bc, bc = conv_step(cache["conv_BC"], bc_new, p["conv_BC"])
    xi = jax.nn.silu(xi)
    bc = jax.nn.silu(bc)
    Bm, Cm = jnp.split(bc, 2, axis=-1)                         # (B,N)
    dt = jax.nn.softplus((xw @ p["dt"]["w"]).astype(jnp.float32) + p["dt_bias"])
    a = jnp.exp(-jnp.exp(p["A_log"])[None, :] * dt)            # (B,H)
    xh = xi.reshape(B, H, P).astype(jnp.float32)
    b = Bm[:, None, :].astype(jnp.float32) * dt[..., None]     # (B,H,N)
    h = a[..., None, None] * cache["h"] + \
        b[..., :, None] * xh[..., None, :]                     # (B,H,N,P)
    y = jnp.einsum("bn,bhnp->bhp", Cm.astype(jnp.float32), h)
    y = y + p["D_skip"][None, :, None] * xh
    y = y.reshape(B, H * P).astype(xw.dtype)
    y = rmsnorm(p["norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    new_cache = {"conv_x": tail_x, "conv_BC": tail_bc, "h": h}
    return y @ p["o"]["w"], new_cache
