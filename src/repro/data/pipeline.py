"""Deterministic sharded data pipeline.

Index-based and stateless: batch `i` of host `h` is a pure function of
(seed, i, h), so restart-after-failure resumes exactly (checkpoint stores
only the step counter), and any host can regenerate any shard — the property
elastic re-scaling needs. Documents are sampled from a Zipfian token model
and packed into fixed-length sequences with EOS separators (real pipelines
swap `_document` for a tokenized corpus reader; the packing, sharding and
determinism machinery is the substance here).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np


@dataclasses.dataclass
class SyntheticLMDataset:
    vocab: int
    seq_len: int
    seed: int = 0
    eos: int = 1
    mean_doc_len: int = 512

    def _document(self, rng: np.random.Generator) -> np.ndarray:
        n = max(8, int(rng.exponential(self.mean_doc_len)))
        # Zipfian unigram stream with a little Markov structure.
        base = rng.zipf(1.3, size=n).astype(np.int64)
        toks = (base + rng.integers(0, 7, size=n)) % (self.vocab - 2) + 2
        return toks

    def batch(self, index: int, batch_size: int) -> Dict[str, np.ndarray]:
        """Batch `index`, deterministically."""
        rng = np.random.default_rng((self.seed, index))
        rows = [pack_documents(
            lambda: self._document(rng), self.seq_len, self.eos)
            for _ in range(batch_size)]
        return {"tokens": np.stack(rows).astype(np.int32)}


def pack_documents(sample_doc, seq_len: int, eos: int) -> np.ndarray:
    """Concatenate documents with EOS until seq_len is filled (no padding)."""
    out: List[np.ndarray] = []
    n = 0
    while n < seq_len:
        d = sample_doc()
        out.append(d)
        out.append(np.array([eos], dtype=np.int64))
        n += len(d) + 1
    return np.concatenate(out)[:seq_len]


def host_shard_iterator(ds: SyntheticLMDataset, global_batch: int,
                        host_index: int, host_count: int,
                        start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    """Each host draws its disjoint slice of every global batch."""
    assert global_batch % host_count == 0
    per_host = global_batch // host_count
    step = start_step
    while True:
        b = ds.batch(step, global_batch)
        lo = host_index * per_host
        yield {k: v[lo:lo + per_host] for k, v in b.items()}
        step += 1
