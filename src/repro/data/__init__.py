from repro.data.pipeline import (SyntheticLMDataset, host_shard_iterator,
                                 pack_documents)
