"""Quickstart: build a Meili app, submit it with a throughput target, watch
the controller plan/place/scale it — the paper's §2.2 workflow end-to-end.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

from repro.apps import ALL_APPS, synth_packets
from repro.core import MeiliController, ParallelDataPlane, paper_cluster
from repro.core.graph import run_pipeline
from repro.core.profiler import measure_app


def main():
    # 1. The pool: the paper's rack (8x BF-2, 4x BF-1, 4x Pensando).
    pool = paper_cluster()
    ctrl = MeiliController(pool)
    print(f"pool: {len(pool.names())} NICs, "
          f"{pool.total('cpu')} cores, {pool.total('regex')} regex, "
          f"{pool.total('crypto')} crypto engines")

    # 2. An application: IPsec Gateway (Listing 1) — needs CPU + regex + AES,
    #    which no single NIC type provides: only pooling can host it.
    app = ALL_APPS()["ISG"]
    print(f"\napp '{app.name}': stages {app.stage_names()}")

    # 3. Offline profiling (one resource unit per stage, paper §6.1).
    pkts = synth_packets(batch=64, num_flows=8, pkt_bytes=256)
    prof = measure_app(app, pkts, iters=3)
    print("profiled stage latencies (ms/batch):",
          {s: round(l * 1e3, 2) for s, l in prof.l_s.items()})
    print(f"single-pipeline: {prof.t_p:.3f} Gbps, latency {prof.l_p*1e3:.1f} ms")

    # 4. Submit with a throughput target -> Algorithm 1 R + Algorithm 2 place.
    #    Two minimal-granularity units per stage: ISG's sha AND aes stages
    #    both bind to the pool's 4 crypto engines, so a 4-units-per-stage
    #    target (the old `t_p * 4`) over-demanded crypto 8 > 4, left aes
    #    unplaced, and achievable pinned at 0 — the long-standing quickstart
    #    IndexError when the failover demo indexed aes's (empty) NIC list.
    dep = ctrl.submit(app, target_gbps=min(2.0, prof.t_p * 2), profile=prof)
    print(f"\nreplication R = {dep.R}")
    print(f"pipelines: {dep.num_pipelines}, achievable {dep.achievable_gbps:.2f} Gbps")
    for s in app.stage_names():
        print(f"  {s:14s} -> {dep.allocation.nics_for(s)}")

    # 5. Run traffic through the replicated data plane; semantics preserved.
    dp = ParallelDataPlane(app, num_pipelines=dep.num_pipelines,
                           capacity_per_pipeline=32)
    out = dp.process(pkts)
    oracle = run_pipeline(app, pkts)
    same = bool((out.mask == oracle.mask).all())
    print(f"\nparallel data plane == single-pipeline oracle: {same}")
    print(f"packets kept: {int(out.mask.sum())}/{out.batch} "
          f"(dropped by ddos/url filters)")

    # 6. Adaptive scaling + failover.
    dep = ctrl.adaptive_scale(app.name, dep.achievable_gbps * 1.5)
    print(f"\nafter scale-up: units {dep.r_s} achievable "
          f"{dep.achievable_gbps:.2f} Gbps")
    aes_nics = dep.allocation.nics_for("aes")
    victim = aes_nics[0] if aes_nics else dep.nics_used()[0]
    ctrl.handle_failure(victim)
    dep = ctrl.deployments[app.name]
    print(f"after {victim} failure: aes now on "
          f"{dep.allocation.nics_for('aes')}, achievable "
          f"{dep.achievable_gbps:.2f} Gbps")


if __name__ == "__main__":
    main()
