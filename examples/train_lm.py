"""End-to-end training example: ~100M-parameter LM, a few hundred steps on
CPU, with checkpointing and a simulated crash + resume.

  PYTHONPATH=src python examples/train_lm.py [--steps 300]

Uses a ~100M-param olmo-family config (12L x 768) — the full assigned configs
train through the identical code path on the production mesh.
"""
import argparse
import os
import shutil

from repro.launch import train as train_mod
from repro.configs import ARCHS


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()
    shutil.rmtree(args.ckpt, ignore_errors=True)

    # ~100M params: 12 x 768 with 8 heads over olmo's family.
    import repro.configs.olmo_1b as olmo
    cfg100m = olmo.CONFIG.replace(n_layers=12, d_model=768, n_heads=8,
                                  n_kv_heads=8, d_ff=2048, d_head=96,
                                  vocab=32768, microbatch=1)
    # register it so the CLI can resolve it
    from repro import configs
    configs.ARCHS["olmo-100m"] = cfg100m

    common = ["--arch", "olmo-100m", "--steps", str(args.steps),
              "--batch", "8", "--seq", "256", "--ckpt-dir", args.ckpt,
              "--ckpt-every", "50", "--log-every", "20"]
    print("=== phase 1: train until a simulated crash at step "
          f"{args.steps // 2} ===")
    rc = train_mod.main(common + ["--fail-at", str(args.steps // 2)])
    assert rc == 17, "expected the simulated crash"
    print("\n=== phase 2: resume from the last committed checkpoint ===")
    rc = train_mod.main(common + ["--resume"])
    assert rc == 0
    print("\ntraining complete; checkpoints in", args.ckpt)


if __name__ == "__main__":
    main()
