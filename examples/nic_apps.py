"""The paper's six SmartNIC applications running on the Meili data plane
(Appendix F), with per-app throughput measurement on this host.

  PYTHONPATH=src python examples/nic_apps.py
"""
import time

import jax
import numpy as np

from repro.apps import ALL_APPS, synth_packets
from repro.core.executor import ParallelDataPlane
from repro.core.graph import run_pipeline


def main():
    pkts = synth_packets(batch=128, num_flows=16, pkt_bytes=512)
    bits = float(np.asarray(pkts.length).sum()) * 8
    print(f"{'app':22s} {'stages':>6s} {'ms/batch':>9s} {'Gbps':>7s} "
          f"{'kept':>5s}  pipeline==oracle")
    for name, app in ALL_APPS().items():
        dp = ParallelDataPlane(app, num_pipelines=2,
                               capacity_per_pipeline=96)
        out = dp.process(pkts)                     # warm up + compile
        t0 = time.perf_counter()
        out = dp.process(pkts)
        dt = time.perf_counter() - t0
        oracle = run_pipeline(app, pkts)
        ok = bool((out.mask == oracle.mask).all())
        print(f"{app.name:22s} {len(app.stages):6d} {dt*1e3:9.1f} "
              f"{bits/dt/1e9:7.2f} {int(out.mask.sum()):5d}  {ok}")


if __name__ == "__main__":
    main()
