"""Meili-planned LM serving example: per-segment replication (Algorithm 1)
over heterogeneous model stages + batched request serving.

  PYTHONPATH=src python examples/serve_pipeline.py --arch jamba-1.5-large-398b

The jamba-family reduced config has genuinely heterogeneous stages (mamba vs
attention vs MoE segments), so the Meili planner produces a non-trivial
replication plan — the paper's partial pipeline replication applied to an LM.
"""
import sys

from repro.launch import serve as serve_mod


def main():
    argv = sys.argv[1:] or ["--arch", "jamba-1.5-large-398b"]
    serve_mod.main(argv + ["--reduced", "--requests", "12", "--tokens", "8",
                           "--slots", "4", "--max-len", "32"])


if __name__ == "__main__":
    main()
