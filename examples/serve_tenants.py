"""Meili-Serve demo: the 6-tenant mix on the paper cluster, diurnal traffic,
closed-loop autoscaling, and one injected NIC failure mid-run.

  PYTHONPATH=src python examples/serve_tenants.py [--ticks 48] [--scenario diurnal]

Prints a per-tick service table (offered/achieved Gbps, p99, units) for one
tenant, the autoscaler/failover event log, and the final SLO report.
"""
import argparse

from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import TenantRegistry, contracts, default_tenant_mix
from repro.service.workload import make_scenario


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--ticks", type=int, default=48)
    ap.add_argument("--scenario", default="diurnal",
                    choices=("steady", "bursty", "diurnal", "churn",
                             "flash_crowd", "adversarial_churn"))
    ap.add_argument("--watch", default="t-fw", help="tenant to print per tick")
    ap.add_argument("--no-dataplane", action="store_true",
                    help="skip real fused-data-plane execution (analytic only)")
    args = ap.parse_args(argv)

    mix = default_tenant_mix()
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    workload = make_scenario(args.scenario, contracts(mix))
    cfg = RuntimeConfig(dataplane_every=0 if args.no_dataplane else 1)
    rt = ServiceRuntime(ctrl, registry, workload, cfg)
    admitted = registry.admit_all()
    print(f"admitted {len(admitted)} tenants: {admitted}")

    fail_tick = int(args.ticks * 0.6)
    rt.run(args.ticks, fail_at=(fail_tick, None))

    print(f"\n{args.watch} per-tick ({args.scenario}; NIC failure at tick "
          f"{fail_tick}):")
    print("tick  offered  achieved  p99(us)  units  event")
    for t in rt.telemetry.series(args.watch):
        print(f"{t.tick:4d}  {t.offered_gbps:7.2f}  {t.achieved_gbps:8.2f}"
              f"  {t.p99_s * 1e6:7.1f}  {t.units:5d}  {t.event}")

    print("\ncontroller events:")
    for e in ctrl.events:
        if e["event"] in ("scale", "failover"):
            tgt = f" target={e.get('target', 0):.1f}" if "target" in e else ""
            print(f"  {e['event']:8s} {e.get('tenant', ''):8s}"
                  f"{tgt}{' nic=' + e['nic'] if 'nic' in e else ''}")

    print("\nSLO report:")
    for tenant, r in rt.slo_report().items():
        print(f"  {tenant:8s} ticks={r['ticks']:3d} "
              f"violations={r['violations']:2d} pass={r['pass']}")
    print(f"\ntenants alive: {len(rt.alive_tenants())}/{len(mix)}")
    print(f"pool usage by tenant: {ctrl.pool.usage_snapshot()}")


if __name__ == "__main__":
    main()
