"""ISSUE 7 acceptance: the chaos benchmark's dumped trace artifact must let
a post-mortem reconstruct a gray failure's full story FROM THE FILE ALONE —
suspicion ramp, quarantine verdict (reason + observers), drain migration
spans, re-admission — and the runtime must report measured percentiles
beside the legacy estimator from one shared telemetry pass."""
import dataclasses
import pathlib

import numpy as np
import pytest

from repro.core.controller import MeiliController
from repro.core.faults import (GRAY, ChaosEngine, FaultEvent, FaultPlan)
from repro.core.pool import paper_cluster
from repro.obs import Obs, load_trace
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.telemetry import TelemetryLog, TenantTick
from repro.service.tenants import (TenantRegistry, contracts,
                                   default_tenant_mix)
from repro.service.workload import make_scenario

FAST = RuntimeConfig(dataplane_every=0, max_sim_seqs=32)


def make_runtime(scenario="steady", cfg=FAST, seed=0):
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    mix = default_tenant_mix()
    for spec in mix:
        registry.register(spec)
    wl = make_scenario(scenario, contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    return rt


# -- the acceptance criterion -------------------------------------------------

def test_chaos_artifact_reconstructs_gray_story(tmp_path):
    """Run the same fast chaos arm ``make bench-chaos`` runs, then drop every
    live object and answer the post-mortem entirely from trace.jsonl."""
    from benchmarks.bench_service import CHAOS_FAST_TICKS, _run_chaos_arm

    rec = _run_chaos_arm(True, CHAOS_FAST_TICKS, seed=0,
                         obs_dir=str(tmp_path))
    sick = rec["gray_nic"]
    path = pathlib.Path(rec["obs_artifacts"]["trace"])
    assert path.exists()

    tr = load_trace(path)                      # the file is the only witness

    # 0) the injected fault itself is on the record
    inj = tr.query(name="gray", nic=sick, kind="fault")
    assert inj and "frac" in inj[0].detail["detail"]

    # 1) suspicion ramp: evidence ticks with a rising streak, each naming
    #    the tenants whose shortfall testified against the NIC
    susp = tr.query(name="gray_suspicion", nic=sick)
    assert len(susp) >= 3
    streaks = [e.detail["streak"] for e in susp]
    assert max(streaks) >= 3 and streaks[0] == 1
    assert all(e.detail["observers"] for e in susp)
    assert all(e.tick >= inj[0].tick for e in susp)

    # 2) quarantine verdict: a decision with a human-readable reason and
    #    the observer set that convicted the NIC
    verdicts = tr.query(name="quarantine_verdict", nic=sick)
    assert len(verdicts) == 1
    v = verdicts[0]
    assert "suspicion" in v.detail["reason"] and ">" in v.detail["reason"]
    assert v.detail["suspicion"] >= 0.3 and v.detail["streak"] >= 3
    assert set(v.detail["observers"]) <= {e.tenant for e in tr.events
                                          if e.tenant}
    assert v.seq > susp[0].seq                 # verdict follows the evidence

    # 3) drain: a gray_drain span on the sick NIC whose CHILDREN are the
    #    forced migrate spans (and any escalation failover) it caused
    drains = [s for s in tr.spans(name="gray_drain") if s.nic == sick]
    assert len(drains) == 1 and drains[0].tick_begin == v.tick
    kids = [s for s in tr.spans() if s.parent_id == drains[0].span_id]
    assert kids and all(k.name in ("migrate", "failover") for k in kids)
    assert any(k.name == "migrate" and k.detail.get("forced") for k in kids)

    # 4) re-admission: the quarantined NIC revives in the repair wave, and
    #    every parked tenant is readmitted — all after the verdict
    revives = [e for e in tr.query(name="revive", kind="fault")
               if sick in (e.nic or "")]
    assert revives and revives[0].tick > v.tick
    parked = tr.query(name="parked", kind="fault")
    readmitted = tr.query(name="readmitted", kind="fault")
    assert {e.tenant for e in parked} == {e.tenant for e in readmitted}
    assert len(readmitted) == rec["readmissions"]

    # the whole story is causally ordered by seq
    chapter = [inj[0].seq, susp[0].seq, v.seq, revives[0].seq]
    assert chapter == sorted(chapter)


# -- measured p99 beside the legacy estimator ---------------------------------

def test_measured_p99_recorded_beside_legacy():
    rt = make_runtime(scenario="bursty", seed=2)
    rt.run(16)
    for tenant in rt.registry.active():
        s = rt.telemetry.series(tenant)
        assert s and all(t.p99_measured_s > 0 for t in s if t.p99_s > 0)
        # the recorded value IS the registry histogram's quantile (the
        # cumulative sample stream), not a copy of the per-tick estimator
        hist = rt.obs.metrics.get("tenant_latency_s", tenant=tenant)
        assert hist is not None and hist.count > 0
        assert s[-1].p99_measured_s == pytest.approx(hist.quantile(0.99))
    summ = rt.telemetry.summary()
    for tenant, row in summ.items():
        assert row["p99_measured_s_max"] > 0
        assert row["p99_s_max"] > 0


# -- telemetry single-pass consistency (satellite 6) --------------------------

def _tick(tick, tenant, ok, grace=False, p99=0.01):
    return TenantTick(tick=tick, tenant=tenant, offered_gbps=1.0,
                      achieved_gbps=1.0, p50_s=p99 / 2, p99_s=p99, units=1,
                      slo_ok=ok, in_grace=grace, p99_measured_s=p99)


def test_summary_and_slo_report_share_warmup_window():
    log = TelemetryLog(warmup_ticks=4)
    for tick in range(10):
        log.record(_tick(tick, "t-a", ok=(tick != 6)))
        log.record(_tick(tick, "t-b", ok=True, grace=(tick == 5)))
    rep = log.slo_report()                     # defaults to warmup_ticks=4
    assert rep["t-a"] == {"ticks": 6, "violations": 1,
                          "violation_frac": pytest.approx(1 / 6),
                          "pass": False}
    assert rep["t-b"]["ticks"] == 5            # grace tick not counted
    assert log.slo_tick_count() == 5 + 5
    summ = log.summary()
    assert summ["t-a"]["ticks"] == 6 and summ["t-b"]["ticks"] == 6
    # explicit override still wins over the shared default
    assert log.slo_report(warmup_ticks=0)["t-a"]["ticks"] == 10
    assert log.summary(warmup_ticks=0)["t-a"]["ticks"] == 10


def test_incremental_grouping_stays_correct_under_interleaving():
    """series()/summary() may be called mid-run; records appended afterwards
    must still land in the one-pass index."""
    log = TelemetryLog()
    log.record(_tick(0, "t-a", ok=True))
    assert len(log.series("t-a")) == 1         # builds the index early
    log.record(_tick(1, "t-a", ok=True))
    log.record(_tick(1, "t-b", ok=False))
    assert [t.tick for t in log.series("t-a")] == [0, 1]
    assert len(log.series("t-b")) == 1
    assert log.summary()["t-a"]["ticks"] == 2
    assert log.slo_report()["t-b"]["violations"] == 1


def test_fault_records_mirror_into_trace():
    obs = Obs()
    log = TelemetryLog(trace=obs.trace)
    log.record_fault(7, "crash", nic="bf2-0", tenant="t-a", detail="boom")
    ev = obs.trace.query(name="crash", kind="fault")
    assert len(ev) == 1 and ev[0].tick == 7 and ev[0].nic == "bf2-0"
    assert ev[0].detail["detail"] == "boom"
    assert log.faults("crash")[0].tenant == "t-a"


# -- gray detector events without a full chaos run ----------------------------

def test_runtime_gray_quarantine_events_match_telemetry(tmp_path):
    """The compact gray scenario from test_faults, seen through the trace:
    the dumped artifact alone carries suspicion -> verdict -> drain."""
    cfg = dataclasses.replace(FAST, gray_detect=True)
    rt = make_runtime(scenario="steady", cfg=cfg, seed=1)
    usage = {}
    for dep in rt.ctrl.deployments.values():
        for n, row in dep.allocation.A.items():
            usage[n] = usage.get(n, 0) + sum(row.values())
    sick = max(usage, key=lambda n: (usage[n], n))
    rt.run(24, chaos=ChaosEngine(FaultPlan(
        [FaultEvent(tick=4, kind=GRAY, nic=sick, fraction=0.25)])))

    art = rt.obs.dump(tmp_path)
    tr = load_trace(art["trace"])
    quarantined = {f.nic for f in rt.telemetry.faults("gray_quarantined")}
    assert {e.nic for e in tr.query(name="gray_quarantined")} == quarantined
    if sick in quarantined:
        assert tr.query(name="quarantine_verdict", nic=sick)
        assert [s for s in tr.spans(name="gray_drain") if s.nic == sick]
