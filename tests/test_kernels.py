"""Per-kernel validation: interpret-mode Pallas vs pure-jnp oracle, swept
over shapes/dtypes; blocked production paths; gradients."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def _rand(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape) * scale, dtype)


# -- flash attention -------------------------------------------------------------

ATTN_SHAPES = [
    # B, Sq, Sk, Hq, Hkv, D
    (1, 64, 64, 1, 1, 32),
    (2, 128, 128, 4, 2, 64),
    (1, 128, 128, 8, 1, 64),      # MQA
    (2, 64, 128, 4, 4, 32),       # cross-length (q suffix)
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("window", [None, 32])
def test_flash_attention_vs_ref(shape, dtype, window):
    B, Sq, Sk, Hq, Hkv, D = shape
    q = _rand((B, Sq, Hq, D), dtype)
    k = _rand((B, Sk, Hkv, D), dtype)
    v = _rand((B, Sk, Hkv, D), dtype)
    want = ref.mha_ref(q, k, v, causal=True, window=window)
    got = ops.attention(q, k, v, causal=True, window=window, impl="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


@pytest.mark.parametrize("block_k", [32, 64, 128])
def test_blocked_attention_matches_ref(block_k):
    q = _rand((2, 128, 4, 32))
    k = _rand((2, 128, 2, 32))
    v = _rand((2, 128, 2, 32))
    want = ref.mha_ref(q, k, v, causal=True)
    got = ops.attention(q, k, v, causal=True, impl="blocked", block_k=block_k)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_blocked_attention_grads_match_ref():
    q = _rand((1, 64, 2, 16))
    k = _rand((1, 64, 1, 16))
    v = _rand((1, 64, 1, 16))

    def loss_blocked(q, k, v):
        return (ops.attention(q, k, v, impl="blocked", block_k=16) ** 2).sum()

    def loss_ref(q, k, v):
        return (ref.mha_ref(q, k, v) ** 2).sum()

    g1 = jax.grad(loss_blocked, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)


def test_windowed_blocked_grads():
    q = _rand((1, 64, 2, 16))
    k = _rand((1, 64, 2, 16))
    v = _rand((1, 64, 2, 16))
    g1 = jax.grad(lambda q: (ops.attention(q, k, v, impl="blocked", window=16,
                                           block_k=32) ** 2).sum())(q)
    g2 = jax.grad(lambda q: (ref.mha_ref(q, k, v, window=16) ** 2).sum())(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-4,
                               rtol=1e-4)


# -- decode attention -------------------------------------------------------------

@pytest.mark.parametrize("B,S,Hq,Hkv,D", [
    (2, 128, 4, 2, 32), (1, 256, 8, 1, 64), (4, 64, 2, 2, 16)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention_vs_ref(B, S, Hq, Hkv, D, dtype):
    q = _rand((B, Hq, D), dtype)
    k = _rand((B, S, Hkv, D), dtype)
    v = _rand((B, S, Hkv, D), dtype)
    kv_len = jnp.asarray(RNG.integers(1, S + 1, size=(B,)), jnp.int32)
    want = ref.decode_ref(q, k, v, kv_len)
    got = ops.decode_attention(q, k, v, kv_len, impl="interpret", block_k=32)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=tol,
                               rtol=tol)


# -- SSD scan -----------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 128, 2, 16, 32, 32), (2, 256, 3, 8, 16, 64), (1, 64, 1, 32, 64, 64)])
def test_ssd_vs_ref(B, S, H, P, N, chunk):
    x = _rand((B, S, H, P), scale=0.5)
    a = jnp.asarray(RNG.uniform(0.5, 0.999, size=(B, S, H)), jnp.float32)
    b = _rand((B, S, H, N), scale=0.3)
    c = _rand((B, S, H, N), scale=0.3)
    y0, h0 = ref.ssd_ref(x, a, b, c)
    for impl in ("interpret", "blocked"):
        y1, h1 = ops.ssd(x, a, b, c, impl=impl, chunk=chunk)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y0), atol=1e-4,
                                   rtol=1e-3)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h0), atol=1e-4,
                                   rtol=1e-3)


def test_ssd_blocked_grads_finite():
    x = _rand((1, 64, 2, 8), scale=0.3)
    a = jnp.asarray(RNG.uniform(0.6, 0.99, size=(1, 64, 2)), jnp.float32)
    b = _rand((1, 64, 2, 16), scale=0.3)
    c = _rand((1, 64, 2, 16), scale=0.3)
    g = jax.grad(lambda x: ops.ssd(x, a, b, c, impl="blocked",
                                   chunk=32)[0].sum())(x)
    assert bool(jnp.isfinite(g).all())


# -- DFA regex ------------------------------------------------------------------------

def test_aho_corasick_counts():
    table, out = ref.build_aho_corasick(["he", "she", "his", "hers"])
    text = b"ushers"
    pay = jnp.asarray(np.frombuffer(text, np.uint8)[None])
    n = ref.dfa_scan(pay, jnp.asarray([len(text)]), jnp.asarray(table),
                     jnp.asarray(out))
    assert int(n[0]) == 3                       # she, he, hers


@pytest.mark.parametrize("B,L,block_b", [(4, 64, 2), (8, 96, 4), (2, 128, 2)])
def test_dfa_kernel_vs_ref(B, L, block_b):
    table, out = ref.build_aho_corasick(["abc", "cab", "bbb"])
    pay = jnp.asarray(RNG.integers(97, 100, size=(B, L)).astype(np.uint8))
    length = jnp.asarray(RNG.integers(1, L + 1, size=(B,)), jnp.int32)
    want = ref.dfa_scan(pay, length, jnp.asarray(table), jnp.asarray(out))
    got = ops.regex_scan(pay, length, table, out, impl="interpret",
                         block_b=block_b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_dfa_respects_length():
    table, out = ref.build_aho_corasick(["xy"])
    pay = jnp.asarray(np.frombuffer(b"xyxyxy", np.uint8)[None])
    for L, expect in [(6, 3), (4, 2), (1, 0)]:
        n = ref.dfa_scan(pay, jnp.asarray([L]), jnp.asarray(table),
                         jnp.asarray(out))
        assert int(n[0]) == expect


# -- crypto ------------------------------------------------------------------------------

def test_cipher_kernel_matches_and_changes_data():
    w = jnp.asarray(RNG.integers(0, 2 ** 32, size=(8, 16),
                                 dtype=np.uint64).astype(np.uint32))
    key = jnp.asarray([1, 2, 3, 4], jnp.uint32)
    want = ref.arx_cipher(w, key)
    got = ops.cipher(w, key, impl="interpret", block_b=4)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert not np.array_equal(np.asarray(got), np.asarray(w))


def test_cipher_key_sensitivity():
    w = jnp.asarray(RNG.integers(0, 2 ** 32, size=(2, 8),
                                 dtype=np.uint64).astype(np.uint32))
    c1 = ref.arx_cipher(w, jnp.asarray([1, 2, 3, 4], jnp.uint32))
    c2 = ref.arx_cipher(w, jnp.asarray([1, 2, 3, 5], jnp.uint32))
    assert not np.array_equal(np.asarray(c1), np.asarray(c2))


def test_hash_kernel_matches():
    w = jnp.asarray(RNG.integers(0, 2 ** 32, size=(8, 32),
                                 dtype=np.uint64).astype(np.uint32))
    key = jnp.asarray([9, 9, 9, 9], jnp.uint32)
    np.testing.assert_array_equal(
        np.asarray(ops.digest(w, key, impl="interpret", block_b=4)),
        np.asarray(ref.keyed_hash(w, key)))
