"""Algorithm 2/3 (locality-aware allocation) — unit + property tests."""
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import pool as pool_mod
from repro.core.allocation import commit, release, resource_alloc
from repro.core.pool import CPU, CRYPTO, REGEX, NicSpec, Pool, paper_cluster


def simple_pool(n=3, cores=8, bw=100.0):
    return Pool([NicSpec(f"n{i}", "x", cores, {}, bw) for i in range(n)])


def test_locality_consolidates_consecutive_stages():
    pool = simple_pool(n=3, cores=8)
    S = ["s1", "s2"]
    alloc = resource_alloc(S, {"s1": 2, "s2": 2}, {"s1": 5.0, "s2": 5.0},
                           pool, {s: CPU for s in S})
    assert alloc.satisfied()
    # both stages fit one NIC -> locality keeps them together
    assert alloc.num_nics_used() == 1
    assert alloc.nics_for("s1") == alloc.nics_for("s2")


def test_spill_when_nic_full():
    pool = simple_pool(n=2, cores=4)
    S = ["s1", "s2"]
    alloc = resource_alloc(S, {"s1": 4, "s2": 3}, {"s1": 1.0, "s2": 1.0},
                           pool, {s: CPU for s in S})
    assert alloc.satisfied()
    assert alloc.num_nics_used() == 2


def test_heterogeneous_isg_needs_pooling():
    """Paper Fig 5: IPsec Gateway is deployable only by pooling BF-2 (regex)
    with Pensando (AES)."""
    pool = paper_cluster(n_bf2=1, n_bf1=0, n_pensando=1)
    S = ["cpu1", "regex", "aes"]
    need = {"cpu1": CPU, "regex": REGEX, "aes": CRYPTO}
    alloc = resource_alloc(S, {s: 1 for s in S}, {s: 5.0 for s in S}, pool,
                           need)
    assert alloc.satisfied()
    assert alloc.nics_for("regex") == ["bf2-0"]
    assert alloc.nics_for("aes") == ["pensando-0"]


def test_bandwidth_cap_limits_allocation():
    """A NIC with tiny bandwidth cannot host high-throughput units
    (Algorithm 3 allocate_on_bw)."""
    pool = Pool([NicSpec("small", "x", 8, {}, bandwidth_gbps=10.0)])
    alloc = resource_alloc(["s1"], {"s1": 8}, {"s1": 5.0}, pool, {"s1": CPU})
    # only floor(10/5)=2 units fit the link
    assert alloc.units("s1") == 2
    assert alloc.unmet["s1"] == 6


def test_colocated_stage_shares_bandwidth():
    """Algorithm 3 lines 10-12: s colocating with s+ re-uses its bandwidth."""
    pool = Pool([NicSpec("n0", "x", 8, {}, bandwidth_gbps=10.0)])
    S = ["s1", "s2"]
    alloc = resource_alloc(S, {"s1": 2, "s2": 2}, {"s1": 5.0, "s2": 5.0},
                           pool, {s: CPU for s in S})
    # s1 consumes the full 10 Gbps; s2 colocates and reclaims the credit.
    assert alloc.units("s1") == 2
    assert alloc.units("s2") == 2


def test_best_effort_on_exhaustion():
    pool = simple_pool(n=1, cores=2)
    alloc = resource_alloc(["s1"], {"s1": 5}, {"s1": 1.0}, pool, {"s1": CPU})
    assert not alloc.satisfied()
    assert alloc.units("s1") == 2
    assert alloc.unmet["s1"] == 3


def test_commit_and_release_roundtrip():
    pool = simple_pool(n=2, cores=4)
    S = ["s1"]
    need = {"s1": CPU}
    t_s = {"s1": 2.0}
    before_free = pool.free_total(CPU)
    before_bw = pool["n0"].free_bw_gbps
    alloc = resource_alloc(S, {"s1": 3}, t_s, pool, need)
    commit(pool, alloc, need)
    assert pool.free_total(CPU) == before_free - 3
    release(pool, alloc, need, t_s)
    assert pool.free_total(CPU) == before_free
    assert pool["n0"].free_bw_gbps == pytest.approx(before_bw)


@given(
    n_nics=st.integers(1, 6), cores=st.integers(1, 16),
    demand=st.integers(0, 64),
    thr=st.floats(0.5, 20.0), bw=st.floats(10.0, 200.0))
@settings(max_examples=150, deadline=None)
def test_property_never_overallocates(n_nics, cores, demand, thr, bw):
    pool = Pool([NicSpec(f"n{i}", "x", cores, {}, bw) for i in range(n_nics)])
    alloc = resource_alloc(["s"], {"s": demand}, {"s": thr}, pool,
                           {"s": CPU})
    placed = alloc.units("s")
    assert placed + alloc.unmet.get("s", 0) == demand
    for n, row in alloc.A.items():
        assert row.get("s", 0) <= cores                   # capacity respected
        assert row.get("s", 0) * thr <= bw + thr          # bw cap (quantized)
    assert all(v >= -1e-9 for v in alloc.bw_after.values())


@given(st.integers(1, 5), st.integers(1, 4))
@settings(max_examples=50, deadline=None)
def test_property_two_stage_locality(n_nics, units):
    """When one NIC can host both stages entirely, Algorithm 2 uses one NIC."""
    pool = Pool([NicSpec(f"n{i}", "x", 2 * units, {}, 1000.0)
                 for i in range(n_nics)])
    S = ["a", "b"]
    alloc = resource_alloc(S, {"a": units, "b": units},
                           {"a": 1.0, "b": 1.0}, pool, {s: CPU for s in S})
    assert alloc.satisfied()
    assert alloc.num_nics_used() == 1
