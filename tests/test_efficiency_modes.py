"""Deployment-mode comparator: pooled vs standalone vs microservice, the
acceptance ratios, and the bench_service smoke path."""
import json

import pytest

from repro.core.pool import CPU, paper_cluster
from repro.service.efficiency import (MODES, provision_standalone,
                                      run_comparison)
from repro.service.runtime import RuntimeConfig
from repro.service.tenants import default_tenant_mix

FAST = RuntimeConfig(dataplane_every=0, max_sim_seqs=32)


@pytest.fixture(scope="module")
def comparison():
    return run_comparison(ticks=40, cfg=FAST, seed=0)


def test_efficiency_ratios_meet_paper_bars(comparison):
    r = comparison["ratios"]
    assert r["pooled_vs_standalone"] >= 2.0, r
    assert r["pooled_vs_microservice"] >= 1.2, r


def test_all_slos_pass_in_every_mode(comparison):
    for scenario, rec in comparison["scenarios"].items():
        for mode in MODES:
            assert rec[mode]["slo_pass"], (scenario, mode, rec[mode]["slo"])


def test_failover_drops_no_tenant(comparison):
    fo = comparison["scenarios"]["bursty"]["failover"]
    assert fo["survived"]
    assert fo["tenants_alive_after"] == len(comparison["tenants"])
    assert fo["failed_nic"] is not None
    assert fo["impacted"]          # the busiest NIC hosted someone


def test_reserved_ordering(comparison):
    # standalone pays whole NICs; microservice pays fixed peak; pooled
    # breathes below both.
    for rec in comparison["scenarios"].values():
        pooled = rec["pooled"]["reserved_units_mean"]
        micro = rec["microservice"]["reserved_units_mean"]
        alone = rec["standalone"]["reserved_units_mean"]
        assert pooled < micro < alone


def test_standalone_provisioner_covers_resource_kinds():
    inventory = [st.spec for st in paper_cluster().nics.values()]
    isg = next(s for s in default_tenant_mix() if s.name == "t-isg")
    ctrl, taken = provision_standalone(isg, inventory)
    dep = ctrl.deployments["t-isg"]
    assert dep.allocation.satisfied()
    kinds_needed = {r for r in isg.app.resource_needs().values() if r != CPU}
    kinds_have = {k for n in taken for k, c in n.accelerators.items() if c > 0}
    assert kinds_needed <= kinds_have
    # the mixed accel demand (regex + crypto) forces a multi-NIC dedication
    assert len(taken) >= 2


def test_standalone_provisioner_handles_exhausted_inventory():
    isg = next(s for s in default_tenant_mix() if s.name == "t-isg")
    ctrl, taken = provision_standalone(isg, [])
    assert taken == []
    dep = ctrl.deployments["t-isg"]      # deployment exists, fully unmet
    assert not dep.allocation.satisfied()
    assert dep.achievable_gbps == 0.0


def test_bench_service_fast_writes_json(tmp_path, capsys):
    from benchmarks import bench_service
    out = tmp_path / "BENCH_service.json"
    bench_service.main(["--fast", "--out", str(out)])
    payload = json.loads(out.read_text())
    assert payload["pass"] is True
    assert payload["fast"] is True
    assert set(payload["efficiency"]) == set(MODES)
    assert payload["ratios"]["pooled_vs_standalone"] >= 2.0
    assert payload["ratios"]["pooled_vs_microservice"] >= 1.2
    # the --fast smoke covers both QoS scenarios (ISSUE 4)
    assert payload["qos"]["pass"] is True
    assert payload["qos"]["isolation"]["innocents_broken_off"]
    assert payload["adversarial_churn"]["pass"] is True
    # ...and the chaos fault-injection A/B (ISSUE 6): recovery-on dominates.
    assert payload["chaos"]["pass"] is True
    on, off = payload["chaos"]["recovery_on"], payload["chaos"]["recovery_off"]
    assert on["slo_ticks"] > off["slo_ticks"]
    assert len(on["permanent_evictions"]) < len(off["permanent_evictions"])
    assert on["still_parked"] == []
    rows = capsys.readouterr().out
    assert "service_eff_pooled" in rows
    assert "service_qos" in rows
    assert "service_adversarial_churn" in rows
    assert "service_chaos" in rows
