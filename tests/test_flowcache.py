"""Megaflow flow cache (ISSUE 9): equivalence, bounding, observability.

The load-bearing property: a TrafficOrchestrator with a flow cache is
BYTE-IDENTICAL to one without — same per-packet assign array, same
flow/spill tables, same per-pipeline loads — across arbitrary interleavings
of churning traffic, migration begin/finish, pipeline halt (failover) and
scale-out, including halted-flow buffering and the saturation regimes where
the fast path falls back. The cache may only change WHEN the answer is
computed, never what it is.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.apps.packets import pareto_flow_weights, synth_packets_weighted
from repro.core.flowcache import FlowCache, FlowCacheConfig
from repro.core.orchestrator import TrafficOrchestrator
from repro.obs.trace import DecisionTrace

from tests._hypothesis_shim import given, settings, st

NPIPE = 4


def _pair(cap, *, capacity=1 << 10, backend="numpy", table_cap=None,
          trace=None, idle_ttl=4096, expire_every=256):
    """(cache-on, cache-off) orchestrators with identical topology."""
    fc = FlowCache(FlowCacheConfig(capacity=capacity, backend=backend,
                                   idle_ttl=idle_ttl,
                                   expire_every=expire_every))
    a = TrafficOrchestrator(num_pipelines=NPIPE, capacity_per_pipeline=cap,
                            flow_cache=fc, table_cap=table_cap, trace=trace)
    b = TrafficOrchestrator(num_pipelines=NPIPE, capacity_per_pipeline=cap)
    return a, b


def _batch(t, *, batch=96, num_flows=300, drift=0, seed=7):
    w = pareto_flow_weights(num_flows, 1.2, seed=seed)
    return synth_packets_weighted(batch=batch, num_flows=num_flows,
                                  weights=w, seed=(seed, 0, t), pkt_bytes=64,
                                  flow_base=drift)


def _assert_same(a, b, ctx):
    assert a.flow_table == b.flow_table, ctx
    assert a.spill_table == b.spill_table, ctx
    la = [p.load for p in a.pipelines]
    lb = [p.load for p in b.pipelines]
    assert la == lb, (ctx, la, lb)
    assert sorted(a.halted_flows) == sorted(b.halted_flows), ctx


def _run_script(cap, script, ticks=40, churn=11):
    """Drive both orchestrators through `ticks` rounds of churning traffic,
    applying the event script {tick: (op, ...)} to BOTH; assert equality
    after every round."""
    a, b = _pair(cap)
    mig = []
    for t in range(ticks):
        for op in script.get(t, ()):
            if op == "migrate" and a.flow_table:
                f = sorted(a.flow_table)[len(a.flow_table) // 2]
                a.begin_migration(f), b.begin_migration(f)
                mig.append(f)
            elif op == "finish" and mig:
                f = mig.pop()
                dst = a._round % NPIPE
                a.finish_migration(f, dst), b.finish_migration(f, dst)
            elif op == "halt":
                live = [p.pid for p in a.pipelines if p.active]
                if len(live) > 1:
                    a.halt_pipeline(live[-1]), b.halt_pipeline(live[-1])
            elif op == "add":
                a.add_pipeline(cap), b.add_pipeline(cap)
        batch = _batch(t, drift=churn * t)
        ra = a.partition_assign(batch)
        rb = b.partition_assign(batch)
        np.testing.assert_array_equal(ra, rb, err_msg=f"tick {t}")
        _assert_same(a, b, f"tick {t}")
    return a, b


# -- equivalence ---------------------------------------------------------------

def test_equivalent_under_churn_roomy():
    a, _ = _run_script(256.0, {})
    # Roomy capacity: the fast path must actually engage, not fall back.
    assert a.fast_stats["fast_batches"] > 30
    assert a.fast_stats["fallbacks"] == 0
    assert a.fast_stats["hit_flows"] > 0


def test_equivalent_under_events():
    script = {5: ("migrate",), 9: ("finish",), 12: ("halt",),
              17: ("migrate", "halt"), 20: ("finish",), 24: ("add",),
              30: ("migrate",), 34: ("finish",)}
    a, _ = _run_script(96.0, script, ticks=40)
    assert a.fast_stats["fast_batches"] > 0


def test_equivalent_at_saturation_with_fallbacks():
    # Tight capacity: hits overcommit, the fast path must detect it and
    # defer to a pristine slow run (equality asserted inside _run_script).
    a, _ = _run_script(26.0, {8: ("halt",)}, ticks=30)
    assert a.fast_stats["fallbacks"] > 0


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_equivalence_property_random_scripts(seed):
    rng = np.random.default_rng(seed)
    cap = float(rng.choice([24, 48, 96, 256]))
    script = {}
    for t in sorted(rng.choice(28, size=6, replace=False).tolist()):
        script[t] = tuple(rng.choice(
            ["migrate", "finish", "halt", "add"],
            size=rng.integers(1, 3)).tolist())
    _run_script(cap, script, ticks=28, churn=int(rng.integers(0, 23)))


def test_halted_flow_buffering_identical():
    a, b = _pair(128.0)
    batch = _batch(0)
    a.partition_assign(batch), b.partition_assign(batch)
    f = sorted(a.flow_table)[0]
    a.begin_migration(f), b.begin_migration(f)
    for t in range(1, 4):
        nb = _batch(t)
        ra, rb = a.partition_assign(nb), b.partition_assign(nb)
        np.testing.assert_array_equal(ra, rb)
    ka, kb = a.halted_flows.get(f, []), b.halted_flows.get(f, [])
    assert len(ka) == len(kb)
    for sa, sb in zip(ka, kb):
        np.testing.assert_array_equal(sa.indices, sb.indices)
    a.finish_migration(f, 1), b.finish_migration(f, 1)
    _assert_same(a, b, "post-finish")


# -- state bounding (satellite a) ---------------------------------------------

def test_flow_table_bounded_under_churn():
    fc = FlowCache(FlowCacheConfig(capacity=1 << 8, backend="numpy",
                                   idle_ttl=16, expire_every=8))
    to = TrafficOrchestrator(num_pipelines=NPIPE, capacity_per_pipeline=256.0,
                            flow_cache=fc, table_cap=200)
    for t in range(60):
        to.partition_assign(_batch(t, drift=40 * t, num_flows=120))
        assert len(to.flow_table) <= 200, t
    assert to.fast_stats["pruned"] > 0
    assert fc.occupancy() <= fc.capacity


def test_idle_expiry_clears_departed_flows():
    # No table_cap: idle expiry alone (not pruning) must clear entries for
    # flows that churned out of the window.
    fc = FlowCache(FlowCacheConfig(capacity=1 << 9, backend="numpy",
                                   idle_ttl=8, expire_every=4))
    to = TrafficOrchestrator(num_pipelines=NPIPE, capacity_per_pipeline=256.0,
                            flow_cache=fc)
    for t in range(40):
        to.partition_assign(_batch(t, drift=60 * t, num_flows=80))
    assert to.fast_stats["expired"] > 0
    assert fc.stats["expirations"] > 0


def test_expired_flow_returning_replaces_correctly():
    fc = FlowCache(FlowCacheConfig(capacity=1 << 8, backend="numpy",
                                   idle_ttl=4, expire_every=2))
    to = TrafficOrchestrator(num_pipelines=NPIPE, capacity_per_pipeline=256.0,
                            flow_cache=fc, table_cap=64)
    ref = TrafficOrchestrator(num_pipelines=NPIPE,
                              capacity_per_pipeline=256.0)
    b0 = _batch(0, num_flows=40)
    to.partition_assign(b0), ref.partition_assign(b0)
    # Long absence: idle expiry + table pruning forget the early flows.
    for t in range(1, 30):
        to.partition_assign(_batch(t, drift=500 + 40 * t, num_flows=40))
    # The returning batch re-places from scratch — placement must follow
    # the current (empty-for-these-flows) tables, identically to a fresh
    # orchestrator in the same load state.
    for p_to, p_ref in zip(to.pipelines, ref.pipelines):
        p_to.load = p_ref.load = 0.0
    ref.flow_table.clear(), ref.spill_table.clear()
    to.flow_table.clear(), to.spill_table.clear()
    back = _batch(0, num_flows=40)
    np.testing.assert_array_equal(to.partition_assign(back),
                                  ref.partition_assign(back))


# -- observability (satellite b) ----------------------------------------------

def test_trace_explains_placements_and_cache_batches():
    trace = DecisionTrace()
    fc = FlowCache(FlowCacheConfig(capacity=1 << 9, backend="numpy"))
    to = TrafficOrchestrator(num_pipelines=NPIPE, capacity_per_pipeline=256.0,
                            flow_cache=fc, trace=trace)
    for t in range(3):
        to.partition_assign(_batch(t, drift=10 * t), tenant="t-cdn")
    names = [e.name for e in trace.events]
    assert "slow_path_place" in names
    assert "flow_cache_batch" in names
    place = next(e for e in trace.events if e.name == "slow_path_place")
    assert place.detail["reason"] in ("new_flow", "cache_evicted",
                                      "stale_epoch", "inactive_home")
    assert place.detail["pipeline"] >= 0
    assert place.tenant == "t-cdn"


def test_invalidation_reasons_counted():
    a, _ = _pair(128.0)
    a.partition_assign(_batch(0))
    fc = a.flow_cache
    e0 = fc.epoch
    f = sorted(a.flow_table)[0]
    a.begin_migration(f)
    a.finish_migration(f, 2)
    live = [p.pid for p in a.pipelines if p.active]
    a.halt_pipeline(live[-1])
    assert fc.epoch == e0 + 3          # begin + finish + halt each bump
    assert fc.stats["invalidations"] == 3


def test_device_mirror_consistent_after_mutations():
    fc = FlowCache(FlowCacheConfig(capacity=1 << 8, backend="jnp"))
    rng = np.random.default_rng(0)
    fids = rng.choice(1 << 40, size=150, replace=False).astype(np.int64)
    fc.record(fids, rng.integers(0, NPIPE, 150).astype(np.int64), 1)
    fc.lookup(fids)                    # flush pending scatters
    assert fc.check_device_mirror()
    fc.delete(fids[:50])
    fc.invalidate("test")
    fc.record(fids[50:100], np.ones(50, np.int64), 2)
    fc.lookup(fids)
    assert fc.check_device_mirror()


# -- benchmark smoke (satellite e) --------------------------------------------

def test_bench_megaflow_fast_smoke():
    from benchmarks import bench_megaflow
    rows = bench_megaflow.run(emit=lambda *_: None, fast=True)
    assert rows and rows[0]["fast"]
    r = rows[0]
    assert r["hit_rate_pkts"] > 0.5
    assert r["fallbacks"] == 0
    assert r["cache_us_per_call"] > 0 and r["slow_us_per_call"] > 0
