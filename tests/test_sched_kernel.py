"""Kernel-vs-oracle property tests (ISSUE 8): every kernel in
``core.sched_kernel`` is checked against the pinned scalar reference in
``core.qos`` / ``service.telemetry`` over randomized inputs.

Hypothesis is optional (see ``tests/_hypothesis_shim``): the ``@given``
variants skip without it, so each property also runs as a seeded-random
loop that executes everywhere. f32 kernel vs f64 scalar means comparisons
are tolerance-based, never bit-exact — the tolerance is the contract.
"""
from __future__ import annotations

import random
import time

import numpy as np
import pytest

from repro.core import sched_kernel as sk
from repro.core.qos import ResourceGovernor, TenantQuota
from tests._hypothesis_shim import given, settings, st

import jax.numpy as jnp

# Relative tolerance for f32 kernel vs f64 scalar on O(1e4)-byte budgets.
RTOL = 5e-4
ATOL = 1e-2


def _mk_gov(weights, **quota_kw):
    gov = ResourceGovernor()
    for t, w in weights.items():
        gov.register(t, TenantQuota(weight=w, **quota_kw))
    return gov


def _rand_case(rng, n):
    names = [f"t{i:02d}" for i in range(n)]
    weights = {t: rng.choice([0.5, 1.0, 1.0, 2.0, 3.0, 5.0]) for t in names}
    queues = {t: rng.uniform(0.0, 20000.0) for t in names}
    caps = {t: rng.choice([rng.uniform(100.0, 15000.0), float("inf")])
            for t in names}
    return names, weights, queues, caps


def _assert_equivalent(order_s, served_s, order_k, served_k, budget,
                       weights, check_order=True):
    assert set(order_s) == set(order_k)
    # f32 kernel vs f64 scalar: where the budget truncates the final round
    # can land one visit position apart, redistributing at most ~one round's
    # deficit earn (quantum * weight) between adjacent rows — the natural
    # service granularity of DWRR. Totals conserve either way (asserted by
    # the caller); per-tenant service agrees to that granularity.
    total_w = sum(weights.values()) or 1.0
    quantum = budget / (8.0 * total_w)
    for t in served_s:
        tol = max(ATOL, 1.05 * quantum * weights[t] + RTOL * served_s[t])
        assert abs(served_k[t] - served_s[t]) <= tol, (
            t, served_s[t], served_k[t], tol)
    # Dispatch order (stamped at each row's FIRST take, early rounds where
    # drift is negligible) must agree for substantively-served tenants.
    # Only asserted from fresh ring state: once an f32-vs-f64 budget
    # boundary shifts the tail-round count by one, the two rings rotate out
    # of phase and orders legitimately differ (both remain valid DWRR
    # rotations; service equivalence above still holds).
    if not check_order:
        return
    floor = max(ATOL, 1e-3 * budget)
    sub_s = [t for t in order_s if served_s[t] > floor]
    sub_k = [t for t in order_k if served_s[t] > floor]
    assert sub_s == sub_k


# -- capped DWRR ---------------------------------------------------------------

def test_dwrr_capped_matches_scalar_seeded():
    rng = random.Random(42)
    for case in range(25):
        n = rng.randint(1, 24)
        names, weights, queues, caps = _rand_case(rng, n)
        budget = rng.uniform(100.0, 50000.0)

        scalar = _mk_gov(weights)
        o_s, s_s = scalar.dwrr_schedule(dict(queues), dict(caps),
                                        capacity_bytes=budget)
        kern = _mk_gov(weights)
        kern.attach_kernel(sk.VectorizedScheduler())
        o_k, s_k = kern.dwrr_schedule(dict(queues), dict(caps),
                                      capacity_bytes=budget)
        _assert_equivalent(o_s, s_s, o_k, s_k, budget, weights)
        # Conservation: never serve more than budget or demand.
        assert sum(s_k.values()) <= budget * (1 + RTOL) + ATOL
        for t in names:
            assert s_k[t] <= queues[t] * (1 + RTOL) + ATOL
            assert s_k[t] <= caps[t] * (1 + RTOL) + ATOL


def test_dwrr_capped_multi_tick_static_membership():
    """Deficits and the ring offset persist across ticks: a multi-tick
    sequence with static membership stays equivalent, not just tick one."""
    rng = random.Random(7)
    names, weights, _, _ = _rand_case(rng, 9)
    scalar = _mk_gov(weights)
    kern = _mk_gov(weights)
    kern.attach_kernel(sk.VectorizedScheduler())
    for tick in range(12):
        queues = {t: rng.uniform(0.0, 8000.0) for t in names}
        caps = {t: rng.uniform(500.0, 6000.0) for t in names}
        budget = rng.uniform(2000.0, 20000.0)
        o_s, s_s = scalar.dwrr_schedule(dict(queues), dict(caps),
                                        capacity_bytes=budget)
        o_k, s_k = kern.dwrr_schedule(dict(queues), dict(caps),
                                      capacity_bytes=budget)
        _assert_equivalent(o_s, s_s, o_k, s_k, budget, weights,
                           check_order=(tick == 0))


def test_dwrr_weights_shape_longrun_share():
    """Weights 2:1:1 converge to ~2:1:1 served bytes under saturation —
    the classic DRR property, on the kernel path."""
    weights = {"a": 2.0, "b": 1.0, "c": 1.0}
    gov = _mk_gov(weights)
    gov.attach_kernel(sk.VectorizedScheduler())
    tot = {t: 0.0 for t in weights}
    for _ in range(50):
        _, served = gov.dwrr_schedule(
            {t: 1e6 for t in weights}, None, capacity_bytes=4000.0)
        for t, v in served.items():
            tot[t] += v
    assert tot["a"] / tot["b"] == pytest.approx(2.0, rel=0.05)
    assert tot["b"] / tot["c"] == pytest.approx(1.0, rel=0.05)


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=16),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_dwrr_capped_matches_scalar_hypothesis(n, seed):
    rng = random.Random(seed)
    names, weights, queues, caps = _rand_case(rng, n)
    budget = rng.uniform(100.0, 50000.0)
    scalar = _mk_gov(weights)
    o_s, s_s = scalar.dwrr_schedule(dict(queues), dict(caps),
                                    capacity_bytes=budget)
    kern = _mk_gov(weights)
    kern.attach_kernel(sk.VectorizedScheduler())
    o_k, s_k = kern.dwrr_schedule(dict(queues), dict(caps),
                                  capacity_bytes=budget)
    _assert_equivalent(o_s, s_s, o_k, s_k, budget, weights)


# -- uncapped (order-only) mode ------------------------------------------------

def test_dwrr_uncapped_matches_scalar_seeded():
    rng = random.Random(11)
    for _ in range(20):
        n = rng.randint(1, 20)
        names, weights, queues, caps = _rand_case(rng, n)
        scalar = _mk_gov(weights)
        o_s, s_s = scalar.dwrr_schedule(dict(queues), dict(caps),
                                        capacity_bytes=None)
        kern = _mk_gov(weights)
        kern.attach_kernel(sk.VectorizedScheduler())
        o_k, s_k = kern.dwrr_schedule(dict(queues), dict(caps),
                                      capacity_bytes=None)
        # Order-only mode has no sequential budget: order is an exact sort,
        # so it must match the scalar exactly (ties break by name).
        assert o_s == o_k
        for t in names:
            assert s_k[t] == pytest.approx(s_s[t], rel=RTOL, abs=ATOL)


def test_dwrr_uncapped_tie_break_by_name():
    weights = {"z": 1.0, "a": 1.0, "m": 1.0}
    gov = _mk_gov(weights)
    gov.attach_kernel(sk.VectorizedScheduler())
    order, served = gov.dwrr_schedule({t: 100.0 for t in weights},
                                      {t: 50.0 for t in weights},
                                      capacity_bytes=None)
    assert order == ["a", "m", "z"]
    assert served == {t: pytest.approx(50.0) for t in weights}


# -- scale_decisions vs scale_verdict ------------------------------------------

def _scale_case(rng, brownout):
    n = rng.randint(1, 12)
    names = [f"s{i:02d}" for i in range(n)]
    weights = {t: rng.choice([1.0, 2.0, 4.0]) for t in names}
    quota = {t: rng.choice([None, rng.uniform(5.0, 30.0)]) for t in names}
    burst = {t: rng.choice([0.0, rng.uniform(1.0, 8.0)]) for t in names}
    gov = ResourceGovernor()
    for t in names:
        gov.register(t, TenantQuota(weight=weights[t], max_gbps=quota[t],
                                    burst_gbps=burst[t]))
    if brownout:
        gov.set_brownout(rng.uniform(0.2, 0.8))
    gov.begin_tick(active=names)
    rows = {t: dict(est_gbps=rng.uniform(0.0, 40.0),
                    offered_gbps=rng.uniform(0.0, 40.0),
                    contract_gbps=rng.uniform(5.0, 25.0),
                    current_gbps=rng.uniform(0.0, 30.0),
                    achievable_gbps=rng.uniform(1.0, 30.0))
            for t in names}
    return gov, names, rows


def _run_scale_both(gov, names, rows):
    # Kernel inputs snapshot BEFORE the scalar calls mutate credits.
    creds = np.array([gov.credits.get(t, 0.0) for t in names],
                     dtype=np.float32)
    quota = np.array([gov.quota(t).max_gbps
                      if gov.quota(t).max_gbps is not None else np.inf
                      for t in names], dtype=np.float32)
    w = np.array([gov.weight(t) for t in names], dtype=np.float32)
    wmax = max((q.weight for q in gov.quotas.values()), default=1.0)
    blevel = gov._brownout if gov._brownout is not None else 1.0
    cols = {k: np.array([rows[t][k] for t in names], dtype=np.float32)
            for k in ("est_gbps", "offered_gbps", "contract_gbps",
                      "current_gbps", "achievable_gbps")}
    granted, rescale, pressure, browned, _ = sk.scale_decisions(
        jnp.asarray(cols["est_gbps"]), jnp.asarray(cols["offered_gbps"]),
        jnp.asarray(cols["contract_gbps"]), jnp.asarray(cols["current_gbps"]),
        jnp.asarray(cols["achievable_gbps"]), jnp.asarray(quota),
        jnp.asarray(creds), jnp.asarray(w), jnp.float32(blevel),
        jnp.float32(wmax), jnp.float32(1.15), jnp.float32(0.2),
        jnp.float32(gov.pressure_frac), jnp.float32(0.1))
    verdicts = [gov.scale_verdict(t, **rows[t]) for t in names]
    return (np.asarray(granted), np.asarray(rescale), np.asarray(pressure),
            np.asarray(browned), verdicts)


@pytest.mark.parametrize("brownout", [False, True])
def test_scale_decisions_matches_scale_verdict(brownout):
    rng = random.Random(97 + brownout)
    for case in range(20):
        gov, names, rows = _scale_case(rng, brownout)
        granted, rescale, pressure, browned, verdicts = _run_scale_both(
            gov, names, rows)
        for i, (t, v) in enumerate(zip(names, verdicts)):
            assert float(granted[i]) == pytest.approx(
                v.target_gbps, rel=1e-4, abs=1e-4), (case, t)
            assert bool(rescale[i]) == v.rescale, (case, t)
            assert bool(pressure[i]) == v.pressure, (case, t)
            assert bool(browned[i]) == v.brownout, (case, t)


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1),
       st.booleans())
def test_scale_decisions_matches_scale_verdict_hypothesis(seed, brownout):
    rng = random.Random(seed)
    gov, names, rows = _scale_case(rng, brownout)
    granted, rescale, pressure, browned, verdicts = _run_scale_both(
        gov, names, rows)
    for i, v in enumerate(verdicts):
        assert float(granted[i]) == pytest.approx(
            v.target_gbps, rel=1e-4, abs=1e-4)
        assert bool(rescale[i]) == v.rescale


# -- burst refill / queue drain ------------------------------------------------

def test_refill_credits_matches_begin_tick():
    rng = random.Random(3)
    names = [f"b{i}" for i in range(16)]
    depth = {t: rng.choice([0.0, rng.uniform(1.0, 10.0)]) for t in names}
    refill = {t: rng.uniform(0.1, 3.0) for t in names}
    gov = ResourceGovernor()
    for t in names:
        gov.register(t, TenantQuota(burst_gbps=depth[t],
                                    burst_refill_gbps=refill[t]))
        gov.credits[t] = rng.uniform(0.0, depth[t]) if depth[t] else 0.0
    before = np.array([gov.credits[t] for t in names], dtype=np.float32)
    out = sk.refill_credits(
        jnp.asarray(before),
        jnp.asarray(np.array([depth[t] for t in names], dtype=np.float32)),
        jnp.asarray(np.array([refill[t] for t in names], dtype=np.float32)))
    gov.begin_tick(active=names)
    for i, t in enumerate(names):
        assert float(out[i]) == pytest.approx(gov.credits[t],
                                              rel=1e-6, abs=1e-6)


def test_queue_drain_matches_measure_math():
    """queue_drain reproduces measure_tenant_tick's arrival/serve/carry
    arithmetic (lines it was lifted from) for random loads."""
    rng = random.Random(5)
    for _ in range(40):
        off = rng.uniform(0.0, 2e6)
        back = rng.uniform(0.0, 5e4)
        cap = rng.uniform(0.0, 2e6)
        grant = rng.choice([np.inf, rng.uniform(0.0, 1e5)])
        dt = 0.1
        arriving = off * dt + back
        served_ref = min(arriving, cap * dt, grant)
        served, new_back, ach = sk.queue_drain(
            jnp.float32(off), jnp.float32(back), jnp.float32(cap),
            jnp.float32(grant), jnp.float32(dt))
        assert float(served) == pytest.approx(served_ref, rel=1e-5, abs=1e-2)
        assert float(new_back) == pytest.approx(arriving - served_ref,
                                                rel=1e-4, abs=0.5)
        assert float(ach) == pytest.approx(served_ref / dt, rel=1e-5,
                                           abs=1e-1)


# -- telemetry reduction -------------------------------------------------------

def test_telemetry_reduce_matches_dict_loop():
    rng = random.Random(13)
    tenants = ["a", "b", "c", "d"]
    recs = [(rng.choice(tenants), rng.uniform(0, 10), rng.uniform(0, 5))
            for _ in range(200)]
    idx = np.array([tenants.index(t) for t, _, _ in recs])
    off = np.array([o for _, o, _ in recs])
    p99 = np.array([p for _, _, p in recs])
    counts, means, maxes = sk.telemetry_reduce_np(
        idx, len(tenants), {"off": off}, {"p99": p99})
    for i, t in enumerate(tenants):
        mine = [(o, p) for tt, o, p in recs if tt == t]
        assert counts[i] == len(mine)
        assert means["off"][i] == pytest.approx(
            sum(o for o, _ in mine) / len(mine))
        assert maxes["p99"][i] == pytest.approx(max(p for _, p in mine))


def test_telemetry_reduce_handles_absent_tenant():
    counts, means, maxes = sk.telemetry_reduce_np(
        np.array([0, 0]), 2, {"x": np.array([1.0, 3.0])},
        {"y": np.array([2.0, 4.0])})
    assert counts[1] == 0 and means["x"][1] == 0.0
    assert maxes["y"][1] == -np.inf


# -- padding / recompile discipline --------------------------------------------

def test_pad_rows_pow2():
    assert sk.pad_rows(1) == 8
    assert sk.pad_rows(8) == 8
    assert sk.pad_rows(9) == 16
    assert sk.pad_rows(100) == 128


def test_churn_repads_without_retracing():
    """Tenant churn inside one pow-2 bucket must not retrace dwrr_step;
    crossing a bucket boundary traces exactly once more."""
    # max_rounds is a static jit arg: an unusual value gives this test its
    # own compile-cache entries, isolating it from shapes other tests (or
    # the same process's earlier ticks) already compiled.
    sched = sk.VectorizedScheduler(max_rounds=997)

    def tick(names):
        w = {t: 1.0 for t in names}
        sched.schedule({t: 100.0 for t in names}, None, 1000.0, weights=w)

    names = [f"c{i:02d}" for i in range(5)]
    tick(names)
    sk.reset_trace_counts()
    tick(names[:4])          # churn within the 8-row bucket
    tick(names)              # and back
    assert sk.trace_counts().get("dwrr_step", 0) == 0
    tick([f"c{i:02d}" for i in range(9)])   # 8 -> 16 rows: one retrace
    assert sk.trace_counts().get("dwrr_step", 0) == 1


def test_fast_smoke_200_tenants_tick_budget_and_zero_recompiles():
    """Tier-1 smoke (ISSUE 8): a 200-tenant tick on the vectorized path
    stays under a generous host-time budget with zero steady-state
    recompiles."""
    n = 200
    weights = {f"m{i:03d}": float(1 + i % 4) for i in range(n)}
    gov = _mk_gov(weights)
    gov.attach_kernel(sk.VectorizedScheduler())
    rng = random.Random(0)

    def one_tick():
        q = {t: rng.uniform(0.0, 1e5) for t in weights}
        caps = {t: 5e4 for t in weights}
        gov.dwrr_schedule(q, caps, capacity_bytes=2e6)

    one_tick()                      # warmup: compile
    sk.reset_trace_counts()
    t0 = time.perf_counter()
    ticks = 30
    for _ in range(ticks):
        one_tick()
    per_tick = (time.perf_counter() - t0) / ticks
    assert sk.trace_counts() == {}, "steady-state recompile detected"
    assert per_tick < 0.05, f"tick cost {per_tick*1e3:.1f} ms over budget"
