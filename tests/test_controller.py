"""MeiliController: demand formula, submit/scale/failover lifecycle."""
import pytest

from repro.core import replication as repl
from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster
from repro.core.profiler import synthetic_profile
from repro.apps import ALL_APPS

BITS = 1500 * 8 * 256.0
ISG_LAT = {"ddos_check": 400e-6, "url_check": 300e-6, "ipsec_encap": 150e-6,
           "sha": 250e-6, "aes": 350e-6}


def make_ctrl():
    return MeiliController(paper_cluster())


def isg_profile():
    app = ALL_APPS(impl="ref")["ISG"]
    return app, synthetic_profile(app.stage_names(), ISG_LAT, BITS)


def test_demand_formula_matches_paper():
    ctrl = make_ctrl()
    app, prof = isg_profile()
    R, r_s, t_R = ctrl.demand(prof, target_gbps=2 * t_R_of(prof))
    n_groups = int(2 * t_R_of(prof) // t_R)
    for s in prof.stages:
        assert r_s[s] >= R[s] * n_groups


def t_R_of(prof):
    R = repl.num_replication(prof.stages, prof.l_s)
    rate = repl.pipeline_throughput(prof.stages, prof.l_s, R)
    return rate * prof.batch_bits() / 1e9


def test_submit_meets_small_target():
    ctrl = make_ctrl()
    app, prof = isg_profile()
    dep = ctrl.submit(app, target_gbps=5.0, profile=prof)
    assert dep.achievable_gbps >= 5.0
    assert dep.allocation.satisfied()
    # heterogeneity: regex on a bf2, aes on a pensando
    assert all(n.startswith("bf2") for n in dep.allocation.nics_for("url_check"))
    assert all(n.startswith("pensando")
               for n in dep.allocation.nics_for("aes"))


def test_adaptive_scale_up_and_down():
    ctrl = make_ctrl()
    app, prof = isg_profile()
    ctrl.submit(app, target_gbps=5.0, profile=prof)
    dep = ctrl.adaptive_scale(app.name, 10.0)
    assert dep.achievable_gbps >= 10.0
    units_up = dict(dep.r_s)
    dep = ctrl.adaptive_scale(app.name, 3.0)
    assert dep.achievable_gbps >= 3.0
    assert sum(dep.r_s.values()) <= sum(units_up.values())


def test_failover_replaces_lost_units():
    ctrl = make_ctrl()
    app, prof = isg_profile()
    dep = ctrl.submit(app, target_gbps=5.0, profile=prof)
    nic = dep.allocation.nics_for("aes")[0]
    impacted = ctrl.handle_failure(nic)
    assert app.name in impacted
    dep2 = ctrl.deployments[app.name]
    assert nic not in dep2.allocation.nics_for("aes")
    assert dep2.allocation.units("aes") >= 1
    assert any(e["event"] == "failover" for e in ctrl.events)


def test_failover_meets_recomputed_targets_and_restores_state():
    """Appendix D end-to-end: after handle_failure the surviving placement
    must still meet the deployment's target, every stage must keep >= its
    pre-failure unit count, and state that lived ONLY on the failed NIC must
    be reachable from every surviving NIC via the replicated snapshot."""
    ctrl = make_ctrl()
    app, prof = isg_profile()
    app.declare_state("isg_sa_table", "full-access")
    dep = ctrl.submit(app, target_gbps=5.0, profile=prof, backup_nic="bf1-0")
    units_before = {s: dep.allocation.units(s) for s in prof.stages}

    victim = dep.allocation.nics_for("aes")[0]
    # State written only on the soon-to-fail NIC (non-external-write style),
    # then the periodic Appendix-D replication snapshots it to the backup.
    ctrl.state.ne_set("isg_sa_table", 0xC0FFEE, local=victim)
    ctrl.replicate_for_failover(app.name)
    assert dep.state_snapshot == {"isg_sa_table": 0xC0FFEE}

    ctrl.handle_failure(victim)
    dep2 = ctrl.deployments[app.name]
    # the recomputed placement fully replaces the lost units...
    failover_ev = [e for e in ctrl.events if e["event"] == "failover"][-1]
    assert failover_ev["unmet"] == {}
    for s in prof.stages:
        assert dep2.allocation.units(s) >= units_before[s], s
        assert victim not in dep2.allocation.nics_for(s), s
    # ...and still meets the target
    assert dep2.achievable_gbps >= dep2.target_gbps
    # migrated units can reach the restored state from every surviving NIC
    for nic in ctrl.pool.names():
        assert ctrl.state.get("isg_sa_table", local=nic) == 0xC0FFEE
    # tenant accounting reflects the post-failover allocation
    assert ctrl.pool.usage_snapshot()[app.name] == dep2.usage()


def test_terminate_reclaims_resources():
    ctrl = make_ctrl()
    app, prof = isg_profile()
    before = ctrl.pool.free_total("cpu")
    ctrl.submit(app, target_gbps=5.0, profile=prof)
    assert ctrl.pool.free_total("cpu") < before
    ctrl.terminate(app.name)
    assert ctrl.pool.free_total("cpu") == before


def test_fcfs_multi_app():
    ctrl = make_ctrl()
    apps = ALL_APPS(impl="ref")
    lat_fw = {"rule_match": 200e-6, "conn_track": 150e-6}
    prof_fw = synthetic_profile(apps["FW"].stage_names(), lat_fw, BITS)
    app, prof = isg_profile()
    d1 = ctrl.submit(app, 5.0, prof)
    d2 = ctrl.submit(apps["FW"], 20.0, prof_fw)
    assert d1.allocation.satisfied() and d2.allocation.satisfied()
    assert len(ctrl.deployments) == 2


def test_replication_dirty_flag_skips_unchanged_snapshots():
    """Appendix-D replication is dirty-flag gated: with no state API write
    since the last snapshot the full cross-NIC traverse is skipped (no
    transport reads), and any write re-arms it."""
    ctrl = make_ctrl()
    app, prof = isg_profile()
    app.declare_state("isg_sa_table", "full-access")
    dep = ctrl.submit(app, target_gbps=5.0, profile=prof, backup_nic="bf1-0")
    victim = dep.allocation.nics_for("aes")[0]
    ctrl.state.ne_set("isg_sa_table", 1, local=victim)

    ctrl.replicate_for_failover(app.name)
    assert dep.state_snapshot == {"isg_sa_table": 1}
    reads_after_first = ctrl.state.transport.reads

    # Unchanged state: the second replication must be a no-op.
    ctrl.replicate_for_failover(app.name)
    assert ctrl.state.transport.reads == reads_after_first
    assert dep.state_snapshot == {"isg_sa_table": 1}

    # A write bumps the version and re-arms the traverse.
    ctrl.state.ne_set("isg_sa_table", 2, local=victim)
    ctrl.replicate_for_failover(app.name)
    assert ctrl.state.transport.reads > reads_after_first
    assert dep.state_snapshot == {"isg_sa_table": 2}
