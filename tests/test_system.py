"""End-to-end behaviour: train -> crash -> resume; Meili serving plan;
paper-workflow integration (submit apps to the controller over the paper
cluster and check the headline behaviours)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster
from repro.core.profiler import synthetic_profile
from repro.apps import ALL_APPS
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.checkpoint import CheckpointManager, latest_step, restore_checkpoint
from repro.data import SyntheticLMDataset, host_shard_iterator
from repro.models import build


def test_train_crash_resume_bitexact(tmp_path):
    """Checkpoint/restart: a run that crashes and resumes must land on the
    same loss trajectory as an uninterrupted run (determinism + atomic
    checkpoints + resumable data stream)."""
    cfg = ARCHS["olmo-1b"].reduced().replace(remat=False, microbatch=1)
    model = build(cfg)
    mesh = make_host_mesh()
    shape = ShapeConfig("t", 32, 4, "train")
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=33)

    def run(steps, ckpt_dir, resume=False):
        params, _ = model.init(jax.random.PRNGKey(0), jnp.float32)
        step_fn, opt_init = make_train_step(model, shape, mesh, base_lr=1e-3,
                                            warmup=2, total_steps=30)
        opt = opt_init(params)
        start = 0
        if resume and latest_step(ckpt_dir):
            (params, opt), start = restore_checkpoint(ckpt_dir, (params, opt))
        it = host_shard_iterator(ds, 4, 0, 1, start_step=start)
        mgr = CheckpointManager(ckpt_dir, every=5)
        jit_step = jax.jit(step_fn)
        losses = []
        for s in range(start, steps):
            batch = {"tokens": jnp.asarray(next(it)["tokens"][:, :32])}
            params, opt, loss, _ = jit_step(params, opt, batch, jnp.int32(s))
            losses.append(float(loss))
            mgr.maybe_save(s + 1, (params, opt))
        return losses

    uninterrupted = run(10, str(tmp_path / "a"))
    part1 = run(5, str(tmp_path / "b"))
    part2 = run(10, str(tmp_path / "b"), resume=True)
    np.testing.assert_allclose(part1 + part2, uninterrupted, rtol=1e-5)


def test_meili_serving_plan():
    from repro.serving.planner import plan_serving
    cfg = ARCHS["jamba-1.5-large-398b"].reduced().replace(remat=False)
    model = build(cfg)
    plan = plan_serving(model, {"seg0": 3.0e-3})
    assert plan.num_pipelines == 1               # single stage: degenerate
    plan = plan_serving(model, {"enc": 2.0e-3, "dec": 0.9e-3})
    assert plan.R["enc"] == 3 and plan.R["dec"] == 1
    assert plan.throughput_gain > 1.5


def test_paper_workflow_end_to_end():
    """§2.2 style scenario: three apps at 20 Gbps targets multiplex onto the
    pool; every deployment meets its target; failover keeps apps placed."""
    bits = 1500 * 8 * 256.0
    ctrl = MeiliController(paper_cluster())
    apps = ALL_APPS(impl="ref")
    lats = {
        "ICG": {"ipcomp_encap": 120e-6, "compress": 260e-6},
        "FW": {"rule_match": 180e-6, "conn_track": 140e-6},
        "FM": {"flow_ext": 90e-6, "flow_metrics": 150e-6},
    }
    deps = {}
    for name, l in lats.items():
        prof = synthetic_profile(apps[name].stage_names(), l, bits)
        deps[name] = ctrl.submit(apps[name], target_gbps=20.0, profile=prof)
    for name, dep in deps.items():
        assert dep.achievable_gbps >= 20.0, name
    used = {n for d in deps.values() for n in d.nics_used()}
    # Algorithm 2 priorities: locality holds per-app; across apps the
    # bandwidth sort legitimately opens fresh NICs. 3 two-stage apps at
    # 20 Gbps must still fit a small neighbourhood of the 16-NIC pool.
    assert len(used) <= 6
    victim = next(iter(used))
    ctrl.handle_failure(victim)
    for name in deps:
        dep = ctrl.deployments[deps[name].app.name]
        assert dep.allocation.units(dep.profile.stages[0]) >= 1


def test_serving_engine_completes_requests():
    from repro.serving.engine import Request, ServingEngine
    cfg = ARCHS["olmo-1b"].reduced().replace(remat=False)
    model = build(cfg)
    params, _ = model.init(jax.random.PRNGKey(0), jnp.float32)
    eng = ServingEngine(model, params, num_pipelines=2, slots_per_pipeline=4,
                        max_len=32)
    for rid in range(6):
        eng.submit(Request(rid=rid, prompt=[2, 3, 4], max_new_tokens=4))
    done = eng.run(max_steps=24)
    assert len(done) == 6
    assert all(len(r.out) == 4 for r in done)
