"""Optional-hypothesis shim for the property-based tests.

``hypothesis`` is not available in every environment this repo runs in.
Importing it at module top used to kill *collection* of five test modules,
losing their plain unit tests too. Test modules import ``given``,
``settings`` and ``st`` from here instead: with hypothesis installed these
are the real thing; without it, ``@given`` rewrites the test into a single
skipped stub (and ``st``/``settings`` become inert placeholders), so the
property tests SKIP while everything else in the module still runs.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                     # pragma: no cover
    import functools

    import pytest

    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Stands in for `st.<anything>(...)` and composite strategies at
        decoration time; never actually draws."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _AnyStrategy()

    def given(*_args, **_kwargs):
        def deco(fn):
            @functools.wraps(fn)
            def stub(*a, **k):              # signature-free: no fixtures
                pass
            stub.__signature__ = __import__("inspect").Signature()
            return pytest.mark.skip(reason="hypothesis not installed")(stub)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn
