"""Per-arch smoke tests (reduced configs): forward/train-step shapes + no
NaNs; decode==forward consistency; loss decreases under training."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS
from repro.configs.base import ShapeConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import build
from repro.models import lm as lm_mod

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32, dtype=jnp.float32):
    if cfg.family == "encdec":
        return {"frames": jnp.zeros((B, S, cfg.d_model), dtype),
                "tokens": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        return {"patches": jnp.zeros((B, cfg.frontend_tokens, cfg.d_model),
                                     dtype),
                "tokens": jnp.ones((B, S), jnp.int32)}
    return {"tokens": jnp.ones((B, S), jnp.int32)}


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_forward_and_loss(name):
    cfg = ARCHS[name].reduced().replace(remat=False)
    model = build(cfg)
    params, axes = model.init(KEY, jnp.float32)
    batch = _batch_for(cfg)
    x = model.forward(params, batch, impl="blocked")
    B = 2
    S_expect = 32 + (cfg.frontend_tokens if cfg.family == "vlm" else 0)
    assert x.shape == (B, S_expect, cfg.d_model)
    assert bool(jnp.isfinite(x).all()), "non-finite activations"
    loss = model.loss(params, batch, impl="blocked")
    assert np.isfinite(float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_one_train_step(name):
    cfg = ARCHS[name].reduced().replace(remat=False, microbatch=2)
    model = build(cfg)
    params, _ = model.init(KEY, jnp.float32)
    mesh = make_host_mesh()
    shape = ShapeConfig("t", 32, 4, "train")
    step_fn, opt_init = make_train_step(model, shape, mesh, warmup=1)
    opt = opt_init(params)
    batch = _batch_for(cfg, B=4)
    params2, opt2, loss, gnorm = jax.jit(step_fn)(params, opt, batch,
                                                  jnp.int32(1))
    assert np.isfinite(float(loss)) and np.isfinite(float(gnorm))
    # parameters actually moved
    moved = jax.tree.reduce(
        lambda acc, ab: acc or bool(jnp.any(ab)),
        jax.tree.map(lambda a, b: jnp.any(a != b), params, params2), False)
    assert moved


@pytest.mark.parametrize("name", sorted(ARCHS))
def test_arch_smoke_decode_step(name):
    cfg = ARCHS[name].reduced().replace(remat=False)
    model = build(cfg)
    params, _ = model.init(KEY, jnp.float32)
    cache, _ = model.init_cache(2, 16, jnp.float32)
    lg, cache2 = model.decode_step(params, cache,
                                   jnp.ones((2,), jnp.int32),
                                   impl="blocked")
    assert lg.shape == (2, cfg.vocab)
    assert bool(jnp.isfinite(lg).all())
    assert int(cache2["pos"]) == 1


@pytest.mark.parametrize("name", ["olmo-1b", "gemma3-1b", "mamba2-370m",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_forward(name):
    cfg = ARCHS[name].reduced().replace(remat=False)
    model = build(cfg)
    params, _ = model.init(jax.random.PRNGKey(1), jnp.float32)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab)
    x = lm_mod.forward(cfg, params, toks, impl="blocked")
    full = lm_mod.logits(cfg, params, x)
    cache, _ = model.init_cache(B, 16, jnp.float32)
    for t in range(S):
        lg, cache = model.decode_step(params, cache, toks[:, t],
                                      impl="blocked")
        np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, t]),
                                   atol=5e-4, rtol=1e-3)


def test_prefill_then_decode_matches_forward():
    cfg = ARCHS["olmo-1b"].reduced().replace(remat=False)
    model = build(cfg)
    params, _ = model.init(jax.random.PRNGKey(1), jnp.float32)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 1), 0, cfg.vocab)
    lg_prefill, cache = model.prefill(params, {"tokens": toks[:, :S]},
                                      max_len=16, impl="blocked",
                                      cache_dtype=jnp.float32)
    x = lm_mod.forward(cfg, params, toks[:, :S], impl="blocked")
    full = lm_mod.logits(cfg, params, x)
    np.testing.assert_allclose(np.asarray(lg_prefill), np.asarray(full[:, -1]),
                               atol=5e-4, rtol=1e-3)
    lg, cache = model.decode_step(params, cache, toks[:, S], impl="blocked")
    x2 = lm_mod.forward(cfg, params, toks, impl="blocked")
    full2 = lm_mod.logits(cfg, params, x2)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full2[:, -1]),
                               atol=5e-4, rtol=1e-3)


def test_training_reduces_loss():
    cfg = ARCHS["olmo-1b"].reduced().replace(remat=False, microbatch=1)
    model = build(cfg)
    params, _ = model.init(KEY, jnp.float32)
    mesh = make_host_mesh()
    shape = ShapeConfig("t", 32, 4, "train")
    step_fn, opt_init = make_train_step(model, shape, mesh, base_lr=1e-2,
                                        warmup=2, total_steps=40)
    opt = opt_init(params)
    jit_step = jax.jit(step_fn)
    tok = jax.random.randint(jax.random.PRNGKey(5), (4, 32), 0, cfg.vocab)
    batch = {"tokens": tok}                     # memorize one batch
    first = last = None
    for i in range(30):
        params, opt, loss, _ = jit_step(params, opt, batch, jnp.int32(i))
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < first * 0.7, (first, last)


def test_param_counts_match_known_scale():
    tot, act = build(ARCHS["qwen2.5-32b"]).param_counts()
    assert 31e9 < tot < 36e9                    # ~32.7B
    assert act == tot
    # NOTE: the ASSIGNED config (48L x 64e x d_ff 1408) totals ~27B — the
    # production Moonlight-16B has 27 layers; we implement the assignment.
    tot, act = build(ARCHS["moonshot-v1-16b-a3b"]).param_counts()
    assert 25e9 < tot < 30e9
    assert 2e9 < act < 4.5e9                    # ~3B active (matches "a3b")
    tot, act = build(ARCHS["jamba-1.5-large-398b"]).param_counts()
    assert 330e9 < tot < 430e9
    assert 60e9 < act < 130e9
    tot, act = build(ARCHS["mamba2-370m"]).param_counts()
    assert 2.5e8 < tot < 5.5e8
