"""Meili-Serve runtime: workload determinism, admission control, the closed
autoscaling loop, churn, failover liveness, and per-tenant attribution."""
import dataclasses

import numpy as np
import pytest

from repro.apps.profiles import paper_profile
from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import (AdmissionError, TenantRegistry, TenantSLA,
                                   TenantSpec, churn_tenant_mix, contracts,
                                   default_tenant_mix)
from repro.service.workload import (ScenarioWorkload, TrafficSpec,
                                    make_scenario)

FAST = RuntimeConfig(dataplane_every=0, max_sim_seqs=32)


def make_runtime(scenario="bursty", mix=None, cfg=FAST, seed=0):
    mix = mix or default_tenant_mix()
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario(scenario, contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg)
    registry.admit_all()
    return rt


# -- workload -----------------------------------------------------------------

def test_workload_deterministic():
    mix = default_tenant_mix()
    wl1 = make_scenario("bursty", contracts(mix), seed=3)
    wl2 = make_scenario("bursty", contracts(mix), seed=3)
    for t in wl1.tenants():
        for tick in range(20):
            assert wl1.offered_gbps(t, tick) == wl2.offered_gbps(t, tick)
    b1 = wl1.batch_for("t-fw", 5)
    b2 = wl2.batch_for("t-fw", 5)
    np.testing.assert_array_equal(np.asarray(b1.payload),
                                  np.asarray(b2.payload))
    np.testing.assert_array_equal(np.asarray(b1.five_tuple),
                                  np.asarray(b2.five_tuple))


def test_workload_patterns():
    specs = {
        "c": TrafficSpec(pattern="constant", peak_gbps=10.0, jitter_frac=0.0),
        "b": TrafficSpec(pattern="bursty", peak_gbps=10.0, duty=0.5,
                         period_ticks=8, trough_frac=0.2, jitter_frac=0.0),
        "d": TrafficSpec(pattern="diurnal", peak_gbps=10.0, period_ticks=16,
                         trough_frac=0.25, jitter_frac=0.0),
    }
    wl = ScenarioWorkload(specs)
    assert all(wl.offered_gbps("c", t) == 10.0 for t in range(16))
    burst = [wl.offered_gbps("b", t) for t in range(8)]
    assert burst[:4] == [10.0] * 4 and burst[4:] == [2.0] * 4
    diurnal = [wl.offered_gbps("d", t) for t in range(16)]
    assert min(diurnal) == pytest.approx(2.5)
    assert max(diurnal) == pytest.approx(10.0)


def test_workload_heavy_tailed_flows_and_disjoint_flow_space():
    mix = default_tenant_mix()
    wl = make_scenario("steady", contracts(mix), seed=1)
    b_fw = wl.batch_for("t-fw", 0, max_pkts=512)
    b_fm = wl.batch_for("t-fm", 0, max_pkts=512)
    # heavy tail: the busiest flow carries far more than a uniform share
    _, counts = np.unique(np.asarray(b_fw.five_tuple)[:, 0],
                          return_counts=True)
    assert counts.max() > 3 * counts.mean()
    # per-tenant flow-id spaces never collide
    fw_src = set(np.asarray(b_fw.five_tuple)[:, 0].tolist())
    fm_src = set(np.asarray(b_fm.five_tuple)[:, 0].tolist())
    assert not (fw_src & fm_src)


# -- admission ----------------------------------------------------------------

def test_admission_rejects_unplaceable_and_rolls_back():
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    mix = default_tenant_mix()
    big = dataclasses.replace(
        mix[2], name="t-huge",
        sla=TenantSLA(target_gbps=500.0, p99_latency_s=1e-3))
    registry.register(big)
    free_before = ctrl.pool.free_total("cpu")
    with pytest.raises(AdmissionError):
        registry.admit("t-huge")
    assert ctrl.pool.free_total("cpu") == free_before
    assert "t-huge" not in ctrl.deployments
    assert "t-huge" in registry.rejected
    assert ctrl.pool.usage_snapshot() == {}


def test_admission_priority_order():
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    for spec in default_tenant_mix():
        registry.register(spec)
    order = registry.admit_all()
    prios = [registry.specs[n].sla.priority for n in order]
    assert prios == sorted(prios, reverse=True)


# -- closed loop --------------------------------------------------------------

def test_autoscaler_tracks_diurnal_load():
    rt = make_runtime("diurnal")
    peak_provision = rt.ctrl.pool.reserved_units()   # admitted at contract
    rt.run(60)
    s = rt.telemetry.series("t-fw")
    units = {t.tick: t.units for t in s}
    offered = {t.tick: t.offered_gbps for t in s}
    peak_tick = max(offered, key=offered.get)
    trough_tick = min((t for t in offered if t > 10), key=offered.get)
    assert units[peak_tick] > units[trough_tick]
    assert any(e["event"] == "scale" for e in rt.ctrl.events)
    # the elastic footprint stays below the fixed contract provision; the
    # *cluster* series barely breathes — staggered tenant phases multiplex,
    # which is exactly the consolidation win the comparator measures.
    reserved = [c.reserved_units for c in rt.telemetry.cluster_ticks]
    assert max(reserved) <= peak_provision
    assert np.mean(reserved) < 0.9 * peak_provision


def test_slo_holds_under_bursty_and_diurnal():
    for scenario in ("bursty", "diurnal"):
        rt = make_runtime(scenario)
        rt.run(48)
        report = rt.slo_report()
        assert report, scenario
        for tenant, r in report.items():
            assert r["pass"], (scenario, tenant, r)


def test_fixed_mode_never_scales():
    cfg = dataclasses.replace(FAST, autoscale=False)
    rt = make_runtime("diurnal", cfg=cfg)
    rt.run(30)
    assert not any(e["event"] == "scale" for e in rt.ctrl.events)
    reserved = {c.reserved_units for c in rt.telemetry.cluster_ticks}
    assert len(reserved) == 1


# -- failover -----------------------------------------------------------------

def test_failover_keeps_all_tenants_alive():
    rt = make_runtime("bursty")
    rt.run(30, fail_at=(12, None))
    assert any(e["event"] == "failover" for e in rt.ctrl.events)
    assert len(rt.alive_tenants()) == len(rt.registry.active()) == 6
    for tenant, r in rt.slo_report().items():
        assert r["pass"], (tenant, r)
    # post-failover ticks for impacted tenants got the grace flag
    impacted = {e["tenant"] for e in rt.ctrl.events
                if e["event"] == "failover"}
    graced = {t.tenant for t in rt.telemetry.tenant_ticks if t.in_grace}
    assert impacted and impacted <= graced


# -- churn --------------------------------------------------------------------

def test_tenant_churn_admits_and_refunds():
    mix = default_tenant_mix()
    mix[1] = dataclasses.replace(mix[1], arrive_tick=5)
    mix[3] = dataclasses.replace(mix[3], depart_tick=10)
    rt = make_runtime("steady", mix=mix)
    departing, arriving = mix[3].name, mix[1].name
    assert arriving not in rt.registry.active()
    rt.run(16)
    assert arriving in rt.registry.active()
    assert departing not in rt.registry.active()
    assert rt.ctrl.pool.usage_snapshot().get(departing) is None
    arr = rt.telemetry.series(arriving)
    assert arr and min(t.tick for t in arr) >= 5
    dep = rt.telemetry.series(departing)
    assert dep and max(t.tick for t in dep) < 10


# -- defragmentation ----------------------------------------------------------

def test_runtime_defrag_recovers_locality_under_churn():
    """The background re-placement loop: same churning mix + seeded traffic
    with defrag off vs on. On must migrate, recover locality (fewer NICs,
    no more hop pairs than off), grace the migrated tenants, and leave the
    pool ledger exact."""
    TICKS = 48
    runs = {}
    for defrag_on in (False, True):
        mix = churn_tenant_mix(ticks=TICKS)
        cfg = dataclasses.replace(FAST, defrag_every=8 if defrag_on else 0,
                                  defrag_max_moves=2)
        ctrl = MeiliController(paper_cluster())
        registry = TenantRegistry(ctrl)
        for spec in mix:
            registry.register(spec)
        wl = make_scenario("churn", contracts(mix), seed=0)
        rt = ServiceRuntime(ctrl, registry, wl, cfg)
        registry.admit_all()
        rt.run(TICKS)
        ctrl.check_ledger()
        runs[defrag_on] = (rt, ctrl)

    rt_off, _ = runs[False]
    rt_on, ctrl_on = runs[True]
    migrated = {e["tenant"] for e in ctrl_on.events if e["event"] == "migrate"}
    assert migrated, "defrag loop never migrated under churn"
    tail = int(0.7 * TICKS)
    loc_off = rt_off.telemetry.locality(from_tick=tail)
    loc_on = rt_on.telemetry.locality(from_tick=tail)
    assert loc_on["nics_used_mean"] < loc_off["nics_used_mean"]
    assert loc_on["hop_pairs_mean"] <= loc_off["hop_pairs_mean"]
    # migrated tenants got the SLO grace window and the migrate event tag
    graced = {t.tenant for t in rt_on.telemetry.tenant_ticks if t.in_grace}
    tagged = {t.tenant for t in rt_on.telemetry.tenant_ticks
              if t.event == "migrate"}
    assert migrated <= graced
    assert migrated & tagged
    # no tenant that passes SLO without defrag regresses with it
    off_pass = {t: r["pass"] for t, r in rt_off.slo_report().items()}
    on_pass = {t: r["pass"] for t, r in rt_on.slo_report().items()}
    assert not [t for t, ok in off_pass.items()
                if ok and not on_pass.get(t, False)]


# -- attribution --------------------------------------------------------------

def test_pool_usage_attribution_tracks_allocation():
    rt = make_runtime("steady")
    for name in rt.registry.active():
        dep = rt.registry.deployment(name)
        assert rt.ctrl.pool.usage_snapshot()[name] == dep.usage()
    total = sum(sum(u.values()) for u in rt.ctrl.pool.usage_snapshot().values())
    assert total == rt.ctrl.pool.reserved_units()
    rt.registry.evict("t-fw")
    assert "t-fw" not in rt.ctrl.pool.usage_snapshot()


def test_dataplane_by_tenant_tagging_survives_rescale():
    cfg = dataclasses.replace(FAST, dataplane_every=1, max_pkts_per_tick=64,
                              pkt_bytes=64)
    mix = [s for s in default_tenant_mix() if s.name in ("t-fw", "t-fm")]
    rt = make_runtime("steady", mix=mix, cfg=cfg)
    rt.run(3)
    stats = rt.dataplane_stats()
    for name in ("t-fw", "t-fm"):
        assert stats[name]["calls"] == 3
        assert stats[name]["packets"] > 0
    # a scale event rebuilds the plane; accumulated attribution must survive
    rt.ctrl.adaptive_scale("t-fw", 5.0)
    assert "t-fw" not in rt._planes
    rt.run(2)
    stats = rt.dataplane_stats()
    assert stats["t-fw"]["calls"] == 5
    assert stats["t-fm"]["calls"] == 5
