"""Test config. NOTE: no XLA_FLAGS here — smoke tests and benches must see
the real device count (1 CPU); only launch/dryrun.py forces 512 host devices,
and the small dry-run test isolates its 8-device flag in a subprocess.
The `slow` marker is registered (and excluded by default) in pytest.ini."""
