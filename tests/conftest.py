"""Test config. NOTE: no XLA_FLAGS here — smoke tests and benches must see
the real device count (1 CPU); only launch/dryrun.py forces 512 host devices,
and the small dry-run test isolates its 8-device flag in a subprocess."""
import pytest


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
