"""Fused data plane: semantics oracle, compile-cache behavior, stacked rings."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import ALL_APPS, synth_packets
from repro.core.executor import (MIN_BUCKET, ParallelDataPlane, PipelineRunner,
                                 _bucket)
from repro.core.graph import chain_runner, run_pipeline, stage_runner
from repro.core.orchestrator import flow_ids
from repro.core.ringbuffer import make_rings, pop_many, push_many

PKTS = synth_packets(batch=96, num_flows=12, pkt_bytes=128, seed=7)


def assert_batches_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# -- semantics oracle ---------------------------------------------------------

@pytest.mark.parametrize("name", ["ID", "FW", "FM"])
def test_fused_equals_oracle_with_spill(name):
    """capacity 8 << 96 packets: every flow spills; oracle must still hold."""
    app = ALL_APPS(impl="ref")[name]
    dp = ParallelDataPlane(app, num_pipelines=4, capacity_per_pipeline=8)
    oracle = run_pipeline(app, PKTS)
    for _ in range(3):                      # state carries across rounds
        assert_batches_equal(dp.process(PKTS), oracle)


def test_fused_equals_unfused_reference_path():
    app = ALL_APPS(impl="ref")["FW"]
    dp = ParallelDataPlane(app, num_pipelines=3, capacity_per_pipeline=16)
    assert_batches_equal(dp.process(PKTS), dp.process_unfused(PKTS))


def test_fused_oracle_with_migration_active():
    """Packets behind a migrating flow are buffered; the processed remainder
    equals the oracle rows of the non-halted packets, in original order."""
    app = ALL_APPS(impl="ref")["FW"]
    dp = ParallelDataPlane(app, num_pipelines=3, capacity_per_pipeline=64)
    dp.process(PKTS)                        # populate the flow table
    f = next(iter(dp.to.flow_table))
    dp.to.begin_migration(f)
    out = dp.process(PKTS)
    keep = np.nonzero(flow_ids(PKTS) != f)[0]
    assert out.batch == keep.size < PKTS.batch
    oracle = run_pipeline(app, PKTS)
    assert_batches_equal(out, jax.tree.map(lambda a: a[jnp.asarray(keep)],
                                           oracle))
    # released buffers re-enter through the normal path after migration
    buffered = dp.to.finish_migration(f, dst_pid=1)
    assert sum(s.indices.size for s in buffered) + keep.size == PKTS.batch
    assert_batches_equal(dp.process(PKTS), oracle)


# -- compile-cache behavior ---------------------------------------------------

def test_zero_steady_state_recompiles():
    app = ALL_APPS(impl="ref")["FW"]
    dp = ParallelDataPlane(app, num_pipelines=4, capacity_per_pipeline=32)
    for _ in range(5):
        dp.process(PKTS)
    assert dp.dispatch_stats["calls"] == 5
    assert dp.dispatch_stats["compiles"] == 1


def test_no_recompiles_after_warmup_via_cache_counters():
    """ISSUE 7: the process-wide compile-cache counters make zero-steady-
    state-recompiles an asserted observable — after warmup, further fused
    dispatches must produce cache HITS only (any miss == a fresh jit)."""
    from repro.core import graph

    app = ALL_APPS(impl="ref")["ID"]
    dp = ParallelDataPlane(app, num_pipelines=2, capacity_per_pipeline=32)
    dp.process(PKTS)                         # warmup compile
    warm_compiles = dp.dispatch_stats["compiles"]
    graph.reset_compile_cache_stats()
    for _ in range(4):
        dp.process(PKTS)
    assert dp.dispatch_stats["compiles"] == warm_compiles
    stats = graph.compile_cache_stats()
    assert stats["dispatch"]["miss"] == 0, (
        f"fused dispatch recompiled after warmup: {stats}")
    assert stats["dispatch"]["hit"] >= 4


def test_dataplane_metrics_and_stage_profile():
    """With a metrics registry attached, dispatch calls/compiles and (in
    profile mode) per-stage device timings land as labeled series."""
    from repro.obs import Obs

    obs = Obs()
    app = ALL_APPS(impl="ref")["FW"]
    dp = ParallelDataPlane(app, num_pipelines=2, capacity_per_pipeline=32,
                           metrics=obs.metrics, profile=True)
    for _ in range(3):
        dp.process(PKTS)
    calls = obs.metrics.get("dataplane_dispatch_calls_total", app=app.name)
    assert calls is not None and calls.value == 3
    lat = obs.metrics.get("dataplane_dispatch_us", app=app.name)
    assert lat is not None and lat.count == 3 and lat.quantile(0.5) > 0
    timings = dp.profile_stages(PKTS)
    assert set(timings) == set(app.stage_names())
    for s in app.stage_names():
        h = obs.metrics.get("dataplane_stage_us", app=app.name, stage=s)
        assert h is not None and h.count >= 1


def test_bucketing_bounds_shapes():
    assert _bucket(1) == MIN_BUCKET
    assert _bucket(16) == 16
    assert _bucket(17) == 32
    assert _bucket(1000) == 1024
    app = ALL_APPS(impl="ref")["FW"]
    dp = ParallelDataPlane(app, num_pipelines=2, capacity_per_pipeline=1000)
    # distinct pow-2 buckets compile at most once each...
    for b in (64, 64, 96, 96, 64):
        dp.process(synth_packets(batch=b, num_flows=4, pkt_bytes=64))
    assert dp.dispatch_stats["compiles"] == 2
    # ...and batch-size drift WITHIN a bucket shares one compiled program
    # (every jit-facing shape — B, egress length, M — is bucketed).
    dp2 = ParallelDataPlane(app, num_pipelines=2, capacity_per_pipeline=1000)
    dp2.process(synth_packets(batch=100, num_flows=4, pkt_bytes=64))
    base = dp2.dispatch_stats["compiles"]
    for b in (120, 100, 97):
        out = dp2.process(synth_packets(batch=b, num_flows=4, pkt_bytes=64))
        assert out.batch == b
    assert dp2.dispatch_stats["compiles"] == base


def test_replicas_share_compiled_programs():
    app = ALL_APPS(impl="ref")["FW"]
    runners = [PipelineRunner(app) for _ in range(4)]
    assert len({id(r._chain) for r in runners}) == 1
    for stage_idx in range(len(app.stages)):
        assert len({id(r.executors[stage_idx].run) for r in runners}) == 1
    assert chain_runner(app) is runners[0]._chain
    assert stage_runner(app.stages[0]) is runners[0].executors[0].run


def test_multi_deployment_shared_stage_identity_no_double_compile():
    """Two *deployments* whose apps are built from the same Function objects
    (same stage identities) must share the process-wide compiled programs:
    the second data plane's dispatch_stats must show zero fresh compiles for
    shapes the first one already ran (multi-tenant service case)."""
    from repro.core.graph import MeiliApp

    app1 = ALL_APPS(impl="ref")["FW"]
    app2 = MeiliApp("fw-tenant-b")          # a second deployment of the same
    app2.stages = list(app1.stages)         # stage chain (shared identities)

    dp1 = ParallelDataPlane(app1, num_pipelines=3, capacity_per_pipeline=64)
    dp1.process(PKTS, tenant="tenant-a")
    assert dp1.dispatch_stats["compiles"] == 1

    dp2 = ParallelDataPlane(app2, num_pipelines=3, capacity_per_pipeline=64)
    # identical stage identities -> the SAME fused dispatch program object
    assert dp2._dispatch is dp1._dispatch
    assert chain_runner(app2) is chain_runner(app1)
    dp2.process(PKTS, tenant="tenant-b")
    assert dp2.dispatch_stats["calls"] == 1
    assert dp2.dispatch_stats["compiles"] == 0      # no double-compile
    # per-tenant attribution stays per-plane and per-tenant
    assert dp1.dispatch_stats["by_tenant"] == {
        "tenant-a": {"calls": 1, "packets": PKTS.batch}}
    assert dp2.dispatch_stats["by_tenant"] == {
        "tenant-b": {"calls": 1, "packets": PKTS.batch}}

    # a *different* stage identity (fresh UCF closures) does NOT collide
    app3 = ALL_APPS(impl="ref")["FW"]
    dp3 = ParallelDataPlane(app3, num_pipelines=3, capacity_per_pipeline=64)
    assert dp3._dispatch is not dp1._dispatch


# -- stacked multi-lane rings -------------------------------------------------

def test_push_pop_many_fifo_and_wraparound():
    proto = {"x": jnp.zeros((2,), jnp.int32)}
    ring = make_rings(proto, cap=8, lanes=3)
    for wave in range(5):                    # 5 waves of up to 5 rows > cap
        n = jnp.asarray([5, 3, 0], jnp.int32)
        rows = {"x": (jnp.arange(30) + 1000 * wave).reshape(3, 5, 2)}
        ring = push_many(ring, rows, n)
        np.testing.assert_array_equal(np.asarray(ring.occupancy), [5, 3, 0])
        ring, out, valid = pop_many(ring, 5)
        np.testing.assert_array_equal(
            np.asarray(valid),
            [[True] * 5, [True, True, True, False, False], [False] * 5])
        for lane, k in ((0, 5), (1, 3)):
            np.testing.assert_array_equal(np.asarray(out["x"][lane, :k]),
                                          np.asarray(rows["x"][lane, :k]))
    np.testing.assert_array_equal(np.asarray(ring.occupancy), [0, 0, 0])


def test_push_pop_many_is_jittable():
    proto = {"x": jnp.zeros((), jnp.float32)}
    ring = make_rings(proto, cap=16, lanes=2)

    @jax.jit
    def roundtrip(ring, rows, n):
        ring = push_many(ring, rows, n)
        return pop_many(ring, 4)

    rows = {"x": jnp.arange(8.0).reshape(2, 4)}
    ring, out, valid = roundtrip(ring, rows, jnp.asarray([4, 2], jnp.int32))
    np.testing.assert_array_equal(np.asarray(out["x"][0]), [0, 1, 2, 3])
    np.testing.assert_array_equal(np.asarray(valid[1]),
                                  [True, True, False, False])
