"""Data pipeline, optimizer, schedules, checkpointing, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)
from repro.data import SyntheticLMDataset, host_shard_iterator
from repro.optim import (adamw_init, adamw_update, clip_by_global_norm,
                         cosine_schedule, wsd_schedule)
from repro.parallel.compression import (compress_tree, dequantize_int8,
                                        quantize_int8, zero_residual)


# -- data ---------------------------------------------------------------------

def test_data_deterministic():
    ds = SyntheticLMDataset(vocab=1000, seq_len=64, seed=7)
    b1 = ds.batch(3, 4)["tokens"]
    b2 = ds.batch(3, 4)["tokens"]
    np.testing.assert_array_equal(b1, b2)
    b3 = ds.batch(4, 4)["tokens"]
    assert not np.array_equal(b1, b3)


def test_data_shapes_and_range():
    ds = SyntheticLMDataset(vocab=100, seq_len=32)
    b = ds.batch(0, 8)["tokens"]
    assert b.shape == (8, 32)
    assert b.min() >= 0 and b.max() < 100


def test_host_shards_disjoint_cover():
    ds = SyntheticLMDataset(vocab=50, seq_len=16, seed=1)
    full = ds.batch(0, 8)["tokens"]
    it0 = host_shard_iterator(ds, 8, 0, 2)
    it1 = host_shard_iterator(ds, 8, 1, 2)
    s0, s1 = next(it0)["tokens"], next(it1)["tokens"]
    np.testing.assert_array_equal(np.concatenate([s0, s1]), full)


def test_resume_replays_stream():
    ds = SyntheticLMDataset(vocab=50, seq_len=16)
    it = host_shard_iterator(ds, 4, 0, 1)
    next(it)
    second = next(it)["tokens"]
    it_resumed = host_shard_iterator(ds, 4, 0, 1, start_step=1)
    np.testing.assert_array_equal(next(it_resumed)["tokens"], second)


# -- optimizer --------------------------------------------------------------------

def test_adamw_converges_on_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = adamw_init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(200):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(params, g, state, lr=0.1,
                                        weight_decay=0.0)
    assert float(loss(params)) < 1e-3


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}                     # norm 5
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(5.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0)


def test_bf16_state_option():
    params = {"w": jnp.ones((4,), jnp.bfloat16)}
    st = adamw_init(params, jnp.bfloat16)
    assert st.mu["w"].dtype == jnp.bfloat16


def test_wsd_schedule_shape():
    lr = wsd_schedule(1.0, warmup=10, total=100, decay_frac=0.2)
    assert float(lr(0)) == 0.0
    assert float(lr(10)) == pytest.approx(1.0)
    assert float(lr(50)) == pytest.approx(1.0)              # stable plateau
    assert float(lr(99)) < 0.2                              # decayed
    c = cosine_schedule(1.0, 10, 100)
    assert float(c(50)) < 1.0 and float(c(99)) < 0.05


# -- checkpoint ---------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.float32),
            "b": {"c": jnp.asarray([1, 2], jnp.int32)}}
    save_checkpoint(str(tmp_path), 5, tree)
    like = jax.tree.map(jnp.zeros_like, tree)
    restored, step = restore_checkpoint(str(tmp_path), like)
    assert step == 5
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_checkpoint_ignores_uncommitted(tmp_path):
    tree = {"a": jnp.zeros((2,))}
    save_checkpoint(str(tmp_path), 1, tree)
    # fake a partial write
    os.makedirs(tmp_path / "step_00000002")
    assert latest_step(str(tmp_path)) == 1


def test_checkpoint_manager_gc(tmp_path):
    m = CheckpointManager(str(tmp_path), every=1, keep=2)
    tree = {"a": jnp.zeros((1,))}
    for s in range(1, 6):
        m.maybe_save(s, tree)
    kept = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert len(kept) == 2 and kept[-1] == "step_00000005"


def test_checkpoint_respects_every(tmp_path):
    m = CheckpointManager(str(tmp_path), every=10)
    assert m.maybe_save(5, {"a": jnp.zeros(1)}) is None
    assert m.maybe_save(10, {"a": jnp.zeros(1)}) is not None


# -- gradient compression ------------------------------------------------------------

def test_quantize_roundtrip_error_bound():
    x = jnp.asarray(np.random.default_rng(0).normal(size=(256,)) * 3)
    q, s = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, s) - x))
    assert err.max() <= float(s) / 2 + 1e-6


def test_error_feedback_is_unbiased_over_steps():
    """Repeatedly compressing the same gradient with error feedback: the
    cumulative transmitted sum approaches the true cumulative gradient."""
    rng = np.random.default_rng(1)
    g = {"w": jnp.asarray(rng.normal(size=(64,)))}
    res = zero_residual(g)
    sent = np.zeros(64)
    for i in range(50):
        q, s, res = compress_tree(g, res)
        sent += np.asarray(dequantize_int8(q["w"], s["w"]))
    np.testing.assert_allclose(sent / 50, np.asarray(g["w"]), atol=1e-2)
