"""ISSUE 10: SLO error-budget engine, multi-window burn-rate alerting, and
the chaos-grade flight recorder.

The acceptance test reconstructs a full incident from artifacts alone: a
gray failure burns a tenant's budget inside its post-failover grace window,
the fast-window page alert fires BEFORE the first SLO-violating tick
outside grace (grace exempts the SLO report, not the budget — that is the
early warning), the pre-armed detector quarantines the sick NIC, the alert
resolves, and ``why_slo`` + the auto-dumped ``flight_*.jsonl`` bundle tell
the same causally-ordered story.

Also pinned: budget math, the firing->resolved lifecycle (dedup +
hold-down), byte-identical alert sequences across seeded replays and across
the legacy vs 1-shard sharded controller, and the exception-safe flight
dump (a failed dump logs ``flight_dump_failed`` and never masks the
sentinel error that triggered it).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import pytest

from repro.core.controller import MeiliController
from repro.core.faults import (FLAP, GRAY, MID_MIGRATION, RACK, REVIVE,
                               ChaosEngine, FaultEvent, FaultPlan,
                               RecoveryConfig)
from repro.core.pool import paper_cluster
from repro.core.shard import ShardedController
from repro.obs import Obs, SLOEngine, BurnAlertManager, BurnRule, PAGE, WARN
from repro.obs.alerts import FIRING, RESOLVED
from repro.obs.flight import load_bundle
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.telemetry import TenantTick
from repro.service.tenants import (TenantRegistry, TenantSLA, contracts,
                                   default_tenant_mix)
from repro.service.workload import make_scenario

FAST = RuntimeConfig(dataplane_every=0, max_sim_seqs=32)


def _tick(tick, tenant="t", offered=10.0, achieved=10.0, p99=1e-4,
          in_grace=False, p99_measured=0.0):
    return TenantTick(tick=tick, tenant=tenant, offered_gbps=offered,
                      achieved_gbps=achieved, p50_s=p99 / 2, p99_s=p99,
                      units=4, slo_ok=True, in_grace=in_grace,
                      p99_measured_s=p99_measured)


SLA = TenantSLA(target_gbps=10.0, p99_latency_s=1e-3)


# -- budget math ---------------------------------------------------------------

def test_budget_math_and_burn_rate():
    eng = SLOEngine(Obs(), horizon_ticks=20)
    # 20-tick horizon at the default 5% budget -> exactly 1 bad tick allowed
    for t in range(10):
        bad = eng.observe(_tick(t, achieved=10.0 if t < 8 else 1.0), SLA)
        assert bad == (t >= 8)
    b = eng.budgets["t"]
    assert b.burned() == 2
    assert b.allowance() == pytest.approx(1.0)
    assert b.remaining_frac() == 0.0          # clamped: burned > allowance
    # burn over the trailing 4 ticks: 2/4 bad at budget_frac 0.05 -> 10x
    assert eng.burn_rate("t", 4) == pytest.approx(10.0)
    assert eng.burn_rate("t", 10) == pytest.approx(2 / 10 / 0.05)
    assert eng.burn_rate("missing", 4) == 0.0
    assert b.burned_ticks() == [8, 9]


def test_budget_warmup_burns_nothing_but_grace_burns():
    eng = SLOEngine(Obs(), horizon_ticks=16, warmup_ticks=2)
    assert not eng.observe(_tick(0, achieved=0.0), SLA)      # warmup
    assert not eng.observe(_tick(1, achieved=0.0), SLA)      # warmup
    # Grace is the pool forgiving itself in slo_report accounting; the
    # tenant still experienced the degradation, so the budget burns.
    assert eng.observe(_tick(2, achieved=0.0, in_grace=True), SLA)
    assert eng.budgets["t"].samples[-1].in_grace
    assert eng.budgets["t"].burned() == 1


def test_budget_p99_sli_prefers_measured_with_legacy_fallback():
    eng = SLOEngine(Obs(), horizon_ticks=16)
    # measured present and over target -> bad, even though legacy is fine
    assert eng.observe(_tick(0, p99=1e-4, p99_measured=5e-3), SLA)
    assert eng.budgets["t"].samples[-1].reason == "p99"
    # measured absent (0.0) -> fall back to the legacy estimator
    assert not eng.observe(_tick(1, p99=1e-4, p99_measured=0.0), SLA)
    assert eng.observe(_tick(2, p99=5e-3, p99_measured=0.0), SLA)
    # throughput shortfall is scored against min(offered, target)
    assert eng.observe(_tick(3, offered=20.0, achieved=8.5), SLA)
    assert eng.budgets["t"].samples[-1].reason == "tput"
    # under-offered tenant is not punished for low absolute throughput
    assert not eng.observe(_tick(4, offered=1.0, achieved=0.95), SLA)


# -- alert lifecycle -----------------------------------------------------------

def _manager(obs=None, holddown=3):
    obs = obs or Obs()
    eng = SLOEngine(obs, horizon_ticks=32)
    rules = (BurnRule(PAGE, window_ticks=4, confirm_ticks=2,
                      burn_threshold=4.0),)
    return eng, BurnAlertManager(eng, obs, rules=rules,
                                 holddown_ticks=holddown)


def test_alert_fires_once_dedups_and_resolves_after_holddown():
    eng, mgr = _manager(holddown=3)
    tick = 0
    # burn hard: every tick bad -> burn 20x over both windows
    for _ in range(4):
        eng.observe(_tick(tick, achieved=0.0), SLA)
        mgr.step(tick)
        tick += 1
    firing = [t for t in mgr.transitions if t.state == FIRING]
    assert len(firing) == 1 and firing[0].severity == PAGE
    assert mgr.active() == [("t", PAGE)]
    # recover: the clear streak must reach the holddown before resolving,
    # and a mid-streak relapse resets it (no flapping)
    for i in range(2):
        eng.observe(_tick(tick, achieved=10.0), SLA)
        mgr.step(tick)
        tick += 1
    assert mgr.active() == [("t", PAGE)]       # holddown not reached
    eng.observe(_tick(tick, achieved=0.0), SLA)   # relapse...
    mgr.step(tick)
    tick += 1
    # ...but one bad tick in a 4-tick window is only 5x... still >= 4x hot:
    # dedup keeps the alert firing without a second transition
    assert len([t for t in mgr.transitions if t.state == FIRING]) == 1
    for _ in range(8):
        eng.observe(_tick(tick, achieved=10.0), SLA)
        mgr.step(tick)
        tick += 1
    resolved = [t for t in mgr.transitions if t.state == RESOLVED]
    assert len(resolved) == 1 and mgr.active() == []
    # metrics + trace carried every transition
    obs = mgr.obs
    assert obs.metrics.get("slo_alert_transitions_total",
                           severity=PAGE, state=FIRING).value == 1
    assert obs.metrics.get("slo_alert_transitions_total",
                           severity=PAGE, state=RESOLVED).value == 1
    assert len(obs.trace.query(name="slo_alert")) == 2


def test_on_page_callback_and_sequence_json():
    eng, mgr = _manager()
    seen = []
    mgr.on_page.append(lambda tenant, tr: seen.append((tenant, tr.tick)))
    for t in range(3):
        eng.observe(_tick(t, achieved=0.0), SLA)
        mgr.step(t)
    # trailing windows divide by min(window, samples): one fully-bad sample
    # already reads as a 20x burn on both windows, so the page is immediate
    assert seen == [("t", 0)]
    seq = json.loads(mgr.sequence())
    assert seq[0]["tenant"] == "t" and seq[0]["state"] == FIRING
    assert set(seq[0]) == {"tick", "tenant", "severity", "state",
                           "burn_long", "burn_short"}   # no wall-clock


# -- determinism ---------------------------------------------------------------

def _chaos_runtime(ctrl_cls, seed=0, ticks=48, **cfg_kw):
    """The PR-5 chaos plan replayed with the SLO layer on (flight dumps off:
    recording must not perturb determinism comparisons)."""
    plan = FaultPlan([
        FaultEvent(tick=5, kind=FLAP, nic="bf2-1", duration_ticks=4),
        FaultEvent(tick=13, kind=GRAY, nic="bf2-2", fraction=0.25),
        FaultEvent(tick=21, kind=MID_MIGRATION),
        FaultEvent(tick=27, kind=RACK, rack="rack0"),
        FaultEvent(tick=34, kind=REVIVE, rack="rack0"),
        FaultEvent(tick=34, kind=REVIVE, nic="bf2-2"),
    ])
    cfg = dataclasses.replace(FAST, gray_detect=True, slo_enabled=True,
                              **cfg_kw)
    ctrl = ctrl_cls(paper_cluster(n_bf2=4, n_bf1=2, n_pensando=2, racks=1))
    registry = TenantRegistry(ctrl)
    mix = [dataclasses.replace(s, backup_nic=("bf1-0", "bf1-1")[i % 2])
           for i, s in enumerate(default_tenant_mix())]
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("chaos", contracts(default_tenant_mix()), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg,
                        recovery=RecoveryConfig(park=True, brownout=True,
                                                seed=seed))
    registry.admit_all()
    rt.run(ticks, chaos=ChaosEngine(plan))
    return rt


def test_shadow_mode_records_pages_but_takes_no_action():
    """``alert_actions=False`` (the overhead benchmark's shadow arm):
    pages still fire and land in the trace, but the runtime takes no
    mitigation — no gray pre-arm, no forced scale consult."""
    live = _chaos_runtime(MeiliController)
    shadow = _chaos_runtime(MeiliController, alert_actions=False)
    for rt in (live, shadow):
        assert any(t.severity == PAGE and t.state == FIRING
                   for t in rt.alerts.transitions)
    assert live.obs.trace.query(name="gray_prearm")
    assert not shadow.obs.trace.query(name="gray_prearm")


def test_alert_sequence_deterministic_across_replays():
    a = _chaos_runtime(MeiliController)
    b = _chaos_runtime(MeiliController)
    assert a.alerts.transitions, "chaos replay produced no alerts"
    assert a.alerts.sequence() == b.alerts.sequence()   # byte-identical


def test_alert_sequence_identical_on_one_shard_sharded_controller():
    legacy = _chaos_runtime(MeiliController)
    sharded = _chaos_runtime(ShardedController)
    assert len(sharded.ctrl.shards) == 1
    assert legacy.alerts.transitions
    assert legacy.alerts.sequence() == sharded.alerts.sequence()
    # shard labels ride only in trace detail, never in the sequence
    ev = sharded.obs.trace.query(name="slo_alert")
    assert ev and all("shard" in e.detail for e in ev)
    ev_l = legacy.obs.trace.query(name="slo_alert")
    assert ev_l and all("shard" not in e.detail for e in ev_l)


# -- flight recorder -----------------------------------------------------------

def _steady_slo_runtime(flight_dir=None, **cfg_kw):
    cfg = dataclasses.replace(FAST, gray_detect=True, slo_enabled=True,
                              flight_dir=flight_dir, **cfg_kw)
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    mix = default_tenant_mix()
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("steady", contracts(mix), seed=0)
    rt = ServiceRuntime(ctrl, registry, wl, cfg,
                        recovery=RecoveryConfig(park=True, brownout=True,
                                                seed=0))
    registry.admit_all()
    return rt


def test_flight_ring_is_bounded_and_snapshots_live_state(tmp_path):
    rt = _steady_slo_runtime(flight_capacity=8)
    rt.run(20)
    ring = list(rt.flight.ring)
    assert len(ring) == 8                      # bounded: capacity, not ticks
    assert [s["tick"] for s in ring] == list(range(12, 20))
    snap = ring[-1]
    assert snap["queues_pkts"] and snap["grants_gbps"]
    assert snap["budgets_remaining"]
    assert set(snap["flight_state"]["nics"]) == set(rt.ctrl.pool.names())
    assert all(v["alive"] for v in snap["flight_state"]["nics"].values())
    # no dump directory configured -> recording on, dumping a silent no-op
    assert rt.flight.dump_safe(trigger="manual", tick=19) is None
    assert rt.flight.dumps == []


def test_flight_dump_bundle_roundtrip(tmp_path):
    rt = _steady_slo_runtime(flight_dir=str(tmp_path))
    rt.run(10)
    path = rt.flight.dump("manual", tick=9)
    bundle = load_bundle(path)
    head = bundle["header"][0]
    assert head["trigger"] == "manual" and head["tick"] == 9
    assert len(bundle["snapshot"]) == head["snapshots"] > 0
    assert len(bundle["trace"]) == head["trace_events"] > 0
    assert bundle["metric_delta"]              # first dump: deltas = absolutes
    # a second immediate dump carries only what changed since the first
    path2 = rt.flight.dump("manual", tick=9)
    assert load_bundle(path2)["metric_delta"] == []


def test_sentinel_failure_dumps_flight_bundle(tmp_path):
    rt = _steady_slo_runtime(flight_dir=str(tmp_path))
    rt.run(4)
    rt._backlog[sorted(rt._backlog)[0]] = -1.0      # trip flow conservation
    engine = ChaosEngine(FaultPlan(
        [FaultEvent(tick=rt.tick_now, kind=FLAP, nic="bf2-0",
                    duration_ticks=2)]))
    with pytest.raises(AssertionError, match="chaos sentinel"):
        rt.run(1, chaos=engine)
    assert len(rt.flight.dumps) == 1
    bundle = load_bundle(rt.flight.dumps[0])
    assert bundle["header"][0]["trigger"] == "sentinel_failure"


def test_failed_flight_dump_never_masks_the_sentinel_error(tmp_path):
    # point the dump directory at an existing FILE: mkdir will fail
    blocker = tmp_path / "not_a_dir"
    blocker.write_text("x")
    rt = _steady_slo_runtime(flight_dir=str(blocker))
    rt.run(4)
    rt._backlog[sorted(rt._backlog)[0]] = -1.0
    engine = ChaosEngine(FaultPlan(
        [FaultEvent(tick=rt.tick_now, kind=FLAP, nic="bf2-0",
                    duration_ticks=2)]))
    # the ORIGINAL sentinel error propagates, not the dump's IO error
    with pytest.raises(AssertionError, match="chaos sentinel"):
        rt.run(1, chaos=engine)
    assert rt.flight.dumps == []
    failed = rt.obs.trace.query(name="flight_dump_failed")
    assert len(failed) == 1
    assert failed[0].detail["trigger"] == "sentinel_failure"
    assert "Error" in failed[0].detail["error"]


# -- the acceptance criterion: incident reconstruction -------------------------

def test_incident_reconstructed_from_artifacts_alone(tmp_path):
    """Gray failure burns budget in-grace -> page fires BEFORE the first
    SLO-violating tick outside grace -> pre-armed detector quarantines the
    NIC -> alert resolves -> ``why_slo`` and the auto-dumped flight bundle
    tell the same causally-ordered story."""
    # Loose p99 targets isolate the SLI to throughput: the cumulative
    # measured-p99 stream would otherwise keep burning long after the
    # incident and the alert could never resolve.
    rt = _steady_slo_runtime(slo_grace_ticks=6, flight_dir=str(tmp_path))
    mix = {s.name: s for s in default_tenant_mix()}
    for name, spec in rt.registry.specs.items():
        rt.registry.specs[name] = dataclasses.replace(
            spec, sla=dataclasses.replace(spec.sla, p99_latency_s=1.0))

    # Fault targets: one tenant whose placement spans >= 2 NICs — flap one
    # (grants the failover grace window), gray another at the same tick.
    victim, nics = next(
        (t, sorted(d.nics_used())) for t, d in rt.ctrl.deployments.items()
        if len(d.nics_used()) >= 2)
    flap_nic, gray_nic = nics[0], nics[1]
    t0 = 8
    plan = FaultPlan([   # due() sorts by kind: the flap (grace) fires first
        FaultEvent(tick=t0, kind=FLAP, nic=flap_nic, duration_ticks=6),
        FaultEvent(tick=t0, kind=GRAY, nic=gray_nic, fraction=0.25),
    ])
    rt.run(64, chaos=ChaosEngine(plan))

    tr = rt.obs.trace
    # -- the page fired BEFORE the first outside-grace SLO violation -------
    pages = [t for t in rt.alerts.transitions
             if t.tenant == victim and t.severity == PAGE]
    assert pages and pages[0].state == FIRING
    page_tick = pages[0].tick
    violations = [t.tick for t in rt.telemetry.series(victim)
                  if t.tick >= rt.cfg.warmup_ticks and not t.in_grace
                  and not t.slo_ok]
    assert violations, "the gray failure must violate the SLO post-grace"
    assert page_tick < violations[0]
    # and the burn that drove it happened in-grace (budget burns, SLO
    # accounting forgives — that is what makes it an early warning)
    burns = tr.query(name="slo_burn", tenant=victim)
    assert burns and burns[0].detail["in_grace"]

    # -- causal order: fault -> burn -> page -> pre-arm -> quarantine ------
    seq_fault = tr.query(name="gray", nic=gray_nic, kind="fault")[0].seq
    seq_burn = burns[0].seq
    seq_page = next(e.seq for e in tr.query(name="slo_alert", tenant=victim)
                    if e.detail["severity"] == PAGE
                    and e.detail["state"] == FIRING)
    prearm = tr.query(name="gray_prearm", tenant=victim)[0]
    quar = tr.query(name="quarantine_verdict", nic=gray_nic)
    assert quar, "the pre-armed detector must quarantine the gray NIC"
    assert (seq_fault < seq_burn < seq_page < prearm.seq < quar[0].seq)
    assert gray_nic in prearm.detail["nics"]

    # -- the alert resolves once the drain restores service ----------------
    resolved = [t for t in rt.alerts.transitions
                if t.tenant == victim and t.severity == PAGE
                and t.state == RESOLVED]
    assert resolved and resolved[0].tick > quar[0].tick

    # -- why_slo tells the same story ---------------------------------------
    story = rt.slo.why_slo(victim)
    assert story["tracked"] and story["burned_ticks"]
    assert story["burned_ticks"][0] >= t0
    assert story["remaining_frac"] < 1.0
    names = [e["name"] for e in story["events"]]
    assert names.index("slo_burn") < names.index("slo_alert")
    assert "gray_prearm" in names

    # -- the auto-dumped bundle agrees, from the file alone -----------------
    dump = pathlib.Path(tmp_path) / f"flight_{page_tick}.jsonl"
    assert str(dump) in rt.flight.dumps
    bundle = load_bundle(dump)
    assert bundle["header"][0]["trigger"] == "page_alert"
    snaps = bundle["snapshot"]
    assert snaps[-1]["tick"] == page_tick
    # the bundle's own snapshots show the victim's budget draining and the
    # page active at dump time
    assert snaps[-1]["budgets_remaining"][victim] < 1.0
    assert [victim, PAGE] in snaps[-1]["alerts_active"]
    # the trailing trace window carries the in-grace burn and the page
    tail = {(r["name"], r.get("tenant")) for r in bundle["trace"]}
    assert ("slo_burn", victim) in tail and ("slo_alert", victim) in tail
