"""Online re-placement / defragmentation (core.defrag + controller.migrate).

Covers: fragmentation scoring, plan quality (packing actually recovers
locality), the make-before-break ledger discipline, do-no-harm rollback,
and flow affinity across a migration.
"""
import pytest

from repro.apps.packets import synth_packets
from repro.core import defrag
from repro.core.controller import MeiliController
from repro.core.graph import MeiliApp
from repro.core.pool import CPU, NicSpec, Pool
from repro.core.profiler import synthetic_profile
from repro.core import replication as repl

BITS = 1500 * 8 * 256.0


def mk_app(name, stages):
    app = MeiliApp(name)
    for s in stages:
        app.pkt_trans(lambda b: b, name=s)
    return app


def prof(stages, lat=100e-6):
    return synthetic_profile(list(stages), {s: lat for s in stages}, BITS)


def target_units(p, k):
    """Target throughput that makes the §6.1 demand formula place exactly
    k units per stage (k-1 whole groups + one minimal-granularity unit)."""
    R = repl.num_replication(p.stages, p.l_s)
    rate = repl.pipeline_throughput(p.stages, p.l_s, R)
    t_R = rate * p.batch_bits() / 1e9
    return (k - 0.5) * t_R


def pool_snapshot(pool):
    return {n: (dict(st.free), st.free_bw_gbps) for n, st in pool.nics.items()}


def fragmented_controller():
    """5 NICs x 4 cores; fillers leave 1 free core per NIC so the victim's
    2+2 units land scattered (a on n0/n1, b on n2/n3 — a fully disjoint
    consecutive pair); terminating three fillers then opens the holes a
    defrag pass can re-pack into."""
    pool = Pool([NicSpec(f"n{i}", "x", 4, {}, 1000.0) for i in range(5)])
    ctrl = MeiliController(pool)
    for i in range(5):
        fp = prof([f"f{i}"])
        ctrl.submit(mk_app(f"filler{i}", [f"f{i}"]), target_units(fp, 3), fp)
    vp = prof(["a", "b"])
    dep = ctrl.submit(mk_app("victim", ["a", "b"]), target_units(vp, 2), vp)
    assert dep.allocation.satisfied()
    for i in range(3):
        ctrl.terminate(f"filler{i}")
    return ctrl


# -- scoring -------------------------------------------------------------------

def test_fragmentation_score_flags_scattered_placement():
    ctrl = fragmented_controller()
    dep = ctrl.deployments["victim"]
    sc = defrag.fragmentation_score(dep, ctrl.pool)
    assert sc.nics_used == 4
    assert sc.min_nics == 1
    assert sc.hop_pairs == 1              # a on {n0,n1}, b on {n2,n3}
    assert sc.stranded_bw_gbps > 0.0      # every NIC colocation-free
    assert sc.score > 3.0
    # a compact deployment on a fresh pool scores ~0
    pool2 = Pool([NicSpec("m0", "x", 8, {}, 1000.0)])
    ctrl2 = MeiliController(pool2)
    vp = prof(["a", "b"])
    dep2 = ctrl2.submit(mk_app("compact", ["a", "b"]), target_units(vp, 2), vp)
    sc2 = defrag.fragmentation_score(dep2, pool2)
    assert sc2.hop_pairs == 0 and sc2.nics_used == 1
    assert sc2.score < 1.0


# -- plan quality --------------------------------------------------------------

def test_defragment_recovers_locality_and_conserves_ledger():
    ctrl = fragmented_controller()
    dep = ctrl.deployments["victim"]
    before = defrag.fragmentation_score(dep, ctrl.pool)
    achievable_before = dep.achievable_gbps
    units_before = {s: dep.allocation.units(s) for s in dep.profile.stages}

    moved = ctrl.defragment(max_migrations=1, min_score=1.0)
    assert len(moved) == 1 and moved[0]["app"] == "victim"

    dep = ctrl.deployments["victim"]
    after = defrag.fragmentation_score(dep, ctrl.pool)
    assert after.nics_used < before.nics_used
    assert after.hop_pairs == 0
    # capacity preserved: same units, achievable not lowered
    assert {s: dep.allocation.units(s) for s in dep.profile.stages} \
        == units_before
    assert dep.achievable_gbps >= achievable_before - 1e-9
    # the pool-truth ledger survived the commit+release cycle, and the
    # tenant attribution tracks the new placement
    ctrl.check_ledger()
    assert ctrl.pool.usage_snapshot()["victim"] == dep.usage()
    assert any(e["event"] == "migrate" for e in ctrl.events)


def test_defragment_converges_then_stops():
    """Repeated passes monotonically improve packing and reach a fixed
    point (greedy make-before-break may need a pass to free the hole the
    next pass packs into); once compact, no further moves happen."""
    ctrl = fragmented_controller()
    passes = 0
    while ctrl.defragment(max_migrations=2, min_score=1.0):
        passes += 1
        assert passes <= 4, "defragment did not converge"
    assert passes >= 1
    dep = ctrl.deployments["victim"]
    sc = defrag.fragmentation_score(dep, ctrl.pool)
    assert sc.score < 1.0
    assert ctrl.defragment(max_migrations=2, min_score=1.0) == []
    ctrl.check_ledger()


# -- do-no-harm guard ----------------------------------------------------------

def test_migrate_rejects_plan_that_raises_hops_and_rolls_back():
    """Victim colocated on one NIC; the only admissible targets would split
    the consecutive pair across two NICs — the guard must refuse and leave
    the pool byte-identical."""
    pool = Pool([NicSpec("n0", "x", 4, {}, 1000.0),
                 NicSpec("n1", "x", 1, {}, 1000.0),
                 NicSpec("n2", "x", 1, {}, 1000.0)])
    ctrl = MeiliController(pool)
    vp = prof(["a", "b"])
    dep = ctrl.submit(mk_app("victim", ["a", "b"]), target_units(vp, 1), vp)
    assert dep.allocation.nics_for("a") == dep.allocation.nics_for("b") \
        == ["n0"]
    snap = pool_snapshot(pool)
    assert ctrl.migrate("victim", only_nics=["n1", "n2"]) is None
    assert pool_snapshot(pool) == snap
    assert dep.allocation.nics_for("a") == ["n0"]
    ctrl.check_ledger()


def test_migrate_rejects_unplaceable_targets():
    ctrl = fragmented_controller()
    snap = pool_snapshot(ctrl.pool)
    # n4 has a filler + 1 free core: nowhere near the victim's 4 units
    assert ctrl.migrate("victim", only_nics=["n4"]) is None
    assert pool_snapshot(ctrl.pool) == snap


def test_migrate_requires_improvement_by_default():
    pool = Pool([NicSpec("n0", "x", 8, {}, 1000.0),
                 NicSpec("n1", "x", 8, {}, 1000.0)])
    ctrl = MeiliController(pool)
    vp = prof(["a", "b"])
    ctrl.submit(mk_app("victim", ["a", "b"]), target_units(vp, 2), vp)
    snap = pool_snapshot(pool)
    # already compact: no plan beats 1 NIC / 0 hops
    assert ctrl.migrate("victim") is None
    assert pool_snapshot(pool) == snap


# -- flow affinity -------------------------------------------------------------

def test_flow_affinity_preserved_across_migration():
    ctrl = fragmented_controller()
    dep = ctrl.deployments["victim"]
    pkts = synth_packets(batch=64, num_flows=8, pkt_bytes=64)
    assign_before = dep.to.partition_assign(pkts)
    homes_before = dict(dep.to.flow_table)
    assert homes_before

    moved = ctrl.defragment(max_migrations=1)
    assert moved
    dep = ctrl.deployments["victim"]
    # every flow kept its identity and landed on an active pipeline,
    # nothing is stuck in the migration side-buffer
    assert set(dep.to.flow_table) == set(homes_before)
    assert dep.to.halted_flows == {}
    active = {p.pid for p in dep.to.pipelines if p.active}
    assert set(dep.to.flow_table.values()) <= active
    # re-partitioning the same traffic honors the (re-homed) affinity:
    # packets of a flow go to that flow's pipeline
    assign_after = dep.to.partition_assign(pkts)
    assert assign_after.shape == assign_before.shape
    from repro.core.orchestrator import flow_ids
    fids = flow_ids(pkts)
    for f, pid in dep.to.flow_table.items():
        sel = assign_after[fids == f]
        assert len(sel) == 0 or (sel == pid).all() or \
            set(sel.tolist()) <= active


def test_migration_buffers_and_releases_inflight_flows():
    """TO protocol under a migration window: packets of a halted flow buffer
    in the side ring and are released to the destination pipeline."""
    ctrl = fragmented_controller()
    dep = ctrl.deployments["victim"]
    pkts = synth_packets(batch=32, num_flows=4, pkt_bytes=64)
    dep.to.partition_assign(pkts)
    flow = next(iter(dep.to.flow_table))
    dep.to.begin_migration(flow)
    assign = dep.to.partition_assign(pkts)   # flow's packets now buffer
    from repro.core.orchestrator import ASSIGN_HALTED, flow_ids
    halted = assign[flow_ids(pkts) == flow]
    assert len(halted) and (halted == ASSIGN_HALTED).all()
    buffered = dep.to.finish_migration(flow, dst_pid=0)
    assert buffered and all(sb.pid == 0 for sb in buffered)
    assert sum(len(sb.indices) for sb in buffered) == len(halted)
    assert dep.to.flow_table[flow] == 0
