"""Property-style ledger round-trip: any lifecycle sequence conserves pool.

The two accounting bugs this pins: (1) release/shrink used to credit back
``units * t_s`` per stage even when colocated consecutive stages shared
bandwidth via the Algorithm-3 credit (over-credit, masked by a capacity
clamp); (2) ``_shrink`` left ``bw_after`` stale and zero-unit rows in the
allocation matrix, so later allocations were computed against a fiction.

The invariant checked here is exact (no clamp, epsilon = fp rounding only):
after ANY random sequence of submit / scale-up / scale-down / migrate /
failover / terminate, terminating everything returns every NIC — alive or
failed — to its empty-pool baseline, and mid-sequence the pool-truth ledger
(free + held == capacity, free_bw + charges == link) holds after every op.
"""
import random

import pytest

from repro.apps.nf import ALL_APPS
from repro.apps.profiles import paper_profile
from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster

APP_KEYS = ("ID", "ICG", "ISG", "FW", "FM", "LLB")


def snapshot(pool):
    return {n: (dict(st.free), st.free_bw_gbps)
            for n, st in pool.nics.items()}


def submit_one(ctrl, rng, counter):
    key = rng.choice(APP_KEYS)
    app = ALL_APPS(impl="ref")[key]
    app.name = f"{key.lower()}-{counter}"
    dep = ctrl.submit(app, target_gbps=rng.uniform(1.0, 8.0),
                      profile=paper_profile(key))
    if not dep.allocation.satisfied():
        ctrl.terminate(app.name)        # strict-admission rollback path
        return None
    return app.name


@pytest.mark.parametrize("seed", range(8))
def test_random_lifecycle_conserves_pool(seed):
    rng = random.Random(seed)
    ctrl = MeiliController(paper_cluster())
    base = snapshot(ctrl.pool)
    live = []
    counter = 0
    failures = 0

    for _ in range(32):
        ops = ["submit", "submit"]
        if live:
            ops += ["scale_up", "scale_down", "terminate", "migrate"]
            if failures < 2:
                ops.append("failover")
        op = rng.choice(ops)
        if op == "submit":
            name = submit_one(ctrl, rng, counter)
            counter += 1
            if name:
                live.append(name)
        elif op == "scale_up":
            name = rng.choice(live)
            ctrl.adaptive_scale(
                name, ctrl.deployments[name].target_gbps + rng.uniform(0.5, 5.0))
        elif op == "scale_down":
            name = rng.choice(live)
            ctrl.adaptive_scale(
                name, max(0.5, ctrl.deployments[name].target_gbps
                          * rng.uniform(0.2, 0.8)))
        elif op == "migrate":
            ctrl.migrate(rng.choice(live))   # None (no gain) is fine
        elif op == "terminate":
            name = live.pop(rng.randrange(len(live)))
            ctrl.terminate(name)
        elif op == "failover":
            used = sorted({n for d in ctrl.deployments.values()
                           for n in d.nics_used()
                           if ctrl.pool[n].alive})
            if used:
                ctrl.handle_failure(rng.choice(used))
                failures += 1
        # Pool truth must hold after EVERY mutation, not only at the end.
        ctrl.check_ledger()

    for name in list(ctrl.deployments):
        ctrl.terminate(name)
    ctrl.check_ledger()

    assert ctrl.pool.usage_snapshot() == {}
    for n, (free, bw) in base.items():
        st = ctrl.pool[n]
        assert st.free == free, f"{n}: unit drift {st.free} != {free}"
        assert st.free_bw_gbps == pytest.approx(bw, abs=1e-6), \
            f"{n}: bandwidth drift {st.free_bw_gbps} != {bw}"


def test_colocated_release_does_not_overcredit():
    """The targeted regression: two colocated stages share bandwidth on one
    NIC via the Algorithm-3 credit; with a second deployment holding real
    bandwidth on the same NIC, the old per-unit release would push free
    bandwidth above pool truth (masked only when the NIC was otherwise
    empty). Exact conservation must hold with the NIC still occupied."""
    from repro.core.allocation import commit, release, resource_alloc
    from repro.core.pool import CPU, NicSpec, Pool

    pool = Pool([NicSpec("n0", "x", 16, {}, bandwidth_gbps=20.0)])
    S = ["s1", "s2"]
    need = {s: CPU for s in S}
    t_s = {"s1": 5.0, "s2": 5.0}
    # Deployment A: 2+2 colocated units; s2 reuses s1's bandwidth, so the
    # net charge is 10 Gbps, not 20.
    a = resource_alloc(S, {"s1": 2, "s2": 2}, t_s, pool, need)
    commit(pool, a, need)
    assert pool["n0"].free_bw_gbps == pytest.approx(10.0)
    # Deployment B occupies the remaining 10 Gbps.
    b = resource_alloc(["s1"], {"s1": 2}, t_s, pool, need)
    commit(pool, b, need)
    assert pool["n0"].free_bw_gbps == pytest.approx(0.0)
    # Releasing A must credit exactly its net 10 Gbps — the naive
    # units*t_s sum (20) would claim bandwidth B still holds.
    release(pool, a, need, t_s)
    assert pool["n0"].free_bw_gbps == pytest.approx(10.0)
    release(pool, b, need, t_s)
    assert pool["n0"].free_bw_gbps == pytest.approx(20.0)
    assert pool["n0"].free == {CPU: 16}


def test_shrink_resyncs_allocator_view():
    """After a scale-down the allocation matrix must carry no zero-unit rows
    and bw_after must equal pool truth (controller.py _shrink resync)."""
    from repro.core.profiler import synthetic_profile

    ctrl = MeiliController(paper_cluster())
    app = ALL_APPS(impl="ref")["FW"]
    prof = synthetic_profile(
        app.stage_names(),
        {"rule_match": 200e-6, "conn_track": 150e-6}, 1500 * 8 * 256.0)
    ctrl.submit(app, target_gbps=20.0, profile=prof)
    dep = ctrl.adaptive_scale(app.name, 2.0)
    for nic, row in dep.allocation.A.items():
        assert all(u > 0 for u in row.values()), (nic, row)
        assert dep.allocation.bw_after[nic] == \
            pytest.approx(ctrl.pool[nic].free_bw_gbps)
    ctrl.check_ledger()


# -- chaos-layer round-trips (ISSUE 6) ----------------------------------------

def _service_runtime(mix, pool, recovery=None, scenario="steady", seed=0):
    from repro.core.faults import RecoveryConfig
    from repro.service.runtime import RuntimeConfig, ServiceRuntime
    from repro.service.tenants import TenantRegistry, contracts
    from repro.service.workload import make_scenario

    ctrl = MeiliController(pool)
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario(scenario, contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl,
                        RuntimeConfig(dataplane_every=0, max_sim_seqs=32),
                        recovery=recovery)
    registry.admit_all()
    return rt


def test_flapping_nic_roundtrip_leaves_pool_at_baseline():
    """A flap (crash + scheduled revive) driven through the full service
    runtime — failover, autoscaling, re-placement — must keep the ledger
    exact every tick and return the pool to its empty baseline once every
    tenant terminates."""
    from repro.core.faults import FLAP, ChaosEngine, FaultEvent, FaultPlan
    from repro.service.tenants import default_tenant_mix

    pool = paper_cluster()
    base = snapshot(pool)
    rt = _service_runtime(default_tenant_mix(), pool, seed=5)
    load = {}
    for dep in rt.ctrl.deployments.values():
        for n, row in dep.allocation.A.items():
            load[n] = load.get(n, 0) + sum(row.values())
    sick = max(load, key=lambda n: (load[n], n))
    rt.run(20, chaos=ChaosEngine(FaultPlan(
        [FaultEvent(tick=6, kind=FLAP, nic=sick, duration_ticks=4)])))
    rt.ctrl.check_ledger()
    assert rt.ctrl.pool[sick].alive
    for name in list(rt.ctrl.deployments):
        rt.ctrl.terminate(name)
    rt.ctrl.check_ledger()
    assert rt.ctrl.pool.usage_snapshot() == {}
    for n, (free, bw) in base.items():
        st = rt.ctrl.pool[n]
        assert st.free == free, f"{n}: unit drift {st.free} != {free}"
        assert st.free_bw_gbps == pytest.approx(bw, abs=1e-6)


def test_over_capacity_failure_evicts_lowest_weight_first():
    """Five equal-size CPU-only tenants with distinct weights on two NICs;
    crashing the fuller NIC leaves surviving capacity for exactly one of its
    three victims. The governor's failover order hands that capacity to the
    heaviest contract, so the evicted set is exactly the lowest-weight
    victims — and the pool still round-trips to baseline, dead NIC
    included."""
    from repro.core.faults import CRASH, ChaosEngine, FaultEvent, FaultPlan
    from repro.core.faults import RecoveryConfig
    from repro.service.tenants import TenantSLA, TenantSpec

    pool = paper_cluster(n_bf2=2, n_bf1=0, n_pensando=0)
    base = snapshot(pool)
    mix = []
    for i in range(5):
        app = ALL_APPS(impl="ref")["FW"]
        mix.append(TenantSpec(
            name=f"t{i + 1}", app=app, profile=paper_profile("FW"),
            sla=TenantSLA(target_gbps=2.0, p99_latency_s=600e-6,
                          priority=i + 1)))
    rt = _service_runtime(mix, pool,
                          recovery=RecoveryConfig(park=False, brownout=False))
    assert len(rt.registry.active()) == 5
    hosted = {}
    for name in rt.registry.active():
        for n in rt.registry.deployment(name).nics_used():
            hosted.setdefault(n, set()).add(name)
    victim_nic = max(hosted, key=lambda n: (len(hosted[n]), n))
    victims = hosted[victim_nic]
    assert len(victims) >= 2, "packing premise: the fuller NIC is shared"
    weight = {s.name: float(s.sla.priority) for s in mix}
    rt.run(16, chaos=ChaosEngine(FaultPlan(
        [FaultEvent(tick=4, kind=CRASH, nic=victim_nic)])))
    evicted = set(rt.recovery.evicted)
    survivors = victims - evicted
    assert evicted and evicted < victims    # over capacity, but not for all
    # Strict weight order: every evicted victim is lighter than every
    # surviving one (heaviest-first re-placement over equal-size demands).
    assert max(weight[t] for t in evicted) < \
        min(weight[t] for t in survivors)
    assert survivors <= set(rt.registry.active())
    rt.ctrl.check_ledger()
    for name in list(rt.ctrl.deployments):
        rt.ctrl.terminate(name)
    rt.ctrl.check_ledger()
    assert rt.ctrl.pool.usage_snapshot() == {}
    for n, (free, bw) in base.items():
        st = rt.ctrl.pool[n]
        assert st.free == free, f"{n}: unit drift {st.free} != {free}"
        assert st.free_bw_gbps == pytest.approx(bw, abs=1e-6)
