"""Megaflow lookup kernel parity (ISSUE 9, satellite f).

Pins the three implementations of the bounded-window exact-match probe —
numpy oracle, jitted jnp fallback, Pallas kernel (interpret mode) — against
each other AND against a plain dict oracle, across load factors, forced
bucket collisions, epoch bumps, and query padding. Also pins the
incremental device-scatter maintenance path (device planes must equal the
host planes after any update sequence) and the trace-time compile counters
the zero-steady-state-recompile gate reads.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import flow_lookup as fl

CAP = 1 << 10
W = 8


def _fill(rng, n, cap=CAP, npipe=8, epoch_mix=None):
    """Build host planes holding n random entries inserted window-style
    (first empty slot in the probe window; overflowing keys dropped), plus
    the dict oracle {fid: (pid, epoch)}."""
    key_lo = np.zeros(cap, np.uint32)
    key_hi = np.zeros(cap, np.uint32)
    pid = np.full(cap, -1, np.int32)
    ep = np.zeros(cap, np.int32)
    oracle = {}
    fids = rng.choice(np.int64(1) << 40, size=n, replace=False).astype(np.int64)
    fids[n // 2:] = -fids[n // 2:]          # negative fids must round-trip
    lo, hi = fl.split_fids(fids)
    base = fl.bucket_hash(lo, hi) & np.uint32(cap - 1)
    for i in range(n):
        e = int(rng.integers(0, 3)) if epoch_mix else 0
        p = int(rng.integers(0, npipe))
        for w in range(W):
            s = (int(base[i]) + w) & (cap - 1)
            if pid[s] < 0:
                key_lo[s], key_hi[s] = lo[i], hi[i]
                pid[s], ep[s] = p, e
                oracle[int(fids[i])] = (p, e)
                break
    return (key_lo, key_hi, pid, ep), fids, oracle


def _oracle_lookup(oracle, q, cur_epoch):
    pids, fresh = [], []
    for f in q.tolist():
        p, e = oracle.get(int(f), (-1, -1))
        hit = p >= 0 and e == cur_epoch
        pids.append(p if hit else -1)
        fresh.append(hit)
    return np.array(pids, np.int32), np.array(fresh, bool)


def _queries(rng, fids, extra=64):
    """Half present keys, half absent (never-inserted) keys, shuffled."""
    absent = rng.choice(np.int64(1) << 40, size=extra).astype(np.int64) | (
        np.int64(1) << 41)                  # disjoint id space
    q = np.concatenate([rng.choice(fids, size=min(len(fids), 192)), absent])
    rng.shuffle(q)
    # pow-2 pad (the pallas wrapper requires F % block_f == 0 after padding)
    F = 1 << (len(q) - 1).bit_length()
    return np.concatenate([q, np.zeros(F - len(q), np.int64)])


@pytest.mark.parametrize("load", [0.25, 0.60, 0.90])
@pytest.mark.parametrize("cur_epoch", [0, 1])
def test_three_way_parity(load, cur_epoch):
    rng = np.random.default_rng(load.__hash__() % 1000 + cur_epoch)
    planes, fids, oracle = _fill(rng, int(CAP * load), epoch_mix=True)
    q = _queries(rng, fids)
    lo, hi = fl.split_fids(q)

    s_np, p_np, f_np = fl.lookup_numpy(*planes, lo, hi, cur_epoch, W)
    jp = [jnp.asarray(a) for a in planes]
    s_j, p_j, f_j = fl.lookup_jnp(*jp, jnp.asarray(lo), jnp.asarray(hi),
                                  cur_epoch, W)
    s_p, p_p, f_p = fl.lookup_pallas(*jp, jnp.asarray(lo), jnp.asarray(hi),
                                     cur_epoch, W, block_f=128,
                                     interpret=True)
    np.testing.assert_array_equal(s_np, np.asarray(s_j))
    np.testing.assert_array_equal(p_np, np.asarray(p_j))
    np.testing.assert_array_equal(f_np, np.asarray(f_j))
    np.testing.assert_array_equal(s_np, np.asarray(s_p))
    np.testing.assert_array_equal(p_np, np.asarray(p_p))
    np.testing.assert_array_equal(f_np, np.asarray(f_p))

    p_o, f_o = _oracle_lookup(oracle, q, cur_epoch)
    np.testing.assert_array_equal(p_np, p_o)
    np.testing.assert_array_equal(f_np, f_o)
    # slot is the revalidation handle: any-epoch key match.
    for i, f in enumerate(q.tolist()):
        assert (s_np[i] >= 0) == (int(f) in oracle)
        if s_np[i] >= 0:
            assert int(planes[2][s_np[i]]) == oracle[int(f)][0]


def test_forced_collisions_share_window():
    """Keys engineered into the SAME bucket must all resolve (window scan,
    not just the home slot)."""
    rng = np.random.default_rng(7)
    cand = rng.choice(np.int64(1) << 40, size=20000, replace=False)
    lo, hi = fl.split_fids(cand)
    bucket = fl.bucket_hash(lo, hi) & np.uint32(CAP - 1)
    tgt = bucket[0]
    same = cand[bucket == tgt][:W]          # window-many colliders
    assert len(same) >= 3, "need a few colliding keys"
    key_lo = np.zeros(CAP, np.uint32)
    key_hi = np.zeros(CAP, np.uint32)
    pid = np.full(CAP, -1, np.int32)
    ep = np.zeros(CAP, np.int32)
    slo, shi = fl.split_fids(same)
    for i in range(len(same)):
        s = (int(tgt) + i) & (CAP - 1)
        key_lo[s], key_hi[s], pid[s] = slo[i], shi[i], i
    q = np.concatenate([same, np.zeros(16 - len(same), np.int64)])
    qlo, qhi = fl.split_fids(q)
    s_np, p_np, f_np = fl.lookup_numpy(key_lo, key_hi, pid, ep, qlo, qhi, 0, W)
    assert (p_np[:len(same)] == np.arange(len(same))).all()
    jp = [jnp.asarray(a) for a in (key_lo, key_hi, pid, ep)]
    s_p, p_p, f_p = fl.lookup_pallas(*jp, jnp.asarray(qlo), jnp.asarray(qhi),
                                     0, W, block_f=16, interpret=True)
    np.testing.assert_array_equal(p_np, np.asarray(p_p))
    np.testing.assert_array_equal(s_np, np.asarray(s_p))


def test_epoch_bump_stales_everything_but_keeps_slots():
    rng = np.random.default_rng(3)
    planes, fids, oracle = _fill(rng, 200)
    q = _queries(rng, fids, extra=0)
    lo, hi = fl.split_fids(q)
    s0, p0, f0 = fl.lookup_numpy(*planes, lo, hi, 0, W)
    s1, p1, f1 = fl.lookup_numpy(*planes, lo, hi, 1, W)   # epoch bumped
    np.testing.assert_array_equal(s0, s1)   # slot: any-epoch match survives
    assert not f1.any()
    assert (p1 == -1).all()
    assert f0.sum() > 0


def test_apply_updates_matches_host():
    """Random incremental scatters: device planes == host planes after each
    flush, including sentinel-padded (dropped) slots."""
    rng = np.random.default_rng(11)
    host = [np.zeros(CAP, np.uint32), np.zeros(CAP, np.uint32),
            np.full(CAP, -1, np.int32), np.zeros(CAP, np.int32)]
    dev = tuple(jnp.asarray(a) for a in host)
    for _ in range(5):
        n = int(rng.integers(1, 50))
        slots = rng.integers(0, CAP, size=n)
        pad = np.full(8, CAP, np.int64)     # sentinels: must be dropped
        u_lo = rng.integers(0, 1 << 32, size=n, dtype=np.uint32)
        u_hi = rng.integers(0, 1 << 32, size=n, dtype=np.uint32)
        u_pid = rng.integers(-1, 8, size=n, dtype=np.int32)
        u_ep = rng.integers(0, 4, size=n, dtype=np.int32)
        host[0][slots], host[1][slots] = u_lo, u_hi
        host[2][slots], host[3][slots] = u_pid, u_ep
        dev = fl.apply_updates(
            dev, np.concatenate([slots, pad]),
            np.concatenate([u_lo, np.zeros(8, np.uint32)]),
            np.concatenate([u_hi, np.zeros(8, np.uint32)]),
            np.concatenate([u_pid, np.zeros(8, np.int32)]),
            np.concatenate([u_ep, np.zeros(8, np.int32)]))
        for d, h in zip(dev, host):
            np.testing.assert_array_equal(np.asarray(d), h)


def test_trace_counts_stable_across_repeat_calls():
    """The compile counters must not grow on warm shapes — the invariant
    the bench's zero-steady-state-recompile gate reads."""
    rng = np.random.default_rng(5)
    planes, fids, _ = _fill(rng, 100)
    jp = [jnp.asarray(a) for a in planes]
    q = _queries(rng, fids, extra=0)
    lo, hi = fl.split_fids(q)
    fl.lookup_jnp(*jp, jnp.asarray(lo), jnp.asarray(hi), 0, W)
    base = sum(fl.trace_counts().values())
    for e in range(4):                      # epoch is traced, not static
        fl.lookup_jnp(*jp, jnp.asarray(lo), jnp.asarray(hi), e, W)
    assert sum(fl.trace_counts().values()) == base
