"""Chaos harness (ISSUE 6): fault plans, gray-failure detection, recovery
with backoff re-admission, brownout grants, and the invariant sentinel."""
import dataclasses

import pytest

from repro.core.controller import MeiliController
from repro.core.faults import (CRASH, FLAP, GRAY, MID_MIGRATION, REVIVE,
                               ChaosEngine, FaultEvent, FaultPlan,
                               GrayFailureDetector, RecoveryConfig,
                               sentinel_check)
from repro.core.pool import paper_cluster
from repro.core.qos import ResourceGovernor, TenantQuota
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import (TenantRegistry, contracts,
                                   default_tenant_mix)
from repro.service.workload import make_scenario

FAST = RuntimeConfig(dataplane_every=0, max_sim_seqs=32)


def make_runtime(scenario="bursty", mix=None, cfg=FAST, seed=0,
                 recovery=None, pool=None):
    mix = mix or default_tenant_mix()
    ctrl = MeiliController(pool or paper_cluster())
    registry = TenantRegistry(ctrl)
    for spec in mix:
        registry.register(spec)
    wl = make_scenario(scenario, contracts(mix), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, cfg, recovery=recovery)
    registry.admit_all()
    return rt


def busiest_nic(ctrl):
    usage = {}
    for dep in ctrl.deployments.values():
        for n, row in dep.allocation.A.items():
            usage[n] = usage.get(n, 0) + sum(row.values())
    return max(usage, key=lambda n: (usage[n], n))


# -- fail_at shim vs explicit plan --------------------------------------------

def test_fail_at_shim_matches_explicit_crash_plan():
    """The legacy single-shot hook must be byte-equivalent to a one-event
    CRASH plan: same NIC failed, same survivors, same fault log."""
    rt_shim = make_runtime(seed=3)
    rt_shim.run(24, fail_at=(10, None))
    rt_plan = make_runtime(seed=3)
    rt_plan.run(24, chaos=ChaosEngine(FaultPlan(
        [FaultEvent(tick=10, kind=CRASH)])))
    shim_faults = [(f.tick, f.kind, f.nic) for f in rt_shim.telemetry.faults()]
    plan_faults = [(f.tick, f.kind, f.nic) for f in rt_plan.telemetry.faults()]
    assert shim_faults == plan_faults
    assert sorted(rt_shim.alive_tenants()) == sorted(rt_plan.alive_tenants())
    rt_shim.ctrl.check_ledger()
    rt_plan.ctrl.check_ledger()


# -- gray-failure detection ----------------------------------------------------

def test_gray_failure_detected_and_drained():
    """A silently degraded NIC (allocator still sees full capacity) must be
    convicted from achieved-throughput deviation alone, drained, and
    quarantined — with the ledger clean throughout."""
    cfg = dataclasses.replace(FAST, gray_detect=True)
    rt = make_runtime(scenario="steady", cfg=cfg, seed=1)
    sick = busiest_nic(rt.ctrl)
    rt.run(24, chaos=ChaosEngine(FaultPlan(
        [FaultEvent(tick=4, kind=GRAY, nic=sick, fraction=0.25)])))
    probations = [f.nic for f in rt.telemetry.faults("gray_probation")]
    assert sick in probations
    assert sick in {f.nic for f in rt.telemetry.faults("gray_quarantined")}
    # Quarantined = dead to the allocator, nothing left placed on it.
    assert not rt.ctrl.pool[sick].alive
    assert all(sick not in dep.nics_used()
               for dep in rt.ctrl.deployments.values())
    rt.ctrl.check_ledger()


def test_gray_detector_exoneration_and_localization():
    """One degraded observer cannot convict a NIC a full-service observer
    shares (min-across-observers); absent evidence holds a streak rather
    than resetting it."""
    det = GrayFailureDetector(threshold=0.3, min_ticks=2)
    for _ in range(4):
        det.observe({"sick": [0.6, 0.5], "shared": [0.6, 0.0]})
    assert det.suspects() == ["sick"]
    assert det.suspicion["shared"] < det.threshold
    streak = det.streak["sick"]
    det.observe({"other": [0.1]})         # no evidence for "sick" this tick
    assert det.streak["sick"] == streak   # held, not reset
    det.clear("sick")
    assert det.suspects() == []


# -- recovery: park -> backoff -> readmit -------------------------------------

def test_parked_tenant_readmitted_after_revive():
    """A tenant whose placement cannot be restored is parked, retried with
    exponential backoff, and re-admitted once the crashed NIC revives."""
    # One ISG tenant on a minimal pool: the contract needs BOTH crypto
    # NICs, so losing one leaves the tenant unplaceable until the revive.
    mix = [dataclasses.replace(default_tenant_mix()[2], backup_nic=None)]
    pool = paper_cluster(n_bf2=1, n_bf1=1, n_pensando=2)
    rt = make_runtime(mix=mix, pool=pool,
                      recovery=RecoveryConfig(park=True, seed=0))
    assert rt.registry.active() == ["t-isg"]
    rt.run(40, chaos=ChaosEngine(FaultPlan([
        FaultEvent(tick=4, kind=CRASH, nic="pensando-0"),
        FaultEvent(tick=18, kind=REVIVE, nic="pensando-0"),
    ])))
    assert [f.tenant for f in rt.telemetry.faults("parked")] == ["t-isg"]
    readmits = rt.telemetry.faults("readmitted")
    assert [f.tenant for f in readmits] == ["t-isg"]
    assert readmits[0].tick >= 18          # only possible after the revive
    assert rt.recovery.parked == {}
    assert rt.recovery.mean_time_to_recover() is not None
    assert rt.registry.active() == ["t-isg"]
    rt.ctrl.check_ledger()


def test_recovery_disabled_evicts_permanently():
    mix = [dataclasses.replace(default_tenant_mix()[2], backup_nic=None)]
    pool = paper_cluster(n_bf2=1, n_bf1=1, n_pensando=2)
    rt = make_runtime(mix=mix, pool=pool,
                      recovery=RecoveryConfig(park=False, brownout=False))
    rt.run(40, chaos=ChaosEngine(FaultPlan([
        FaultEvent(tick=4, kind=CRASH, nic="pensando-0"),
        FaultEvent(tick=18, kind=REVIVE, nic="pensando-0"),
    ])))
    assert rt.recovery.evicted == ["t-isg"]
    assert rt.telemetry.faults("readmitted") == []
    assert rt.registry.active() == []      # revive does not resurrect policy
    rt.ctrl.check_ledger()


# -- brownout ------------------------------------------------------------------

def test_brownout_factor_monotone_in_weight():
    gov = ResourceGovernor()
    gov.register("light", TenantQuota(max_gbps=10.0, weight=1.0))
    gov.register("heavy", TenantQuota(max_gbps=10.0, weight=3.0))
    assert gov.brownout_factor("light") == 1.0    # no brownout set
    gov.set_brownout(0.5)
    light, heavy = gov.brownout_factor("light"), gov.brownout_factor("heavy")
    assert 0.5 <= light < heavy <= 1.0
    gov.set_brownout(None)
    assert gov.brownout_factor("heavy") == 1.0


def test_scale_verdict_clamps_under_brownout():
    gov = ResourceGovernor()
    gov.register("t", TenantQuota(max_gbps=10.0, weight=1.0))
    # A heavier peer: brownout is weight-proportional, the heaviest tenant
    # keeps its full grant while lighter ones shed toward the level.
    gov.register("vip", TenantQuota(max_gbps=10.0, weight=4.0))
    gov.set_brownout(0.5)
    v = gov.scale_verdict("t", est_gbps=10.0, offered_gbps=10.0,
                          contract_gbps=10.0, current_gbps=10.0,
                          achievable_gbps=10.0)
    assert v.brownout
    assert v.target_gbps <= gov.brownout_factor("t") * 10.0 + 1e-9
    gov.set_brownout(None)
    v2 = gov.scale_verdict("t", est_gbps=10.0, offered_gbps=10.0,
                           contract_gbps=10.0, current_gbps=10.0,
                           achievable_gbps=10.0)
    assert not v2.brownout
    assert v2.target_gbps > v.target_gbps


# -- invariant sentinel --------------------------------------------------------

def test_sentinel_catches_flow_and_backlog_corruption():
    rt = make_runtime()
    rt.run(4)
    sentinel_check(rt)                     # healthy: no complaint
    dep = rt.registry.deployment("t-fw")
    dep.to.flow_table[999] = 424242        # flow mapped to missing pipeline
    with pytest.raises(AssertionError, match="missing pipeline"):
        sentinel_check(rt)
    del dep.to.flow_table[999]
    rt._backlog["t-fw"] = -1.0
    with pytest.raises(AssertionError, match="negative backlog"):
        sentinel_check(rt)


# -- mid-migration fault -------------------------------------------------------

def test_mid_migration_fault_conserves_flows_and_ledger():
    """A crash landed between make-before-break begin and finish (flows
    buffered, ledger already swapped) must leave no orphan flow and no
    ledger drift; the run itself sentinels after the event."""
    rt = make_runtime(seed=2)
    rt.run(24, chaos=ChaosEngine(FaultPlan(
        [FaultEvent(tick=8, kind=MID_MIGRATION)])))
    assert rt.telemetry.faults("mid_migration")   # fired (or honest no-op)
    for name in rt.registry.active():
        dep = rt.registry.deployment(name)
        pids = {p.pid for p in dep.to.pipelines}
        assert all(pid in pids for pid in dep.to.flow_table.values()), name
    rt.ctrl.check_ledger()


# -- failover no-op path -------------------------------------------------------

def test_inject_failure_with_nothing_allocated_is_noop():
    """No allocations anywhere: the failover path must record a no-op event
    instead of raising (chaos plans may fire into an empty pool)."""
    ctrl = MeiliController(paper_cluster())
    registry = TenantRegistry(ctrl)
    wl = make_scenario("steady", {})
    rt = ServiceRuntime(ctrl, registry, wl, FAST)
    failed, impacted = rt.inject_failure(None)
    assert failed is None and impacted == []
    assert rt.telemetry.faults("failover_skipped")
    ctrl.check_ledger()


# -- flap through the runtime --------------------------------------------------

def test_flap_schedules_revive_and_heals():
    rt = make_runtime(seed=4)
    sick = busiest_nic(rt.ctrl)
    rt.run(20, chaos=ChaosEngine(FaultPlan([
        FaultEvent(tick=5, kind=FLAP, nic=sick, duration_ticks=3)])))
    assert [f.nic for f in rt.telemetry.faults("flap")] == [sick]
    revives = rt.telemetry.faults("revive")
    assert revives and revives[0].tick == 8
    assert rt.ctrl.pool[sick].alive
    rt.ctrl.check_ledger()
