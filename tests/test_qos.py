"""QoS governor (ISSUE 4): quota admission + burst-credit roundtrip via the
pool ledger, DWRR weighted fairness under saturation, partial grants under
contention, the relocated do-no-harm/failover policies, and flash-crowd
isolation across seeds."""
import dataclasses

import pytest

from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster
from repro.core.qos import ResourceGovernor, TenantQuota, quota_from_sla
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import (AdmissionError, TenantRegistry, TenantSLA,
                                   TenantSpec, contracts, default_tenant_mix)
from repro.service.workload import make_scenario

FAST = RuntimeConfig(dataplane_every=0, max_sim_seqs=32)
QOS_POOL = dict(n_bf2=3, n_bf1=1, n_pensando=2)


def _registry(pool=None, governor=None):
    ctrl = MeiliController(pool or paper_cluster(),
                           governor=governor or ResourceGovernor())
    return ctrl, TenantRegistry(ctrl)


# -- quota admission + ledger roundtrip ---------------------------------------

def test_quota_clamps_submission_target_and_ledger_roundtrips():
    ctrl, registry = _registry()
    spec = default_tenant_mix()[3]           # t-fw, contract 10
    spec = dataclasses.replace(spec, quota=TenantQuota(max_gbps=4.0))
    registry.register(spec)
    baseline = {n: dict(ctrl.pool[n].free) for n in ctrl.pool.nics}
    dep = registry.admit(spec.name)
    # submit routed through the governor: the placed target is the quota,
    # not the contract, and the pool quota row records the entitlement.
    assert dep.target_gbps == pytest.approx(4.0)
    assert ctrl.pool.quota_row(spec.name)["max_gbps"] == pytest.approx(4.0)
    ctrl.check_ledger()
    registry.evict(spec.name)
    ctrl.check_ledger()
    assert {n: dict(ctrl.pool[n].free) for n in ctrl.pool.nics} == baseline
    assert ctrl.pool.quota_row(spec.name) == {}   # forget() cleared the row


def test_admission_rejection_routes_through_governor_verdict():
    ctrl, registry = _registry()
    spec = default_tenant_mix()[2]
    spec = dataclasses.replace(
        spec, name="t-huge",
        sla=TenantSLA(target_gbps=500.0, p99_latency_s=1e-3))
    registry.register(spec)
    with pytest.raises(AdmissionError):
        registry.admit("t-huge")
    assert "unplaceable" in registry.rejected["t-huge"]
    ctrl.check_ledger()
    assert ctrl.pool.usage_snapshot() == {}


# -- burst credits (token bucket) ---------------------------------------------

def test_burst_credits_spend_and_refill_roundtrip():
    gov = ResourceGovernor()
    gov.register("t", TenantQuota(max_gbps=5.0, burst_gbps=3.0,
                                  burst_refill_gbps=1.0))
    assert gov.credits["t"] == pytest.approx(3.0)
    # Over-quota ask: granted = quota + full bucket; bucket drains.
    v = gov.scale_verdict("t", est_gbps=20.0, offered_gbps=20.0,
                          contract_gbps=5.0, current_gbps=5.0,
                          achievable_gbps=5.0)
    assert v.target_gbps == pytest.approx(8.0)        # 5 + 3 credits
    assert v.burst_credit_spent == pytest.approx(3.0)
    assert gov.credits["t"] == pytest.approx(0.0)
    # Idle ticks refill the bucket at the declared rate, up to the depth.
    for expect in (1.0, 2.0, 3.0, 3.0):
        gov.begin_tick(active=["t"])
        assert gov.credits["t"] == pytest.approx(expect)
    # In-quota asks never burn credit.
    v = gov.scale_verdict("t", est_gbps=2.0, offered_gbps=2.0,
                          contract_gbps=5.0, current_gbps=5.0,
                          achievable_gbps=5.0)
    assert v.burst_credit_spent == 0.0
    assert gov.credits["t"] == pytest.approx(3.0)


def test_noop_verdict_burns_no_credit():
    """A verdict that does not trigger a rescale must not drain the bucket:
    credit pays for grants actually taken, not for asks."""
    gov = ResourceGovernor()
    gov.register("t", TenantQuota(max_gbps=10.0, burst_gbps=3.0))
    # Demand hovering just over quota, target already there: no pressure,
    # gap below threshold -> rescale=False every tick.
    for _ in range(5):
        v = gov.scale_verdict("t", est_gbps=9.5, offered_gbps=9.5,
                              contract_gbps=10.0, current_gbps=10.5,
                              achievable_gbps=12.0)
        assert not v.rescale
        assert v.burst_credit_spent == 0.0
    assert gov.credits["t"] == pytest.approx(3.0)


# -- partial grant under contention -------------------------------------------

def test_scale_verdict_partially_grants_against_headroom_ledger():
    pool = paper_cluster(n_bf2=0, n_bf1=1, n_pensando=0)   # 15 cpu units
    gov = ResourceGovernor()
    gov.bind(pool)
    gov.register("a", TenantQuota(weight=2.0))
    gov.register("b", TenantQuota(weight=1.0))
    pool["bf1-0"].take("cpu", 9)                            # 6 units free
    gov.begin_tick(pool, ["a", "b"])
    # Each unit is worth 2 Gbps; both tenants ask for ~5 units of growth.
    va = gov.scale_verdict("a", est_gbps=10.0, offered_gbps=10.0,
                           contract_gbps=20.0, current_gbps=0.0,
                           achievable_gbps=0.1, unit_gbps=2.0,
                           stage_kinds=["cpu"])
    vb = gov.scale_verdict("b", est_gbps=10.0, offered_gbps=10.0,
                           contract_gbps=20.0, current_gbps=0.0,
                           achievable_gbps=0.1, unit_gbps=2.0,
                           stage_kinds=["cpu"])
    # First asker drains the ledger; the second is partially granted.
    assert va.granted_frac == pytest.approx(1.0)
    assert vb.target_gbps < va.target_gbps
    assert vb.granted_frac < 1.0


def test_quota_max_units_caps_growth():
    gov = ResourceGovernor()
    gov.register("t", TenantQuota(max_units=3))
    v = gov.scale_verdict("t", est_gbps=100.0, offered_gbps=100.0,
                          contract_gbps=100.0, current_gbps=2.0,
                          achievable_gbps=2.0, unit_gbps=2.0,
                          stage_kinds=["cpu"], held_units=2)
    # 1 unit of room -> at most +2 Gbps of growth granted.
    assert v.target_gbps <= 4.0 + 1e-9


# -- DWRR ---------------------------------------------------------------------

def test_dwrr_weighted_fairness_under_saturation():
    gov = ResourceGovernor()
    for t, w in (("a", 2.0), ("b", 1.0), ("c", 1.0)):
        gov.register(t, TenantQuota(weight=w))
    served = {t: 0.0 for t in "abc"}
    backlog = {t: 0.0 for t in "abc"}
    cap = 100.0
    for _ in range(200):
        # Persistent saturation: every tenant offers the full link each tick.
        queues = {t: backlog[t] + cap for t in served}
        _, got = gov.dwrr_schedule(queues, capacity_bytes=cap)
        for t in served:
            served[t] += got[t]
            backlog[t] = queues[t] - got[t]
    assert served["a"] / served["b"] == pytest.approx(2.0, rel=0.1)
    assert served["b"] / served["c"] == pytest.approx(1.0, rel=0.1)


def test_dwrr_uncapped_drains_to_rate_caps_in_backlog_order():
    gov = ResourceGovernor()
    for t in ("x", "y"):
        gov.register(t, TenantQuota())
    queues = {"x": 50.0, "y": 500.0}
    caps = {"x": 100.0, "y": 200.0}
    order, served = gov.dwrr_schedule(queues, caps, capacity_bytes=None)
    assert served == {"x": 50.0, "y": 200.0}   # min(queue, rate cap) each
    assert order[0] == "y"                      # biggest weighted backlog first


def test_dwrr_disabled_governor_ignores_weights():
    gov = ResourceGovernor(enabled=False)
    gov.register("a", TenantQuota(weight=8.0))
    gov.register("b", TenantQuota(weight=1.0))
    served = {"a": 0.0, "b": 0.0}
    backlog = {"a": 0.0, "b": 0.0}
    for _ in range(100):
        queues = {t: backlog[t] + 100.0 for t in served}
        _, got = gov.dwrr_schedule(queues, capacity_bytes=100.0)
        for t in served:
            served[t] += got[t]
            backlog[t] = queues[t] - got[t]
    assert served["a"] / served["b"] == pytest.approx(1.0, rel=0.05)


# -- relocated policies -------------------------------------------------------

def test_migration_verdict_is_do_no_harm():
    gov = ResourceGovernor()
    ok = dict(hops_before=2, hops_after=1, achievable_before=5.0,
              achievable_after=5.0, nics_before=3, nics_after=2)
    assert gov.migration_verdict(**ok)
    assert not gov.migration_verdict(**{**ok, "hops_after": 3})
    assert not gov.migration_verdict(**{**ok, "achievable_after": 4.0})
    # no improvement -> rejected unless the caller pinned the targets
    same = dict(hops_before=1, hops_after=1, achievable_before=5.0,
                achievable_after=5.0, nics_before=2, nics_after=2)
    assert not gov.migration_verdict(**same)
    assert gov.migration_verdict(**same, require_improvement=False)
    # the guard holds even with QoS policy disabled
    assert not ResourceGovernor(enabled=False).migration_verdict(
        **{**ok, "hops_after": 3})


def test_replacement_demand_splits_room_across_stages():
    """A binding unit quota deals re-placement room round-robin so no lost
    stage is zeroed (a zeroed stage kills the tenant outright)."""
    gov = ResourceGovernor()
    gov.register("t", TenantQuota(max_units=6))
    out = gov.replacement_demand("t", {"sha": 2, "aes": 2}, held_units=4)
    assert out == {"sha": 1, "aes": 1}        # room 2, split 1/1
    # Uncapped (or disabled) passes the demand through untouched.
    gov2 = ResourceGovernor()
    gov2.register("u", TenantQuota())
    assert gov2.replacement_demand("u", {"a": 3}, held_units=99) == {"a": 3}


def test_failover_order_is_weight_descending_stable():
    gov = ResourceGovernor()
    gov.register("lo1", TenantQuota(weight=1.0))
    gov.register("hi", TenantQuota(weight=3.0))
    gov.register("lo2", TenantQuota(weight=1.0))
    assert gov.failover_order(["lo1", "hi", "lo2"]) == ["hi", "lo1", "lo2"]
    # disabled -> every tenant weighs 1.0, so the (weight, name) tie-break
    # pins name order regardless of how the list was handed in
    assert ResourceGovernor(enabled=False).failover_order(
        ["lo1", "hi", "lo2"]) == ["hi", "lo1", "lo2"]


def test_ordering_is_invariant_to_registration_order():
    """Determinism fix (ISSUE 8): priority_order and dwrr_schedule tie-break
    by (weight, name), never by dict insertion order — any registration
    shuffle of the same quotas yields byte-identical decisions."""
    import random

    quotas = {f"t{i:02d}": TenantQuota(weight=float(1 + i % 3))
              for i in range(12)}
    queues = {t: 1000.0 * (1 + i % 5) for i, t in enumerate(quotas)}
    caps = {t: 4000.0 for t in quotas}
    baseline = None
    rng = random.Random(8)
    for trial in range(6):
        names = list(quotas)
        rng.shuffle(names)
        gov = ResourceGovernor()
        for t in names:
            gov.register(t, quotas[t])
        order, served = gov.dwrr_schedule(dict(queues), dict(caps),
                                          capacity_bytes=9000.0)
        got = (gov.priority_order(list(quotas)), order,
               sorted(served.items()))
        if baseline is None:
            baseline = got
        else:
            assert got == baseline, f"shuffle {trial} diverged"


# -- flash-crowd isolation ----------------------------------------------------

def _flash_run(seed: int, ticks: int = 48):
    mix = [dataclasses.replace(s, backup_nic=None)
           for s in default_tenant_mix()]
    ctrl, registry = _registry(pool=paper_cluster(**QOS_POOL))
    for spec in mix:
        registry.register(spec)
    wl = make_scenario("flash_crowd", contracts(mix), seed=seed,
                       surge=8.0, crowd="t-fw")
    rt = ServiceRuntime(ctrl, registry, wl, FAST)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()
    return ctrl, rt


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_flash_crowd_cannot_break_in_quota_tenants(seed):
    """A crowd tenant at 8x its quota queues behind its own deficit: every
    other (in-quota) tenant stays within SLO, the crowd's provision target
    never exceeds its quota, and its excess shows up as its own backlog."""
    ctrl, rt = _flash_run(seed)
    report = rt.slo_report()
    for tenant, r in report.items():
        if tenant != "t-fw":
            assert r["pass"], (seed, tenant, r)
    crowd = rt.telemetry.series("t-fw")
    quota = ctrl.governor.quota("t-fw").max_gbps
    assert max(t.granted_gbps for t in crowd) <= quota + 1e-6
    assert max(t.backlog_pkts for t in crowd) > 0.0
