"""Sharded control plane (ISSUE 8): the 1-shard bit-compatibility contract
and the multi-shard routing/reconciliation behaviors.

The headline test: a ``ShardedController`` over a single-rack pool IS the
legacy ``MeiliController`` — identical placements, identical TelemetryLog
summaries, and an identical trace event sequence once the ``shard`` labels
(the only sanctioned difference) are normalized away. Byte-compared, not
spot-checked.
"""
from __future__ import annotations

import dataclasses
import json

import pytest

from repro.core.controller import MeiliController
from repro.core.faults import (FLAP, GRAY, MID_MIGRATION, RACK, REVIVE,
                               ChaosEngine, FaultEvent, FaultPlan,
                               RecoveryConfig)
from repro.core.pool import paper_cluster
from repro.core.shard import ControlShard, ShardedController
from repro.obs import RECONCILE
from repro.service.runtime import RuntimeConfig, ServiceRuntime
from repro.service.tenants import (TenantRegistry, contracts,
                                   default_tenant_mix)
from repro.service.workload import make_scenario

FAST = RuntimeConfig(dataplane_every=0, max_sim_seqs=32)


# -- helpers -------------------------------------------------------------------

def _normalized_events(trace):
    """Trace events minus the sanctioned sharding differences: the
    ``shard``-ish detail labels and wall-clock stamps."""
    out = []
    for e in trace.events:
        d = {k: v for k, v in e.detail.items()
             if k not in ("shard", "shard_from")}
        d.pop("duration_s", None)
        out.append((e.tick, e.kind, e.name, e.tenant, e.nic, e.span_id,
                    e.parent_id, e.phase, json.dumps(d, sort_keys=True)))
    return out


def _normalized_faults(tele):
    return [dataclasses.replace(f, shard=None) for f in tele.faults()]


def _run_pair(scenario, seed=0, ticks=40, pool_kw=None, chaos_plan=None,
              recovery=None, backups=None):
    """Run the same seeded scenario under the legacy and the 1-shard
    sharded controller; return both runtimes."""
    pool_kw = dict(pool_kw or {})
    pool_kw["racks"] = 1
    out = []
    for cls in (MeiliController, ShardedController):
        ctrl = cls(paper_cluster(**pool_kw))
        registry = TenantRegistry(ctrl)
        mix = default_tenant_mix()
        if backups is not None:
            mix = [dataclasses.replace(s, backup_nic=backups[i % len(backups)])
                   for i, s in enumerate(mix)]
        for spec in mix:
            registry.register(spec)
        wl = make_scenario(scenario, contracts(default_tenant_mix()),
                           seed=seed)
        rt = ServiceRuntime(ctrl, registry, wl, FAST, recovery=recovery)
        registry.admit_all()
        engine = ChaosEngine(chaos_plan) if chaos_plan is not None else None
        rt.run(ticks, chaos=engine)
        ctrl.check_ledger()
        out.append(rt)
    return out


def _assert_identical(rt_legacy, rt_sharded):
    assert (json.dumps(rt_legacy.telemetry.summary(), sort_keys=True)
            == json.dumps(rt_sharded.telemetry.summary(), sort_keys=True))
    assert rt_legacy.slo_report() == rt_sharded.slo_report()
    assert (_normalized_faults(rt_legacy.telemetry)
            == _normalized_faults(rt_sharded.telemetry))
    assert (_normalized_events(rt_legacy.obs.trace)
            == _normalized_events(rt_sharded.obs.trace))


# -- 1-shard bit-compatibility -------------------------------------------------

@pytest.mark.parametrize("scenario", ["bursty", "diurnal"])
def test_one_shard_is_legacy_controller(scenario):
    rt_l, rt_s = _run_pair(scenario, seed=0, ticks=40)
    assert len(rt_s.ctrl.shards) == 1
    _assert_identical(rt_l, rt_s)


def test_one_shard_is_legacy_controller_under_chaos():
    """The chaos --fast scenario (flap + gray + mid-migration crash + rack
    outage + repair wave) on a single-rack pool: recovery parking, brownout,
    gray detection — every decision byte-identical across controllers."""
    ticks = 48
    plan = FaultPlan([
        FaultEvent(tick=5, kind=FLAP, nic="bf2-1", duration_ticks=4),
        FaultEvent(tick=13, kind=GRAY, nic="bf2-2", fraction=0.25),
        FaultEvent(tick=21, kind=MID_MIGRATION),
        FaultEvent(tick=27, kind=RACK, rack="rack0"),
        FaultEvent(tick=34, kind=REVIVE, rack="rack0"),
        FaultEvent(tick=34, kind=REVIVE, nic="bf2-2"),
    ])
    cfgs = dict(
        scenario="chaos", seed=0, ticks=ticks,
        pool_kw=dict(n_bf2=4, n_bf1=2, n_pensando=2),
        chaos_plan=plan,
        recovery=RecoveryConfig(park=True, brownout=True, seed=0),
        backups=("bf1-0", "bf1-1"))
    rt_l, rt_s = _run_pair(**cfgs)
    assert rt_s.telemetry.faults(), "chaos plan did not fire"
    _assert_identical(rt_l, rt_s)


def test_one_shard_trace_has_no_reconcile_spans():
    """Single-shard reconciliation is vacuous and must stay silent — the
    1-shard trace is the legacy trace."""
    _, rt_s = _run_pair("bursty", ticks=20)
    assert rt_s.obs.trace.spans(name=RECONCILE) == []


# -- multi-shard routing -------------------------------------------------------

def _sharded_runtime(ticks=24, scenario="bursty", seed=0, staleness=4):
    pool = paper_cluster()          # 4 racks
    ctrl = ShardedController(pool, staleness_ticks=staleness)
    registry = TenantRegistry(ctrl)
    for spec in default_tenant_mix():
        registry.register(spec)
    wl = make_scenario(scenario, contracts(default_tenant_mix()), seed=seed)
    rt = ServiceRuntime(ctrl, registry, wl, FAST)
    registry.admit_all()
    rt.run(ticks)
    ctrl.check_ledger()
    return rt


def test_multi_shard_assigns_owners_and_reconciles():
    rt = _sharded_runtime()
    ctrl = rt.ctrl
    assert len(ctrl.shards) == 4
    for t in rt.alive_tenants():
        shard = ctrl.shard_of(t)
        assert shard in ctrl.shards
    # Every shard digest was refreshed within the staleness bound.
    for sh in ctrl.shards.values():
        assert rt.obs.trace.now_tick - sh.digest_tick <= ctrl.staleness_ticks
    spans = rt.obs.trace.spans(name=RECONCILE)
    assert spans, "multi-shard run must audit reconcile spans"
    for sp in spans:
        assert sp.detail["staleness_bound"] == ctrl.staleness_ticks
        assert all(age <= ctrl.staleness_ticks + 1
                   for age in sp.detail["ages"].values())


def test_multi_shard_tick_equals_legacy_tenant_outcomes():
    """Sharding changes placement scope, not workload accounting: the same
    scenario admits the same tenants and keeps them alive."""
    pool_legacy = paper_cluster()
    ctrl_l = MeiliController(pool_legacy)
    reg_l = TenantRegistry(ctrl_l)
    for spec in default_tenant_mix():
        reg_l.register(spec)
    reg_l.admit_all()
    rt = _sharded_runtime()
    assert sorted(rt.registry.admitted) == sorted(reg_l.admitted)
    assert sorted(rt.alive_tenants()) == sorted(rt.registry.admitted)


def test_cross_rack_spill_is_audited():
    """A tenant whose demand exceeds any one rack's headroom spills
    pool-wide, and the spill is a traced decision ``why()`` can explain."""
    # One rack of the small pool cannot hold the whole default mix: keep
    # admitting until some placement must cross racks.
    pool = paper_cluster(n_bf2=4, n_bf1=2, n_pensando=2, racks=2)
    ctrl = ShardedController(pool)
    registry = TenantRegistry(ctrl)
    mix = []
    for i in range(6):
        for spec in default_tenant_mix():
            mix.append(dataclasses.replace(
                spec, name=f"{spec.name}-{i}", backup_nic=None))
    for spec in mix:
        registry.register(spec)
    registry.admit_all()
    events = ctrl.obs.trace.query(name="cross_rack_placement")
    assert events, "over-packed 2-rack pool must spill cross-rack"
    ev = events[0]
    assert ev.detail["shard"] in ctrl.shards
    assert ev.detail["reason"].startswith("shard headroom exhausted")
    # why(tenant, tick) surfaces the spill decision end to end.
    assert any(e.name == "cross_rack_placement"
               for e in ctrl.obs.trace.why(ev.tenant, ev.tick))


def test_drain_candidates_prefer_owning_shard():
    pool = paper_cluster()
    ctrl = ShardedController(pool)
    nic = pool.names()[0]
    rack = pool.nics[nic].spec.rack
    cands = ctrl.drain_nic_candidates(nic)
    assert len(cands) >= 2
    # First candidate set: the sick NIC's shard minus itself.
    assert cands[0]
    assert all(pool.nics[n].spec.rack == rack for n in cands[0])
    assert nic not in cands[0]
    # Fallback: the pool-wide healthy set.
    assert set(cands[0]) < set(cands[-1])


def test_control_shard_digest_and_score():
    pool = paper_cluster(n_bf2=2, n_bf1=1, n_pensando=1, racks=1)
    sh = ControlShard("rack0", pool.rack_members("rack0"))
    sh.refresh(pool, tick=3)
    assert sh.digest_tick == 3
    assert sh.digest.get("cpu", 0) > 0
    assert sh.digest_fit({"cpu": 1})
    assert not sh.digest_fit({"cpu": 10 ** 6})
    # score = binding kind's slack ratio
    cpu_free = sh.digest["cpu"]
    assert sh.score({"cpu": 2}) == pytest.approx(cpu_free / 2)
