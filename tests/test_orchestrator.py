"""Traffic Orchestrator + ring buffers — data-plane invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.apps.packets import synth_packets
from repro.core.orchestrator import SubBatch, TrafficOrchestrator, flow_ids
from repro.core.ringbuffer import make_ring, peek, pop, push


# -- ring buffer ---------------------------------------------------------------

def test_ring_fifo_and_wraparound():
    proto = {"x": jnp.zeros((3,), jnp.int32)}
    ring = make_ring(proto, cap=8)
    for wave in range(5):                       # 5 waves of 5 > cap wraps
        rows = {"x": (jnp.arange(15) + 100 * wave).reshape(5, 3)}
        assert int(ring.space) >= 5
        ring = push(ring, rows)
        ring, out, valid = pop(ring, 5)
        assert bool(valid.all())
        np.testing.assert_array_equal(np.asarray(out["x"]),
                                      np.asarray(rows["x"]))
    assert int(ring.occupancy) == 0


def test_ring_partial_pop_masks_garbage():
    ring = make_ring({"x": jnp.zeros((), jnp.int32)}, cap=4)
    ring = push(ring, {"x": jnp.asarray([7, 8])})
    ring, out, valid = pop(ring, 4)
    assert valid.tolist() == [True, True, False, False]
    assert out["x"][:2].tolist() == [7, 8]


def test_ring_occupancy_monotonic_cursors():
    ring = make_ring({"x": jnp.zeros((), jnp.int32)}, cap=4)
    ring = push(ring, {"x": jnp.asarray([1, 2, 3])})
    assert int(ring.occupancy) == 3
    ring, _, _ = pop(ring, 2)
    assert int(ring.occupancy) == 1
    assert int(ring.head) == 2 and int(ring.tail) == 3  # monotonic (mod cap)


def test_ring_peek_does_not_consume():
    ring = make_ring({"x": jnp.zeros((), jnp.int32)}, cap=4)
    ring = push(ring, {"x": jnp.asarray([5])})
    rows, valid = peek(ring, 1)
    assert int(rows["x"][0]) == 5
    assert int(ring.occupancy) == 1


# -- partition / aggregation ------------------------------------------------------

def test_partition_aggregate_identity():
    pkts = synth_packets(batch=64, num_flows=10, pkt_bytes=64)
    to = TrafficOrchestrator(num_pipelines=4, capacity_per_pipeline=8)
    subs = to.partition(pkts)
    out = to.aggregate(subs, total=64)
    for a, b in zip(jax.tree.leaves(pkts), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_flow_stickiness_under_capacity():
    pkts = synth_packets(batch=32, num_flows=4, pkt_bytes=64)
    to = TrafficOrchestrator(num_pipelines=4, capacity_per_pipeline=1000)
    to.partition(pkts)
    first = dict(to.flow_table)
    to.partition(pkts)                          # same flows again
    assert to.flow_table == first


def test_heavy_flow_spills_only_at_capacity():
    """Paper §5.1.2: a flow splits across pipelines only when its pipeline
    hits the capacity limit."""
    pkts = synth_packets(batch=40, num_flows=1, pkt_bytes=64)
    to = TrafficOrchestrator(num_pipelines=4, capacity_per_pipeline=16)
    subs = to.partition(pkts)
    sizes = sorted((len(s.indices) for s in subs), reverse=True)
    assert sum(sizes) == 40
    assert sizes[0] == 16                      # home pipeline filled first
    assert len(sizes) == 3                     # spill uses minimum pipelines


def test_light_flows_stay_single_pipeline():
    pkts = synth_packets(batch=8, num_flows=1, pkt_bytes=64)
    to = TrafficOrchestrator(num_pipelines=4, capacity_per_pipeline=16)
    subs = to.partition(pkts)
    assert len(subs) == 1


def test_migration_buffers_and_releases():
    pkts = synth_packets(batch=16, num_flows=2, pkt_bytes=64)
    to = TrafficOrchestrator(num_pipelines=2, capacity_per_pipeline=100)
    to.partition(pkts)
    f = next(iter(to.flow_table))
    to.begin_migration(f)
    subs = to.partition(pkts)                   # packets of f get buffered
    assert all((flow_ids(s.data) != f).all() for s in subs)
    buffered = to.finish_migration(f, dst_pid=1)
    assert to.flow_table[f] == 1
    assert sum(len(b.indices) for b in buffered) > 0


def test_halt_pipeline_reroutes():
    pkts = synth_packets(batch=16, num_flows=4, pkt_bytes=64)
    to = TrafficOrchestrator(num_pipelines=2, capacity_per_pipeline=100)
    to.partition(pkts)
    flows = to.halt_pipeline(0)
    subs = to.partition(pkts)
    assert all(s.pid != 0 for s in subs)


@given(batch=st.integers(1, 64), flows=st.integers(1, 16),
       pipes=st.integers(1, 6), cap=st.integers(1, 64))
@settings(max_examples=40, deadline=None)
def test_property_partition_is_a_partition(batch, flows, pipes, cap):
    pkts = synth_packets(batch=batch, num_flows=flows, pkt_bytes=32)
    to = TrafficOrchestrator(num_pipelines=pipes, capacity_per_pipeline=cap)
    subs = to.partition(pkts)
    idx = np.concatenate([s.indices for s in subs]) if subs else np.array([])
    assert sorted(idx.tolist()) == list(range(batch))   # exactly once each
    seqs = [s.seq for s in subs]
    assert len(set(seqs)) == len(seqs)                   # unique seq numbers
