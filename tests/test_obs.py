"""Observability layer (ISSUE 7): percentiles, metrics registry, decision
trace — span nesting, queries, and the JSONL artifact round trip."""
import json

import numpy as np
import pytest

from repro.apps import ALL_APPS
from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster
from repro.core.profiler import synthetic_profile
from repro.obs import Obs, load_trace
from repro.obs.metrics import MetricsRegistry
from repro.obs.percentiles import P2Quantile, Reservoir
from repro.obs.trace import DecisionTrace

BITS = 1500 * 8 * 256.0
ISG_LAT = {"ddos_check": 400e-6, "url_check": 300e-6, "ipsec_encap": 150e-6,
           "sha": 250e-6, "aes": 350e-6}


def isg_profile():
    app = ALL_APPS(impl="ref")["ISG"]
    return app, synthetic_profile(app.stage_names(), ISG_LAT, BITS)


# -- percentiles --------------------------------------------------------------

def test_reservoir_exact_below_capacity():
    rng = np.random.default_rng(3)
    xs = rng.normal(5.0, 2.0, size=1000)
    r = Reservoir(capacity=4096, seed=0)
    r.observe_many(xs)
    assert r.exact
    for q in (0.5, 0.9, 0.99):
        assert r.quantile(q) == pytest.approx(
            float(np.quantile(xs, q)), rel=1e-12, abs=1e-12)


def test_reservoir_sampled_above_capacity_stays_close():
    rng = np.random.default_rng(4)
    xs = rng.lognormal(0.0, 0.5, size=50_000)
    r = Reservoir(capacity=4096, seed=1)
    r.observe_many(xs)
    assert not r.exact and r.count == 50_000
    assert r.quantile(0.99) == pytest.approx(
        float(np.quantile(xs, 0.99)), rel=0.05)


def test_p2_tracks_numpy_quantile():
    rng = np.random.default_rng(5)
    xs = rng.lognormal(0.0, 0.4, size=20_000)
    est = P2Quantile(0.99)
    for x in xs:
        est.observe(float(x))
    assert est.value() == pytest.approx(float(np.quantile(xs, 0.99)), rel=0.05)


# -- metrics registry ---------------------------------------------------------

def test_registry_label_model_and_prometheus_render():
    reg = MetricsRegistry()
    reg.counter("reqs_total", tenant="a").inc()
    reg.counter("reqs_total", tenant="a").inc(2)
    reg.counter("reqs_total", tenant="b").inc()
    # label order never splits a series
    assert reg.counter("dual", x="1", y="2") is reg.counter("dual", y="2", x="1")
    assert reg.get("reqs_total", tenant="a").value == 3
    assert reg.get("reqs_total", tenant="b").value == 1
    assert reg.get("reqs_total", tenant="zzz") is None
    assert len(reg.series("reqs_total")) == 2

    h = reg.histogram("lat_us", tenant="a")
    h.observe_many(np.arange(1.0, 101.0))
    assert h.count == 100 and h.quantile(0.5) == pytest.approx(50.5, rel=0.02)

    text = reg.render_prometheus()
    # counters: `_total` suffix exactly once (already-suffixed names untouched)
    assert 'reqs_total{tenant="a"} 3' in text
    assert "reqs_total_total" not in text
    assert "# TYPE reqs_total counter" in text
    reg.counter("plain", tenant="a").inc()
    text = reg.render_prometheus()
    assert 'plain_total{tenant="a"} 1' in text
    # histograms: spec-conformant cumulative buckets ending in +Inf
    assert "# TYPE lat_us histogram" in text
    assert 'lat_us_bucket{le="+Inf",tenant="a"} 100' in text
    # DEFAULT_BUCKETS top out at 10: values 1..100 put 1,2.5,5,10 on the
    # ladder -> cumulative 10 observations at le="10"
    assert 'lat_us_bucket{le="10",tenant="a"} 10' in text
    assert 'lat_us_count{tenant="a"} 100' in text
    assert 'lat_us_sum{tenant="a"} 5050' in text
    # quantiles stay queryable in code/JSONL, not in the exposition
    assert 'quantile=' not in text


def test_histogram_cumulative_buckets_monotone():
    reg = MetricsRegistry()
    h = reg.histogram("x_s", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    cum = h.cumulative_buckets()
    assert cum == [("0.1", 1), ("1", 3), ("10", 4), ("+Inf", 5)]
    vals = [c for _, c in cum]
    assert vals == sorted(vals)


def test_metrics_jsonl_dump(tmp_path):
    reg = MetricsRegistry()
    reg.gauge("pool_headroom_gbps", nic="bf2-0").set(7.5)
    reg.histogram("lat_s", tenant="t").observe(0.25)
    out = tmp_path / "metrics.jsonl"
    reg.dump_jsonl(out)
    recs = [json.loads(l) for l in out.read_text().splitlines()]
    byname = {(r["name"], tuple(sorted(r["labels"].items()))): r for r in recs}
    assert byname[("pool_headroom_gbps", (("nic", "bf2-0"),))]["value"] == 7.5
    assert byname[("lat_s", (("tenant", "t"),))]["count"] == 1


# -- decision trace -----------------------------------------------------------

def test_trace_span_nesting_and_why():
    tr = DecisionTrace()
    tr.set_tick(7)
    with tr.span("migrate", tenant="t-a") as outer:
        tr.event("scale_verdict", tenant="t-a", reason="granted")
        with tr.span("failover", nic="bf2-1", tenant="t-a"):
            tr.event("replace_unit", tenant="t-a", nic="bf2-2", kind="fault")
        outer.note(outcome="committed")
    spans = tr.spans()
    mig = next(s for s in spans if s.name == "migrate")
    fo = next(s for s in spans if s.name == "failover")
    assert fo.parent_id == mig.span_id and fo.span_id in mig.children
    assert mig.detail["outcome"] == "committed"
    assert mig.duration_s is not None and mig.duration_s >= 0
    # the nested point event is attributed to the innermost open span
    ev = tr.query(name="replace_unit")[0]
    assert ev.parent_id == fo.span_id and ev.tick == 7
    why = tr.why("t-a", 7)
    assert [e.name for e in why if e.phase != "end"] == [
        "migrate", "scale_verdict", "failover", "replace_unit"]
    assert tr.why("t-a", 8) == []


def test_why_tick_range_is_span_closed():
    """ISSUE 10 satellite: the range form of ``why`` returns every event in
    [tick_lo, tick_hi] and pulls in the out-of-window halves of any span
    that straddles the boundary — no dangling begin/end."""
    tr = DecisionTrace()
    tr.set_tick(3)
    tr.event("slo_burn", tenant="t-a", reason="p99")
    tr.set_tick(5)
    with tr.span("gray_drain", tenant="t-a", nic="bf2-2"):
        tr.set_tick(9)   # the span END lands outside the queried window
        tr.event("quarantine_verdict", tenant="t-a", nic="bf2-2")
    tr.set_tick(12)
    tr.event("slo_alert", tenant="t-a", state="resolved")
    tr.event("other", tenant="t-b")   # different tenant, never included

    sel = tr.why("t-a", tick_lo=3, tick_hi=6)
    names = [(e.name, e.phase) for e in sel]
    # burn + span begin in window; span end (tick 9) pulled in as closure
    assert ("slo_burn", "") in names
    assert ("gray_drain", "begin") in names and ("gray_drain", "end") in names
    assert not any(e.name == "slo_alert" for e in sel)
    assert not any(e.tenant == "t-b" for e in sel)
    # causal (seq) order survives the closure merge
    seqs = [e.seq for e in sel]
    assert seqs == sorted(seqs)
    # single-tick form still behaves as before
    assert [e.name for e in tr.why("t-a", 12)] == ["slo_alert"]
    # open-ended range = whole history for the tenant
    assert len(tr.why("t-a")) == 5


def test_controller_submit_migrate_failover_span_story():
    """ISSUE 7 acceptance slice: a mid-migration crash produces a failover
    span NESTED inside the migrate span, with the submit span before both —
    the causal story is readable straight off the trace."""
    from repro.core.qos import TenantQuota

    ctrl = MeiliController(paper_cluster())
    app, prof = isg_profile()
    ctrl.governor.register("t-isg", TenantQuota(max_gbps=5.0))
    ctrl.submit(app, target_gbps=7.0, profile=prof, tenant="t-isg")

    def on_swap(app_name):
        nic = sorted(ctrl.deployments[app_name].nics_used())[0]
        ctrl.handle_failure(nic)

    ctrl.mid_migration_hook = on_swap
    ev = ctrl.migrate(app.name, forced=True, require_improvement=False)
    assert ev is not None

    tr = ctrl.obs.trace
    sub = tr.spans(name="submit")[0]
    mig = tr.spans(name="migrate")[0]
    fo = tr.spans(name="failover")[0]
    assert sub.parent_id is None and sub.span_id < mig.span_id
    assert fo.parent_id == mig.span_id          # crash landed mid-migration
    assert mig.detail["outcome"] == "committed"
    assert sub.detail["granted_gbps"] >= 5.0
    # the governor's admission clamp was audited into the SAME trace, inside
    # the submit span (7.0 asked, quota caps at 5.0)
    clamp = tr.query(name="admission_verdict", tenant="t-isg") or \
        tr.query(name="admission_clamp", tenant="t-isg")
    assert clamp and clamp[0].parent_id == sub.span_id
    assert clamp[0].detail["granted_gbps"] == pytest.approx(5.0)


def test_trace_jsonl_round_trip_identical_queries(tmp_path):
    ctrl = MeiliController(paper_cluster())
    app, prof = isg_profile()
    ctrl.submit(app, target_gbps=5.0, profile=prof, tenant="t-isg")
    ctrl.obs.trace.set_tick(3)
    ctrl.migrate(app.name, forced=True, require_improvement=False)
    live = ctrl.obs.trace

    path = tmp_path / "trace.jsonl"
    live.dump_jsonl(path)
    loaded = load_trace(path)

    assert [e.to_json() for e in loaded.events] == \
           [e.to_json() for e in live.events]
    for q in ({"name": "migrate"}, {"tenant": "t-isg"},
              {"kind": "decision"}, {"tick": 3}):
        assert [e.to_json() for e in loaded.query(**q)] == \
               [e.to_json() for e in live.query(**q)]
    assert [e.to_json() for e in loaded.why("t-isg", 3)] == \
           [e.to_json() for e in live.why("t-isg", 3)]
    assert loaded.spans() == live.spans()
    # a loaded trace keeps recording without seq/span-id collisions
    before = {e.seq for e in loaded.events}
    loaded.event("post_mortem_note", kind="mark")
    assert loaded.events[-1].seq not in before


def test_obs_dump_artifacts(tmp_path):
    obs = Obs()
    obs.metrics.counter("c_total").inc()
    obs.trace.event("hello", tenant="t")
    paths = obs.dump(tmp_path / "art")
    tr = load_trace(paths["trace"])
    assert tr.query(name="hello")[0].tenant == "t"
    assert "c_total 1" in (tmp_path / "art" / "metrics.prom").read_text()
