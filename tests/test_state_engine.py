"""State engine: operators, access patterns, bounded-inconsistency sync."""
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core.state_engine import (FULL_ACCESS, NON_EXTERNAL_WRITE,
                                     LinkedHashTable, StateService,
                                     bounded_sync)


def make_service(n=3):
    return StateService([f"nic{i}" for i in range(n)], buckets=64)


def test_full_access_visible_everywhere():
    svc = make_service()
    svc.declare("ctr", FULL_ACCESS)
    svc.fstate_set("ctr", 42)
    for nic in svc.engines:
        assert svc.get("ctr", local=nic) == 42
    svc.fstate_remove("ctr")
    assert svc.get("ctr", local="nic0") is None


def test_non_external_write_local_write_global_read():
    svc = make_service()
    svc.declare("x", NON_EXTERNAL_WRITE)
    svc.ne_set("x", 7, local="nic1")
    # GET falls back to a remote read from nic1 (paper §4.3)
    r0 = svc.transport.reads
    assert svc.get("x", local="nic0") == 7
    assert svc.transport.reads == r0 + 1
    # local read does not touch the transport
    r1 = svc.transport.reads
    assert svc.get("x", local="nic1") == 7
    assert svc.transport.reads == r1


def test_traverse_pulls_tables_once():
    svc = make_service(n=4)
    for i, nic in enumerate(svc.engines):
        svc.ne_set(f"k{i}", i, local=nic)
    r0 = svc.transport.reads
    entries = svc.traverse(local="nic0")
    assert {e.s_name for e in entries} == {"k0", "k1", "k2", "k3"}
    # one batched read per remote engine, not per key
    assert svc.transport.reads == r0 + 3


def test_compute_ships_instruction():
    svc = make_service()
    svc.fstate_set("v", 5)
    out = svc.compute("v", ucf=lambda vals: sum(vals), combine=sum)
    assert out == 15                           # 5 on each of 3 engines


def test_expiry_lifespan():
    t = LinkedHashTable(buckets=8)
    t.put("a", 1, now=0.0)
    t.put("b", 2, now=400.0)
    assert t.expire(now=600.0, lifespan=500.0) == 1
    assert t.get("a") is None and t.get("b") is not None


def test_hash_collisions_still_correct():
    t = LinkedHashTable(buckets=1)             # force every key to collide
    for i in range(50):
        t.put(f"key{i}", i)
    assert all(t.get(f"key{i}").value == i for i in range(50))
    assert t.remove("key25") and t.get("key25") is None
    assert t.size == 49


def test_bounded_sync_counters_converge():
    """Paper §5.1.2: after the T-periodic merge, every replica holds the
    global value of a sum-like state."""
    values = np.array([[5.0], [3.0], [0.0]])
    snaps = np.zeros_like(values)
    merged, snaps = bounded_sync(values, snaps)
    np.testing.assert_allclose(merged, [[8.0]] * 3)
    # second epoch of local updates
    merged[0] += 2
    merged2, _ = bounded_sync(merged, snaps)
    np.testing.assert_allclose(merged2, [[10.0]] * 3)


@given(st.lists(st.lists(st.floats(-100, 100), min_size=2, max_size=5),
                min_size=1, max_size=6))
@settings(max_examples=100, deadline=None)
def test_property_bounded_sync_sum_preserving(updates_per_round):
    """Over any update sequence, post-sync replicas agree and equal the total
    of all deltas ever applied (counter semantics)."""
    P = len(updates_per_round[0])
    values = np.zeros((P, 1))
    snaps = np.zeros((P, 1))
    total = 0.0
    for round_updates in [updates_per_round[0]]:
        for i, d in enumerate(round_updates[:P]):
            values[i] += d
            total += d
    values, snaps = bounded_sync(values, snaps)
    np.testing.assert_allclose(values, total, atol=1e-6)


def test_bounded_sync_device_form():
    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from repro.core.state_engine import bounded_sync_deltas

    devs = jax.devices()
    if len(devs) < 1:
        pytest.skip("no devices")
    # single-device shard_map over a size-1 axis still exercises the psum path
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1,), ("p",))
    f = shard_map(lambda v, s: bounded_sync_deltas(v, s, "p"), mesh=mesh,
                  in_specs=(P("p"), P("p")), out_specs=(P("p"), P("p")))
    v = jnp.asarray([[4.0]])
    s = jnp.asarray([[1.0]])
    merged, snap = f(v, s)
    assert float(merged[0, 0]) == 4.0
