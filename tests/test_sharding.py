"""Logical-axis sharding resolver properties."""
import jax
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st
from jax.sharding import PartitionSpec

from repro.parallel.sharding import default_rules, spec_for


def mesh_2d():
    return jax.make_mesh((1, 1), ("data", "model"))


def mesh_like(shape_by_axis):
    """A fake mesh-shaped object is not enough — build real 1-device meshes
    and only exercise divisibility logic via axis sizes of 1? Instead use
    the actual device mesh with logical sizes by monkeypatching shape."""
    return None


class _FakeMesh:
    """Minimal mesh stand-in so divisibility logic is testable without
    actually creating hundreds of devices."""

    def __init__(self, sizes):
        self.axis_names = tuple(sizes)
        self.shape = dict(sizes)


def test_heads_take_model_axis_when_divisible():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = spec_for(("embed", "heads", "head_dim"), (512, 16, 64),
                    default_rules(), mesh)
    assert spec == PartitionSpec("data", "model", None)


def test_no_head_dim_fallback_by_default():
    # contraction-dim TP is disabled by default (see sharding.py note):
    # indivisible heads leave attention unsharded on the model axis.
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = default_rules()
    spec = spec_for(("embed", "heads", "head_dim"), (512, 36, 64), rules,
                    mesh)
    assert spec[1] is None and spec[2] is None


def test_batch_uses_pod_and_data_jointly():
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    spec = spec_for(("batch", "seq"), (256, 4096), default_rules(), mesh)
    assert spec == PartitionSpec(("pod", "data"), None)


def test_kv_heads_priority_over_kv_seq():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = spec_for(("layers", "batch", "kv_seq", "kv_heads", "head_dim"),
                    (16, 128, 32768, 16, 128), default_rules(), mesh)
    # kv_heads (priority 1) wins the model axis; kv_seq stays unsharded
    assert spec[3] == "model"
    assert spec[2] is None


def test_unknown_axis_replicates():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = spec_for(("mystery", None), (7, 3), default_rules(), mesh)
    assert spec == PartitionSpec(None, None)


def test_no_fsdp_rules():
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = spec_for(("vocab", "embed"), (50304, 2048), default_rules(False),
                    mesh)
    assert spec == PartitionSpec("model", None)


def test_divisibility_respected_fake_mesh():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = default_rules()
    # 36 heads % 16 != 0 and no head_dim fallback -> attention unsharded
    spec = spec_for(("embed", "heads", "head_dim"), (2304, 36, 64), rules,
                    mesh)
    assert spec == PartitionSpec("data", None, None)
    # vocab 256206 % 16 != 0 -> replicated; embed gets data (fsdp)
    spec = spec_for(("vocab", "embed"), (256206, 1024), rules, mesh)
    assert spec == PartitionSpec(None, "data")


def test_batch_fallback_to_data_only_fake_mesh():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    rules = default_rules()
    # batch 16 % (2*16) != 0 -> falls back to data alone
    spec = spec_for(("batch", "seq"), (16, 128), rules, mesh)
    assert spec == PartitionSpec("data", None)


@given(st.integers(1, 64), st.integers(1, 64), st.integers(1, 8),
       st.integers(1, 8))
@settings(max_examples=200, deadline=None)
def test_property_spec_always_divides(d1, d2, m1, m2):
    mesh = _FakeMesh({"data": m1, "model": m2})
    rules = default_rules()
    spec = spec_for(("embed", "ff"), (d1, d2), rules, mesh)
    for dim, s in zip((d1, d2), spec):
        if s is None:
            continue
        axes = (s,) if isinstance(s, str) else s
        size = int(np.prod([mesh.shape[a] for a in axes]))
        assert dim % size == 0


@given(st.integers(1, 8), st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_property_no_axis_used_twice(m1, m2):
    mesh = _FakeMesh({"data": m1, "model": m2})
    rules = default_rules()
    spec = spec_for(("embed", "heads", "head_dim", "ff"),
                    (m1 * m2 * 4, m2 * 2, m2 * 2, m2 * 2), rules, mesh)
    used = []
    for s in spec:
        if s is None:
            continue
        used.extend((s,) if isinstance(s, str) else s)
    assert len(used) == len(set(used))
