"""Dry-run machinery on a small in-process mesh (the 256/512-chip production
runs live in experiments/dryrun; this guards the mechanics in CI). Runs in a
subprocess so the 8-device XLA flag never leaks into other tests.

Uses the `reduced()` (tiny-dims, same-family) variant of olmo-1b with short
sequences so the lower+compile fits the tier-1 time budget — the mechanics
under test (SPMD sharding, collectives in the compiled HLO, roofline
decomposition) are dimension-independent."""
import json
import subprocess
import sys

CODE = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ["JAX_PLATFORMS"] = "cpu"   # never probe for TPU in the subprocess
import json, jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec
from repro.configs import get_arch
from repro.configs.base import ShapeConfig
from repro.models import build
from repro.launch.steps import (batch_shardings, build_shardings,
                                cache_shardings, make_serve_step,
                                make_train_step, opt_state_struct_and_sharding)
from repro.launch import roofline as rl
from repro.launch.decompose import decompose_cell
from repro.parallel.sharding import default_rules

cfg = get_arch("olmo-1b").reduced()
model = build(cfg)
mesh = jax.make_mesh((4, 2), ("data", "model"))
rules = default_rules()
out = {}

# train lower+compile
shape = ShapeConfig("t", 512, 8, "train")
p_struct, p_shard, _ = build_shardings(model, mesh, rules)
b_struct, b_shard = batch_shardings(model, shape, mesh, rules)
step_fn, _ = make_train_step(model, shape, mesh, rules)
o_struct, o_shard = opt_state_struct_and_sharding(model, mesh, p_shard,
                                                  p_struct, jnp.bfloat16)
sc = NamedSharding(mesh, PartitionSpec())
comp = jax.jit(step_fn, in_shardings=(p_shard, o_shard, b_shard, sc),
               out_shardings=(p_shard, o_shard, sc, sc),
               donate_argnums=(0, 1)).lower(
    p_struct, o_struct, b_struct,
    jax.ShapeDtypeStruct((), jnp.int32)).compile()
ca = comp.cost_analysis()
if isinstance(ca, (list, tuple)):      # older jax returns one dict per device
    ca = ca[0] if ca else {}
out["train_flops"] = float(ca.get("flops", 0))
out["train_coll"] = rl.collective_bytes(comp.as_text())["total"]

# decode lower+compile
shape_d = ShapeConfig("d", 256, 8, "decode")
c_struct, c_shard = cache_shardings(model, shape_d, mesh, rules)
b_struct, b_shard = batch_shardings(model, shape_d, mesh, rules)
serve = make_serve_step(model)
comp_d = jax.jit(serve, in_shardings=(p_shard, c_shard, b_shard["tokens"]),
                 donate_argnums=(1,)).lower(
    p_struct, c_struct, b_struct["tokens"]).compile()
out["decode_ok"] = 1

# decomposition
dec = decompose_cell(model, shape, mesh, rules)
out["roofline"] = dec["roofline"]
print(json.dumps(out))
"""


def test_dryrun_small_mesh():
    res = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True, timeout=600,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "HOME": "/root"})
    assert res.returncode == 0, res.stderr[-3000:]
    out = json.loads(res.stdout.strip().splitlines()[-1])
    assert out["train_flops"] > 0
    assert out["train_coll"] > 0                # SPMD => real collectives
    assert out["decode_ok"] == 1
    r = out["roofline"]
    assert r["dominant"] in ("compute", "memory", "collective")
    # tiny dims pad heavily on TPU-tile granularity, so the useful-flops
    # ratio sits far below the production configs' band — it just has to
    # be a sane positive fraction here.
    assert 0.0 < r["useful_flops_ratio"] < 1.5
    assert r["t_compute"] > 0 and r["t_memory"] > 0


def test_collective_parser_on_synthetic_hlo():
    from repro.launch.roofline import collective_bytes
    hlo = """
  %all-reduce.1 = f32[64,512]{1,0} all-reduce(%x), channel_id=1
  %ag = bf16[128,256]{1,0} all-gather(%y), dimensions={0}
  %t = (f32[8]{0}, f32[8]{0}) all-reduce(%a, %b), channel_id=2
  %ar-start = f32[16]{0} all-reduce-start(%c)
  %ar-done = f32[16]{0} all-reduce-done(%ar-start)
  %other = f32[4]{0} add(%p, %q)
"""
    out = collective_bytes(hlo)
    assert out["all-reduce"] == 64 * 512 * 4 + 2 * 8 * 4 + 16 * 4
    assert out["all-gather"] == 128 * 256 * 2
    assert out["count"] == 4
