"""Algorithm 1 (partial pipeline replication) — unit + property tests."""
import math

import pytest
from _hypothesis_shim import given, settings, st

from repro.core import replication as repl
from repro.core import sim


def test_paper_fig7c():
    """Fig 7(c): R = <2, 2, 3, 1> and 3 pipelines."""
    stages = ["S1", "S2", "S3", "S4"]
    lat = {"S1": 2.0, "S2": 1.7, "S3": 2.9, "S4": 1.0}
    R = repl.num_replication(stages, lat)
    assert R == {"S1": 2, "S2": 2, "S3": 3, "S4": 1}
    assert repl.num_pipelines(R) == 3


def test_paper_fig8b_pattern_ii():
    """Pipeline (II): split at S2, then S4; prefix stages scale to the min."""
    stages = ["S1", "S2", "S3", "S4"]
    lat = {"S1": 3.0, "S2": 1.0, "S3": 2.5, "S4": 1.2}
    R = repl.num_replication(stages, lat)
    assert R["S2"] == 1 and R["S4"] == 1
    assert R["S1"] == math.ceil(3.0 / 1.0)
    assert R["S3"] == math.ceil(2.5 / 1.2)


def test_uniform_stages_degenerate():
    stages = ["a", "b", "c"]
    R = repl.num_replication(stages, {s: 1.0 for s in stages})
    assert R == {s: 1 for s in stages}


def test_rejects_nonpositive_latency():
    with pytest.raises(ValueError):
        repl.num_replication(["a"], {"a": 0.0})


@st.composite
def pipelines(draw):
    n = draw(st.integers(1, 8))
    lat = {f"s{i}": draw(st.floats(0.1, 50.0)) for i in range(n)}
    return [f"s{i}" for i in range(n)], lat


@given(pipelines())
@settings(max_examples=200, deadline=None)
def test_property_global_min_gets_one(p):
    stages, lat = p
    R = repl.num_replication(stages, lat)
    d = min(stages, key=lambda s: lat[s])
    assert R[d] == 1
    assert all(r >= 1 for r in R.values())


@given(pipelines())
@settings(max_examples=200, deadline=None)
def test_property_capacity_matches_local_min(p):
    """Within each sub-pipeline, every stage's replicated capacity (R/L) is at
    least the capacity of the sub-pipeline's minimum stage."""
    stages, lat = p
    R = repl.num_replication(stages, lat)
    # reconstruct the recursive partition
    rest = list(stages)
    while rest:
        d = min(range(len(rest)), key=lambda i: lat[rest[i]])
        d_cap = 1.0 / lat[rest[d]]
        for s in rest[:d]:
            assert R[s] / lat[s] >= d_cap - 1e-9
        rest = rest[d + 1:]


@given(pipelines())
@settings(max_examples=100, deadline=None)
def test_property_partial_beats_full_when_min_is_last(p):
    """When the global minimum stage is LAST, the whole pipeline is one
    sub-pipeline and Algorithm 1 matches full replication's throughput with
    no more resources: ceil(max/L_d)·n >= Σ ceil(L_i/L_d)."""
    stages, lat = p
    d = min(stages, key=lambda s: lat[s])
    stages = [s for s in stages if s != d] + [d]      # move min to the end
    R = repl.num_replication(stages, lat)
    T_partial = repl.pipeline_throughput(stages, lat, R)
    c = math.ceil(T_partial * max(lat[s] for s in stages))
    full = repl.full_replication(stages, c)
    assert repl.pipeline_throughput(stages, lat, full) >= T_partial - 1e-9
    assert sum(R.values()) <= sum(full.values()) + 1e-9


def test_known_limitation_suffix_bottleneck():
    """Documented property of the paper's Algorithm 1 (DESIGN.md §5): it
    eliminates bubbles within sub-pipelines but does NOT balance a
    long-latency stage sitting AFTER the global minimum — the prefix can be
    overprovisioned relative to the suffix bottleneck. This pins the
    behaviour so any 'fix' is a conscious deviation from the paper."""
    stages = ["S1", "S2", "S3"]
    lat = {"S1": 10.0, "S2": 1.0, "S3": 9.0}
    R = repl.num_replication(stages, lat)
    assert R == {"S1": 10, "S2": 1, "S3": 1}
    # throughput capped by the unreplicated suffix stage S3:
    assert repl.pipeline_throughput(stages, lat, R) == pytest.approx(1 / 9)


@given(pipelines())
@settings(max_examples=30, deadline=None)
def test_property_sim_removes_bubbles(p):
    """Simulated steady-state throughput with R approaches the bottleneck
    service rate once enough sequences are in flight (> max replication)."""
    stages, lat = p
    R = repl.num_replication(stages, lat)
    n = min(4000, max(150, 25 * max(R.values())))
    res = sim.simulate(stages, lat, R, num_seqs=n)
    bound = min(R[s] / lat[s] for s in stages)
    assert res.throughput >= 0.7 * bound
