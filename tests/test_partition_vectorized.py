"""Vectorized flow-granular partitioner vs a straightforward reference loop.

The production partitioner (core.orchestrator.partition_assign) makes one
decision per unique flow and scatters per-packet assignments with numpy. The
reference here walks every packet of every flow one at a time with the plain
§5.1.2 rules. Both must produce identical flow->pipeline assignments and
identical TO state (flow table, spill table, loads) across random flow
mixes, spill pressure, migration-halted flows, and inactive pipelines.
"""
import numpy as np
import pytest

from repro.apps.packets import synth_packets
from repro.core.orchestrator import (ASSIGN_HALTED, TrafficOrchestrator,
                                     flow_ids)


def reference_partition_assign(to: TrafficOrchestrator, batch) -> np.ndarray:
    """One-packet-at-a-time flow-granular walk — the semantics oracle."""
    fids = flow_ids(batch)
    for p in to.pipelines:
        p.load = 0.0
    assign = np.full(len(fids), -1, np.int64)
    groups = {}
    for i, f in enumerate(fids):                 # first-appearance order
        groups.setdefault(int(f), []).append(i)
    avail = {p.pid: (p.capacity if p.active else 0.0) for p in to.pipelines}
    actives = [p.pid for p in to.pipelines if p.active]
    for f, idxs in groups.items():
        if f in to.halted_flows:
            for i in idxs:
                assign[i] = ASSIGN_HALTED
            continue
        if not actives:
            raise ValueError("partition: no active pipelines")
        home = to.flow_table.get(f)
        for i in idxs:
            pid = None
            if home is not None and to.pipelines[home].active \
                    and avail[home] >= 1.0:
                pid = home
            if pid is None:
                for spid in to.spill_table.get(f, ()):
                    if to.pipelines[spid].active and avail[spid] >= 1.0:
                        pid = spid
                        break
            if pid is None:
                pid = max(actives, key=lambda q: avail[q])
                if avail[pid] < 1.0:             # everything saturated
                    pid = max(actives, key=lambda q: to.pipelines[q].capacity)
                if home is None:
                    to.flow_table[f] = pid
                    home = pid
                elif pid != home:
                    sp = to.spill_table.setdefault(f, [])
                    if pid not in sp:
                        sp.append(pid)
            assign[i] = pid
            avail[pid] = max(0.0, avail[pid] - 1.0)
            to.pipelines[pid].load += 1.0
    return assign


def make_pair(pipes, cap):
    return (TrafficOrchestrator(pipes, cap), TrafficOrchestrator(pipes, cap))


def check_equal(to_v, to_r, batch):
    got = to_v.partition_assign(batch)
    want = reference_partition_assign(to_r, batch)
    np.testing.assert_array_equal(got, want)
    assert to_v.flow_table == to_r.flow_table
    assert to_v.spill_table == to_r.spill_table
    assert [p.load for p in to_v.pipelines] == \
           pytest.approx([p.load for p in to_r.pipelines])


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("pipes,cap", [(1, 8), (2, 4), (4, 16), (6, 3),
                                       (3, 17.5), (4, 1000.0)])
def test_random_mixes_match_reference(seed, pipes, cap):
    rng = np.random.default_rng(seed)
    to_v, to_r = make_pair(pipes, cap)
    for round_ in range(3):                       # state carries across rounds
        batch = synth_packets(batch=int(rng.integers(1, 200)),
                              num_flows=int(rng.integers(1, 40)),
                              pkt_bytes=32, seed=seed * 10 + round_)
        check_equal(to_v, to_r, batch)


@pytest.mark.parametrize("B,flows", [(40, 1), (120, 2), (64, 5)])
def test_spill_pressure_matches_reference(B, flows):
    to_v, to_r = make_pair(4, 10)                 # heavy spill: 4x10 << B
    batch = synth_packets(batch=B, num_flows=flows, pkt_bytes=32, seed=1)
    check_equal(to_v, to_r, batch)
    check_equal(to_v, to_r, batch)                # spill tables now populated


def test_overload_path_matches_reference():
    to_v, to_r = make_pair(3, 2)                  # total capacity 6 << B
    batch = synth_packets(batch=50, num_flows=8, pkt_bytes=32, seed=2)
    check_equal(to_v, to_r, batch)


def test_halted_flows_match_reference():
    batch = synth_packets(batch=60, num_flows=6, pkt_bytes=32, seed=3)
    to_v, to_r = make_pair(3, 100)
    check_equal(to_v, to_r, batch)
    f = next(iter(to_v.flow_table))
    to_v.begin_migration(f)
    to_r.begin_migration(f)
    got = to_v.partition_assign(batch)
    want = reference_partition_assign(to_r, batch)
    np.testing.assert_array_equal(got, want)
    assert (got == ASSIGN_HALTED).sum() > 0
    # the vectorized TO buffered exactly the halted packets
    buffered = np.concatenate([s.indices for s in to_v.halted_flows[f]])
    np.testing.assert_array_equal(np.sort(buffered),
                                  np.nonzero(got == ASSIGN_HALTED)[0])


def test_inactive_pipelines_match_reference():
    batch = synth_packets(batch=80, num_flows=10, pkt_bytes=32, seed=4)
    to_v, to_r = make_pair(4, 30)
    check_equal(to_v, to_r, batch)
    to_v.halt_pipeline(0)
    to_r.halt_pipeline(0)
    check_equal(to_v, to_r, batch)
    assert all(p != 0 for p in
               (to_v.partition_assign(batch)).tolist())


def test_all_pipelines_inactive_raises():
    to = TrafficOrchestrator(2, 8)
    to.halt_pipeline(0)
    to.halt_pipeline(1)
    with pytest.raises(ValueError):
        to.partition_assign(synth_packets(batch=4, num_flows=2, pkt_bytes=32))


def test_all_halted_batch_buffers_even_without_active_pipelines():
    """Scale-down mid-migration: a batch made only of halted-flow packets
    must buffer, not crash, even when every pipeline is inactive."""
    batch = synth_packets(batch=8, num_flows=2, pkt_bytes=32, seed=6)
    to = TrafficOrchestrator(1, 100)
    to.partition_assign(batch)
    for f in list(to.flow_table):
        to.begin_migration(f)
    to.halt_pipeline(0)
    assign = to.partition_assign(batch)
    assert (assign == ASSIGN_HALTED).all()
    assert sum(s.indices.size for b in to.halted_flows.values()
               for s in b) == 8


def test_partition_subs_still_partition_the_batch():
    batch = synth_packets(batch=77, num_flows=9, pkt_bytes=32, seed=5)
    to = TrafficOrchestrator(3, 20)
    subs = to.partition(batch)
    idx = np.concatenate([s.indices for s in subs])
    assert sorted(idx.tolist()) == list(range(77))
    seqs = [s.seq for s in subs]
    assert len(set(seqs)) == len(seqs)
