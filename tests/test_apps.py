"""The six paper applications: semantics + parallel data-plane equivalence."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.apps import ALL_APPS, app_resources, synth_packets
from repro.apps.nf import ddos_check
from repro.core.executor import ParallelDataPlane
from repro.core.graph import run_pipeline
from repro.core.pool import COMPRESSION, CPU, CRYPTO, REGEX

PKTS = synth_packets(batch=48, num_flows=6, pkt_bytes=256, seed=3)


@pytest.mark.parametrize("name", ["ID", "ICG", "ISG", "FW", "FM", "LLB"])
def test_parallel_equals_oracle(name):
    app = ALL_APPS(impl="ref")[name]
    oracle = run_pipeline(app, PKTS)
    dp = ParallelDataPlane(app, num_pipelines=3, capacity_per_pipeline=10)
    out = dp.process(PKTS)
    for a, b in zip(jax.tree.leaves(oracle), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_resource_footprints_match_paper_table3():
    apps = ALL_APPS(impl="ref")
    assert app_resources(apps["ID"]) == sorted({CPU, REGEX})
    assert app_resources(apps["ICG"]) == sorted({CPU, COMPRESSION})
    assert app_resources(apps["ISG"]) == sorted({CPU, REGEX, CRYPTO})
    assert app_resources(apps["FW"]) == [CPU]
    assert app_resources(apps["FM"]) == [CPU]
    assert app_resources(apps["LLB"]) == [CPU]
    assert len(apps["ISG"].stages) >= 4          # Listing 1's four functions


def test_stage_counts_match_paper():
    apps = ALL_APPS(impl="ref")
    assert len(apps["ID"].stages) == 3
    assert len(apps["ICG"].stages) == 2
    assert len(apps["FW"].stages) == 2
    assert len(apps["FM"].stages) == 2


def test_url_filter_drops_matches():
    app = ALL_APPS(impl="ref")["ID"]
    out = run_pipeline(app, PKTS)
    hits = np.asarray(out.meta["match_num"])
    mask = np.asarray(out.mask)
    assert hits.max() > 0, "traffic should contain embedded patterns"
    assert not mask[hits > 0].any(), "matched packets must be dropped"
    assert mask[hits == 0].all()


def test_ddos_check_flags_low_entropy():
    payload = np.zeros((2, 256), np.uint8)
    payload[0] = 65                              # constant payload: low joint H
    rng = np.random.default_rng(0)
    payload[1] = rng.integers(0, 256, 256)
    batch = dataclasses.replace(
        PKTS, payload=jnp.asarray(payload),
        length=jnp.asarray([256, 256]),
        five_tuple=PKTS.five_tuple[:2], mask=jnp.ones(2, bool), meta={})
    keep = ddos_check(batch)
    assert bool(keep[1])                         # random traffic passes


def test_ipsec_encrypts_payload_and_sets_esp():
    app = ALL_APPS(impl="ref")["ISG"]
    out = run_pipeline(app, PKTS)
    assert (np.asarray(out.five_tuple[:, 4]) == 50).all()
    assert not np.array_equal(np.asarray(out.payload), np.asarray(PKTS.payload))
    assert "digest" in out.meta


def test_flow_monitor_counters():
    app = ALL_APPS(impl="ref")["FM"]
    out = run_pipeline(app, PKTS)
    assert "pkt_count" in out.meta and "byte_count" in out.meta
    np.testing.assert_array_equal(np.asarray(out.meta["byte_count"]),
                                  np.asarray(PKTS.length))


def test_l7lb_assigns_backends():
    app = ALL_APPS(impl="ref")["LLB"]
    out = run_pipeline(app, PKTS)
    be = np.asarray(out.meta["backend"])
    assert be.min() >= 0 and be.max() < 8
    assert len(np.unique(be)) > 1                # spreads load
