PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench bench-dataplane

# Full run (no -x): the suite currently carries one known pre-existing
# failure (test_dryrun_small); stopping at it would skip later modules.
test:
	python -m pytest -q

# Full benchmark sweep (all paper figures + the data-plane grid).
bench:
	python -m benchmarks.run

# Just the fused data-plane grid; writes BENCH_dataplane.json.
bench-dataplane:
	python -m benchmarks.bench_dataplane
