PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-slow bench bench-obs bench-dataplane bench-megaflow bench-service bench-defrag bench-qos bench-chaos bench-control bench-slo check-bench

# Tier-1 suite. pytest.ini excludes `slow` tests by default (the small
# dry-run compiles a full train step and can take minutes), so this can
# never wedge the time budget; run them explicitly with `make test-slow`.
# The benchmark regression gate rides along: it compares the headline
# numbers recorded in BENCH_service.json against benchmarks/
# bench_baseline.json (no-op when no benchmark output exists).
test: check-bench
	python -m pytest -q

# Regression gate over recorded benchmark output (ISSUE 7).
check-bench:
	python -m benchmarks.check_bench

test-slow:
	python -m pytest -q -m slow

# Full benchmark sweep (all paper figures + the data-plane grid + Meili-Serve).
bench:
	python -m benchmarks.run

# Full sweep with observability artifacts: structured run log (rows.jsonl +
# meta.json) written under ./obs_artifacts (ISSUE 7).
bench-obs:
	python -m benchmarks.run --emit-obs

# Just the fused data-plane grid; writes BENCH_dataplane.json.
bench-dataplane:
	python -m benchmarks.bench_dataplane

# Megaflow fast path A/B (ISSUE 9): flow cache on vs slow-path-only
# classification at 10^4..10^5 concurrent churning flows; merges the
# `megaflow` record into BENCH_dataplane.json. Gated by `make check-bench`
# (classification speedup >= 5x, hit-rate >= 0.95, zero steady recompiles).
bench-megaflow:
	python -m benchmarks.bench_megaflow

# Meili-Serve deployment-mode comparison; writes BENCH_service.json.
# (`--fast` variant is exercised inside `make test` as a smoke check.)
bench-service:
	python -m benchmarks.bench_service

# Churn-heavy defragmentation A/B only (locality decay vs recovery);
# merges the `defrag` record into BENCH_service.json.
bench-defrag:
	python -m benchmarks.bench_service --scenario churn

# QoS governor scenarios (ISSUE 4): flash-crowd isolation A/B (governor on
# vs off) + adversarial-churn admission pressure; merges the `qos` and
# `adversarial_churn` records into BENCH_service.json.
bench-qos:
	python -m benchmarks.bench_service --scenario flashcrowd
	python -m benchmarks.bench_service --scenario adversarial

# Chaos fault-injection A/B (ISSUE 6): identical compound fault plan
# (flap, gray failure, mid-migration crash, rack outage, repair wave) run
# with recovery on vs off; merges the `chaos` record into
# BENCH_service.json and (ISSUE 7) dumps the decision-audit trace +
# metrics artifacts for both arms under ./obs_artifacts.
bench-chaos:
	python -m benchmarks.bench_service --scenario chaos --emit-obs

# Control-plane cost A/B (ISSUE 8): sharded+vectorized scheduling kernel vs
# the legacy scalar path at 100..1000 tenants on a synthetic 500-NIC rack;
# merges the `control` record into BENCH_service.json. The flat-control-
# cost bar (growth <= 1.5x from 100 to 1000 tenants) is gated by
# `make check-bench`.
bench-control:
	python -m benchmarks.bench_control

# SLO/alerting/flight overhead A/B (ISSUE 10): the fast chaos scenario with
# the error-budget engine + multi-window burn-rate alerting + flight
# recorder ON vs OFF; merges the `slo` record into BENCH_service.json. The
# <=5% wall-clock overhead bar is gated by `make check-bench`.
bench-slo:
	python -m benchmarks.bench_service --scenario slo
