"""Megaflow fast path: cache-on vs slow-path classification at 10⁵ flows.

The regime the flow cache exists for (ISSUE 9): 10⁴–10⁵ concurrent
short-lived flows with per-tick churn (the ``megaflow`` scenario's sliding
flow-id window), batched at 16k packets over 8 pipelines with 2× capacity
headroom. Two arms process the SAME tick sequence:

  cache arm — ParallelDataPlane with the flow cache (default config,
              2^18-slot table): steady-state classification is one device
              lookup + an O(misses) slow loop;
  slow arm  — flow_cache=False: the full per-unique-flow Python loop every
              batch (the pre-ISSUE-9 data plane).

Reported per flow count: end-to-end µs/batch and packets/s for both arms,
the classification-stage time (partition_assign alone — the loop the cache
replaces; the NF-chain compute after it is byte-identical in both arms and
so dilutes any end-to-end ratio), ``speedup`` (classification, the ≥5×
bar), ``speedup_e2e`` (whole process() call), steady-state hit rate
(flow-level and packet-weighted — the committed bar gates the
packet-weighted one), eviction/invalidation/fallback counters, and
steady-state recompiles (fused dispatch + lookup/scatter kernels, via
trace-time counters) which must be zero — the cache is prewarmed across
every pow-2 bucket before the timed window. Arms are interleaved over the
same tick chunks and each takes its min-over-rounds (contention-robust).

Results merge into BENCH_dataplane.json under the ``megaflow`` key
(bench_dataplane preserves it when rewriting its grid) and are gated by
benchmarks/check_bench.py: hit-rate ≥ 0.95, classification speedup ≥ 5×
and end-to-end speedup ≥ 2× at 10⁵ flows, zero steady recompiles.

Run headlessly:  PYTHONPATH=src python -m benchmarks.bench_megaflow
Fast smoke:      PYTHONPATH=src python -m benchmarks.bench_megaflow --fast
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import jax

from benchmarks.common import row
from repro.apps.nf import firewall
from repro.core.executor import ParallelDataPlane
from repro.core.flowcache import FlowCacheConfig
from repro.kernels import flow_lookup
from repro.service.workload import megaflow

GRID_FLOWS = (10_000, 100_000)
PIPELINES = 8
BATCH = 16384
PKT_BYTES = 64
CAP_HEADROOM = 2.0           # per-pipeline capacity = headroom * B / P


def _ticks(flows: int, nticks: int, batch: int, seed: int = 0) -> list:
    wl = megaflow({"cdn": 100.0}, seed=seed, concurrent_flows=flows)
    return [wl.batch_for("cdn", t, max_pkts=batch, pkt_bytes=PKT_BYTES)
            for t in range(nticks)]


def _plane(batch: int, cache: bool, table_pow: int) -> ParallelDataPlane:
    return ParallelDataPlane(
        firewall(), num_pipelines=PIPELINES,
        capacity_per_pipeline=CAP_HEADROOM * batch / PIPELINES,
        flow_cache=cache,
        flow_cache_config=FlowCacheConfig(capacity=1 << table_pow))


def _instrument_assign(dp: ParallelDataPlane) -> dict:
    """Wrap the plane's partition_assign with an accumulating wall timer."""
    acc = {"t": 0.0}
    orig = dp.to.partition_assign

    def timed(batch, tenant=None):
        t0 = time.perf_counter()
        r = orig(batch, tenant=tenant)
        acc["t"] += time.perf_counter() - t0
        return r

    dp.to.partition_assign = timed
    return acc


def bench_one(flows: int, fast: bool = False) -> dict:
    batch = 2048 if fast else BATCH
    warm = 6 if fast else 24
    rounds = 2 if fast else 3
    chunk = 2 if fast else 8
    table_pow = 14 if fast else 18
    iters = rounds * chunk
    ticks = _ticks(flows, warm + iters, batch)

    dp = _plane(batch, cache=True, table_pow=table_pow)
    dp.to.flow_cache.prewarm(max_queries=1 << (batch - 1).bit_length())
    for b in ticks[:warm]:
        jax.block_until_ready(dp.process(b))
    slow = _plane(batch, cache=False, table_pow=table_pow)
    for b in ticks[:2]:
        jax.block_until_ready(slow.process(b))
    acc_c = _instrument_assign(dp)
    acc_s = _instrument_assign(slow)

    fs0 = dict(dp.to.fast_stats)
    cs0 = dict(dp.to.flow_cache.stats)
    comp0 = dp.dispatch_stats["compiles"]
    tr0 = sum(flow_lookup.trace_counts().values())
    # Both arms run the SAME tick chunks, interleaved round-robin; per-arm
    # time is the min over rounds (robust against CPU contention spikes —
    # a mean would let one noisy window swing the speedup ratio). Timed
    # per window: end-to-end process() AND the classification stage alone
    # (partition_assign — the path the cache replaces; the NF-chain compute
    # after it is identical in both arms).
    cache_best = slow_best = float("inf")
    cache_assign = slow_assign = float("inf")
    for r in range(rounds):
        cticks = ticks[warm + r * chunk:warm + (r + 1) * chunk]
        a0 = acc_c["t"]
        t0 = time.perf_counter()
        for b in cticks:
            jax.block_until_ready(dp.process(b))
        cache_best = min(cache_best, (time.perf_counter() - t0) / chunk)
        cache_assign = min(cache_assign, (acc_c["t"] - a0) / chunk)
        a0 = acc_s["t"]
        t0 = time.perf_counter()
        for b in cticks:
            jax.block_until_ready(slow.process(b))
        slow_best = min(slow_best, (time.perf_counter() - t0) / chunk)
        slow_assign = min(slow_assign, (acc_s["t"] - a0) / chunk)
    cache_us = cache_best * 1e6
    slow_us = slow_best * 1e6
    fs = {k: dp.to.fast_stats[k] - fs0[k] for k in fs0}
    cs = {k: dp.to.flow_cache.stats[k] - cs0[k] for k in cs0}
    recompiles = (dp.dispatch_stats["compiles"] - comp0
                  + sum(flow_lookup.trace_counts().values()) - tr0)

    flows_seen = fs["hit_flows"] + fs["miss_flows"]
    pkts_seen = fs["hit_pkts"] + fs["miss_pkts"]
    rec = {
        "name": f"megaflow_F{flows}",
        "flows": flows,
        "B": batch,
        "pipelines": PIPELINES,
        "fast": fast,
        "cache_us_per_call": cache_us,
        "slow_us_per_call": slow_us,
        "cache_assign_us": cache_assign * 1e6,
        "slow_assign_us": slow_assign * 1e6,
        "cache_pps": batch / (cache_us * 1e-6),
        "slow_pps": batch / (slow_us * 1e-6),
        "speedup": slow_assign / cache_assign,
        "speedup_e2e": slow_us / cache_us,
        "hit_rate_flows": fs["hit_flows"] / max(1, flows_seen),
        "hit_rate_pkts": fs["hit_pkts"] / max(1, pkts_seen),
        "fast_batches": fs["fast_batches"],
        "fallbacks": fs["fallbacks"],
        "evictions": cs["evictions"],
        "invalidations": cs["invalidations"],
        "inserts": cs["inserts"],
        "occupancy": dp.to.flow_cache.occupancy(),
        "steady_state_recompiles": recompiles,
    }
    if not fast:
        assert recompiles == 0, ("steady-state recompile detected", rec)
    return rec


def run(emit=print, fast: bool = False) -> list:
    results = []
    for flows in ((2000,) if fast else GRID_FLOWS):
        r = bench_one(flows, fast=fast)
        results.append(r)
        emit(row(r["name"], r["cache_us_per_call"],
                 f"{r['speedup']:.2f}x_e2e{r['speedup_e2e']:.2f}x"
                 f"_hit{r['hit_rate_pkts']:.3f}"))
    return results


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke mode: small batch/table, no gates")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    results = run(emit=print, fast=args.fast)
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_dataplane.json"
    payload = json.loads(out.read_text()) if out.exists() else {}
    payload["megaflow"] = {
        "benchmark": "megaflow flow cache on/off",
        "app": "firewall",
        "pkt_bytes": PKT_BYTES,
        "fast": args.fast,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "rows": results,
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out} (megaflow record)")


if __name__ == "__main__":
    main()
