"""Fig 9 + Fig 10: single-flow throughput/latency vs number of pipelines.

Meili partitions one flow across replicated pipelines (§5.1.2); Baseline
processes a flow on one NIC only. Meili-local replicates on one NIC (<=7
pipelines: one core is the TO); Meili-remote adds one NIC per pipeline with
the §8.5 hop/TO penalty (~5-10% throughput, +5-8 µs latency).
"""
from __future__ import annotations

from benchmarks.common import (APP_STAGE_LATENCY_US, HOP_US, PKT_BITS, row,
                               unit_gbps)
from repro.core import sim

PARTITION_OVERHEAD = 0.04      # paper: Meili@1 pipeline slightly < Baseline
REMOTE_PENALTY = 0.075         # paper: ~5-10% drop for cross-NIC pipelines


def single_pipeline_gbps(lat: dict) -> float:
    return PKT_BITS / (max(lat.values()) * 1e-6) / 1e9


def run(emit=print) -> dict:
    out = {}
    for app, lat in APP_STAGE_LATENCY_US.items():
        stages = list(lat)
        base = single_pipeline_gbps(lat)
        for n in (1, 2, 4, 7):
            local = base * n * (1 - PARTITION_OVERHEAD)
            remote = local * (1 - REMOTE_PENALTY) if n > 1 else local
            # latency from the event simulator + hop penalties
            R1 = {s: 1 for s in stages}
            res = sim.simulate(stages, {s: lat[s] for s in stages}, R1, 50,
                               arrival_interval=max(lat.values()))
            lat_local = res.avg_latency + (0.4 if n > 1 else 0.0)  # TO partition
            lat_remote = lat_local + (HOP_US + 2.0 if n > 1 else 0.0)
            out[(app, n)] = (local, remote)
            emit(row(f"fig9_{app}_p{n}_local", lat_local,
                     f"{local:.2f}Gbps"))
            emit(row(f"fig9_{app}_p{n}_remote", lat_remote,
                     f"{remote:.2f}Gbps"))
        emit(row(f"fig9_{app}_baseline", res.avg_latency, f"{base:.2f}Gbps"))
    # headline checks (paper: FW/FM ~25 Gbps @7, LLB ~60 Gbps @7)
    for app, target in (("FW", 25.0), ("FM", 25.0), ("LLB", 60.0)):
        got = out[(app, 7)][0]
        emit(row(f"fig9_check_{app}@7", 0.0,
                 f"{got:.1f}Gbps_vs_paper~{target}Gbps"))
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
