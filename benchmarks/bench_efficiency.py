"""Fig 11(a/b): cluster resource efficiency — Meili vs Baseline-dedicate vs
Baseline-colocate.

Protocol (paper §8.2): set one uniform throughput target for every app,
check whether the system can satisfy ALL of them simultaneously (FCFS);
lower the target until it fits; report the max achievable per-app target.

  * Baseline-dedicate: whole-app instances, each instance owns a full NIC.
  * Baseline-colocate: whole-app instances, instances may share NICs.
  * Meili: stage-granular allocation over the pool (Algorithm 2).

Instance placement for the baselines respects the paper's Table 3
constraints (ID needs regex -> BF-2 only; ICG needs compression -> BF-2 or
Pensando; FW/FM/LLB CPU-only -> any NIC).
"""
from __future__ import annotations

import itertools
from typing import Dict, List

from benchmarks.common import (APP_STAGE_LATENCY_US, APP_STAGE_RESOURCE,
                               row, unit_gbps)
from repro.core.allocation import commit, resource_alloc
from repro.core.pool import CPU, NicSpec, Pool, paper_cluster

APPS5 = ["ID", "ICG", "FW", "FM", "LLB"]


def make_cluster(pensando: bool) -> Pool:
    return paper_cluster(n_bf2=8, n_bf1=4, n_pensando=4 if pensando else 0)


def stage_unit_gbps(app: str) -> Dict[str, float]:
    return {s: unit_gbps(l) for s, l in APP_STAGE_LATENCY_US[app].items()}


def nic_supports(nic: NicSpec, app: str) -> bool:
    needs = set(APP_STAGE_RESOURCE[app].values())
    return all(nic.capacity(r) > 0 for r in needs)


def instance_throughput(nic: NicSpec, app: str, cores: int) -> float:
    """Best whole-app instance rate on one NIC given `cores` CPU cores:
    greedy water-filling of cores to the bottleneck CPU stage; accelerator
    stages are capped by the NIC's engine count."""
    t_s = stage_unit_gbps(app)
    res = APP_STAGE_RESOURCE[app]
    alloc = {s: (0 if res[s] == CPU else nic.capacity(res[s]))
             for s in t_s}
    cpu_stages = [s for s in t_s if res[s] == CPU]
    if not all(alloc[s] > 0 for s in t_s if res[s] != CPU):
        return 0.0
    for _ in range(cores):
        # give the next core to the current CPU bottleneck
        s = min(cpu_stages, key=lambda s: alloc[s] * t_s[s])
        alloc[s] += 1
    rate = min(alloc[s] * t_s[s] for s in t_s)
    return rate


def baseline_feasible(pool_nics: List[NicSpec], target: float,
                      colocate: bool) -> bool:
    """Greedy FCFS placement of whole-app instances until every app reaches
    `target` (the paper's per-instance scaling)."""
    cores_free = {n.name: n.cores for n in pool_nics}
    accel_free = {n.name: dict(n.accelerators) for n in pool_nics}
    owner = {n.name: None for n in pool_nics}

    for app in APPS5:
        need = target
        res = APP_STAGE_RESOURCE[app]
        t_s = stage_unit_gbps(app)
        for nic in pool_nics:
            if need <= 1e-9:
                break
            if not nic_supports(nic, app):
                continue
            if not colocate and owner[nic.name] is not None:
                continue
            if colocate:
                # use remaining cores/accels on this NIC
                cores = cores_free[nic.name]
                if cores <= 0:
                    continue
                # accel stages need free engines
                if any(res[s] != CPU and accel_free[nic.name].get(res[s], 0)
                       <= 0 for s in t_s):
                    continue
            else:
                cores = nic.cores
            spec = NicSpec(nic.name, nic.kind, cores,
                           accel_free[nic.name] if colocate
                           else dict(nic.accelerators), nic.bandwidth_gbps)
            rate = instance_throughput(spec, app, cores)
            if rate <= 0:
                continue
            got = min(rate, need)
            # cores consumed proportional to the fraction of capacity used
            used_cores = cores if not colocate else max(
                1, int(round(cores * got / max(rate, 1e-9))))
            cores_free[nic.name] -= used_cores
            if colocate:
                for s in t_s:
                    if res[s] != CPU:
                        accel_free[nic.name][res[s]] -= 1
            owner[nic.name] = app
            need -= got
        if need > 1e-6:
            return False
    return True


def meili_feasible(pensando: bool, target: float, with_isg: float = 0.0
                   ) -> bool:
    pool = make_cluster(pensando)
    # reserve one TO core per NIC is already in paper_cluster specs
    apps = APPS5 + (["ISG"] if with_isg > 0 else [])
    for app in apps:
        tgt = with_isg if app == "ISG" else target
        t_s = stage_unit_gbps(app)
        need = APP_STAGE_RESOURCE[app]
        r_s = {s: max(1, int(-(-tgt // t_s[s]))) for s in t_s}
        alloc = resource_alloc(list(t_s), r_s, t_s, pool, need)
        if not alloc.satisfied():
            return False
        commit(pool, alloc, need)
    return True


def max_target(feasible, lo=0.0, hi=110.0, step=0.1) -> float:
    t = hi
    while t > lo:
        if feasible(t):
            return t
        t = round(t - step, 3)
    return 0.0


def run(emit=print) -> dict:
    out = {}
    for pensando, label in ((False, "cluster1"), (True, "cluster2")):
        nics = [st.spec for st in make_cluster(pensando).nics.values()]
        ded = max_target(lambda t: baseline_feasible(nics, t, colocate=False),
                         step=0.5)
        col = max_target(lambda t: baseline_feasible(nics, t, colocate=True),
                         step=0.5)
        mei = max_target(lambda t: meili_feasible(pensando, t), step=0.5)
        out[label] = (ded, col, mei)
        emit(row(f"fig11a_{label}_dedicate", 0, f"{ded:.1f}Gbps"))
        emit(row(f"fig11a_{label}_colocate", 0, f"{col:.1f}Gbps"))
        emit(row(f"fig11a_{label}_meili", 0, f"{mei:.1f}Gbps"))
        emit(row(f"fig11a_{label}_gain_vs_dedicate", 0,
                 f"{mei / max(ded, 1e-9):.2f}x_paper1.82x"))
        emit(row(f"fig11a_{label}_gain_vs_colocate", 0,
                 f"{mei / max(col, 1e-9):.2f}x_paper1.46x"))
    # Fig 11(b): ISG coexists in cluster 2 (infeasible for both baselines).
    for isg_t in (5.0, 10.0, 20.0):
        ok = meili_feasible(True, out["cluster2"][2] - 6.0, with_isg=isg_t)
        emit(row(f"fig11b_isg_{isg_t:.0f}Gbps", 0,
                 f"feasible={ok}_baselines=infeasible"))
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
