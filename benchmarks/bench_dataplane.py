"""End-to-end data-plane throughput: ParallelDataPlane.process (ISSUE 1).

Measures packets/sec of the full partition -> dispatch -> aggregate hot path
at B in {1k, 16k} x pipelines in {1, 4, 8}, the grid the §5.1.2 single-flow
scalability claim rests on. Emits the standard ``name,us_per_call,derived``
CSV rows and writes ``BENCH_dataplane.json`` next to the repo root so later
PRs have a perf trajectory to compare against.

The app is the CPU-only Firewall (no accelerator impl selection noise);
traffic is the deterministic synthetic mix (128 flows, 256 B payloads —
payload width only scales the copy cost, not the dispatch overhead under
test). Per-pipeline capacity is sized to B/pipelines so the batch exactly
fills the replica set and spill paths stay exercised.

Run headlessly:  PYTHONPATH=src python -m benchmarks.bench_dataplane
"""
from __future__ import annotations

import json
import pathlib
import time

import jax

from benchmarks.common import row, timeit
from repro.apps.nf import firewall
from repro.apps.packets import synth_packets
from repro.core.executor import ParallelDataPlane

GRID_B = (1024, 16384)
GRID_PIPELINES = (1, 4, 8)
PKT_BYTES = 256
NUM_FLOWS = 128

# Pre-fusion baseline, measured on this exact grid at the seed commit
# (per-packet Python partition + per-sub-batch per-stage dispatch + ad hoc
# rings). Kept for the perf trajectory; speedup_vs_seed in the JSON is
# current/seed.
SEED_US_PER_CALL = {
    ("dataplane_B1024_P1"): 23037.083,
    ("dataplane_B1024_P4"): 57112.819,
    ("dataplane_B1024_P8"): 92385.512,
    ("dataplane_B16384_P1"): 76708.208,
    ("dataplane_B16384_P4"): 96271.901,
    ("dataplane_B16384_P8"): 218263.888,
}


def bench_one(B: int, npipe: int, iters: int = 10, warmup: int = 3) -> dict:
    pkts = synth_packets(batch=B, num_flows=NUM_FLOWS, pkt_bytes=PKT_BYTES)
    dp = ParallelDataPlane(firewall(), num_pipelines=npipe,
                           capacity_per_pipeline=max(1.0, B / npipe))
    for _ in range(warmup):
        jax.block_until_ready(dp.process(pkts))
    compiles_after_warmup = getattr(dp, "dispatch_stats", {}).get("compiles")
    us = timeit(dp.process, pkts, iters=iters, warmup=0) * 1e6
    stats = getattr(dp, "dispatch_stats", None)
    steady_compiles = (stats["compiles"] - compiles_after_warmup
                       if stats else None)
    name = f"dataplane_B{B}_P{npipe}"
    seed_us = SEED_US_PER_CALL.get(name)
    return {
        "name": name,
        "B": B,
        "pipelines": npipe,
        "us_per_call": us,
        "pps": B / (us * 1e-6),
        "steady_state_recompiles": steady_compiles,
        "seed_us_per_call": seed_us,
        "speedup_vs_seed": (seed_us / us) if seed_us else None,
    }


def run(emit=print) -> list:
    results = []
    for B in GRID_B:
        for npipe in GRID_PIPELINES:
            r = bench_one(B, npipe)
            results.append(r)
            emit(row(r["name"], r["us_per_call"],
                     f"{r['pps'] / 1e6:.3f}Mpps"))
            if r["steady_state_recompiles"] is not None:
                assert r["steady_state_recompiles"] == 0, (
                    "steady-state recompile detected", r)
    return results


def main() -> None:
    print("name,us_per_call,derived")
    results = run(emit=print)
    out = pathlib.Path(__file__).resolve().parent.parent / "BENCH_dataplane.json"
    payload = {
        "benchmark": "ParallelDataPlane.process",
        "app": "firewall",
        "pkt_bytes": PKT_BYTES,
        "num_flows": NUM_FLOWS,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        "rows": results,
    }
    if out.exists():                 # bench_megaflow shares this file
        prev = json.loads(out.read_text())
        if "megaflow" in prev:
            payload["megaflow"] = prev["megaflow"]
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out}")


if __name__ == "__main__":
    main()
