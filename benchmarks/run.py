"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. Mapping:
  bench_pipeline     -> Fig 7/8  (Algorithm 1 partial replication)
  bench_scalability  -> Fig 9/10 (single-flow throughput/latency scaling)
  bench_efficiency   -> Fig 11   (resource efficiency vs two baselines)
  bench_bandwidth    -> Fig 13   (allocation under bandwidth constraints)
  bench_adaptive     -> Fig 14 + Fig 18 (adaptive scaling, failover)
  bench_redirection  -> Fig 15/16/17 (TO microbenchmarks)
  bench_state        -> Fig 20 + App. C (state engine ops)
  bench_kernels      -> kernel hot-spots (µs/call + TPU roofline context)
  bench_dataplane    -> fused data-plane pps (ISSUE 1; writes BENCH_dataplane.json)
  bench_megaflow     -> megaflow flow cache on/off at 10^4..10^5 flows (ISSUE 9)
  bench_service      -> Meili-Serve efficiency modes + defrag A/B (ISSUE 2/3)
                        + QoS flash-crowd isolation A/B and adversarial-churn
                        records (ISSUE 4) + chaos fault-injection A/B with
                        recovery on/off (ISSUE 6); writes BENCH_service.json
  bench_control      -> control-plane cost at 100..1000 tenants, sharded+
                        vectorized vs legacy (ISSUE 8; merges the `control`
                        record into BENCH_service.json)

Run one module headlessly:   python -m benchmarks.bench_dataplane
Run everything:              python -m benchmarks.run   (or: make bench)
With artifacts:              python -m benchmarks.run --emit-obs
                             (structured rows.jsonl + meta.json under
                             --obs-dir; make bench-obs)
"""
import argparse
import sys
import traceback

from benchmarks import (bench_adaptive, bench_bandwidth, bench_control,
                        bench_dataplane, bench_efficiency, bench_kernels,
                        bench_megaflow, bench_pipeline, bench_redirection,
                        bench_scalability, bench_service, bench_state)
from repro.obs.runlog import RunLogger

ALL = [
    ("fig7_8", bench_pipeline),
    ("fig9_10", bench_scalability),
    ("fig11", bench_efficiency),
    ("fig13", bench_bandwidth),
    ("fig14_18", bench_adaptive),
    ("fig15_17", bench_redirection),
    ("fig20", bench_state),
    ("kernels", bench_kernels),
    ("dataplane", bench_dataplane),
    ("megaflow", bench_megaflow),
    ("service", bench_service),
    ("control", bench_control),
]


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-obs", action="store_true",
                    help="write the structured run log (rows.jsonl + "
                         "meta.json) under --obs-dir")
    ap.add_argument("--obs-dir", default="obs_artifacts",
                    help="artifact directory for --emit-obs "
                         "(default: ./obs_artifacts)")
    args = ap.parse_args(argv)

    logger = RunLogger("benchmarks.run",
                       out_dir=args.obs_dir if args.emit_obs else None)
    logger.emit("name,us_per_call,derived")
    failures = []
    for name, mod in ALL:
        try:
            mod.run(emit=logger.emit)
        except Exception:                      # noqa: BLE001
            failures.append(name)
            traceback.print_exc()
            logger.emit(f"{name},0,ERROR")
    logger.note(failures=failures)
    logger.close()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
