"""Fig 20 (+ Appendix C): state-engine read/write latencies, local vs remote,
TRAVERSE and COMPUTE — measured on our linked-hash-table implementation.
The paper's trend to reproduce: reads overtake writes at high state counts
(h_key collision scans), TRAVERSE >> COMPUTE (bulk pull vs shipped add)."""
from __future__ import annotations

import time

from benchmarks.common import row
from repro.core.state_engine import StateService


def run(emit=print) -> dict:
    out = {}
    for log_n in (8, 10, 12, 14):
        n = 2 ** log_n
        svc = StateService(["nicA", "nicB"], buckets=4096)
        t0 = time.perf_counter()
        for i in range(n):
            svc.ne_set(f"s{i}", i, local="nicA")
        w_us = (time.perf_counter() - t0) / n * 1e6
        t0 = time.perf_counter()
        for i in range(n):
            svc.get(f"s{i}", local="nicA")
        r_us = (time.perf_counter() - t0) / n * 1e6
        t0 = time.perf_counter()
        for i in range(0, n, max(1, n // 256)):
            svc.get(f"s{i}", local="nicB")       # remote read path
        rr_us = (time.perf_counter() - t0) / max(1, n // max(1, n // 256)) * 1e6
        out[n] = (w_us, r_us)
        emit(row(f"fig20_write_{n}", w_us, "local"))
        emit(row(f"fig20_read_{n}", r_us, "local"))
        emit(row(f"fig20_read_remote_{n}", rr_us, "remote"))
    # TRAVERSE vs COMPUTE across 8 engines
    svc = StateService([f"nic{i}" for i in range(8)], buckets=4096)
    for i in range(2 ** 12):
        svc.ne_set(f"k{i}", i, local=f"nic{i % 8}")
    t0 = time.perf_counter()
    entries = svc.traverse(local="nic0")
    tr_ms = (time.perf_counter() - t0) * 1e3
    svc.fstate_set("agg", 1)
    t0 = time.perf_counter()
    svc.compute("agg", ucf=lambda vals: sum(vals), combine=sum)
    cp_us = (time.perf_counter() - t0) * 1e6
    emit(row("appC_traverse_4096x8", tr_ms * 1e3,
             f"{tr_ms:.2f}ms_paper~10.7ms"))
    emit(row("appC_compute", cp_us, f"{cp_us:.1f}us_paper~64us"))
    out["traverse_ms"] = tr_ms
    out["compute_us"] = cp_us
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
