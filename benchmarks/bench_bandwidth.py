"""Fig 13: allocation under heterogeneous available bandwidth.

Cluster 1 (4 BF-1 + 8 BF-2), 50 Gbps target per app, deploying ID, ICG, FW,
FM, LLB sequentially; BF-1/BF-2 available bandwidth swept over
(100,100) (100,50) (50,100) (50,50) (25,*): the bandwidth-hungry LLB
(latest in FCFS order) degrades when NIC links are capped — Algorithm 3's
allocate_on_bw path."""
from __future__ import annotations

from benchmarks.common import (APP_STAGE_LATENCY_US, APP_STAGE_RESOURCE, row,
                               unit_gbps)
from repro.core.allocation import commit, resource_alloc
from repro.core.pool import Pool, paper_cluster

APPS = ["ID", "ICG", "FW", "FM", "LLB"]
TARGET = 50.0


def run_case(bw_bf1: float, bw_bf2: float) -> dict:
    pool = paper_cluster(n_bf2=8, n_bf1=4, n_pensando=0)
    for name, st in pool.nics.items():
        st.free_bw_gbps = bw_bf1 if name.startswith("bf1") else bw_bf2
    achieved = {}
    for app in APPS:
        t_s = {s: unit_gbps(l) for s, l in APP_STAGE_LATENCY_US[app].items()}
        need = APP_STAGE_RESOURCE[app]
        r_s = {s: max(1, int(-(-TARGET // t_s[s]))) for s in t_s}
        alloc = resource_alloc(list(t_s), r_s, t_s, pool, need)
        commit(pool, alloc, need)
        achieved[app] = min(alloc.units(s) * t_s[s] for s in t_s)
    return achieved


def run(emit=print) -> dict:
    out = {}
    cases = [(100, 100), (100, 50), (50, 100), (50, 50), (25, 100), (100, 25)]
    for bw1, bw2 in cases:
        got = run_case(bw1, bw2)
        out[(bw1, bw2)] = got
        oks = sum(1 for a in APPS if got[a] >= TARGET - 1e-6)
        emit(row(f"fig13_bf1={bw1}_bf2={bw2}", 0,
                 f"LLB={got['LLB']:.1f}Gbps_met{oks}/5"))
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
