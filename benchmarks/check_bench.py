"""Benchmark regression gate (ISSUE 7 satellite): compare the headline
numbers in ``BENCH_service.json`` against the recorded baseline.

Checked, each within the tolerance declared in ``bench_baseline.json``:

  * the two efficiency ratio bars (pooled vs standalone / vs microservice);
  * the chaos A/B's SLO-tick counts (and that recovery-on still dominates);
  * the control-plane A/B's flat-cost bar (ISSUE 8): the sharded+vectorized
    arm's per-tick cost growth from 100 to 1000 tenants stays <=
    ``control_flatness_max``, with zero steady-state kernel recompiles;
  * the megaflow flow-cache bars (ISSUE 9, record in BENCH_dataplane.json):
    classification speedup, end-to-end speedup, steady-state hit-rate,
    zero fallbacks and zero steady-state recompiles at 10^5 flows.

Fast-mode records are skipped per check: ``--fast``/partial runs use fewer
ticks, so their numbers are not comparable to the recorded full-mode
baseline — the gate only scores records whose run shape matches. When
``BENCH_service.json`` does not exist at all the gate passes with a notice
(a fresh clone has no benchmark output; the gate guards *recorded* results
against regression, it does not force a bench run into ``make test``).

Run:  PYTHONPATH=src python -m benchmarks.check_bench   (make check-bench)
"""
from __future__ import annotations

import json
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
BASELINE = pathlib.Path(__file__).resolve().parent / "bench_baseline.json"


def _within(current: float, recorded: float, rel_tol: float) -> bool:
    return abs(current - recorded) <= rel_tol * abs(recorded)


def check(bench: dict, baseline: dict, emit=print) -> bool:
    ok = True

    # Efficiency ratio bars — only meaningful for full-mode comparisons.
    ratios = bench.get("ratios")
    if ratios is None:
        emit("check-bench: no ratios record (partial-scenario JSON), skipped")
    elif bench.get("fast"):
        emit("check-bench: fast-mode ratios not comparable, skipped")
    else:
        tol = baseline.get("ratio_rel_tol", 0.10)
        for name, recorded in baseline.get("ratios", {}).items():
            cur = ratios.get(name)
            if cur is None:
                emit(f"check-bench: FAIL {name} missing from BENCH JSON")
                ok = False
                continue
            good = _within(cur, recorded, tol)
            emit(f"check-bench: {'ok  ' if good else 'FAIL'} {name} "
                 f"{cur:.4f} vs recorded {recorded:.4f} (tol {tol:.0%})")
            ok = ok and good

    # Chaos A/B SLO-tick counts — the record is self-describing (carries its
    # own fast flag), so a fast chaos record merged into a full JSON skips.
    chaos = bench.get("chaos")
    base_chaos = baseline.get("chaos_slo_ticks")
    if chaos is None or base_chaos is None:
        emit("check-bench: no chaos record, skipped")
    elif chaos.get("fast"):
        emit("check-bench: fast-mode chaos record not comparable, skipped")
    else:
        tol = baseline.get("chaos_rel_tol", 0.25)
        for arm in ("on", "off"):
            cur = chaos.get(f"recovery_{arm}", {}).get("slo_ticks")
            recorded = base_chaos.get(arm)
            if cur is None or recorded is None:
                emit(f"check-bench: FAIL chaos slo_ticks[{arm}] missing")
                ok = False
                continue
            good = _within(cur, recorded, tol)
            emit(f"check-bench: {'ok  ' if good else 'FAIL'} chaos "
                 f"slo_ticks[{arm}] {cur} vs recorded {recorded} "
                 f"(tol {tol:.0%})")
            ok = ok and good
        on = chaos.get("recovery_on", {}).get("slo_ticks")
        off = chaos.get("recovery_off", {}).get("slo_ticks")
        if on is not None and off is not None:
            good = on > off
            emit(f"check-bench: {'ok  ' if good else 'FAIL'} chaos "
                 f"dominance on({on}) > off({off})")
            ok = ok and good

    # Control-plane flatness (ISSUE 8): the sharded+vectorized arm's
    # per-tick cost must stay ~flat in tenant count. Self-describing
    # record; fast-mode runs are still gated (the flatness RATIO is scale-
    # free — fewer ticks change the absolute µs, not the growth shape).
    control = bench.get("control")
    bar = baseline.get("control_flatness_max")
    if control is None or bar is None:
        emit("check-bench: no control record, skipped")
    else:
        cur = control.get("flatness_vectorized")
        if cur is None:
            emit("check-bench: FAIL control flatness missing")
            ok = False
        else:
            good = cur <= bar
            counts = control.get("tenant_counts", [])
            span = (f"{min(counts)}->{max(counts)}" if counts else "?")
            emit(f"check-bench: {'ok  ' if good else 'FAIL'} control "
                 f"flatness {cur:.2f}x over {span} tenants "
                 f"(bar {bar:.1f}x)")
            ok = ok and good
        rec = control.get("steady_state_recompiles")
        if rec is not None:
            good = rec == 0
            emit(f"check-bench: {'ok  ' if good else 'FAIL'} control "
                 f"steady-state recompiles = {rec}")
            ok = ok and good

    # Megaflow flow cache (ISSUE 9): at the gating flow count the cache-on
    # arm must beat the slow classification path >= megaflow_min_speedup x
    # (and the whole process() call >= megaflow_min_speedup_e2e x), with a
    # steady-state packet hit-rate >= megaflow_min_hit_rate, zero fallbacks
    # and zero steady-state recompiles. The record rides in
    # BENCH_dataplane.json (merged in by main()); fast-mode records skip.
    # SLO/alerting/flight overhead (ISSUE 10): the always-on budget scoring
    # + per-tick burn-rule evaluation + flight-ring snapshots (shadow arm)
    # must cost <= slo_overhead_max of wall-clock on the chaos scenario.
    # The gated number is in-run attributed (layer entry points timed
    # inside the arm that runs them, over the same run's non-layer wall) —
    # run_slo's docstring documents why the naive cross-run A/B ratio is
    # recorded but not gated. Fast-mode records are aliveness smokes and
    # skip, same as the others; the gated record comes from
    # `make bench-slo`.
    slo = bench.get("slo")
    bar = baseline.get("slo_overhead_max")
    if slo is None or bar is None:
        emit("check-bench: no slo record, skipped")
    elif slo.get("fast"):
        emit("check-bench: fast-mode slo record not comparable, skipped")
    else:
        cur = slo.get("overhead_frac")
        if cur is None:
            emit("check-bench: FAIL slo overhead_frac missing")
            ok = False
        else:
            good = cur <= bar
            emit(f"check-bench: {'ok  ' if good else 'FAIL'} slo overhead "
                 f"{cur * 100:+.1f}% (bar {bar * 100:.0f}%)")
            ok = ok and good
        for name in ("page_alerts", "flight_dumps"):
            cur = slo.get(name)
            if cur is None:
                emit(f"check-bench: FAIL slo {name} missing")
                ok = False
                continue
            good = cur > 0
            emit(f"check-bench: {'ok  ' if good else 'FAIL'} slo "
                 f"{name} = {cur} (want > 0)")
            ok = ok and good

    mega = bench.get("megaflow")
    bar = baseline.get("megaflow_min_speedup")
    if mega is None or bar is None:
        emit("check-bench: no megaflow record, skipped")
    elif mega.get("fast"):
        emit("check-bench: fast-mode megaflow record not comparable, skipped")
    else:
        gate_flows = baseline.get("megaflow_gate_flows", 100_000)
        rows = [r for r in mega.get("rows", []) if r.get("flows") == gate_flows]
        if not rows:
            emit(f"check-bench: FAIL megaflow row for {gate_flows} flows "
                 "missing")
            ok = False
        for r in rows:
            checks = [
                ("speedup", r.get("speedup"), bar, "ge"),
                ("speedup_e2e", r.get("speedup_e2e"),
                 baseline.get("megaflow_min_speedup_e2e", 2.0), "ge"),
                ("hit_rate_pkts", r.get("hit_rate_pkts"),
                 baseline.get("megaflow_min_hit_rate", 0.95), "ge"),
                ("fallbacks", r.get("fallbacks"), 0, "eq"),
                ("steady_state_recompiles",
                 r.get("steady_state_recompiles"), 0, "eq"),
            ]
            for name, cur, want, op in checks:
                if cur is None:
                    emit(f"check-bench: FAIL megaflow {name} missing")
                    ok = False
                    continue
                good = (cur >= want) if op == "ge" else (cur == want)
                rel = ">=" if op == "ge" else "=="
                emit(f"check-bench: {'ok  ' if good else 'FAIL'} megaflow "
                     f"{name} {cur:.3f} (want {rel} {want})")
                ok = ok and good
    return ok


def main(argv=None) -> None:
    path = ROOT / "BENCH_service.json"
    if len(argv or sys.argv[1:]) == 1:
        path = pathlib.Path((argv or sys.argv[1:])[0])
    if not path.exists():
        print(f"check-bench: {path.name} not found, nothing to gate (ok)")
        return
    bench = json.loads(path.read_text())
    dp_path = ROOT / "BENCH_dataplane.json"
    if "megaflow" not in bench and dp_path.exists():
        bench["megaflow"] = json.loads(dp_path.read_text()).get("megaflow")
    baseline = json.loads(BASELINE.read_text())
    if not check(bench, baseline):
        raise SystemExit("check-bench: headline numbers regressed "
                         "past tolerance")
    print("check-bench: pass")


if __name__ == "__main__":
    main()
