"""Fig 15/16/17: Traffic Orchestrator microbenchmarks (measured here).

Fig 15: single-TO redirection throughput vs packet size (our TO partitions
batches with host-side flow lookups + device gathers; we report Gbps from
measured wall time). Fig 16: per-packet redirection latency vs packet size.
Fig 17: end-to-end partition+aggregate latency, same-NIC vs distributed
(hop-penalty model from §8.5)."""
from __future__ import annotations

import time

from benchmarks.common import HOP_US, row, timeit
from repro.apps.packets import synth_packets
from repro.core.orchestrator import TrafficOrchestrator


def run(emit=print) -> dict:
    out = {}
    B = 512
    for pkt_bytes in (64, 128, 256, 512, 1500):
        pkts = synth_packets(batch=B, num_flows=32, pkt_bytes=pkt_bytes)
        to = TrafficOrchestrator(num_pipelines=4, capacity_per_pipeline=B)

        def rt():
            subs = to.partition(pkts)
            return to.aggregate(subs, total=B)

        us = timeit(rt, iters=5) * 1e6
        gbps = (B * pkt_bytes * 8) / (us * 1e-6) / 1e9
        per_pkt_us = us / B
        out[pkt_bytes] = (gbps, per_pkt_us)
        emit(row(f"fig15_redirect_{pkt_bytes}B", us, f"{gbps:.2f}Gbps"))
        emit(row(f"fig16_perpkt_{pkt_bytes}B", per_pkt_us,
                 "sub-us-goal" if per_pkt_us < 1.0 else "above-1us(CPU-host)"))
    # Fig 17: partition+aggregate E2E, 1..8 pipelines, same vs distributed
    pkts = synth_packets(batch=B, num_flows=1, pkt_bytes=1500)
    for n in (1, 2, 4, 8):
        to = TrafficOrchestrator(num_pipelines=n,
                                 capacity_per_pipeline=B // n + 1)
        us = timeit(lambda: to.aggregate(to.partition(pkts), total=B),
                    iters=5) * 1e6
        emit(row(f"fig17_same_nic_p{n}", us, f"{us:.0f}us"))
        emit(row(f"fig17_distributed_p{n}", us + HOP_US,
                 f"+{HOP_US}us_hop"))
        out[f"pipes{n}"] = us
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
