"""Fig 7/8: partial pipeline replication vs full replication (Algorithm 1's
efficiency claim), on the discrete-event simulator for the three §5.1.1
pipeline patterns."""
from __future__ import annotations

from benchmarks.common import row
from repro.core import replication as repl
from repro.core import sim

PATTERNS = {
    "listing1": {"S1": 2.0, "S2": 1.7, "S3": 2.9, "S4": 1.0},   # Fig 7
    "pattern_I": {"S1": 1.0, "S2": 2.0, "S3": 3.0, "S4": 4.0},
    "pattern_II": {"S1": 3.0, "S2": 1.0, "S3": 2.5, "S4": 1.2},
    "pattern_III": {"S1": 4.0, "S2": 2.0, "S3": 1.5, "S4": 1.0},
}


def run(emit=print) -> dict:
    out = {}
    for name, lat in PATTERNS.items():
        stages = list(lat)
        R = repl.num_replication(stages, lat)
        n = repl.num_pipelines(R)
        full = repl.full_replication(stages, n)
        r_part = sim.simulate(stages, lat, R, 200)
        r_full = sim.simulate(stages, lat, full, 200)
        eff_p = r_part.utilization(lat)
        eff_f = r_full.utilization(lat)
        out[name] = (eff_p, eff_f, r_part.throughput, r_full.throughput)
        emit(row(f"fig7_{name}_partial", r_part.avg_latency,
                 f"R={list(R.values())}_thr={r_part.throughput:.3f}"
                 f"_util={eff_p:.3f}"))
        emit(row(f"fig7_{name}_full", r_full.avg_latency,
                 f"x{n}_thr={r_full.throughput:.3f}_util={eff_f:.3f}"))
        emit(row(f"fig7_{name}_verdict", 0,
                 f"partial_util_gain={eff_p / max(eff_f, 1e-9):.2f}x"))
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
