"""Control-plane cost benchmark (ISSUE 8): sharded+vectorized vs legacy.

Measures the per-tick *control* cost — burst-credit refill, headroom
bookkeeping, scale verdicts, DWRR scheduling, backlog drain math, and the
telemetry reduction — at tenant counts up to 1000 on a synthetic 500-NIC /
10-rack pool. Two arms do the same logical work each tick:

  legacy       the scalar path: per-tenant Python dict loops
               (``ResourceGovernor.begin_tick`` + ``scale_verdict`` per
               tenant + the scalar ``dwrr_schedule`` + per-tenant backlog
               and telemetry accumulation), with the full-pool headroom
               scan every tick.
  vectorized   the sharded control plane's array program
               (``core.sched_kernel``): tenants as rows of stacked arrays,
               one jitted ``refill_credits`` + ``scale_decisions`` +
               ``dwrr_step`` + ``queue_drain`` + ``telemetry_accumulate``
               per tick; host work is O(rescales), not O(tenants); the
               headroom scan is the shards' digest refresh, amortized over
               the reconcile staleness bound.

The arm drives the kernels on persistent stacked arrays directly — the
end state of the refactor — rather than through the dict adapter
(``VectorizedScheduler``) the drop-in runtime path uses: the adapter's
dict marshalling is O(tenants) Python and exists for bit-compatibility,
not for the 1000-tenant regime this benchmark scores.

Acceptance (gated by ``check_bench``): the vectorized arm's per-tick cost
grows <= ``flatness_bar`` (1.5x) from the smallest to the largest tenant
count — i.e. control cost is ~flat in tenant count — with zero
steady-state recompiles; the record lands in ``BENCH_service.json`` under
``control``.

Run:        PYTHONPATH=src python -m benchmarks.bench_control
Smoke (CI): PYTHONPATH=src python -m benchmarks.bench_control --fast
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

import jax.numpy as jnp

from benchmarks.common import row
from repro.core import sched_kernel as sk
from repro.core.pool import paper_cluster
from repro.core.qos import ResourceGovernor, TenantQuota
from repro.core.shard import ControlShard
from repro.obs.runlog import RunLogger

TENANT_COUNTS = [100, 300, 1000]
TICKS = 24
FAST_TICKS = 6
WARMUP = 2
STALENESS = 4
FLATNESS_BAR = 1.5

# Synthetic rack at the ROADMAP's target scale: 500 NICs over 10 racks.
POOL = dict(n_bf2=250, n_bf1=125, n_pensando=125, racks=10)
DT_S = 0.1
PKT_BITS = 12000.0


def _mk_pool():
    return paper_cluster(**POOL)


def _tenant_params(n: int, seed: int):
    """Deterministic per-tenant contract/quota/traffic parameters."""
    rng = np.random.default_rng(seed)
    names = [f"t{i:04d}" for i in range(n)]
    return {
        "names": names,
        "weight": rng.choice([1.0, 2.0, 4.0], size=n),
        "contract": rng.uniform(2.0, 12.0, size=n),
        "quota": np.where(rng.random(n) < 0.5,
                          rng.uniform(6.0, 20.0, size=n), np.inf),
        "burst": rng.uniform(0.0, 4.0, size=n),
        "refill": rng.uniform(0.2, 1.0, size=n),
        "phase": rng.uniform(0.0, 2 * np.pi, size=n),
    }


def _offered(p, tick: int) -> np.ndarray:
    """Diurnal-ish offered load, identical on both arms."""
    return p["contract"] * (0.7 + 0.4 * np.sin(0.3 * tick + p["phase"]))


# -- legacy arm ----------------------------------------------------------------

def _legacy_arm(n: int, ticks: int, seed: int) -> float:
    """Mean per-tick seconds of the scalar control path."""
    pool = _mk_pool()
    p = _tenant_params(n, seed)
    names = p["names"]
    gov = ResourceGovernor()
    for i, t in enumerate(names):
        gov.register(t, TenantQuota(
            weight=float(p["weight"][i]),
            max_gbps=(None if np.isinf(p["quota"][i])
                      else float(p["quota"][i])),
            burst_gbps=float(p["burst"][i]),
            burst_refill_gbps=float(p["refill"][i])))
    current = {t: float(p["contract"][i]) for i, t in enumerate(names)}
    backlog = {t: 0.0 for t in names}
    stats = {t: [0, 0.0, 0.0, -np.inf] for t in names}   # n, off, ach, max
    times = []
    for tick in range(ticks):
        off = _offered(p, tick)
        t0 = time.perf_counter()
        # credit refill + full-pool headroom scan, every tick
        gov.begin_tick(pool=pool, active=names)
        caps_b, queues = {}, {}
        for i, t in enumerate(names):
            v = gov.scale_verdict(
                t, est_gbps=float(off[i]), offered_gbps=float(off[i]),
                contract_gbps=float(p["contract"][i]),
                current_gbps=current[t],
                achievable_gbps=current[t])
            if v.rescale:
                current[t] = v.target_gbps
            cap_pps = current[t] * 1e9 / PKT_BITS
            off_pps = float(off[i]) * 1e9 / PKT_BITS
            queues[t] = off_pps * DT_S + backlog[t]
            caps_b[t] = cap_pps * DT_S
        budget = 0.6 * sum(queues.values())
        _, served = gov.dwrr_schedule(queues, caps_b,
                                      capacity_bytes=budget)
        for i, t in enumerate(names):
            got = min(queues[t], caps_b[t], served[t])
            backlog[t] = queues[t] - got
            ach = got / DT_S * PKT_BITS / 1e9
            s = stats[t]
            s[0] += 1
            s[1] += float(off[i])
            s[2] += ach
            s[3] = max(s[3], backlog[t])
        times.append(time.perf_counter() - t0)
    return float(np.mean(times[WARMUP:]))


# -- sharded + vectorized arm --------------------------------------------------

def _vectorized_arm(n: int, ticks: int, seed: int) -> tuple:
    """Mean per-tick seconds of the array-program control path, plus the
    steady-state kernel recompile count (must be zero)."""
    pool = _mk_pool()
    p = _tenant_params(n, seed)
    racks = sorted({st.spec.rack for st in pool.nics.values()})
    shards = [ControlShard(r, pool.rack_members(r)) for r in racks]
    for sh in shards:
        sh.refresh(pool, -1)

    pad = sk.pad_rows(n)
    mask = np.zeros(pad, np.float32)
    mask[:n] = 1.0

    def col(x, fill=0.0):
        out = np.full(pad, fill, np.float32)
        out[:n] = x
        return jnp.asarray(out)

    mask_j = jnp.asarray(mask)
    weights = col(p["weight"])
    contract = col(p["contract"])
    quota = col(p["quota"], fill=np.inf)
    depth = col(p["burst"])
    refill = col(p["refill"])
    phase = np.concatenate([p["phase"], np.zeros(pad - n)])
    credits = col(p["burst"])
    current = col(p["contract"])
    deficits = jnp.zeros(pad, jnp.float32)
    backlog = jnp.zeros(pad, jnp.float32)
    tele = sk.telemetry_state(pad)
    ring_offset = 0
    times = []
    rescale_rows = 0
    for tick in range(ticks):
        offered = (np.asarray(contract)
                   * (0.7 + 0.4 * np.sin(0.3 * tick + phase))
                   ).astype(np.float32)
        off_j = jnp.asarray(offered * mask)
        t0 = time.perf_counter()
        # reconcile: digest refresh amortized over the staleness bound
        if tick % STALENESS == 0:
            for sh in shards:
                sh.refresh(pool, tick)
        if tick == WARMUP:
            sk.reset_trace_counts()
        credits = sk.refill_credits(credits, depth, refill)
        granted, rescale, _, _, _ = sk.scale_decisions(
            off_j, off_j, contract, current, current, quota, credits,
            weights, jnp.float32(1.0), jnp.float32(4.0), jnp.float32(1.15),
            jnp.float32(0.2), jnp.float32(0.92), jnp.float32(0.1))
        # host walks only the sparse flagged rows (the O(rescales) side)
        flagged = np.nonzero(np.asarray(rescale))[0]
        rescale_rows += len(flagged)
        current = jnp.where(rescale, granted, current)
        cap_pps = current * (1e9 / PKT_BITS)
        off_pps = off_j * (1e9 / PKT_BITS)
        queues = off_pps * DT_S + backlog
        caps_b = cap_pps * DT_S
        budget = 0.6 * float(jnp.sum(queues))
        served, deficits, _, rounds = sk.dwrr_step(
            queues, weights, deficits, caps_b, mask_j,
            jnp.float32(budget), jnp.int32(ring_offset))
        ring_offset = (ring_offset + int(rounds)) % pad
        got, backlog, ach_pps = sk.queue_drain(
            off_pps, backlog, cap_pps, served, jnp.float32(DT_S))
        tele = sk.telemetry_accumulate(
            tele, off_j, ach_pps * (PKT_BITS / 1e9), backlog,
            jnp.zeros_like(off_j), mask_j)
        tele[0].block_until_ready()
        times.append(time.perf_counter() - t0)
    recompiles = sum(sk.trace_counts().values())
    return float(np.mean(times[WARMUP:])), recompiles, rescale_rows


# -- harness -------------------------------------------------------------------

def run(emit=print, fast: bool = False, seed: int = 0) -> dict:
    ticks = FAST_TICKS if fast else TICKS
    legacy, vector, recompiles = {}, {}, {}
    for n in TENANT_COUNTS:
        legacy[n] = _legacy_arm(n, ticks, seed)
        vector[n], recompiles[n], _ = _vectorized_arm(n, ticks, seed)
        emit(row(f"control_tick_legacy_{n}", legacy[n] * 1e6,
                 f"{n}tenants"))
        emit(row(f"control_tick_vectorized_{n}", vector[n] * 1e6,
                 f"{n}tenants_recompiles{recompiles[n]}"))
    lo, hi = min(TENANT_COUNTS), max(TENANT_COUNTS)
    rec = {
        "fast": fast,
        "seed": seed,
        "ticks": ticks,
        "pool": dict(POOL, nics=sum(
            v for k, v in POOL.items() if k != "racks")),
        "tenant_counts": TENANT_COUNTS,
        "staleness_ticks": STALENESS,
        "legacy_us_per_tick": {str(n): legacy[n] * 1e6
                               for n in TENANT_COUNTS},
        "vectorized_us_per_tick": {str(n): vector[n] * 1e6
                                   for n in TENANT_COUNTS},
        "speedup": {str(n): legacy[n] / vector[n] for n in TENANT_COUNTS},
        "flatness_legacy": legacy[hi] / legacy[lo],
        "flatness_vectorized": vector[hi] / vector[lo],
        "flatness_bar": FLATNESS_BAR,
        "steady_state_recompiles": sum(recompiles.values()),
    }
    rec["pass"] = bool(rec["flatness_vectorized"] <= FLATNESS_BAR
                       and rec["speedup"][str(hi)] > 1.0
                       and rec["steady_state_recompiles"] == 0)
    emit(row("control_flatness", 0,
             f"vec{rec['flatness_vectorized']:.2f}x_"
             f"legacy{rec['flatness_legacy']:.2f}x_bar{FLATNESS_BAR}x"))
    emit(row("control_speedup_1000", 0,
             f"{rec['speedup'][str(hi)]:.1f}x"))
    emit(row("control", 0, f"pass={rec['pass']}"))
    return rec


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke mode: fewer ticks")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root "
                         "BENCH_service.json, merged under 'control')")
    args = ap.parse_args(argv)

    logger = RunLogger("bench_control")
    logger.note(fast=args.fast, seed=args.seed)
    logger.emit("name,us_per_call,derived")
    rec = run(emit=logger.emit, fast=args.fast, seed=args.seed)
    out = (pathlib.Path(args.out) if args.out else
           pathlib.Path(__file__).resolve().parent.parent
           / "BENCH_service.json")
    # Merge into the existing service JSON (the partial-record pattern):
    # the control A/B is one more self-describing record beside defrag/
    # qos/chaos, not a separate artifact.
    payload = {}
    if out.exists():
        try:
            payload = json.loads(out.read_text())
        except ValueError:
            payload = {}
    payload["control"] = rec
    payload["timestamp"] = time.strftime("%Y-%m-%dT%H:%M:%S")
    out.write_text(json.dumps(payload, indent=2) + "\n")
    logger.close()
    print(f"# wrote {out}")
    if not rec["pass"]:
        raise SystemExit("control benchmark below acceptance bars")


if __name__ == "__main__":
    main()
