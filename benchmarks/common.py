"""Shared benchmark utilities + the calibrated paper-cluster cost model.

Without SmartNIC hardware, testbed figures are reproduced on a discrete-time
cost model (core/sim.py) whose per-stage latencies are calibrated so that
single-pipeline app throughputs land in the ranges the paper reports
(Fig 9: ~4-9 Gbps per pipeline at 1500 B). Each benchmark prints CSV rows
``name,us_per_call,derived`` where `derived` carries the figure's headline
quantity; EXPERIMENTS.md tags every number measured-here vs paper-reported.
"""
from __future__ import annotations

import time
from typing import Callable, Dict

import jax

from repro import hw

# Calibrated per-stage, per-1500B-packet latencies (µs) on one resource unit
# (ARM A72 core or accelerator engine). Derived from the paper's observable
# aggregates: Fig 9 single-pipeline rates, Fig 2 bottleneck structure
# (L7 Filter regex-bound, Malware Detection CPU-bound), §8.5 TO overhead.
APP_STAGE_LATENCY_US: Dict[str, Dict[str, float]] = {
    # Intrusion Detection [3 fn: CPU, regex]  (CPU-bound like Malware Det.;
    # regex engine ~13 Gbps, matching Fig 2's L7-Filter regex bound)
    "ID": {"flow_ext": 2.20, "dpi_regex": 0.92, "verdict": 1.80},
    # IPComp Gateway [2 fn: CPU, compression]
    "ICG": {"ipcomp_encap": 1.80, "compress": 2.10},
    # IPsec Gateway [4 fn: CPU, regex, AES] — Listing 1
    "ISG": {"ddos_check": 2.00, "url_check": 0.92, "ipsec_encap": 1.00,
            "sha": 1.30, "aes": 1.90},
    # Firewall [2 fn: CPU]  (Fig 9: ~25 Gbps @ 7 pipelines => ~3.7 Gbps each)
    "FW": {"rule_match": 2.90, "conn_track": 3.20},
    # Flow Monitor [2 fn: CPU]
    "FM": {"flow_ext": 2.90, "flow_metrics": 3.20},
    # L7 Load Balancer [socket]  (Fig 9: ~60 Gbps @ 7 => ~8.8 Gbps each)
    "LLB": {"reg_sock": 0.20, "epoll_in": 1.36},
}

# Resource kind per stage (matches apps/nf.py definitions).
APP_STAGE_RESOURCE: Dict[str, Dict[str, str]] = {
    "ID": {"flow_ext": "cpu", "dpi_regex": "regex", "verdict": "cpu"},
    "ICG": {"ipcomp_encap": "cpu", "compress": "compression"},
    "ISG": {"ddos_check": "cpu", "url_check": "regex", "ipsec_encap": "cpu",
            "sha": "crypto", "aes": "crypto"},
    "FW": {"rule_match": "cpu", "conn_track": "cpu"},
    "FM": {"flow_ext": "cpu", "flow_metrics": "cpu"},
    "LLB": {"reg_sock": "cpu", "epoll_in": "cpu"},
}

PKT_BITS = hw.PKT_BYTES * 8.0
# Remote hop penalty between stages on different NICs (paper §8.5: ~4.5 µs
# round trip; Table 1 shows +3.75 µs avg for the distributed IPComp GW).
HOP_US = 4.5


def unit_gbps(lat_us: float) -> float:
    """Throughput of one resource unit running a stage (1500 B packets)."""
    return PKT_BITS / (lat_us * 1e-6) / 1e9


def timeit(fn: Callable, *args, iters: int = 10, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
