"""Shared benchmark utilities + the calibrated paper-cluster cost model.

Without SmartNIC hardware, testbed figures are reproduced on a discrete-time
cost model (core/sim.py) whose per-stage latencies are calibrated so that
single-pipeline app throughputs land in the ranges the paper reports
(Fig 9: ~4-9 Gbps per pipeline at 1500 B). Each benchmark prints CSV rows
``name,us_per_call,derived`` where `derived` carries the figure's headline
quantity; EXPERIMENTS.md tags every number measured-here vs paper-reported.
"""
from __future__ import annotations

import time
from typing import Callable

import jax

# The calibrated cost model now lives in src (repro.apps.profiles) so the
# service runtime can use it without importing benchmarks/; these names are
# re-exported for the existing figure benchmarks.
from repro.apps.profiles import (APP_STAGE_LATENCY_US,  # noqa: F401
                                 APP_STAGE_RESOURCE, HOP_US, PKT_BITS,
                                 unit_gbps)


def timeit(fn: Callable, *args, iters: int = 10, warmup: int = 3) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters


def row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.3f},{derived}"
