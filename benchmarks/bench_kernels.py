"""Kernel micro-benchmarks: µs/call of the production (blocked) paths on this
host + interpret-mode spot checks. Roofline-model time on the TPU target is
derived per-shape for context."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, timeit
from repro import hw
from repro.kernels import ops

RNG = np.random.default_rng(0)


def run(emit=print) -> dict:
    out = {}
    # flash attention
    B, S, Hq, Hkv, D = 1, 512, 8, 2, 64
    q = jnp.asarray(RNG.normal(size=(B, S, Hq, D)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(B, S, Hkv, D)), jnp.float32)
    us = timeit(lambda: ops.attention(q, k, v, impl="blocked"), iters=5) * 1e6
    flops = 4 * B * Hq * S * S * D / 2
    tpu_us = flops / hw.PEAK_FLOPS_BF16 * 1e6
    out["attention"] = us
    emit(row("kernel_attention_b512", us, f"tpu_roofline={tpu_us:.1f}us"))
    # decode attention
    qd = jnp.asarray(RNG.normal(size=(8, Hq, D)), jnp.float32)
    kd = jnp.asarray(RNG.normal(size=(8, 4096, Hkv, D)), jnp.float32)
    kv_len = jnp.full((8,), 4096, jnp.int32)
    us = timeit(lambda: ops.decode_attention(qd, kd, kd, kv_len,
                                             impl="blocked"), iters=5) * 1e6
    emit(row("kernel_decode_4k", us,
             f"bytes={2 * kd.size * 4}"))
    # ssd
    x = jnp.asarray(RNG.normal(size=(2, 512, 4, 32)) * 0.3, jnp.float32)
    a = jnp.asarray(RNG.uniform(0.7, 0.99, size=(2, 512, 4)), jnp.float32)
    bmat = jnp.asarray(RNG.normal(size=(2, 512, 4, 32)) * 0.3, jnp.float32)
    us = timeit(lambda: ops.ssd(x, a, bmat, bmat, impl="blocked")[0],
                iters=5) * 1e6
    emit(row("kernel_ssd_b512", us, "chunked"))
    # dfa regex
    table, cnt = ops.build_aho_corasick(["attack", "GET /admin", "cmd.exe"])
    pay = jnp.asarray(RNG.integers(0, 256, size=(256, 1500)).astype(np.uint8))
    length = jnp.full((256,), 1500, jnp.int32)
    us = timeit(lambda: ops.regex_scan(pay, length, table, cnt,
                                       impl="blocked"), iters=3) * 1e6
    gbps = 256 * 1500 * 8 / (us * 1e-6) / 1e9
    emit(row("kernel_dfa_regex_256x1500B", us, f"{gbps:.2f}Gbps"))
    # crypto
    w = jnp.asarray(RNG.integers(0, 2 ** 32, size=(256, 375),
                                 dtype=np.uint64).astype(np.uint32))
    key = jnp.asarray([1, 2, 3, 4], jnp.uint32)
    us = timeit(lambda: ops.cipher(w, key, impl="blocked"), iters=5) * 1e6
    gbps = 256 * 1500 * 8 / (us * 1e-6) / 1e9
    emit(row("kernel_arx_cipher_256x1500B", us, f"{gbps:.2f}Gbps"))
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
