"""Fig 14 (adaptive scaling) + Fig 18 (failover).

Flow Monitor at 10 Gbps, retargeted to 20/40/back-to-10; we report the
controller's decision+rewire response time (the paper measures ~400 ms
end-to-end including container start; our executor spawn is jit-cached, so
the controller path is the comparable part). Failover: fail a NIC hosting
stages, measure re-placement time + post-recovery capacity (paper: <500 ms)."""
from __future__ import annotations

import time

from benchmarks.common import (APP_STAGE_LATENCY_US, APP_STAGE_RESOURCE,
                               PKT_BITS, row)
from repro.apps import ALL_APPS
from repro.core.controller import MeiliController
from repro.core.pool import paper_cluster
from repro.core.profiler import synthetic_profile

BITS = PKT_BITS * 256


def run(emit=print) -> dict:
    out = {}
    ctrl = MeiliController(paper_cluster())
    fm = ALL_APPS(impl="ref")["FM"]
    lat = {s: l * 1e-6 * 256 for s, l in APP_STAGE_LATENCY_US["FM"].items()}
    prof = synthetic_profile(fm.stage_names(), lat, BITS)
    dep = ctrl.submit(fm, target_gbps=10.0, profile=prof)
    emit(row("fig14_deploy_10Gbps", 0, f"achievable={dep.achievable_gbps:.1f}"))
    for tgt in (20.0, 40.0, 10.0):
        t0 = time.perf_counter()
        dep = ctrl.adaptive_scale(fm.name, tgt)
        dt_ms = (time.perf_counter() - t0) * 1e3
        ok = dep.achievable_gbps >= tgt
        out[tgt] = (dt_ms, dep.achievable_gbps)
        emit(row(f"fig14_scale_to_{tgt:.0f}Gbps", dt_ms * 1e3,
                 f"response={dt_ms:.2f}ms_met={ok}_paper~400ms"))

    # Fig 18: failover of FM + ISG
    isg = ALL_APPS(impl="ref")["ISG"]
    lat_isg = {s: l * 1e-6 * 256
               for s, l in APP_STAGE_LATENCY_US["ISG"].items()}
    prof_isg = synthetic_profile(isg.stage_names(), lat_isg, BITS)
    dep_isg = ctrl.submit(isg, target_gbps=5.0, profile=prof_isg)
    ctrl.replicate_for_failover(isg.name)
    victim = dep_isg.allocation.nics_for("aes")[0]
    t0 = time.perf_counter()
    impacted = ctrl.handle_failure(victim)
    dt_ms = (time.perf_counter() - t0) * 1e3
    dep_isg = ctrl.deployments[isg.name]
    emit(row("fig18_failover", dt_ms * 1e3,
             f"recovered={dep_isg.achievable_gbps:.1f}Gbps_"
             f"response={dt_ms:.2f}ms_paper<500ms_impacted={impacted}"))
    out["failover_ms"] = dt_ms
    return out


def main() -> None:
    run()


if __name__ == "__main__":
    main()
