"""Meili-Serve resource-efficiency benchmark (ISSUE 2; paper §8, Fig 13).

Runs the default 6-tenant mix through the deployment-mode comparator
(pooled vs standalone vs microservice) under the bursty and diurnal
scenarios, with one NIC failure injected into the pooled bursty run, and
writes ``BENCH_service.json`` with the efficiency ratios, per-scenario
per-tenant SLO compliance, and the failover record.

Headline acceptance bars (checked by ``main`` and surfaced in the JSON):
  pooled efficiency >= 2x standalone, >= 1.2x microservice, all tenant SLOs
  pass under both scenarios, and the injected failure drops no tenant.

Run headlessly:   PYTHONPATH=src python -m benchmarks.bench_service
Smoke (CI) mode:  PYTHONPATH=src python -m benchmarks.bench_service --fast
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

from benchmarks.common import row
from repro.service.efficiency import MODES, run_comparison
from repro.service.runtime import RuntimeConfig

TICKS = 120
FAST_TICKS = 32

BARS = {"pooled_vs_standalone": 2.0, "pooled_vs_microservice": 1.2}


def run(emit=print, fast: bool = False, seed: int = 0) -> dict:
    cfg = RuntimeConfig() if not fast else RuntimeConfig(
        dataplane_every=0, max_sim_seqs=48)
    res = run_comparison(ticks=FAST_TICKS if fast else TICKS, cfg=cfg,
                         seed=seed)
    for mode in MODES:
        emit(row(f"service_eff_{mode}", 0,
                 f"{res['efficiency'][mode]:.3f}Gbps_per_unit"))
    for name, ratio in res["ratios"].items():
        emit(row(f"service_{name}", 0,
                 f"{ratio:.2f}x_bar{BARS[name]:.1f}x"))
    for scenario, rec in res["scenarios"].items():
        for mode in MODES:
            emit(row(f"service_slo_{scenario}_{mode}", 0,
                     f"pass={rec[mode]['slo_pass']}"))
        if "failover" in rec:
            fo = rec["failover"]
            emit(row(f"service_failover_{scenario}", 0,
                     f"nic={fo['failed_nic']}_alive={fo['tenants_alive_after']}"
                     f"_survived={fo['survived']}"))
    res["bars"] = BARS
    res["pass"] = check(res)
    return res


def check(res: dict) -> bool:
    ok = all(res["ratios"][k] >= bar for k, bar in BARS.items())
    for rec in res["scenarios"].values():
        ok = ok and all(rec[m]["slo_pass"] for m in MODES)
        if "failover" in rec:
            ok = ok and rec["failover"]["survived"]
    return ok


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smoke mode: fewer ticks, analytic model only")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default=None,
                    help="output JSON path (default: repo-root BENCH_service.json)")
    args = ap.parse_args(argv)

    print("name,us_per_call,derived")
    res = run(emit=print, fast=args.fast, seed=args.seed)
    out = (pathlib.Path(args.out) if args.out else
           pathlib.Path(__file__).resolve().parent.parent / "BENCH_service.json")
    payload = {
        "benchmark": "meili-serve deployment-mode comparison",
        "fast": args.fast,
        "seed": args.seed,
        "ticks": FAST_TICKS if args.fast else TICKS,
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S"),
        **res,
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    print(f"# wrote {out}")
    if not res["pass"]:
        raise SystemExit("service benchmark below acceptance bars")


if __name__ == "__main__":
    main()
